"""Benchmark harness — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (mirrored to runs/bench/).

    PYTHONPATH=src python -m benchmarks.run                 # fast (tiny suite)
    PYTHONPATH=src python -m benchmarks.run --scale default # paper-scale circuits
    PYTHONPATH=src python -m benchmarks.run --only fig9,kernel
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "default", "paper"], default="tiny")
    ap.add_argument("--only", default=None,
                    help="comma list: fig9,table1,table2,variation,kernel,"
                         "roofline,explorer,characterization,service,"
                         "system,faults")
    args = ap.parse_args()
    which = set(args.only.split(",")) if args.only else {
        "fig9", "table1", "table2", "variation", "kernel", "roofline",
        "explorer", "characterization", "service", "system", "faults",
    }

    from .common import Csv

    csv = Csv()
    # One persistent characterization cache shared by every bench that
    # runs the Algorithm-I front half (fig9's and table1's sweeps reuse
    # each other's transforms; reruns of the harness start warm).
    cache = "runs/cha_cache"
    print("name,us_per_call,derived")
    if "fig9" in which:
        from . import bench_fig9

        bench_fig9.run(csv, scale=args.scale, cache=cache)
    if "table1" in which:
        from . import bench_table1

        bench_table1.run(csv, scale=args.scale, cache=cache)
    if "table2" in which:
        from . import bench_table2

        bench_table2.run(csv)
    if "variation" in which:
        from . import bench_variation

        bench_variation.run(csv)
        # energy-model variation sweep (yield FoM): vmapped vs serial,
        # merged into runs/BENCH_explorer_variation.json
        bench_variation.run_model_sweep(
            csv, scale=args.scale, cache_dir=cache,
            out_json="runs/BENCH_explorer_variation.json",
        )
    if "kernel" in which:
        from . import bench_kernel

        # merged into BENCH_explorer.json under "kernel" alongside the
        # explorer / variation / characterization sections
        bench_kernel.run(csv, out_json="BENCH_explorer.json")
    if "characterization" in which:
        from . import bench_characterization

        # front-half device-vs-python record, merged under
        # "characterization" in BENCH_explorer.json
        bench_characterization.run(
            csv, scale=args.scale, out_json="BENCH_explorer.json",
            serial_reference=False,
        )
    if "service" in which:
        from . import bench_service

        # warm persistent query engine: cold/warm latency, rps, trace
        # accounting — merged under "service" in BENCH_explorer.json
        bench_service.run_service_bench(
            csv, scale=args.scale, cache_dir=cache,
            out_json="BENCH_explorer.json",
        )
    if "roofline" in which:
        from . import bench_roofline

        bench_roofline.run(csv)
    if "system" in which:
        from . import bench_system

        # workload-lowered rCiM vs conventional roofline per token —
        # merged under "system" in BENCH_explorer.json
        bench_system.run(csv, scale=args.scale, out_json="BENCH_explorer.json")
    if "faults" in which:
        from . import bench_faults

        # journal overhead + crash-recovery latency of the resumable
        # sweep — merged under "faults" in BENCH_explorer.json
        bench_faults.run(csv, scale=args.scale, cache=cache,
                         out_json="BENCH_explorer.json")
    if "explorer" in which:
        from . import bench_explorer

        bench_explorer.run(csv, scale=args.scale)
    csv.save("bench.csv")


if __name__ == "__main__":
    main()
