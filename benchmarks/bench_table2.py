"""Table II — architecture comparison: throughput (GOPS), energy efficiency
(TOPS/W), compute density (GOPS/mm^2) for the rCiM topologies vs published
prior-work numbers (normalized to 8KB as in the paper).

Consumes the batched engine: all topologies are evaluated per NAND/NOR mix
in one ``table2_batch`` array pass over a ``TopologyTable``.  A second
section sweeps the programmatic (rows x cols x macros) design grid
(`sram.topology_grid`) — the open topology space beyond the paper's 12
library entries — in the same single pass and reports the density/
efficiency frontier."""

from __future__ import annotations

import numpy as np

from repro.core.batch import TopologyTable, table2_batch
from repro.core.sram import EnergyModel, SramTopology, topology_grid

from .common import Csv

# Published comparison points (Table II of the paper).
PRIOR_WORK = {
    "TVLSI21_7T": dict(gops=44.752, tops_w=8.86),
    "ISSCC19_8T": dict(gops=32.7, tops_w=5.27),
    "DAC19_6T": dict(gops=560.0, tops_w=None),
    "TVLSI23_6T": dict(gops=162.0, tops_w=None),
    "JSSC23_8T": dict(gops=1851.0, tops_w=270.5),
}

PAPER_SELF = {
    "(256x256)x1": dict(gops=(88.2, 106.6), tops_w=(8.64, 10.45)),
    "(256x256)x3": dict(gops=(264.83, 320.0), tops_w=(8.64, 10.45)),
    "(512x256)x3": dict(gops=(529.66, 640.0), tops_w=(17.18, 20.77)),
}


def run(csv: Csv) -> list[dict]:
    em = EnergyModel()
    rows = []
    labels = ["(256x256)x1", "(256x256)x3", "(512x256)x3"]
    table = TopologyTable.from_topologies(
        [SramTopology(8, 1), SramTopology(8, 3), SramTopology(16, 3)]
    )
    # One vectorized pass per NAND/NOR mix over the whole topology table.
    m_nand = table2_batch(table, em, nor_fraction=0.0)
    m_nor = table2_batch(table, em, nor_fraction=1.0)
    m_mix = table2_batch(table, em, nor_fraction=0.5)
    for i, label in enumerate(labels):
        gops = (m_nor["throughput_gops"][i], m_nand["throughput_gops"][i])
        topsw = (m_nor["tops_per_watt"][i], m_nand["tops_per_watt"][i])
        dens = m_mix["gops_per_mm2"][i]
        want = PAPER_SELF[label]
        rows.append(dict(topo=label, gops=gops, tops_w=topsw, gops_mm2=dens))
        csv.add(
            f"table2/{label}", 0.0,
            f"GOPS={gops[0]:.1f}-{gops[1]:.1f}(paper {want['gops'][0]}-{want['gops'][1]});"
            f"TOPS/W={topsw[0]:.2f}-{topsw[1]:.2f}(paper {want['tops_w'][0]}-{want['tops_w'][1]});"
            f"GOPS/mm2={dens:.0f}",
        )
    # headline ratios vs prior work (8KB single macro)
    m = {k: v[0] for k, v in m_mix.items()}
    isscc = PRIOR_WORK["ISSCC19_8T"]
    csv.add(
        "table2/vs_ISSCC19", 0.0,
        f"throughput_x={m['throughput_gops']/isscc['gops']:.2f}(paper 2.6x);"
        f"efficiency_x={m['tops_per_watt']/isscc['tops_w']:.2f}(paper 1.6x)",
    )

    # Open design grid beyond the 12-entry library: one vectorized pass
    # over every (rows x cols x macros) point, report the best of each
    # Table-II metric across the grid.
    grid_topos = topology_grid()
    gt = TopologyTable.from_topologies(grid_topos)
    g = table2_batch(gt, em, nor_fraction=0.5)
    for metric in ("throughput_gops", "tops_per_watt", "gops_per_mm2"):
        i = int(np.argmax(g[metric]))
        csv.add(
            f"table2/grid_best_{metric}", 0.0,
            f"{grid_topos[i].name}={g[metric][i]:.1f};"
            f"grid_points={len(grid_topos)}",
        )
    return rows
