"""Roofline table reader — aggregates runs/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (one row per arch x shape x mesh)."""

from __future__ import annotations

import glob
import json
import os

from .common import Csv


def load_records(out_dir: str = "runs/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag"):
            continue  # explorer variants live in their own table
        recs.append(rec)
    return recs


def run(csv: Csv, out_dir: str = "runs/dryrun") -> list[dict]:
    recs = load_records(out_dir)
    if not recs:
        csv.add("roofline/NO_RECORDS", 0.0, "run repro.launch.dryrun first")
        return []
    n_ok = n_skip = 0
    for rec in recs:
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if "skipped" in rec:
            n_skip += 1
            csv.add(name, 0.0, f"SKIP:{rec['skipped']}")
            continue
        n_ok += 1
        r = rec["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        csv.add(
            name, rec["compile_s"] * 1e6,
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;bottleneck={r['bottleneck']};"
            f"roofline_frac={frac:.3f};useful={r['useful_ratio']:.2f};"
            f"hbm={rec['hbm_per_device_gb']:.2f}GB",
        )
    csv.add("roofline/SUMMARY", 0.0, f"ok={n_ok};skipped={n_skip}")
    return recs
