"""System-level bench: workload-lowered rCiM vs conventional roofline.

For each benched config the record holds per-token energy/latency on
both sides (rCiM via the fused suite kernels over the topology library;
baseline via the traced roofline sweep + pJ/op coefficients), the
lowering conservation flag, and the winner topology per primitive tile.
Conservation is additionally checked for EVERY config in the zoo (the
lowering is pure integer arithmetic, so this is cheap), and the traced
bandwidth sweep's compile discipline is recorded (one trace per sweep
shape, zero retraces on value-only changes).

Merged into BENCH_explorer.json under ``"system"``.

    PYTHONPATH=src python -m benchmarks.bench_system --smoke \
        --out runs/BENCH_explorer_smoke.json
"""

from __future__ import annotations

import numpy as np

from .common import Csv, merge_json, timeit

# Diverse families: ssm, dense, dense-27b, moe, rglru-hybrid.
BENCH_ARCHES = ("mamba2-780m", "qwen1.5-4b", "gemma3-27b",
                "deepseek-moe-16b", "recurrentgemma-9b")


def run(csv: Csv, scale: str = "tiny", shape: str = "decode_32k",
        out_json: str = "BENCH_explorer.json", smoke: bool = False) -> dict:
    from repro.configs import ARCH_IDS, get_config
    from repro.core import workloads as W
    from repro.core.batch import TRACE_COUNTS
    from repro.launch import system as S
    from repro.models.config import SHAPES

    # -- conservation across the whole zoo (pure-int, fast) ----------------
    conserved = {}
    for arch in ARCH_IDS:
        lowered = W.lower_config(get_config(arch), SHAPES[shape])
        conserved[arch] = bool(W.conservation_report(lowered)["ok"])

    # -- per-config comparison ---------------------------------------------
    configs = {}
    for arch in BENCH_ARCHES:
        us = timeit(S.compare_system, arch, shape,
                    n_warmup=0, n_iter=1 if smoke else 2)
        rec = S.compare_system(arch, shape)
        configs[arch] = rec
        csv.add(
            f"system/{arch}/{shape}", us,
            f"rcim={rec['rcim']['energy_per_token_j']:.3e}J,"
            f"{rec['rcim']['latency_per_token_s']:.3e}s;"
            f"accel={rec['baseline']['energy_per_token_j']:.3e}J,"
            f"{rec['baseline']['latency_per_token_s']:.3e}s;"
            f"Eratio={rec['energy_ratio_rcim_over_accel']:.1f};"
            f"conserved={rec['conserved']}",
        )

    # -- traced BW sweep discipline ----------------------------------------
    cost = S.token_cost(get_config(BENCH_ARCHES[0]), SHAPES[shape])
    n_points = 5 if smoke else 17
    bw1 = np.linspace(2e11, 2e12, n_points)
    bw2 = np.linspace(3e11, 3e12, n_points)
    c0 = TRACE_COUNTS["roofline_sweep"]
    out1 = S.sweep_roofline(cost, hbm_bw=bw1)
    c1 = TRACE_COUNTS["roofline_sweep"]
    out2 = S.sweep_roofline(cost, hbm_bw=bw2)
    c2 = TRACE_COUNTS["roofline_sweep"]
    sweep_rec = dict(
        n_points=int(n_points),
        compiles=int(c1 - c0),
        recompiles_on_value_change=int(c2 - c1),
        memory_s_monotone=bool(np.all(np.diff(out1["memory_s"]) < 0)),
        memory_s=out1["memory_s"].tolist(),
        hbm_bw=out1["hbm_bw"].tolist(),
    )
    csv.add(
        "system/bw_sweep", 0.0,
        f"n={n_points};compiles={sweep_rec['compiles']};"
        f"retraces={sweep_rec['recompiles_on_value_change']};"
        f"monotone={sweep_rec['memory_s_monotone']}",
    )
    del out2

    record = dict(
        shape=shape,
        configs=configs,
        conservation=conserved,
        conservation_checked=len(conserved),
        bw_sweep=sweep_rec,
    )
    merge_json(out_json, {"system": record})
    return record


def main() -> None:  # pragma: no cover - CLI convenience
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--out", default="BENCH_explorer.json")
    args = ap.parse_args()
    csv = Csv()
    print("name,us_per_call,derived")
    run(csv, shape=args.shape, out_json=args.out, smoke=args.smoke)
    csv.save("bench_system.csv")


if __name__ == "__main__":  # pragma: no cover
    main()
