"""Fig 9 — power / latency / energy across the 12 rCiM topologies.

Two sections (both dimensions of the paper's 6912-implementation study,
decoupled so the sweep stays CPU-tractable):

  A. *recipe sweep* — all 64 synthesis recipes x 12 topologies per circuit
     at ``scale`` (tiny/default).  Shows the recipe-quality spread the
     paper's Table I best/worst rows rely on.

  B. *topology trends* — paper-scale circuits (characterization only, no
     transforms) swept over the 12 topologies.  This is the width-bound
     regime where Fig 9's claims live: 3-macro vs 1-macro energy (-39%),
     macro-doubling energy drop (-47%), 6-macro latency (-66% vs single).
"""

from __future__ import annotations

import time

from repro.core import circuits as C
from repro.core.explorer import explore
from repro.core.mapping import schedule_stats
from repro.core.sram import MACRO_SIZES_KB, EnergyModel, SramTopology, evaluate

from .common import Csv


def run(csv: Csv, scale: str = "tiny", recipes=None) -> dict:
    results = {}
    # ---- section A: recipe sweep -----------------------------------------
    suite = C.benchmark_suite(scale=scale)
    total = 0
    for name, rtl in suite.items():
        t0 = time.time()
        res = explore(rtl, recipes=recipes)
        dt = (time.time() - t0) * 1e6
        results[name] = res
        total += len(res.evaluations)
        es = [ev.metrics.energy_nj for ev in res.evaluations if ev.schedule.fits]
        spread = (max(es) / min(es)) if es else 0.0
        csv.add(
            f"fig9/recipes/{name}", dt,
            f"impls={len(res.evaluations)};best={res.best.topo.name}"
            f"({','.join(res.best.recipe) or '-'});"
            f"energy_spread={spread:.1f}x",
        )
    csv.add("fig9/recipes/TOTAL", 0.0,
            f"implementations={total}(paper 6912 at server scale)")

    # ---- section B: topology trends at paper scale -------------------------
    em = EnergyModel()
    trends = dict(d3m=[], d48=[], lat6=[], best6=[])
    for name, rtl in C.benchmark_suite(scale="paper").items():
        st = rtl.characterize()

        def met(kb, m):
            t = SramTopology(kb, m)
            return evaluate(schedule_stats(st, t), t, em)

        e41, e81 = met(4, 1), met(8, 1)
        d48 = 100 * (1 - e81.energy_nj / e41.energy_nj)
        d3m = sum(
            100 * (1 - met(kb, 3).energy_nj / met(kb, 1).energy_nj)
            for kb in MACRO_SIZES_KB
        ) / len(MACRO_SIZES_KB)
        lat6 = sum(
            100 * (1 - met(kb, 6).latency_ns / met(kb, 1).latency_ns)
            for kb in MACRO_SIZES_KB
        ) / len(MACRO_SIZES_KB)
        best6 = 100 * (
            1 - min(met(kb, 6).energy_nj for kb in MACRO_SIZES_KB) / e41.energy_nj
        )
        for k, v in zip(("d3m", "d48", "lat6", "best6"), (d3m, d48, lat6, best6)):
            trends[k].append(v)
        csv.add(
            f"fig9/topology/{name}", 0.0,
            f"gates={st.total_gates};levels={st.n_levels};"
            f"E_3m_vs_1m={d3m:.0f}%;E_4to8KB={d48:.0f}%;"
            f"T_6m_vs_1m={lat6:.0f}%;E_best6_vs_1x4KB={best6:.0f}%",
        )
    n = len(trends["d3m"])
    csv.add(
        "fig9/topology/AVERAGE", 0.0,
        f"E_3m_vs_1m={sum(trends['d3m'])/n:.1f}%(paper 39);"
        f"E_4to8KB={sum(trends['d48'])/n:.1f}%(paper 47);"
        f"T_6m_vs_1m={sum(trends['lat6'])/n:.1f}%(paper 66);"
        f"E_best6_vs_1x4KB={sum(trends['best6'])/n:.1f}%(paper 80.9)",
    )
    return results
