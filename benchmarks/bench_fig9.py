"""Fig 9 — power / latency / energy across the 12 rCiM topologies.

Two sections (both dimensions of the paper's 6912-implementation study,
decoupled so the sweep stays CPU-tractable):

  A. *recipe sweep* — all 64 synthesis recipes x 12 topologies per circuit
     at ``scale`` (tiny/default).  Shows the recipe-quality spread the
     paper's Table I best/worst rows rely on.

  B. *topology trends* — paper-scale circuits (characterization only, no
     transforms) swept over the 12 topologies.  This is the width-bound
     regime where Fig 9's claims live: 3-macro vs 1-macro energy (-39%),
     macro-doubling energy drop (-47%), 6-macro latency (-66% vs single).

Both sections ride the suite-level engine (core/batch.py): section A is
one `explorer.explore_suite` call (suite characterization + a single
circuits x recipes x topologies sweep); section B stacks the paper-scale
baselines into a `SuiteTable` and runs ONE `evaluate_suite` call for all
circuits x 12 topologies.
"""

from __future__ import annotations

import time

from repro.core import circuits as C
from repro.core.batch import SuiteTable, TopologyTable, evaluate_suite
from repro.core.explorer import explore_suite
from repro.core.sram import (
    MACRO_SIZES_KB,
    TOPOLOGY_LIBRARY,
    EnergyModel,
    SramTopology,
    evaluate,
)
from repro.core.mapping import schedule_stats

from .common import Csv


def run(csv: Csv, scale: str = "tiny", recipes=None, backend: str = "jax",
        cache=None) -> dict:
    # ---- section A: recipe sweep (one suite-level call) --------------------
    suite = C.benchmark_suite(scale=scale)
    t0 = time.time()
    results = explore_suite(suite, recipes=recipes, backend=backend,
                            cache=cache)
    total = 0
    for name, res in results.items():
        total += res.n_evaluations
        es = res.sweep_energies(fits_only=True)
        spread = (float(es.max()) / float(es.min())) if es.size else 0.0
        csv.add(
            f"fig9/recipes/{name}", res.wall_s * 1e6,
            f"impls={res.n_evaluations};best={res.best.topo.name}"
            f"({','.join(res.best.recipe) or '-'});"
            f"energy_spread={spread:.1f}x",
        )
    csv.add("fig9/recipes/TOTAL", (time.time() - t0) * 1e6,
            f"implementations={total}(paper 6912 at server scale)")

    # ---- section B: topology trends at paper scale -------------------------
    em = EnergyModel()
    topo_table = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    topo_index = {
        (t.macro_kb, t.n_macros): i for i, t in enumerate(TOPOLOGY_LIBRARY)
    }
    paper_suite = C.benchmark_suite(scale="paper")
    stats = {name: rtl.characterize() for name, rtl in paper_suite.items()}

    if backend == "jax":
        # ONE jitted pass: all circuits x 12 topologies (baseline recipe).
        sg = evaluate_suite(
            SuiteTable.from_cha({n: {(): s} for n, s in stats.items()}),
            topo_table, em,
        )

        def met(name, kb, m):
            g = sg.grid(name)
            i = topo_index[(kb, m)]
            return float(g.energy_nj[i, 0]), float(g.latency_ns[i, 0])
    else:
        def met(name, kb, m):
            t = SramTopology(kb, m)
            ref = evaluate(schedule_stats(stats[name], t), t, em)
            return ref.energy_nj, ref.latency_ns

    trends = dict(d3m=[], d48=[], lat6=[], best6=[])
    for name in paper_suite:
        st = stats[name]

        def e(kb, m):
            return met(name, kb, m)[0]

        def lat(kb, m):
            return met(name, kb, m)[1]

        d48 = 100 * (1 - e(8, 1) / e(4, 1))
        d3m = sum(
            100 * (1 - e(kb, 3) / e(kb, 1)) for kb in MACRO_SIZES_KB
        ) / len(MACRO_SIZES_KB)
        lat6 = sum(
            100 * (1 - lat(kb, 6) / lat(kb, 1)) for kb in MACRO_SIZES_KB
        ) / len(MACRO_SIZES_KB)
        best6 = 100 * (
            1 - min(e(kb, 6) for kb in MACRO_SIZES_KB) / e(4, 1)
        )
        for k, v in zip(("d3m", "d48", "lat6", "best6"), (d3m, d48, lat6, best6)):
            trends[k].append(v)
        csv.add(
            f"fig9/topology/{name}", 0.0,
            f"gates={st.total_gates};levels={st.n_levels};"
            f"E_3m_vs_1m={d3m:.0f}%;E_4to8KB={d48:.0f}%;"
            f"T_6m_vs_1m={lat6:.0f}%;E_best6_vs_1x4KB={best6:.0f}%",
        )
    n = len(trends["d3m"])
    csv.add(
        "fig9/topology/AVERAGE", 0.0,
        f"E_3m_vs_1m={sum(trends['d3m'])/n:.1f}%(paper 39);"
        f"E_4to8KB={sum(trends['d48'])/n:.1f}%(paper 47);"
        f"T_6m_vs_1m={sum(trends['lat6'])/n:.1f}%(paper 66);"
        f"E_best6_vs_1x4KB={sum(trends['best6'])/n:.1f}%(paper 80.9)",
    )
    return results
