"""Fig 9 — power / latency / energy across the 12 rCiM topologies.

Two sections (both dimensions of the paper's 6912-implementation study,
decoupled so the sweep stays CPU-tractable):

  A. *recipe sweep* — all 64 synthesis recipes x 12 topologies per circuit
     at ``scale`` (tiny/default).  Shows the recipe-quality spread the
     paper's Table I best/worst rows rely on.

  B. *topology trends* — paper-scale circuits (characterization only, no
     transforms) swept over the 12 topologies.  This is the width-bound
     regime where Fig 9's claims live: 3-macro vs 1-macro energy (-39%),
     macro-doubling energy drop (-47%), 6-macro latency (-66% vs single).

Both sections consume the batched exploration grid (core/batch.py): the
recipe sweep runs ``explore(backend="jax")`` and reads the
``ExplorationGrid``; the topology trends are one ``evaluate_batch`` call
per circuit instead of 12 scalar schedule/evaluate pairs.
"""

from __future__ import annotations

import time

from repro.core import circuits as C
from repro.core.batch import TopologyTable, WorkloadTable, evaluate_batch
from repro.core.explorer import explore
from repro.core.mapping import schedule_stats
from repro.core.sram import (
    MACRO_SIZES_KB,
    TOPOLOGY_LIBRARY,
    EnergyModel,
    SramTopology,
    evaluate,
)

from .common import Csv


def run(csv: Csv, scale: str = "tiny", recipes=None, backend: str = "jax") -> dict:
    results = {}
    # ---- section A: recipe sweep -----------------------------------------
    suite = C.benchmark_suite(scale=scale)
    total = 0
    for name, rtl in suite.items():
        t0 = time.time()
        res = explore(rtl, recipes=recipes, backend=backend)
        dt = (time.time() - t0) * 1e6
        results[name] = res
        total += res.n_evaluations
        es = res.sweep_energies(fits_only=True)
        spread = (float(es.max()) / float(es.min())) if es.size else 0.0
        csv.add(
            f"fig9/recipes/{name}", dt,
            f"impls={res.n_evaluations};best={res.best.topo.name}"
            f"({','.join(res.best.recipe) or '-'});"
            f"energy_spread={spread:.1f}x",
        )
    csv.add("fig9/recipes/TOTAL", 0.0,
            f"implementations={total}(paper 6912 at server scale)")

    # ---- section B: topology trends at paper scale -------------------------
    em = EnergyModel()
    topo_table = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    topo_index = {
        (t.macro_kb, t.n_macros): i for i, t in enumerate(TOPOLOGY_LIBRARY)
    }
    trends = dict(d3m=[], d48=[], lat6=[], best6=[])
    for name, rtl in C.benchmark_suite(scale="paper").items():
        st = rtl.characterize()
        if backend == "jax":
            # One jitted pass over all 12 topologies for this circuit.
            grid = evaluate_batch(
                WorkloadTable.from_stats([((), st)]), topo_table, em
            )

            def met(kb, m):
                i = topo_index[(kb, m)]
                return float(grid.energy_nj[i, 0]), float(grid.latency_ns[i, 0])
        else:
            def met(kb, m):
                t = SramTopology(kb, m)
                ref = evaluate(schedule_stats(st, t), t, em)
                return ref.energy_nj, ref.latency_ns

        def e(kb, m):
            return met(kb, m)[0]

        def lat(kb, m):
            return met(kb, m)[1]

        d48 = 100 * (1 - e(8, 1) / e(4, 1))
        d3m = sum(
            100 * (1 - e(kb, 3) / e(kb, 1)) for kb in MACRO_SIZES_KB
        ) / len(MACRO_SIZES_KB)
        lat6 = sum(
            100 * (1 - lat(kb, 6) / lat(kb, 1)) for kb in MACRO_SIZES_KB
        ) / len(MACRO_SIZES_KB)
        best6 = 100 * (
            1 - min(e(kb, 6) for kb in MACRO_SIZES_KB) / e(4, 1)
        )
        for k, v in zip(("d3m", "d48", "lat6", "best6"), (d3m, d48, lat6, best6)):
            trends[k].append(v)
        csv.add(
            f"fig9/topology/{name}", 0.0,
            f"gates={st.total_gates};levels={st.n_levels};"
            f"E_3m_vs_1m={d3m:.0f}%;E_4to8KB={d48:.0f}%;"
            f"T_6m_vs_1m={lat6:.0f}%;E_best6_vs_1x4KB={best6:.0f}%",
        )
    n = len(trends["d3m"])
    csv.add(
        "fig9/topology/AVERAGE", 0.0,
        f"E_3m_vs_1m={sum(trends['d3m'])/n:.1f}%(paper 39);"
        f"E_4to8KB={sum(trends['d48'])/n:.1f}%(paper 47);"
        f"T_6m_vs_1m={sum(trends['lat6'])/n:.1f}%(paper 66);"
        f"E_best6_vs_1x4KB={sum(trends['best6'])/n:.1f}%(paper 80.9)",
    )
    return results
