"""Variation analysis: (a) Figs 10-12 Monte-Carlo sense-margin study,
(b) the energy-model variation sweep (yield FoM) through the batched
engine.

Part (a): we cannot re-run Spectre; the bitline-discharge distributions
are modeled as the Gaussians the paper characterizes (mean/sigma per
case, Figs 10-11) and we verify the *architectural* claim: the sense
margin around Vref = VDD/2 keeps the NAND2/NOR2 decision correct at
>= 5-sigma over 5000 samples, for all three topologies and all PVT
corners.

Part (b) (`run_model_sweep`): N `EnergyModel` variants (seeded
Monte-Carlo around the calibrated constants) swept through the whole
circuits x recipes x topologies grid — ONE vmapped call versus the
serial one-`evaluate_suite`-per-variant loop the old static-model API
forced.  Cross-checks that every (circuit, variant) winner agrees
between the vmapped sweep, the serial jax runs, and (optionally) the
scalar python backend, records the jit trace count, and merges a
``"variation"`` section into ``BENCH_explorer.json``.

Also times the *fused device-resident* back half
(`batch.evaluate_select_suite`: evaluate + three-tier FilterEnergy in
one jitted pass, only (C, V) winners + per-winner metrics transferred)
against the host path (materialize the full (C, V, T, R) tensors, then
`select_best_batch`), recording the device->host payload bytes of each
— the headline number of the device-resident pipeline.

    PYTHONPATH=src python -m benchmarks.bench_variation           # full: 9 circuits, 65 recipes, 16 variants
    PYTHONPATH=src python -m benchmarks.bench_variation --smoke   # CI: 4 circuits, 9 recipes
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from .common import Csv, merge_json, timeit

VDD = 1.0
VREF = VDD / 2

# (mean mV, sigma mV) per case, from Fig 10.
FIG10 = {
    "(4KB)x3": dict(nor={"01": (110, 14), "00": (986, 3), "11": (90, 12)},
                    nand={"01": (623, 35), "00": (984, 2.2), "11": (85, 32)}),
    "(8KB)x3": dict(nor={"01": (97, 24), "00": (993, 1.9), "11": (76, 16.4)},
                    nand={"01": (665, 27), "00": (989, 1.8), "11": (98, 37)}),
    "(16KB)x3": dict(nor={"01": (114.3, 27), "00": (990, 2.7), "11": (86, 18)},
                     nand={"01": (685, 31), "00": (993, 2.1), "11": (99.4, 34.2)}),
}

# Fig 11: NAND2 "01/10" borderline case across (temp, vdd).
FIG11 = {
    (0, 0.9): (620, 27), (0, 1.0): (608, 22), (0, 1.1): (587, 19.4),
    (25, 0.9): (647, 24), (25, 1.0): (665, 17), (25, 1.1): (678, 22),
    (125, 0.9): (710, 20), (125, 1.0): (692, 21), (125, 1.1): (674, 19.2),
}

N_SAMPLES = 5000


def _fail_rate(mean_mv, sigma_mv, want_above: bool, rng) -> float:
    v = rng.normal(mean_mv, sigma_mv, N_SAMPLES) / 1000.0
    bad = (v <= VREF) if want_above else (v >= VREF)
    return bad.mean()


def run(csv: Csv) -> None:
    rng = np.random.default_rng(0)
    worst_margin = 1e9
    for topo, ops in FIG10.items():
        fails = 0.0
        for op, cases in ops.items():
            for case, (mu, sd) in cases.items():
                # NAND2: "00" and "01/10" must read above Vref (logic 1);
                # "11" below.  NOR2: only "00" reads above.
                want_above = (op == "nand" and case in ("00", "01")) or (
                    op == "nor" and case == "00"
                )
                fails += _fail_rate(mu, sd, want_above, rng)
                worst_margin = min(worst_margin, abs(mu - 500) / sd)
        csv.add(f"variation/fig10/{topo}", 0.0,
                f"total_misreads_over_{N_SAMPLES}x18cases={int(fails*N_SAMPLES)}")
    for (temp, vdd), (mu, sd) in FIG11.items():
        fr = _fail_rate(mu, sd, True, rng)
        worst_margin = min(worst_margin, abs(mu - 500) / sd)
        csv.add(f"variation/fig11/T{temp}C_V{vdd}", 0.0,
                f"mean={mu}mV;sigma={sd}mV;misread_rate={fr:.2e}")
    csv.add("variation/summary", 0.0,
            f"worst_sense_margin={worst_margin:.1f}sigma(>=3.5 required)")
    assert worst_margin >= 3.5


# ---------------------------------------------------------------------------
# (b) Energy-model variation sweep: vmapped vs serial-per-model
# ---------------------------------------------------------------------------

SMOKE_CIRCUITS = ("adder", "bar", "sqrt", "max")
SMOKE_RECIPES = 8


def run_model_sweep(
    csv: Csv | None = None,
    scale: str = "tiny",
    only=None,
    n_recipes: int | None = None,
    n_variants: int = 16,
    sigma: float = 0.10,
    n_iter: int = 3,
    out_json: str = "BENCH_explorer.json",
    cache_dir: str | None = None,
    n_jobs: int | None = None,
    check_python: bool = False,
    merge_key: str = "variation",
) -> dict:
    """Time the N-variant model sweep both ways and cross-check winners.

    * ``sweep``  — ONE `evaluate_suite` call with a `ModelTable`: the
      circuits x variants x topologies x recipes hypercube, one compile.
    * ``serial`` — N `evaluate_suite` calls, one static `EnergyModel`
      each: what the old static-argnames API forced (and even this is
      flattering to it — the old engine also paid a fresh jit compile
      per model, which the serial loop here no longer does).

    Also times the *selection* stage both ways (batched
    `SuiteVariationGrid.best_indices` vs the per-(circuit, variant)
    `select_best` loop it replaced, winner agreement asserted on every
    cell) and pushes one correlated `(V, T)`
    `ModelTable.bitcell_sigma_per_macro` sweep through the same kernels
    (exactly one extra compile for the new params shape).

    Merges the result into ``out_json`` under a ``"variation"`` key.
    """
    from repro.core import circuits as C
    from repro.core.batch import (
        _METRIC_KEYS,
        _SCHED_KEYS,
        SuiteTable,
        TopologyTable,
        evaluate_select_suite,
        evaluate_suite,
        select_best,
        trace_counts,
    )
    from repro.core.explorer import explore
    from repro.core.sram import TOPOLOGY_LIBRARY, EnergyModel, ModelTable
    from repro.core.transforms import characterize_suite, enumerate_recipes

    csv = csv or Csv()
    recipes = enumerate_recipes()
    if n_recipes is not None:
        recipes = recipes[:n_recipes]
    suite = C.benchmark_suite(scale=scale, only=only)

    t0 = time.time()
    cha = characterize_suite(suite, recipes, cache=cache_dir, n_jobs=n_jobs)
    cha_s = time.time() - t0

    suite_table = SuiteTable.from_cha(cha)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.monte_carlo(
        EnergyModel(), n=n_variants, sigma=sigma, seed=0
    )

    # Cold call: the whole hypercube must cost exactly one new trace.
    before = trace_counts().get("evaluate_suite", 0)
    svg = evaluate_suite(suite_table, topos, table)
    compiles = trace_counts().get("evaluate_suite", 0) - before
    # Float-only model change: must be served from the jit cache.
    evaluate_suite(
        suite_table, topos,
        ModelTable.monte_carlo(EnergyModel(), n=n_variants, sigma=sigma,
                               seed=1),
    )
    recompiles_on_float_change = (
        trace_counts().get("evaluate_suite", 0) - before - compiles
    )

    def run_serial():
        return [
            evaluate_suite(suite_table, topos, table.model(v))
            for v in range(n_variants)
        ]

    # The cold-call / float-change probes above already warmed the jit
    # cache (both the V=n_variants and V=1 shapes trace on the serial
    # loop's first call only), so no extra timeit warmup is needed and
    # the parity grids double as the serial warmup run.
    serial_grids = run_serial()
    t_sweep = timeit(
        lambda: evaluate_suite(suite_table, topos, table),
        n_warmup=0, n_iter=n_iter,
    )
    t_serial = timeit(run_serial, n_warmup=0, n_iter=n_iter)
    speedup = t_serial / t_sweep if t_sweep > 0 else float("inf")

    # Winner agreement on every (circuit, variant) — and cell-level
    # equality of the sweep against each serial static-model run.
    all_agree = True
    py_checked = 0
    for name in svg.circuits:
        vgrid = svg.variation(name)
        idx = vgrid.best_indices()
        for v in range(n_variants):
            serial = serial_grids[v].grid(name)
            agree = int(idx[v]) == serial.best_index()
            agree &= np.array_equal(vgrid.energy_nj[v], serial.energy_nj)
            agree &= np.array_equal(vgrid.latency_ns[v], serial.latency_ns)
            if check_python:
                res_py = explore(
                    suite[name], cha=cha[name], model=table.model(v),
                    backend="python",
                )
                ti, ri = vgrid.unravel(int(idx[v]))
                agree &= (
                    res_py.best.recipe == vgrid.recipes[ri]
                    and res_py.best.topo == vgrid.topologies[ti]
                )
                py_checked += 1
            all_agree &= agree

    # Selection stage: the batched (C, V) masked-argmin pass
    # (`SuiteVariationGrid.best_indices`) vs the per-(circuit, variant)
    # python loop over `select_best` it replaced — the last serial
    # O(C*V) segment of the sweep.
    def loop_selection(grid) -> np.ndarray:
        out = np.empty((len(grid.circuits), n_variants), dtype=np.int64)
        for c, name in enumerate(grid.circuits):
            vgrid = grid.variation(name)
            feas = np.broadcast_to(vgrid.feasible[:, None], vgrid.fits.shape)
            for v in range(n_variants):
                out[c, v] = select_best(
                    vgrid.energy_nj[v], vgrid.fits,
                    latency=vgrid.latency_ns[v], feasible=feas,
                )
        return out

    selection_agree = bool(
        np.array_equal(svg.best_indices(), loop_selection(svg))
    )
    t_sel_batched = timeit(svg.best_indices, n_warmup=0, n_iter=n_iter)
    t_sel_loop = timeit(loop_selection, svg, n_warmup=0, n_iter=n_iter)
    sel_speedup = t_sel_loop / t_sel_batched if t_sel_batched > 0 else float("inf")

    # Correlated (V, T) sweep: per-macro-geometry bitcell sigma.  The
    # (V, T)-shaped params are a new traced shape — exactly one more
    # compile — and the batched winners must agree with the per-cell
    # loop here too.
    corr_table = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=n_variants, sigma=sigma, seed=0
    )
    before_corr = trace_counts().get("evaluate_suite", 0)
    svg_corr = evaluate_suite(suite_table, topos, corr_table)
    corr_compiles = trace_counts().get("evaluate_suite", 0) - before_corr
    corr_agree = bool(
        np.array_equal(svg_corr.best_indices(), loop_selection(svg_corr))
    )

    # Fused device-resident back half: evaluate + three-tier FilterEnergy
    # in ONE jitted pass — only the (C, V) winners + per-winner metrics
    # cross the host boundary (the grid stays a lazy device view), vs the
    # host path that pulls the full (C, V, T, R) float64 tensors across
    # before reducing them to the same (C, V) indices.
    host_idx = svg.best_indices()
    before_fused = trace_counts().get("fused_suite", 0)
    sg_fused, sel = evaluate_select_suite(suite_table, topos, table)
    fused_compiles = trace_counts().get("fused_suite", 0) - before_fused
    fused_agree = bool(
        np.array_equal(sel.winner_idx.astype(np.int64), host_idx)
    )
    flat_e = svg.energy_nj.reshape(len(svg.circuits), n_variants, -1)
    fused_agree &= bool(
        np.array_equal(
            np.take_along_axis(flat_e, host_idx[..., None], -1)[..., 0],
            sel.winner_energy_nj,
        )
    )
    # Payload across the host boundary: the host path materializes every
    # schedule + metric tensor; the fused path only the SelectionResult.
    payload_host = sum(
        getattr(svg, k).nbytes for k in _METRIC_KEYS + _SCHED_KEYS
    )
    payload_fused = sel.payload_bytes

    def fused_sweep():
        # winners + per-winner metrics land on host; tensors stay put
        return evaluate_select_suite(suite_table, topos, table)[1]

    def host_sweep():
        # today's path: materialize the full tensors, then reduce
        g = evaluate_suite(suite_table, topos, table)
        return g.best_indices()

    t_fused = timeit(fused_sweep, n_warmup=0, n_iter=n_iter)
    t_host = timeit(host_sweep, n_warmup=0, n_iter=n_iter)
    fused_speedup = t_host / t_fused if t_fused > 0 else float("inf")

    record = dict(
        scale=scale,
        n_circuits=len(suite),
        n_recipes=len(recipes) + 1,
        n_variants=n_variants,
        sigma=sigma,
        implementations=svg.size,
        characterize_s=round(cha_s, 3),
        sweep_us=round(t_sweep, 1),
        serial_us=round(t_serial, 1),
        speedup=round(speedup, 2),
        compiles=compiles,
        recompiles_on_float_change=recompiles_on_float_change,
        all_agree=bool(all_agree),
        python_winners_checked=py_checked,
        selection_batched_us=round(t_sel_batched, 1),
        selection_loop_us=round(t_sel_loop, 1),
        selection_speedup=round(sel_speedup, 2),
        selection_agree=selection_agree,
        correlated_compiles=corr_compiles,
        correlated_agree=bool(corr_agree),
        fused_us=round(t_fused, 1),
        host_us=round(t_host, 1),
        fused_speedup=round(fused_speedup, 2),
        fused_agree=fused_agree,
        fused_compiles=fused_compiles,
        payload_fused_bytes=int(payload_fused),
        payload_host_bytes=int(payload_host),
        payload_shrink=round(payload_host / max(1, payload_fused), 1),
    )

    merge_json(out_json, {merge_key: record})

    csv.add(
        f"variation/model_sweep/{merge_key}", t_sweep,
        f"serial_us={t_serial:.0f};speedup={speedup:.1f}x;"
        f"variants={n_variants};impls={svg.size};compiles={compiles};"
        f"agree={all_agree};selection_speedup={sel_speedup:.1f}x;"
        f"selection_agree={selection_agree};"
        f"correlated_compiles={corr_compiles};"
        f"fused_agree={fused_agree};fused_compiles={fused_compiles};"
        f"payload={payload_host}B->{payload_fused}B "
        f"({payload_host / max(1, payload_fused):.0f}x);json={out_json}",
    )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "default", "paper"],
                    default="tiny")
    ap.add_argument("--recipes", type=int, default=None,
                    help="limit recipe count (default: all 64)")
    ap.add_argument("--variants", type=int, default=16,
                    help="Monte-Carlo model variants")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: few circuits, few recipes, python "
                         "winner cross-check on every (circuit, variant)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent characterization cache directory")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--out", default="BENCH_explorer.json")
    ap.add_argument("--merge-key", default="variation",
                    help="key the record is merged under in --out")
    ap.add_argument("--skip-pvt", action="store_true",
                    help="skip the Figs 10-12 sense-margin Monte-Carlo")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    csv = Csv()
    if not args.skip_pvt:
        run(csv)
    kw = dict(scale=args.scale, n_recipes=args.recipes,
              n_variants=args.variants, out_json=args.out,
              cache_dir=args.cache_dir, n_jobs=args.jobs,
              merge_key=args.merge_key)
    if args.smoke:
        kw.update(scale="tiny", only=SMOKE_CIRCUITS, n_recipes=SMOKE_RECIPES,
                  n_iter=1, check_python=True)
    run_model_sweep(csv, **kw)


if __name__ == "__main__":
    main()
