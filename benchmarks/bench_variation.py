"""Figs 10-12 — Monte-Carlo process/voltage/temperature variation analysis.

We cannot re-run Spectre; the bitline-discharge distributions are modeled
as the Gaussians the paper characterizes (mean/sigma per case, Figs 10-11)
and we verify the *architectural* claim: the sense margin around
Vref = VDD/2 keeps the NAND2/NOR2 decision correct at >= 5-sigma over
5000 samples, for all three topologies and all PVT corners."""

from __future__ import annotations

import numpy as np

from .common import Csv

VDD = 1.0
VREF = VDD / 2

# (mean mV, sigma mV) per case, from Fig 10.
FIG10 = {
    "(4KB)x3": dict(nor={"01": (110, 14), "00": (986, 3), "11": (90, 12)},
                    nand={"01": (623, 35), "00": (984, 2.2), "11": (85, 32)}),
    "(8KB)x3": dict(nor={"01": (97, 24), "00": (993, 1.9), "11": (76, 16.4)},
                    nand={"01": (665, 27), "00": (989, 1.8), "11": (98, 37)}),
    "(16KB)x3": dict(nor={"01": (114.3, 27), "00": (990, 2.7), "11": (86, 18)},
                     nand={"01": (685, 31), "00": (993, 2.1), "11": (99.4, 34.2)}),
}

# Fig 11: NAND2 "01/10" borderline case across (temp, vdd).
FIG11 = {
    (0, 0.9): (620, 27), (0, 1.0): (608, 22), (0, 1.1): (587, 19.4),
    (25, 0.9): (647, 24), (25, 1.0): (665, 17), (25, 1.1): (678, 22),
    (125, 0.9): (710, 20), (125, 1.0): (692, 21), (125, 1.1): (674, 19.2),
}

N_SAMPLES = 5000


def _fail_rate(mean_mv, sigma_mv, want_above: bool, rng) -> float:
    v = rng.normal(mean_mv, sigma_mv, N_SAMPLES) / 1000.0
    bad = (v <= VREF) if want_above else (v >= VREF)
    return bad.mean()


def run(csv: Csv) -> None:
    rng = np.random.default_rng(0)
    worst_margin = 1e9
    for topo, ops in FIG10.items():
        fails = 0.0
        for op, cases in ops.items():
            for case, (mu, sd) in cases.items():
                # NAND2: "00" and "01/10" must read above Vref (logic 1);
                # "11" below.  NOR2: only "00" reads above.
                want_above = (op == "nand" and case in ("00", "01")) or (
                    op == "nor" and case == "00"
                )
                fails += _fail_rate(mu, sd, want_above, rng)
                worst_margin = min(worst_margin, abs(mu - 500) / sd)
        csv.add(f"variation/fig10/{topo}", 0.0,
                f"total_misreads_over_{N_SAMPLES}x18cases={int(fails*N_SAMPLES)}")
    for (temp, vdd), (mu, sd) in FIG11.items():
        fr = _fail_rate(mu, sd, True, rng)
        worst_margin = min(worst_margin, abs(mu - 500) / sd)
        csv.add(f"variation/fig11/T{temp}C_V{vdd}", 0.0,
                f"mean={mu}mV;sigma={sd}mV;misread_rate={fr:.2e}")
    csv.add("variation/summary", 0.0,
            f"worst_sense_margin={worst_margin:.1f}sigma(>=3.5 required)")
    assert worst_margin >= 3.5
