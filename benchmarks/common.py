"""Shared benchmark helpers: timing, CSV emission, json merging."""

from __future__ import annotations

import json
import os
import time


def merge_json(path: str, updates: dict) -> dict:
    """Merge ``updates`` into the json file at ``path`` (several benches
    co-own top-level keys of BENCH_explorer.json).  A corrupt/truncated
    previous file is discarded rather than crashing after a long run,
    and the write is temp-file + atomic replace so an interrupted bench
    can never truncate the other benches' recorded sections."""
    merged: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    merged.update(updates)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(merged, f, indent=1)
    os.replace(tmp, path)
    return merged


def timeit(fn, *args, n_warmup: int = 1, n_iter: int = 3, **kw) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(n_warmup):
        fn(*args, **kw)
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


class Csv:
    """Collects ``name,us_per_call,derived`` rows and mirrors them to disk."""

    def __init__(self, out_dir: str = "runs/bench"):
        self.rows: list[tuple[str, float, str]] = []
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, us: float, derived: str = "") -> None:
        self.rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    def save(self, fname: str) -> None:
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in self.rows:
                f.write(f"{n},{u:.1f},{d}\n")
