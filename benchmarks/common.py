"""Shared benchmark helpers: timing + CSV emission."""

from __future__ import annotations

import os
import time


def timeit(fn, *args, n_warmup: int = 1, n_iter: int = 3, **kw) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(n_warmup):
        fn(*args, **kw)
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


class Csv:
    """Collects ``name,us_per_call,derived`` rows and mirrors them to disk."""

    def __init__(self, out_dir: str = "runs/bench"):
        self.rows: list[tuple[str, float, str]] = []
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, us: float, derived: str = "") -> None:
        self.rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    def save(self, fname: str) -> None:
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in self.rows:
                f.write(f"{n},{u:.1f},{d}\n")
