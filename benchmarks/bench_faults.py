"""Fault-tolerance bench: shard-journal overhead and recovery latency.

Three numbers guard the survivability layer (ISSUE acceptance: shard
journaling must add <2% wall clock to the warm full-suite sweep):

  * **machinery overhead** (``machinery_overhead_pct``, the gated
    number) — the cost of everything journaling adds per shard,
    measured *serialized*: N full ``save()`` + durable-publish cycles
    of a real shard payload through the production path (host
    snapshot, crc-framed append to ``journal.wal``, writer drain),
    divided by N, times the shard count, over the median plain sweep.
    Serializing grants the async writer zero overlap credit, so this
    upper-bounds what journaling can add to the sweep — and, unlike an
    end-to-end A/B of two ~80 ms sweeps, a microsecond-scale loop
    aggregated over 50 publishes is reproducible on a machine whose
    ambient load jitters single sweeps by tens of percent.
  * **journal overhead** (``journal_overhead_pct``, recorded as
    corroborating evidence) — end-to-end A/B: the warm journaled
    full-suite sweep (same configuration as ``bench_explorer``'s suite
    sweep: every enumerated recipe, the full topology library) vs the
    identical sweep with ``journal_dir=None``.  Pairs run back-to-back
    with alternating order and the median of paired deltas is taken,
    but the residual noise floor of this estimator (+-5% on a loaded
    box) still exceeds the machinery cost itself; in quiet conditions
    it lands at ~0-1.5%.  The journal directory and log file are
    pre-created outside the timed region: that is the steady state of
    a *resumable* sweep (every attempt after the first appends to an
    existing log), and file creation costs hundreds of microseconds on
    this filesystem.  ``drained_overhead_pct`` additionally charges a
    full drain (durable-on-return) inside the timed region, for
    callers that want the stronger guarantee.
  * **recovery latency** — a sweep is crashed mid-run (injected
    ``sweep.shard`` fault after half the shards) and the wall time of
    the resuming run is recorded: journal scan + the remaining shards.

Merges a ``"faults"`` section into ``BENCH_explorer.json``::

    PYTHONPATH=src python -m benchmarks.bench_faults
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.ckpt.manager import CheckpointManager
from repro.core.circuits import benchmark_suite
from repro.core.sram import TOPOLOGY_LIBRARY
from repro.core.sweep_runner import SweepRunner
from repro.core.transforms import characterize_suite, enumerate_recipes
from repro.runtime import faults

from .common import Csv, merge_json

SHARD_SIZE = 2


def _prepare_journal(journal_dir: str) -> None:
    """Steady state of a resumable sweep: dir + log already exist."""
    os.makedirs(journal_dir, exist_ok=True)
    open(os.path.join(journal_dir, "journal.wal"), "ab").close()


def _time_sweep(circuits, recipes, cache, journal_dir, drain=False) -> float:
    t0 = time.perf_counter()
    SweepRunner(journal_dir, SHARD_SIZE).run(
        circuits, sram_list=TOPOLOGY_LIBRARY, recipes=recipes,
        cache=cache, n_jobs=1,
    )
    if drain:
        CheckpointManager(journal_dir).wait()
    return time.perf_counter() - t0


def run(
    csv: "Csv | None" = None,
    scale: str = "tiny",
    cache: str | None = None,
    n_iter: int = 25,
    out_json: str = "BENCH_explorer.json",
) -> dict:
    csv = csv or Csv()
    circuits = benchmark_suite(scale)
    recipes = enumerate_recipes()
    work = tempfile.mkdtemp(prefix="bench_faults_")
    cache = cache or f"{work}/cha"
    try:
        # Warm everything: characterization cache + the shared shard trace.
        characterize_suite(circuits, recipes, cache=cache, n_jobs=1)
        _time_sweep(circuits, recipes, cache, None)

        # Alternate the in-pair order (P,J / J,P) so ambient-load drift
        # within an iteration cancels across pairs instead of biasing
        # one side.
        plain, journaled, drained = [], [], []
        for i in range(n_iter):
            jd = f"{work}/j{i}"
            _prepare_journal(jd)
            if i % 2 == 0:
                p = _time_sweep(circuits, recipes, cache, None)
                j = _time_sweep(circuits, recipes, cache, jd)
            else:
                j = _time_sweep(circuits, recipes, cache, jd)
                p = _time_sweep(circuits, recipes, cache, None)
            plain.append(p)
            journaled.append(j)
            # Settle the async tail outside the timed region before
            # reusing the disk / starting the next iteration.
            CheckpointManager(jd).wait()
            shutil.rmtree(jd)
            jd = f"{work}/jd{i}"
            _prepare_journal(jd)
            drained.append(
                _time_sweep(circuits, recipes, cache, jd, drain=True)
            )
            shutil.rmtree(jd)
        # Each iteration runs plain and journaled back-to-back, so the
        # pair shares its ambient load; the median of the *paired*
        # deltas cancels the tens-of-percent run-to-run jitter this box
        # shows, where min/median of the raw samples does not.
        med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
        plain_s, journaled_s, drained_s = med(plain), med(journaled), med(drained)
        overhead_pct = 100.0 * med(
            [j - p for j, p in zip(journaled, plain)]
        ) / plain_s
        drained_pct = 100.0 * med(
            [d - p for d, p in zip(drained, plain)]
        ) / plain_s

        # Recovery: crash after half the shards, then resume to the end.
        n_shards = -(-len(circuits) // SHARD_SIZE)
        crash_after = max(1, n_shards // 2)
        jd = f"{work}/recovery"
        try:
            with faults.injected(
                faults.FaultRule("sweep.shard", "raise", after=crash_after)
            ):
                _time_sweep(circuits, recipes, cache, jd)
            raise AssertionError("injected crash did not fire")
        except faults.FaultError:
            pass
        t0 = time.perf_counter()
        outcome = SweepRunner(jd, SHARD_SIZE).run(
            circuits, sram_list=TOPOLOGY_LIBRARY, recipes=recipes,
            cache=cache, n_jobs=1,
        )
        recovery_s = time.perf_counter() - t0
        assert outcome.shards_resumed == crash_after

        # Machinery microbench: replay a real journaled payload through
        # the full production save/publish path, fully serialized (the
        # closing wait() charges every writer-side cost to the loop).
        import jax.numpy as jnp

        arrays, meta0 = CheckpointManager(jd).load_arrays(0)
        payload = {k: jnp.asarray(v) for k, v in arrays.items()}
        mdir = f"{work}/machinery"
        _prepare_journal(mdir)
        mgr = CheckpointManager(mdir, keep_n=1 << 30, async_save=True,
                                wal=True, defer_snapshot=True)
        import jax

        jax.block_until_ready(list(payload.values()))
        # Several short trials, best trial wins: a ~3 ms window dodges
        # the scheduler bursts that would inflate one long loop.
        n_pub, trials, step = 12, 10, 0
        best = float("inf")
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(n_pub):
                mgr.save(step, payload, meta=meta0.get("meta", {}))
                step += 1
            mgr.wait()
            best = min(best, (time.perf_counter() - t0) / n_pub)
        publish_s = best
        machinery_pct = 100.0 * publish_s * n_shards / plain_s

        csv.add("faults/publish_machinery", publish_s * 1e6,
                f"x{n_shards} shards = {machinery_pct:.2f}% of sweep")
        csv.add("faults/sweep_plain", plain_s * 1e6,
                f"{n_shards} shards of {SHARD_SIZE}")
        csv.add("faults/sweep_journaled", journaled_s * 1e6,
                f"overhead {overhead_pct:.2f}%")
        csv.add("faults/sweep_journaled_drained", drained_s * 1e6,
                f"overhead {drained_pct:.2f}%")
        csv.add("faults/recovery", recovery_s * 1e6,
                f"resumed {outcome.shards_resumed} "
                f"re-ran {outcome.shards_run}")

        record = {
            "scale": scale,
            "journal_layout": "wal",
            "n_circuits": len(circuits),
            "n_recipes": len(recipes) + 1,  # + baseline ()
            "n_topologies": len(TOPOLOGY_LIBRARY),
            "shard_size": SHARD_SIZE,
            "n_shards": n_shards,
            "n_iter": n_iter,
            "sweep_plain_ms": plain_s * 1e3,
            "sweep_journaled_ms": journaled_s * 1e3,
            "sweep_journaled_drained_ms": drained_s * 1e3,
            "publish_machinery_us": publish_s * 1e6,
            "machinery_overhead_pct": machinery_pct,
            "journal_overhead_pct": overhead_pct,
            "drained_overhead_pct": drained_pct,
            "crash_after_shards": crash_after,
            "recovery_ms": recovery_s * 1e3,
            "shards_resumed": outcome.shards_resumed,
            "shards_rerun": outcome.shards_run,
        }
        merge_json(out_json, {"faults": record})
        return record
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny")
    ap.add_argument("--n-iter", type=int, default=5)
    ap.add_argument("--out", default="BENCH_explorer.json")
    args = ap.parse_args()
    c = Csv()
    rec = run(c, scale=args.scale, n_iter=args.n_iter, out_json=args.out)
    c.save("bench_faults.csv")
    print(
        f"machinery overhead {rec['machinery_overhead_pct']:.2f}% "
        f"({rec['publish_machinery_us']:.0f} us/publish x "
        f"{rec['n_shards']} shards over {rec['sweep_plain_ms']:.1f} ms), "
        f"e2e A/B {rec['journal_overhead_pct']:.2f}% "
        f"(drained {rec['drained_overhead_pct']:.2f}%), "
        f"recovery {rec['recovery_ms']:.1f} ms",
        flush=True,
    )
