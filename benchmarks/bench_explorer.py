"""Exploration-backend benchmark: scalar python loop vs tensorized jax grid.

Times the back half of Algorithm I (schedule -> evaluate -> filter over the
full recipe x topology grid) with the characterization front half hoisted
out and shared, so the numbers isolate exactly what `core/batch.py`
tensorizes.  Also cross-checks that both backends pick the identical best
implementation per circuit.

    PYTHONPATH=src python -m benchmarks.bench_explorer                # 9 circuits, 65 recipes
    PYTHONPATH=src python -m benchmarks.bench_explorer --smoke        # CI: 4 circuits, 9 recipes
    PYTHONPATH=src python -m benchmarks.bench_explorer --scale default

Emits ``BENCH_explorer.json``: per-circuit wall times for both backends,
the speedup, and suite aggregates.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core import circuits as C
from repro.core.explorer import characterize_recipes, explore
from repro.core.transforms import enumerate_recipes

from .common import Csv, timeit

SMOKE_CIRCUITS = ("adder", "bar", "sqrt", "max")
SMOKE_RECIPES = 8


def run(
    csv: Csv | None = None,
    scale: str = "tiny",
    n_recipes: int | None = None,
    only=None,
    n_iter: int = 3,
    out_json: str = "BENCH_explorer.json",
    mode: str = "physical",
) -> dict:
    csv = csv or Csv()
    recipes = enumerate_recipes()
    if n_recipes is not None:
        recipes = recipes[:n_recipes]
    suite = C.benchmark_suite(scale=scale, only=only)

    per_circuit = {}
    totals = dict(python_us=0.0, jax_us=0.0, cha_s=0.0, implementations=0)
    for name, rtl in suite.items():
        t0 = time.time()
        cha = characterize_recipes(rtl, recipes)
        cha_s = time.time() - t0

        t_py = timeit(
            lambda: explore(rtl, cha=cha, mode=mode, backend="python"),
            n_warmup=1, n_iter=n_iter,
        )
        t_jx = timeit(
            lambda: explore(rtl, cha=cha, mode=mode, backend="jax"),
            n_warmup=1, n_iter=n_iter,
        )
        res_py = explore(rtl, cha=cha, mode=mode, backend="python")
        res_jx = explore(rtl, cha=cha, mode=mode, backend="jax")
        agree = (
            res_py.best.recipe == res_jx.best.recipe
            and res_py.best.topo == res_jx.best.topo
            and abs(res_py.best.metrics.energy_nj - res_jx.best.metrics.energy_nj)
            < 1e-6
        )
        speedup = t_py / t_jx if t_jx > 0 else float("inf")
        per_circuit[name] = dict(
            gates=res_py.best.stats.total_gates,
            implementations=res_py.n_evaluations,
            characterize_s=round(cha_s, 3),
            python_us=round(t_py, 1),
            jax_us=round(t_jx, 1),
            speedup=round(speedup, 2),
            best=dict(
                topo=res_jx.best.topo.name,
                recipe=",".join(res_jx.best.recipe) or "-",
                energy_nj=res_jx.best.metrics.energy_nj,
            ),
            backends_agree=agree,
        )
        totals["python_us"] += t_py
        totals["jax_us"] += t_jx
        totals["cha_s"] += cha_s
        totals["implementations"] += res_py.n_evaluations
        csv.add(
            f"explorer/{name}", t_jx,
            f"python_us={t_py:.0f};jax_us={t_jx:.0f};"
            f"speedup={speedup:.1f}x;agree={agree}",
        )

    suite_speedup = (
        totals["python_us"] / totals["jax_us"] if totals["jax_us"] else 0.0
    )
    out = dict(
        scale=scale,
        n_recipes=len(recipes) + 1,  # + baseline ()
        n_circuits=len(suite),
        per_circuit=per_circuit,
        total=dict(
            implementations=totals["implementations"],
            characterize_s=round(totals["cha_s"], 3),
            python_us=round(totals["python_us"], 1),
            jax_us=round(totals["jax_us"], 1),
            speedup=round(suite_speedup, 2),
            all_agree=all(c["backends_agree"] for c in per_circuit.values()),
        ),
    )
    with open(out_json, "w") as f:
        json.dump(out, f, indent=1)
    csv.add(
        "explorer/TOTAL", totals["jax_us"],
        f"python_us={totals['python_us']:.0f};jax_us={totals['jax_us']:.0f};"
        f"speedup={suite_speedup:.1f}x;json={out_json}",
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "default", "paper"], default="tiny")
    ap.add_argument("--recipes", type=int, default=None,
                    help="limit recipe count (default: all 64)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: few circuits, few recipes, 1 iter")
    ap.add_argument("--out", default="BENCH_explorer.json")
    args = ap.parse_args()
    kw = dict(scale=args.scale, n_recipes=args.recipes, out_json=args.out)
    if args.smoke:
        kw.update(scale="tiny", n_recipes=SMOKE_RECIPES, only=SMOKE_CIRCUITS,
                  n_iter=1)
    print("name,us_per_call,derived")
    run(**kw)


if __name__ == "__main__":
    main()
