"""Exploration-engine benchmark: end-to-end (characterize + sweep) wall
time for the whole suite, old serial path vs the suite-level engine.

Three front-half configurations are timed:

  * ``serial``  — the PR-1 reference: per-circuit prefix-*tree* runner (no
    structural dedup, no cache, no pool), one ``characterize`` per recipe.
  * ``cold``    — `transforms.characterize_suite` against an empty
    on-disk cache: shared-prefix DAG with structural dedup + process pool.
  * ``warm``    — the same call again: every (circuit, recipe) served from
    the `CharacterizationCache`, no transform runs at all.

The back half is timed both ways: the per-circuit scalar loop
(``backend="python"``) and the one-call suite sweep
(`explorer.explore_suite`, circuits x recipes x topologies vmapped,
riding the fused device-resident pipeline: FilterEnergy runs inside the
jitted pass and only the winners cross the host boundary).
Cross-checks that every backend picks the identical best implementation.

    PYTHONPATH=src python -m benchmarks.bench_explorer            # full: 9 circuits, 65 recipes
    PYTHONPATH=src python -m benchmarks.bench_explorer --smoke    # CI: 4 circuits, 9 recipes, no serial baseline
    PYTHONPATH=src python -m benchmarks.bench_explorer --scale default

Emits ``BENCH_explorer.json``: per-circuit and suite-total wall times for
every path plus the end-to-end speedups (``total.e2e_*``).
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
import time

from repro.core import circuits as C
from repro.core.explorer import explore, explore_suite
from repro.core.transforms import (
    CharacterizationCache,
    _TRANSFORM_FNS,
    characterize_suite,
    enumerate_recipes,
)

from .common import Csv, merge_json, timeit

SMOKE_CIRCUITS = ("adder", "bar", "sqrt", "max")
SMOKE_RECIPES = 8


def characterize_prefix_tree(rtl, recipes):
    """The PR-1 front half, kept as the benchmark's reference point:
    prefix-shared transform applications (64 per circuit), one ``ChaAIG``
    per recipe — no structural dedup, no persistence, no pool."""
    cache = {(): rtl}

    def run(r):
        if r not in cache:
            cache[r] = _TRANSFORM_FNS[r[-1]](run(r[:-1]))
        return cache[r]

    return {r: run(r).characterize() for r in [()] + list(recipes)}


def run(
    csv: Csv | None = None,
    scale: str = "tiny",
    n_recipes: int | None = None,
    only=None,
    n_iter: int = 3,
    out_json: str = "BENCH_explorer.json",
    mode: str = "physical",
    baseline: bool = True,
    n_jobs: int | None = None,
    cache_dir: str | None = None,
) -> dict:
    csv = csv or Csv()
    recipes = enumerate_recipes()
    if n_recipes is not None:
        recipes = recipes[:n_recipes]
    suite = C.benchmark_suite(scale=scale, only=only)

    # ---- front half -------------------------------------------------------
    serial_s = {}
    if baseline:
        for name, rtl in suite.items():
            t0 = time.time()
            characterize_prefix_tree(rtl, [tuple(r) for r in recipes])
            serial_s[name] = time.time() - t0

    own_cache_dir = cache_dir is None
    cache_root = cache_dir or tempfile.mkdtemp(prefix="repro-cha-cache-")
    try:
        cache = CharacterizationCache(cache_root)
        t0 = time.time()
        cha = characterize_suite(suite, recipes, cache=cache, n_jobs=n_jobs)
        cold_s = time.time() - t0
        t0 = time.time()
        cha_warm = characterize_suite(suite, recipes, cache=cache, n_jobs=n_jobs)
        warm_s = time.time() - t0
        assert cha_warm == cha, "warm cache characterization drifted"
    finally:
        if own_cache_dir:
            shutil.rmtree(cache_root, ignore_errors=True)

    # ---- back half --------------------------------------------------------
    t_suite = timeit(
        lambda: explore_suite(suite, cha=cha, mode=mode, backend="jax"),
        n_warmup=1, n_iter=n_iter,
    )
    res_suite = explore_suite(suite, cha=cha, mode=mode, backend="jax")

    per_circuit = {}
    totals = dict(python_us=0.0, jax_us=0.0, implementations=0)
    for name, rtl in suite.items():
        t_py = timeit(
            lambda: explore(rtl, cha=cha[name], mode=mode, backend="python"),
            n_warmup=1, n_iter=n_iter,
        )
        t_jx = timeit(
            lambda: explore(rtl, cha=cha[name], mode=mode, backend="jax"),
            n_warmup=1, n_iter=n_iter,
        )
        res_py = explore(rtl, cha=cha[name], mode=mode, backend="python")
        res_sx = res_suite[name]
        agree = (
            res_py.best.recipe == res_sx.best.recipe
            and res_py.best.topo == res_sx.best.topo
            and abs(res_py.best.metrics.energy_nj - res_sx.best.metrics.energy_nj)
            < 1e-6
        )
        speedup = t_py / t_jx if t_jx > 0 else float("inf")
        per_circuit[name] = dict(
            gates=res_py.best.stats.total_gates,
            implementations=res_py.n_evaluations,
            characterize_serial_s=round(serial_s.get(name, 0.0), 3),
            python_us=round(t_py, 1),
            jax_us=round(t_jx, 1),
            speedup=round(speedup, 2),
            best=dict(
                topo=res_sx.best.topo.name,
                recipe=",".join(res_sx.best.recipe) or "-",
                energy_nj=res_sx.best.metrics.energy_nj,
            ),
            backends_agree=agree,
        )
        totals["python_us"] += t_py
        totals["jax_us"] += t_jx
        totals["implementations"] += res_py.n_evaluations
        csv.add(
            f"explorer/{name}", t_jx,
            f"python_us={t_py:.0f};jax_us={t_jx:.0f};"
            f"speedup={speedup:.1f}x;agree={agree}",
        )

    suite_speedup = (
        totals["python_us"] / totals["jax_us"] if totals["jax_us"] else 0.0
    )
    serial_total = sum(serial_s.values())
    suite_sweep_s = t_suite * 1e-6
    e2e = dict(
        # end-to-end = characterize + full-suite sweep, in seconds
        serial_s=round(serial_total + totals["jax_us"] * 1e-6, 3)
        if baseline else None,
        cold_s=round(cold_s + suite_sweep_s, 3),
        warm_s=round(warm_s + suite_sweep_s, 3),
    )
    if baseline and e2e["cold_s"]:
        e2e["speedup_cold"] = round(e2e["serial_s"] / e2e["cold_s"], 2)
        e2e["speedup_warm"] = round(e2e["serial_s"] / e2e["warm_s"], 2)
    out = dict(
        scale=scale,
        n_recipes=len(recipes) + 1,  # + baseline ()
        n_circuits=len(suite),
        fused_selection=True,  # explore_suite runs FilterEnergy on device
        per_circuit=per_circuit,
        total=dict(
            implementations=totals["implementations"],
            characterize_serial_s=round(serial_total, 3) if baseline else None,
            characterize_cold_s=round(cold_s, 3),
            characterize_warm_s=round(warm_s, 3),
            python_us=round(totals["python_us"], 1),
            jax_us=round(totals["jax_us"], 1),
            suite_sweep_us=round(t_suite, 1),
            speedup=round(suite_speedup, 2),
            e2e=e2e,
            all_agree=all(c["backends_agree"] for c in per_circuit.values()),
        ),
    )
    # Merge-preserving write: other benches (bench_variation's model
    # sweep) own sibling top-level keys in the same json.
    merge_json(out_json, out)
    csv.add(
        "explorer/TOTAL", totals["jax_us"],
        f"python_us={totals['python_us']:.0f};jax_us={totals['jax_us']:.0f};"
        f"speedup={suite_speedup:.1f}x;cha_cold={cold_s:.1f}s;"
        f"cha_warm={warm_s:.2f}s;json={out_json}",
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "default", "paper"], default="tiny")
    ap.add_argument("--recipes", type=int, default=None,
                    help="limit recipe count (default: all 64)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: few circuits, few recipes, 1 iter, "
                         "no serial baseline")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the serial (PR-1 reference) front half")
    ap.add_argument("--jobs", type=int, default=None,
                    help="characterization workers (default: min(4, cpus))")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent characterization cache directory "
                         "(default: fresh temp dir, deleted afterwards)")
    ap.add_argument("--out", default="BENCH_explorer.json")
    args = ap.parse_args()
    kw = dict(scale=args.scale, n_recipes=args.recipes, out_json=args.out,
              baseline=not args.no_baseline, n_jobs=args.jobs,
              cache_dir=args.cache_dir)
    if args.smoke:
        kw.update(scale="tiny", n_recipes=SMOKE_RECIPES, only=SMOKE_CIRCUITS,
                  n_iter=1, baseline=False)
    print("name,us_per_call,derived")
    run(**kw)


if __name__ == "__main__":
    main()
