"""CiM engine microbenchmark (§III-B execution model).

Times the Pallas bit-plane kernel (interpret mode on CPU — wall numbers are
for regression tracking, not TPU projections) and cross-checks the rCiM
analytical model's prediction for the same workload: ops/cycle, energy, and
the modeled speedup of the in-VMEM evaluation vs per-level HBM round-trips.

Runs standalone (``python -m benchmarks.bench_kernel``) or from
``benchmarks.run``; either way the numbers are merged into
``BENCH_explorer.json`` under a ``"kernel"`` key with the same
merge-preserving write the other benches use, so the kernel-level
regression record lives next to the explorer/variation sections instead
of only in the CSV mirror.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import circuits as C
from repro.core.mapping import schedule_stats
from repro.core.sram import EnergyModel, SramTopology, evaluate
from repro.kernels import ops

from .common import Csv, merge_json, timeit


def run(csv: Csv, out_json: str = "BENCH_explorer.json") -> dict:
    em = EnergyModel()
    rng = np.random.default_rng(0)
    record: dict = {"per_circuit": {}}
    for name, gen, n_vec in [
        ("adder16", lambda: C.gen_adder(16), 8192),
        ("mult8", lambda: C.gen_multiplier(8), 4096),
        ("max8x4", lambda: C.gen_max(8, 4), 8192),
    ]:
        aig = gen()
        net = aig.to_gate_netlist()
        cc = ops.compile_netlist(net)
        bits = rng.integers(0, 2, size=(aig.n_pis, n_vec)).astype(np.uint8)
        packed = ops.ref.pack_vectors(bits)

        us = timeit(ops.cim_evaluate, cc, packed, packed=True,
                    block_words=128, n_warmup=1, n_iter=3)
        gate_evals = cc.n_gates * n_vec
        # analytical rCiM prediction for the same netlist on an 8KB macro
        st = aig.characterize()
        topo = SramTopology(8, 1)
        met = evaluate(schedule_stats(st, topo), topo, em)
        record["per_circuit"][name] = dict(
            us=round(us, 1),
            n_gates=cc.n_gates,
            n_rows=cc.n_rows,
            reuse_factor=round(cc.reuse_factor, 2),
            n_vectors=n_vec,
            geval_per_s_m=round(gate_evals / (us * 1e-6) / 1e6, 1),
            model_cycles=int(met.cycles),
            model_energy_nj=round(met.energy_nj, 4),
            model_throughput_gops=round(met.throughput_gops, 1),
        )
        csv.add(
            f"kernel/{name}", us,
            f"gates={cc.n_gates};rows={cc.n_rows}(reuse {cc.reuse_factor:.1f}x);"
            f"vec={n_vec};geval_per_s={gate_evals/(us*1e-6)/1e6:.1f}M;"
            f"rcim_model:cycles={met.cycles},E={met.energy_nj:.4f}nJ,"
            f"thr={met.throughput_gops:.0f}GOPS",
        )

    # VMEM-residency claim: the modeled HBM round-trip cost per level vs
    # keeping bit-planes resident (DESIGN.md memory-hierarchy mapping).
    aig = C.gen_adder(16)
    cc = ops.compile_netlist(aig.to_gate_netlist())
    n_vec = 8192
    bytes_planes = cc.n_rows * n_vec // 8
    levels = aig.characterize().n_levels
    hbm_bw, vmem_bw = 819e9, 20e12  # v5e HBM vs ~VMEM bandwidth
    t_roundtrip = 2 * bytes_planes * levels / hbm_bw
    t_resident = 2 * bytes_planes * levels / vmem_bw
    record["vmem_residency"] = dict(
        levels=levels,
        bytes_planes=bytes_planes,
        modeled_speedup=round(t_roundtrip / t_resident),
    )
    csv.add("kernel/vmem_residency_model", 0.0,
            f"levels={levels};modeled_speedup={t_roundtrip/t_resident:.0f}x")

    # Merge-preserving write: bench_explorer / bench_variation own
    # sibling top-level keys in the same json.
    merge_json(out_json, {"kernel": record})
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_explorer.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(Csv(), out_json=args.out)


if __name__ == "__main__":
    main()
