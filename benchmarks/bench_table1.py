"""Table I — best-case vs worst-case implementation per benchmark circuit:
SRAM size, macro count, recipe, level count, gate counts, P/T/E.

Runs the whole suite through one `explorer.explore_suite` call (shared
front half + a single circuits x recipes x topologies sweep); `best_worst`
then runs the shared filter/argmin on each circuit's grid view."""

from __future__ import annotations

from repro.core import circuits as C
from repro.core.explorer import best_worst, explore_suite

from .common import Csv


def run(csv: Csv, scale: str = "tiny", recipes=None, backend: str = "jax",
        cache=None) -> list[dict]:
    suite = C.benchmark_suite(scale=scale)
    results = explore_suite(suite, recipes=recipes, backend=backend,
                            cache=cache)
    rows = []
    savings = []
    for name, res in results.items():
        b, w = best_worst(res)
        saving = 100 * (1 - b.metrics.energy_nj / w.metrics.energy_nj)
        savings.append(saving)
        for tag, ev in (("best", b), ("worst", w)):
            rows.append(
                dict(benchmark=name, case=tag, sram_kb=ev.topo.macro_kb,
                     macros=ev.topo.n_macros, recipe=",".join(ev.recipe) or "-",
                     levels=ev.stats.n_levels, nand=ev.stats.nand_count,
                     nor=ev.stats.nor_count, inv=ev.stats.inv_count,
                     power_mw=round(ev.metrics.power_mw, 3),
                     latency_ns=round(ev.metrics.latency_ns, 3),
                     energy_nj=round(ev.metrics.energy_nj, 6))
            )
        csv.add(
            f"table1/{name}", res.wall_s * 1e6,
            f"best={b.topo.name}({','.join(b.recipe) or '-'})"
            f";worst={w.topo.name}({','.join(w.recipe) or '-'})"
            f";saving={saving:.1f}%",
        )
    avg = sum(savings) / len(savings)
    csv.add("table1/AVERAGE", 0.0,
            f"avg_best_vs_worst_saving={avg:.1f}%(paper 89.12%)")
    return rows
