"""Table I — best-case vs worst-case implementation per benchmark circuit:
SRAM size, macro count, recipe, level count, gate counts, P/T/E."""

from __future__ import annotations

import time

from repro.core import circuits as C
from repro.core.explorer import best_worst, explore

from .common import Csv


def run(csv: Csv, scale: str = "tiny", recipes=None, backend: str = "jax") -> list[dict]:
    suite = C.benchmark_suite(scale=scale)
    rows = []
    savings = []
    for name, rtl in suite.items():
        t0 = time.time()
        # Batched grid sweep; best_worst runs the shared filter/argmin on it.
        res = explore(rtl, recipes=recipes, backend=backend)
        b, w = best_worst(res)
        dt = (time.time() - t0) * 1e6
        saving = 100 * (1 - b.metrics.energy_nj / w.metrics.energy_nj)
        savings.append(saving)
        for tag, ev in (("best", b), ("worst", w)):
            rows.append(
                dict(benchmark=name, case=tag, sram_kb=ev.topo.macro_kb,
                     macros=ev.topo.n_macros, recipe=",".join(ev.recipe) or "-",
                     levels=ev.stats.n_levels, nand=ev.stats.nand_count,
                     nor=ev.stats.nor_count, inv=ev.stats.inv_count,
                     power_mw=round(ev.metrics.power_mw, 3),
                     latency_ns=round(ev.metrics.latency_ns, 3),
                     energy_nj=round(ev.metrics.energy_nj, 6))
            )
        csv.add(
            f"table1/{name}", dt,
            f"best={b.topo.name}({','.join(b.recipe) or '-'})"
            f";worst={w.topo.name}({','.join(w.recipe) or '-'})"
            f";saving={saving:.1f}%",
        )
    avg = sum(savings) / len(savings)
    csv.add("table1/AVERAGE", 0.0,
            f"avg_best_vs_worst_saving={avg:.1f}%(paper 89.12%)")
    return rows
