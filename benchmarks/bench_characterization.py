"""Characterization benchmark: Alg. I front half, device vs python ints.

Times the transform pipeline (`transforms.characterize_suite`) that creates
and characterizes every recipe AIG, on both backends:

  * ``serial``  — the PR-1 reference: per-circuit prefix-tree runner with
    the python-int transform loops, no structural dedup, no cache.
  * ``python``  — `characterize_suite(backend="python")` against an empty
    cache: shared-prefix DAG + structural dedup, python-int cone loops.
  * ``device``  — `characterize_suite(backend="device")`: the same DAG
    with the truth-table inner loops of rewrite/refactor/resub batched
    through `kernels.aig_sim` mega-programs (bit-packed uint32 lanes, one
    device call per transform round instead of per-node python walks).

"Cold" means an empty `CharacterizationCache`, matching the semantics of
`bench_explorer`'s ``characterize_cold_s``; the device numbers include
jax tracing for this process (the persistent compilation cache installed
by `kernels.aig_sim` absorbs the XLA compiles across processes).

Also records a per-transform breakdown (one application of each transform
to every base RTL AIG, python vs device, fingerprint-checked) and a
parity flag: the device and python backends must produce identical
`AigStats` for every (circuit, recipe) and identical output fingerprints
for every (circuit, transform).

    PYTHONPATH=src python -m benchmarks.bench_characterization           # full: 9 circuits, 65 recipes
    PYTHONPATH=src python -m benchmarks.bench_characterization --smoke   # CI subset

Merges a ``"characterization"`` section into ``BENCH_explorer.json``
(merge-preserving write, same as the explorer/variation/kernel benches).
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
import time

from repro.core import circuits as C
from repro.core.transforms import (
    TRANSFORM_NAMES,
    CharacterizationCache,
    characterize_suite,
    enumerate_recipes,
    resolve_backend,
    transform_fns,
)

from .common import Csv, merge_json

SMOKE_CIRCUITS = ("adder", "bar", "sqrt", "max")
SMOKE_RECIPES = 8


def _characterize_prefix_tree(rtl, recipes, fns):
    """PR-1 reference front half: prefix-shared transform applications,
    one characterize per recipe — no structural dedup, no persistence."""
    cache = {(): rtl}

    def step(r):
        if r not in cache:
            cache[r] = fns[r[-1]](step(r[:-1]))
        return cache[r]

    return {r: step(r).characterize() for r in [()] + list(recipes)}


def _suite_cold(suite, recipes, backend, n_jobs):
    """One cache-cold + one cache-warm `characterize_suite` run against a
    throwaway on-disk cache; returns (cha, cold_s, warm_s)."""
    root = tempfile.mkdtemp(prefix=f"repro-cha-{backend}-")
    try:
        cache = CharacterizationCache(root)
        t0 = time.time()
        cha = characterize_suite(
            suite, recipes, cache=cache, n_jobs=n_jobs, backend=backend
        )
        cold_s = time.time() - t0
        t0 = time.time()
        again = characterize_suite(
            suite, recipes, cache=cache, n_jobs=n_jobs, backend=backend
        )
        warm_s = time.time() - t0
        assert again == cha, f"warm-cache characterization drifted ({backend})"
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return cha, cold_s, warm_s


def run(
    csv: Csv | None = None,
    scale: str = "tiny",
    n_recipes: int | None = None,
    only=None,
    out_json: str = "BENCH_explorer.json",
    serial_reference: bool = True,
    n_jobs: int | None = None,
) -> dict:
    csv = csv or Csv()
    recipes = enumerate_recipes()
    if n_recipes is not None:
        recipes = recipes[:n_recipes]
    suite = C.benchmark_suite(scale=scale, only=only)
    have_device = resolve_backend("auto") == "device"

    py_fns = transform_fns("python")
    dev_fns = transform_fns("device") if have_device else py_fns

    # ---- serial python-int reference (the pre-dedup PR-1 shape) ----------
    serial_s = None
    if serial_reference:
        t0 = time.time()
        for rtl in suite.values():
            _characterize_prefix_tree(rtl, [tuple(r) for r in recipes], py_fns)
        serial_s = time.time() - t0

    # ---- suite characterization, both backends ---------------------------
    cha_py, python_cold_s, python_warm_s = _suite_cold(
        suite, recipes, "python", n_jobs
    )
    device_cold_s = device_warm_s = None
    stats_agree = None
    n_stats_checked = 0
    if have_device:
        cha_dev, device_cold_s, device_warm_s = _suite_cold(
            suite, recipes, "device", n_jobs
        )
        stats_agree = True
        for name in suite:
            for r, st in cha_py[name].items():
                n_stats_checked += 1
                if cha_dev[name][r] != st:
                    stats_agree = False

    # ---- per-transform breakdown (one application per base circuit) ------
    per_transform = {}
    for t in TRANSFORM_NAMES:
        py_t = dev_t = 0.0
        fp_agree = True
        for rtl in suite.values():
            t0 = time.time()
            out_py = py_fns[t](rtl)
            py_t += time.time() - t0
            if have_device:
                t0 = time.time()
                out_dev = dev_fns[t](rtl)
                dev_t += time.time() - t0
                if out_dev.fingerprint() != out_py.fingerprint():
                    fp_agree = False
        per_transform[t] = dict(
            python_s=round(py_t, 3),
            device_s=round(dev_t, 3) if have_device else None,
            speedup=round(py_t / dev_t, 2) if have_device and dev_t else None,
            fingerprints_agree=fp_agree if have_device else None,
        )

    parity = bool(stats_agree) and all(
        pt["fingerprints_agree"] for pt in per_transform.values()
    ) if have_device else None
    # PR-5's recorded front-half cold time (same tiny-scale suite, the
    # pre-device python path without this PR's host-side optimizations) —
    # kept as the fixed reference the cold-start work is measured against.
    pr5_recorded_cold_s = 20.438 if (scale == "tiny" and only is None) else None
    record = dict(
        scale=scale,
        n_recipes=len(recipes) + 1,  # + baseline ()
        n_circuits=len(suite),
        backend_available=have_device,
        pr5_recorded_cold_s=pr5_recorded_cold_s,
        serial_python_s=round(serial_s, 3) if serial_s is not None else None,
        python_cold_s=round(python_cold_s, 3),
        python_warm_s=round(python_warm_s, 3),
        device_cold_s=round(device_cold_s, 3) if have_device else None,
        device_warm_s=round(device_warm_s, 3) if have_device else None,
        speedup_vs_python=(
            round(python_cold_s / device_cold_s, 2)
            if have_device and device_cold_s else None
        ),
        speedup_vs_serial=(
            round(serial_s / device_cold_s, 2)
            if have_device and serial_s is not None and device_cold_s else None
        ),
        speedup_vs_pr5=(
            round(pr5_recorded_cold_s / device_cold_s, 2)
            if have_device and pr5_recorded_cold_s and device_cold_s else None
        ),
        speedup_warm_vs_python=(
            round(python_cold_s / device_warm_s, 2)
            if have_device and device_warm_s else None
        ),
        note="single-CPU XLA backend: device cold includes per-process jit "
             "tracing; the persistent caches (XLA compile cache + "
             "CharacterizationCache) carry the cold-start win across "
             "processes, and resub is the transform the device "
             "accelerates most (batched signatures + cone verification)",
        per_transform=per_transform,
        parity=dict(
            agree=parity,
            stats_checked=n_stats_checked,
            note="AigStats per (circuit, recipe) + output fingerprints "
                 "per (circuit, transform), device vs python",
        ),
    )
    merge_json(out_json, {"characterization": record})

    spd = record["speedup_vs_python"]
    derived = f"python_cold={python_cold_s:.2f}s"
    if serial_s is not None:
        derived += f";serial={serial_s:.2f}s"
    if have_device:
        derived += (
            f";device_cold={device_cold_s:.2f}s;device_warm={device_warm_s:.3f}s"
            f";speedup_vs_python={spd}x;parity={parity}"
        )
    derived += f";json={out_json}"
    csv.add(
        "characterization/TOTAL",
        (device_cold_s if have_device else python_cold_s) * 1e6,
        derived,
    )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "default", "paper"],
                    default="tiny")
    ap.add_argument("--recipes", type=int, default=None,
                    help="limit recipe count (default: all 64)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: few circuits, few recipes, "
                         "no serial reference")
    ap.add_argument("--no-serial", action="store_true",
                    help="skip the serial PR-1 reference")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--out", default="BENCH_explorer.json")
    args = ap.parse_args()
    kw = dict(scale=args.scale, n_recipes=args.recipes, out_json=args.out,
              serial_reference=not args.no_serial, n_jobs=args.jobs)
    if args.smoke:
        kw.update(scale="tiny", only=SMOKE_CIRCUITS,
                  n_recipes=SMOKE_RECIPES, serial_reference=True)
    print("name,us_per_call,derived")
    run(**kw)


if __name__ == "__main__":
    main()
