"""Exploration-service stress bench: cold vs warm latency, throughput,
trace accounting, and per-request winner agreement with the offline path.

Phases:

  1. **cold** — first request per circuit: pays characterization + (for
     a new bucket shape) jit compilation.
  2. **warm throughput** — a burst of mixed-constraint requests over the
     now-cached fingerprints, submitted all at once (continuous
     batching): requests/sec.
  3. **warm latency** — sequential submits (one in flight at a time):
     end-to-end p50/p99 per request.  Asserted ``<< cold p50``.
  4. **re-rank** — constraint-only changes over a cached grid: asserted
     to add **zero** new jit traces of any kernel.
  5. **agreement** — every response's winner replayed against a fresh
     offline `explore_request`: topology + recipe identical, energy
     bit-identical to the offline device grid cell.

Trace accounting: the fused suite kernel must have traced exactly once
per distinct bucket shape the service reports — repeat shapes reuse the
compiled sweep.

    PYTHONPATH=src python -m benchmarks.bench_service           # full
    PYTHONPATH=src python -m benchmarks.bench_service --smoke   # CI

Merges a ``"service"`` section into ``BENCH_explorer.json``.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from .common import Csv, merge_json

SMOKE_CIRCUITS = ("adder", "bar", "sqrt", "max")
SMOKE_RECIPES = 8


def _percentiles(ms: list) -> tuple[float, float]:
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 99))


def run_service_bench(
    csv: Csv | None = None,
    scale: str = "tiny",
    only=None,
    n_recipes: int | None = None,
    n_requests: int = 32,
    n_variants: int = 8,
    out_json: str = "BENCH_explorer.json",
    cache_dir: str | None = None,
    merge_key: str = "service",
) -> dict:
    from repro.core import batch as B
    from repro.core.circuits import benchmark_suite
    from repro.core.explorer import explore_request
    from repro.core.sram import TOPOLOGY_LIBRARY, ModelTable
    from repro.core.transforms import enumerate_recipes
    from repro.serve.explore_service import (
        ExplorationService,
        ExploreRequest,
    )

    if not B.jax_available():
        raise RuntimeError("service bench needs jax")

    topos = TOPOLOGY_LIBRARY
    recipes = enumerate_recipes()
    if n_recipes is not None:
        recipes = recipes[:n_recipes]
    circuits = list(benchmark_suite(scale=scale, only=only).values())
    sweep = ModelTable.monte_carlo(n=n_variants, seed=0)
    kb_mid = sorted(t.total_kb for t in topos)[len(topos) // 2]
    constraint_mix = [
        dict(),
        dict(max_latency_ns=1e4),
        dict(max_memory_kb=kb_mid),
        dict(max_memory_kb=kb_mid, max_latency_ns=1e4),
    ]

    svc = ExplorationService(
        sram_list=topos, recipes=recipes, cache=cache_dir, max_batch=8
    )
    responses = []
    try:
        # -- phase 1: cold -------------------------------------------------
        traces0 = B.trace_counts()
        cold_ms = []
        for c in circuits:
            t0 = time.perf_counter()
            r = svc.explore(ExploreRequest(c))
            cold_ms.append((time.perf_counter() - t0) * 1e3)
            assert r.ok, r.error
            responses.append(r)
        # one cold sweep request (its own (V>1) bucket + model grid)
        t0 = time.perf_counter()
        r = svc.explore(ExploreRequest(circuits[0], model_sweep=sweep))
        cold_sweep_ms = (time.perf_counter() - t0) * 1e3
        assert r.ok, r.error
        responses.append(r)

        # -- phase 2: warm throughput (burst) ------------------------------
        # sweep requests reuse circuits[0]'s warmed (fingerprint, model)
        # grid; every other combination was warmed in the cold phase too
        burst = [
            ExploreRequest(
                circuits[0] if i % 5 == 4 else circuits[i % len(circuits)],
                model_sweep=sweep if i % 5 == 4 else None,
                **constraint_mix[i % len(constraint_mix)],
            )
            for i in range(n_requests)
        ]
        t0 = time.perf_counter()
        rs = [f.result() for f in svc.submit_batch(burst)]
        burst_s = time.perf_counter() - t0
        assert all(r.ok for r in rs), [r.error for r in rs if not r.ok]
        assert all(r.cha_cache_hit and r.grid_cache_hit for r in rs)
        responses.extend(rs)
        rps = n_requests / burst_s

        # -- phase 3: warm latency (sequential) ----------------------------
        warm_ms = []
        for i in range(min(n_requests, 16)):
            req = ExploreRequest(
                circuits[i % len(circuits)],
                **constraint_mix[i % len(constraint_mix)],
            )
            t0 = time.perf_counter()
            r = svc.explore(req)
            warm_ms.append((time.perf_counter() - t0) * 1e3)
            assert r.ok and r.grid_cache_hit
            responses.append(r)

        # -- phase 4: re-rank-only constraint changes ----------------------
        traces_rerank = B.trace_counts()
        for kw in constraint_mix[1:] + [dict(max_latency_ns=123.0)]:
            r = svc.explore(ExploreRequest(circuits[0], **kw))
            assert r.ok and r.grid_cache_hit
            responses.append(r)
        rerank_retrace = sum(B.trace_counts().values()) - sum(
            traces_rerank.values()
        )
        assert rerank_retrace == 0, (
            f"constraint re-ranks recompiled {rerank_retrace} kernels"
        )

        # -- trace accounting: one fused trace per bucket shape ------------
        stats = svc.stats()
        fused_traces = B.trace_counts().get("fused_suite", 0) - traces0.get(
            "fused_suite", 0
        )
        assert fused_traces == stats["distinct_buckets"], (
            f"{fused_traces} fused traces for "
            f"{stats['distinct_buckets']} bucket shapes"
        )
        assert stats["batches"] >= stats["distinct_buckets"]
    finally:
        svc.close()

    # -- phase 5: winner agreement with the offline path -------------------
    # (after the service run so the offline calls' own jit traces cannot
    # pollute the accounting above)
    offline_cache: dict = {}
    n_agree = 0
    for r in responses:
        key = (
            r.fingerprint,
            r.request.max_memory_kb,
            r.request.max_latency_ns,
            r.request.model_sweep is not None,
        )
        if key not in offline_cache:
            offline_cache[key] = explore_request(
                r.request.circuit,
                topos,
                recipes,
                max_memory_kb=r.request.max_memory_kb,
                max_latency_ns=r.request.max_latency_ns,
                model_sweep=r.request.model_sweep,
            )
        off = offline_cache[key]
        assert r.winner.topology.name == off.best.topo.name, (
            r.request.circuit.name, r.winner.topology.name, off.best.topo.name
        )
        assert r.winner.recipe == tuple(off.best.recipe)
        ti = off.grid.topologies.index(off.best.topo)
        ri = off.grid.recipes.index(tuple(off.best.recipe))
        assert r.winner.energy_nj == off.grid.cell(ti, ri).energy_nj
        n_agree += 1

    cold_p50, _ = _percentiles(cold_ms)
    warm_p50, warm_p99 = _percentiles(warm_ms)
    assert warm_p50 < cold_p50 / 10, (
        f"warm p50 {warm_p50:.1f} ms not << cold p50 {cold_p50:.1f} ms"
    )

    summary = {
        "scale": scale,
        "n_circuits": len(circuits),
        "n_recipes": len(recipes),
        "n_requests_total": len(responses),
        "cold_p50_ms": round(cold_p50, 3),
        "cold_sweep_ms": round(cold_sweep_ms, 3),
        "warm_p50_ms": round(warm_p50, 3),
        "warm_p99_ms": round(warm_p99, 3),
        "burst_rps": round(rps, 2),
        "rerank_retrace": rerank_retrace,
        "fused_traces": fused_traces,
        "distinct_buckets": stats["distinct_buckets"],
        "winners_agree": n_agree,
        "cha_hits": stats.get("cha_hits", 0),
        "grid_hits": stats.get("grid_hits", 0),
    }
    if csv is not None:
        csv.add("service/cold_p50", cold_p50 * 1e3,
                f"first-request latency ({len(circuits)} circuits)")
        csv.add("service/warm_p50", warm_p50 * 1e3,
                f"p99={warm_p99:.1f}ms")
        csv.add("service/burst", burst_s * 1e6 / n_requests,
                f"rps={rps:.1f}")
        csv.add("service/traces", 0.0,
                f"fused={fused_traces};buckets={stats['distinct_buckets']};"
                f"rerank_retrace={rerank_retrace}")
        csv.add("service/agreement", 0.0,
                f"winners_agree={n_agree}/{len(responses)}")
    merge_json(out_json, {merge_key: summary})
    print(f"service bench: {summary}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default="BENCH_explorer.json")
    ap.add_argument("--cache", default=None)
    args = ap.parse_args()

    csv = Csv()
    kw: dict = dict(out_json=args.out, cache_dir=args.cache)
    if args.smoke:
        kw.update(scale="tiny", only=SMOKE_CIRCUITS,
                  n_recipes=SMOKE_RECIPES, n_requests=16, n_variants=4)
    if args.requests is not None:
        kw["n_requests"] = args.requests
    run_service_bench(csv, **kw)
    csv.save("bench_service.csv")


if __name__ == "__main__":
    main()
