"""Unified kernel registry: one trace counter, one catalogue of kernels.

Before this module existed, `core/batch.py`, `kernels/aig_sim.py`, and
`launch/system.py` each hand-rolled the same idiom — a module-level
``TRACE_COUNTS`` Counter incremented inside every jitted function body
(the increment runs only while jax *traces*, never on cached dispatch)
plus a ``trace_counts()`` snapshot helper.  The registry replaces the
three copies:

  * `TRACE_COUNTS` — the single process-wide Counter.  The kernel
    modules re-export it, so ``batch.TRACE_COUNTS["fused_suite"]`` and
    friends keep working and all counters share one namespace.
  * `register_counter(name, module)` — declares which module owns a
    counter key.  `trace_counts(module=...)` filters the snapshot to one
    module's kernels, which is exactly what the old per-module
    ``trace_counts()`` returned — the re-exported aliases keep their
    historical scope, so tests that compare whole snapshots are not
    perturbed by *other* modules' kernels tracing in between.
  * `register_kernel(name, module, build)` — additionally hands the
    static analyzer a lazy *representative-shape builder*: a zero-arg
    callable returning a `KernelExample` (a freshly made jit wrapper —
    fresh so its trace cache is empty and the counter increment provably
    runs — plus small-but-representative operands and the static
    arguments).  `repro.analysis.jaxpr_lint` abstract-traces every
    registered kernel through these builders and walks the jaxprs for
    discipline violations; no real device work happens.

The registry deliberately imports nothing from the kernel modules (they
import *it*), and `kernel_specs()` imports the default kernel modules
lazily so plain ``import repro.analysis`` stays cheap.
"""

from __future__ import annotations

import collections
import dataclasses
import importlib
import importlib.util
from typing import Any, Callable, Mapping, Sequence

#: The single per-process jit trace counter.  Kernel bodies bump
#: ``TRACE_COUNTS[<kernel name>]`` as their first traced-side statement;
#: because the Python body only runs while jax traces, the counter
#: counts *compiles*, not calls.
TRACE_COUNTS: "collections.Counter[str]" = collections.Counter()

#: counter key -> owning module (dotted name), filled by `register_counter`.
KERNEL_OWNERS: dict[str, str] = {}

#: Modules whose import registers the real kernels (each module calls
#: `register_counter` / `register_kernel` at import time).  This is also
#: the list `jaxpr_lint` walks by default.
DEFAULT_KERNEL_MODULES: tuple[str, ...] = (
    "repro.core.batch",
    "repro.kernels.aig_sim",
    "repro.kernels.cim_logic",
    "repro.launch.system",
)


def count_trace(kernel: str) -> None:
    """Bump ``kernel``'s trace counter — call this (or the equivalent
    ``TRACE_COUNTS[kernel] += 1``) as the first statement of every jitted
    function body."""
    TRACE_COUNTS[kernel] += 1


def trace_counts(module: str | None = None) -> dict[str, int]:
    """Snapshot of the jit trace counters.

    ``module=None`` returns the global view (every kernel of every
    module); a dotted module name restricts the snapshot to that module's
    registered counters — the scope the old per-module ``trace_counts``
    helpers had, preserved so whole-snapshot comparisons don't race
    against unrelated modules tracing.
    """
    if module is None:
        return dict(TRACE_COUNTS)
    return {
        k: v
        for k, v in TRACE_COUNTS.items()
        if KERNEL_OWNERS.get(k) == module
    }


@dataclasses.dataclass(frozen=True)
class KernelExample:
    """One abstract-traceable kernel instance: a callable (typically a
    *fresh* jit wrapper so tracing re-runs the Python body), positional
    example operands at representative shapes, the static (trace-time)
    keyword arguments, and any donated argument names the production
    wrapper would use."""

    fn: Callable[..., Any]
    args: tuple
    statics: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    donate_argnames: tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A registered kernel: its counter key, owning module, and the lazy
    builder the jaxpr lint layer traces it through.

    ``x64``: trace under ``jax.experimental.enable_x64`` (the float64
    kernels' production context); integer-only kernels register with
    ``x64=False`` and are exempt from the dtype-drift rule (they carry
    no floats to drift).
    """

    name: str
    module: str
    build: Callable[[], KernelExample]
    x64: bool = True


_REGISTRY: "dict[str, KernelSpec]" = {}


def register_counter(name: str, module: str) -> None:
    """Declare ``module`` as the owner of counter key ``name`` (for the
    module-scoped `trace_counts` views).  Idempotent for the same owner;
    two modules claiming one key is a bug."""
    owner = KERNEL_OWNERS.get(name)
    if owner is not None and owner != module:
        raise ValueError(
            f"trace counter {name!r} already registered to {owner}"
        )
    KERNEL_OWNERS[name] = module


def register_kernel(
    name: str,
    module: str,
    build: Callable[[], KernelExample],
    x64: bool = True,
) -> None:
    """Register a kernel for abstract tracing (and declare its counter).

    ``build`` must be cheap to *store* (it is called only when the lint
    layer runs) and must return a `KernelExample` whose ``fn`` is a
    freshly constructed jit wrapper: a fresh wrapper has an empty trace
    cache, so tracing it provably re-runs the Python body and the
    counter-increment check cannot be satisfied by a stale cache entry.
    """
    register_counter(name, module)
    prev = _REGISTRY.get(name)
    if prev is not None and prev.module != module:
        raise ValueError(
            f"kernel {name!r} already registered by {prev.module}"
        )
    _REGISTRY[name] = KernelSpec(name=name, module=module, build=build, x64=x64)


def load_kernel_module(spec: str):
    """Import a kernel module by dotted name or by ``.py`` file path
    (file paths let the lint fixtures register seeded-violation kernels
    without living on the import path)."""
    if spec.endswith(".py"):
        mod_spec = importlib.util.spec_from_file_location(
            "_lint_fixture_" + spec.replace("/", "_").replace(".", "_"), spec
        )
        if mod_spec is None or mod_spec.loader is None:
            raise ImportError(f"cannot load kernel module from {spec}")
        mod = importlib.util.module_from_spec(mod_spec)
        mod_spec.loader.exec_module(mod)
        return mod
    return importlib.import_module(spec)


def kernel_specs(
    modules: Sequence[str] | None = None,
) -> list[KernelSpec]:
    """The registered kernels of ``modules`` (default: the real kernel
    modules), importing each module first so its registrations run.

    File-path entries register under the module name they pass to
    `register_kernel`; the filter keys on that name, so a fixture file
    should use a unique module string and request it back verbatim.
    """
    mods = DEFAULT_KERNEL_MODULES if modules is None else tuple(modules)
    wanted: set[str] = set()
    for m in mods:
        before = dict(_REGISTRY)
        load_kernel_module(m)
        if m.endswith(".py"):
            # A file registers under whatever module string(s) it passes
            # to register_kernel; re-executing it replaces those entries
            # with fresh KernelSpec objects, so identity comparison
            # recovers the file's registrations on repeat loads too.
            wanted.update(
                s.module
                for k, s in _REGISTRY.items()
                if before.get(k) is not s
            )
        else:
            wanted.add(m)
    return sorted(
        (s for s in _REGISTRY.values() if s.module in wanted),
        key=lambda s: (s.module, s.name),
    )
