"""Layer 2: source-AST lint for repo-specific jit-discipline bug classes.

Pure path-based analysis — no imports of the linted code — so CI can run
it on stripped *copies* of kernel modules to prove the rules actually
guard the annotations (remove one ``# repro: host-boundary`` or one
``TRACE_COUNTS[...] += 1`` and the lint run must flip to failing).

Rules (each pins a bug class this repo has actually fixed):

``ast-host-sync-in-jit`` (error)
    A host materializer — ``float(x)``, ``x.item()``, ``np.asarray(x)``,
    ``np.array(x)``, ``jax.device_get(x)`` — lexically inside a
    jit-wrapped function.  Inside a traced body these either fail at
    trace time or, worse, silently bake a traced value into a constant;
    there is no legitimate use, so the annotation comment is only an
    escape hatch for exotic cases.

``ast-host-sync-unannotated`` (error)
    The same materializers in a *device-adjacent* function of a kernel
    module (a file carrying the ``# repro: kernel-module`` marker),
    without a ``# repro: host-boundary`` annotation on the call line or
    the line above.  Device-adjacent = the function's source mentions
    jax/jnp/lax, the lazy-grid internals (``_raw``, ``_LAZY_FIELDS``,
    ``_cell_scalar``), ``enable_x64``, or ``device_get`` — i.e. places
    where an innocuous-looking ``np.asarray`` can be an accidental
    device->host transfer of a whole sweep tensor.  Annotating makes the
    intentional boundary crossings (lazy-grid ``cell()`` gathers, winner
    payload marshaling) explicit and budgeted; everything else is a bug.

``ast-truthy-table`` (error)
    ``x or default`` / ``if x`` / ``not x`` / ``x if ... else`` tests on
    a value whose annotation or construction names a ``__len__``-bearing
    table type (ModelTable, TopologyTable, WorkloadTable, SuiteTable,
    the grid classes).  An *empty* table is falsy, so ``model or
    DEFAULT`` silently swaps in the default — the PR-4 ModelTable bug
    class.  Use ``is None``.

``ast-jit-no-counter`` (error)
    A function wrapped by ``jax.jit`` (decorator, ``functools.partial``
    decorator, or a ``jax.jit(fn)`` call naming a function defined in an
    enclosing scope) whose body never increments the registry trace
    counter (``TRACE_COUNTS[...] += 1`` or ``count_trace(...)``).
    Uncounted kernels are invisible to the one-compile-per-shape
    contract the benches assert; opt out explicitly with
    ``# repro: no-trace-count`` for wrappers that jit *caller-supplied*
    functions.
"""

from __future__ import annotations

import ast
import dataclasses
import os

from .findings import Finding, relpath

#: Marker opting a module into the kernel-module rule set (host-sync
#: annotation discipline).  A comment so stripped copies keep it.
KERNEL_MODULE_MARK = "# repro: kernel-module"
#: Annotation acknowledging an intentional device->host materialization.
HOST_BOUNDARY_MARK = "# repro: host-boundary"
#: Annotation opting a jit wrapper out of the trace-counter rule.
NO_COUNT_MARK = "# repro: no-trace-count"

#: Substrings that make a function "device-adjacent": its body plausibly
#: holds device arrays, so bare materializers need the annotation.
DEVICE_TOKENS = (
    "jnp.",
    "jax.",
    "lax.",
    "._raw(",
    "_LAZY_FIELDS",
    "_cell_scalar",
    "enable_x64",
    "device_get",
)

#: ``__len__``-bearing table/grid classes truthiness is banned on.
TABLE_TYPES = (
    "ModelTable",
    "TopologyTable",
    "WorkloadTable",
    "SuiteTable",
    "ExplorationGrid",
    "VariationGrid",
    "SuiteGrid",
    "SuiteVariationGrid",
)

_NUMPY_NAMES = ("np", "numpy", "jnp")


def _is_jit_expr(node: ast.AST) -> bool:
    """``jax.jit`` / ``jit`` / ``partial(jax.jit, ...)`` /
    ``functools.partial(jax.jit, ...)``."""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    if isinstance(node, ast.Name) and node.id == "jit":
        return True
    if isinstance(node, ast.Call):
        f = node.func
        is_partial = (
            isinstance(f, ast.Attribute) and f.attr == "partial"
        ) or (isinstance(f, ast.Name) and f.id == "partial")
        if is_partial and node.args:
            return _is_jit_expr(node.args[0])
    return False


def _materializer(call: ast.Call) -> "str | None":
    """The host-materializer kind of a call, or None."""
    f = call.func
    if isinstance(f, ast.Name) and f.id == "float" and call.args:
        return "float()"
    if isinstance(f, ast.Attribute):
        if f.attr == "item" and not call.args:
            return ".item()"
        if f.attr in ("asarray", "array"):
            base = f.value
            if isinstance(base, ast.Name) and base.id in ("np", "numpy"):
                return f"np.{f.attr}()"
            # `B.np.asarray` style module aliasing
            if (
                isinstance(base, ast.Attribute)
                and base.attr in ("np", "numpy")
            ):
                return f"np.{f.attr}()"
        if f.attr == "device_get":
            return "jax.device_get()"
    return None


@dataclasses.dataclass
class _Scope:
    """A lexical scope (module or function) and its immediate child
    function definitions, for resolving ``jax.jit(fn)`` by name."""

    node: ast.AST
    parent: "_Scope | None"
    defs: dict
    #: every child def, including same-named methods of sibling classes
    #: (``defs`` keeps first-wins name resolution; the walk must still
    #: visit ALL of them or later classes' methods escape the lint)
    all_defs: list

    def resolve(self, name: str) -> "ast.FunctionDef | None":
        s: "_Scope | None" = self
        while s is not None:
            if name in s.defs:
                return s.defs[name]
            s = s.parent
        return None


def _child_defs(node: ast.AST) -> "tuple[dict, list]":
    """Function defs belonging to ``node``'s scope — looking *through*
    class bodies and control-flow blocks (a method or a conditionally
    defined function is still this scope's child, not a separate one),
    but not into nested functions."""
    by_name = {}
    all_defs = []
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(n.name, n)
            all_defs.append(n)
        elif not isinstance(n, ast.Lambda):
            stack.extend(ast.iter_child_nodes(n))
    all_defs.sort(key=lambda f: f.lineno)
    return by_name, all_defs


def _walk_scopes(node: ast.AST, parent: "_Scope | None" = None):
    by_name, all_defs = _child_defs(node)
    scope = _Scope(node=node, parent=parent, defs=by_name, all_defs=all_defs)
    yield scope
    for fn in scope.all_defs:
        yield from _walk_scopes(fn, scope)


def _scope_calls(scope: _Scope):
    """Call nodes belonging to ``scope`` itself (not nested functions)."""
    skip = set()
    for fn in scope.all_defs:
        for sub in ast.walk(fn):
            skip.add(id(sub))
    for sub in ast.walk(scope.node):
        if id(sub) in skip or sub is scope.node:
            continue
        yield sub


def _ann_names(annotation: "ast.AST | None") -> str:
    if annotation is None:
        return ""
    try:
        return ast.unparse(annotation)
    except Exception:  # pragma: no cover - unparse is total on ast nodes
        return ""


def _tableish_type(text: str) -> bool:
    """Whether an annotation names a table type *as the value's own
    type* — ``ModelTable``, ``Optional[ModelTable]``, ``ModelTable |
    None`` — and not merely as a generic parameter of a container
    (``Mapping[str, WorkloadTable]`` is a dict; its truthiness is
    fine)."""
    t = text.strip().strip("\"'").strip()
    if t.startswith("Optional[") and t.endswith("]"):
        t = t[len("Optional["):-1]
    parts = [p.strip().strip("\"'") for p in t.split("|")]
    parts = [p for p in parts if p and p != "None"]
    return len(parts) == 1 and parts[0] in TABLE_TYPES


class _FileLint:
    def __init__(self, path: str, source: str, root: "str | None"):
        self.path = relpath(path, root)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.is_kernel_module = KERNEL_MODULE_MARK in source
        self.findings: list[Finding] = []
        # ast.walk order is stable but not line-ordered; sort at the end.

    # -- comment-annotation helpers -------------------------------------

    def _line(self, n: int) -> str:
        return self.lines[n - 1] if 1 <= n <= len(self.lines) else ""

    def _annotated(self, lineno: int, mark: str) -> bool:
        return mark in self._line(lineno) or mark in self._line(lineno - 1)

    def _add(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        self.findings.append(
            Finding(
                rule=rule,
                severity="error",
                path=self.path,
                line=line,
                message=message,
                context=self._line(line).strip(),
            )
        )

    # -- jit-wrapper discovery ------------------------------------------

    def _jit_wrapped(self) -> "dict[int, ast.FunctionDef]":
        """id(FunctionDef) -> node for every function this file jit-wraps:
        decorated defs, plus defs named as the first argument of a
        ``jax.jit(...)`` call in an enclosing scope."""
        wrapped: dict[int, ast.FunctionDef] = {}
        self._jit_sites: dict[int, int] = {}  # id(def) -> jit call line
        for scope in _walk_scopes(self.tree):
            for fn in scope.all_defs:
                for dec in fn.decorator_list:
                    if _is_jit_expr(dec):
                        wrapped[id(fn)] = fn
                        self._jit_sites[id(fn)] = dec.lineno
        for scope in _walk_scopes(self.tree):
            for sub in _scope_calls(scope):
                if not isinstance(sub, ast.Call):
                    continue
                if not _is_jit_expr(sub.func) or isinstance(
                    sub.func, ast.Call
                ):
                    # `partial(jax.jit, ...)` as a *call* is a decorator
                    # factory, handled above; here we want jax.jit(fn).
                    continue
                if sub.args and isinstance(sub.args[0], ast.Name):
                    target = scope.resolve(sub.args[0].id)
                    if target is not None:
                        wrapped[id(target)] = target
                        self._jit_sites.setdefault(id(target), sub.lineno)
        return wrapped

    # -- rules -----------------------------------------------------------

    def run(self) -> list[Finding]:
        wrapped = self._jit_wrapped()
        self._rule_jit_no_counter(wrapped)
        self._rule_host_sync(wrapped)
        self._rule_truthy_table()
        self.findings.sort(key=lambda f: (f.line, f.rule))
        return self.findings

    def _rule_jit_no_counter(self, wrapped) -> None:
        for fn in wrapped.values():
            has_counter = False
            for sub in ast.walk(fn):
                if (
                    isinstance(sub, ast.AugAssign)
                    and isinstance(sub.op, ast.Add)
                    and isinstance(sub.target, ast.Subscript)
                ):
                    base = sub.target.value
                    if (
                        isinstance(base, ast.Name)
                        and base.id == "TRACE_COUNTS"
                    ) or (
                        isinstance(base, ast.Attribute)
                        and base.attr == "TRACE_COUNTS"
                    ):
                        has_counter = True
                        break
                if isinstance(sub, ast.Call):
                    f = sub.func
                    if (
                        isinstance(f, ast.Name) and f.id == "count_trace"
                    ) or (
                        isinstance(f, ast.Attribute)
                        and f.attr == "count_trace"
                    ):
                        has_counter = True
                        break
            if has_counter:
                continue
            site = self._jit_sites.get(id(fn), fn.lineno)
            if self._annotated(fn.lineno, NO_COUNT_MARK) or self._annotated(
                site, NO_COUNT_MARK
            ):
                continue
            self._add(
                "ast-jit-no-counter",
                fn,
                f"jit-wrapped function {fn.name!r} never increments the "
                f"registry trace counter (TRACE_COUNTS[...] += 1 / "
                f"count_trace(...)); uncounted kernels escape the "
                f"one-compile-per-shape contract "
                f"(opt out with {NO_COUNT_MARK!r})",
            )

    def _device_adjacent(self, fn: ast.FunctionDef) -> bool:
        try:
            seg = ast.get_source_segment(self.source, fn) or ""
        except Exception:  # pragma: no cover
            seg = ""
        return any(tok in seg for tok in DEVICE_TOKENS)

    def _rule_host_sync(self, wrapped) -> None:
        # inside-jit: always an error, anywhere
        for fn in wrapped.values():
            for sub in ast.walk(fn):
                if not isinstance(sub, ast.Call):
                    continue
                kind = _materializer(sub)
                if kind is None:
                    continue
                if self._annotated(sub.lineno, HOST_BOUNDARY_MARK):
                    continue
                self._add(
                    "ast-host-sync-in-jit",
                    sub,
                    f"{kind} inside the jit-wrapped function "
                    f"{fn.name!r}: a host sync in a traced body either "
                    f"fails at trace time or bakes a traced value into "
                    f"a constant",
                )
        if not self.is_kernel_module:
            return
        wrapped_ids = set(wrapped)
        seen: set[int] = set()
        for scope in _walk_scopes(self.tree):
            fn = scope.node
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if id(fn) in wrapped_ids or not self._device_adjacent(fn):
                continue
            for sub in _scope_calls(scope):
                if not isinstance(sub, ast.Call) or id(sub) in seen:
                    continue
                kind = _materializer(sub)
                if kind is None:
                    continue
                seen.add(id(sub))
                # nested-in-jit calls already reported above
                if self._annotated(sub.lineno, HOST_BOUNDARY_MARK):
                    continue
                self._add(
                    "ast-host-sync-unannotated",
                    sub,
                    f"{kind} in device-adjacent function {fn.name!r} "
                    f"of a kernel module: if the operand is a device "
                    f"array this is a hidden device->host transfer — "
                    f"annotate the intentional boundary with "
                    f"{HOST_BOUNDARY_MARK!r} or keep the value on "
                    f"device",
                )

    def _rule_truthy_table(self) -> None:
        for scope in _walk_scopes(self.tree):
            tableish = self._tableish_names(scope)
            if not tableish:
                continue
            for sub in _scope_calls(scope):
                name = self._truthiness_target(sub)
                if name is not None and name in tableish:
                    self._add(
                        "ast-truthy-table",
                        sub,
                        f"truthiness test on {name!r}, a __len__-bearing "
                        f"table ({tableish[name]}): an empty table is "
                        f"falsy, so `or`-defaults/`if` silently replace "
                        f"it — use `is None`",
                    )

    def _tableish_names(self, scope: _Scope) -> dict[str, str]:
        """Names in ``scope`` whose annotation or construction names a
        table type."""
        node = scope.node
        out: dict[str, str] = {}
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = list(node.args.args) + list(node.args.kwonlyargs)
            if node.args.vararg:
                args.append(node.args.vararg)
            for a in args:
                ann = _ann_names(a.annotation)
                if _tableish_type(ann):
                    out[a.arg] = ann
        for sub in _scope_calls(scope):
            targets: list[ast.AST] = []
            value = None
            if isinstance(sub, ast.Assign):
                targets, value = sub.targets, sub.value
            elif isinstance(sub, ast.AnnAssign) and sub.target is not None:
                ann = _ann_names(sub.annotation)
                if _tableish_type(ann) and isinstance(
                    sub.target, ast.Name
                ):
                    out[sub.target.id] = ann
                targets, value = [sub.target], sub.value
            if value is None or not isinstance(value, ast.Call):
                continue
            ctor = value.func
            ctor_name = ""
            if isinstance(ctor, ast.Name):
                ctor_name = ctor.id
            elif isinstance(ctor, ast.Attribute):
                # ModelTable.from_models(...), TopologyTable.from_...
                base = ctor.value
                if isinstance(base, ast.Name):
                    ctor_name = base.id
            if ctor_name in TABLE_TYPES:
                for t in targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = ctor_name
        return out

    @staticmethod
    def _truthiness_target(node: ast.AST) -> "str | None":
        """The bare name whose truthiness ``node`` tests, if any."""
        if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.Or):
            first = node.values[0]
            if isinstance(first, ast.Name):
                return first.id
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            if isinstance(node.operand, ast.Name):
                return node.operand.id
        if isinstance(node, (ast.If, ast.IfExp)):
            if isinstance(node.test, ast.Name):
                return node.test.id
        if isinstance(node, ast.While) and isinstance(node.test, ast.Name):
            return node.test.id
        return None


def lint_file(path: str, root: "str | None" = None) -> list[Finding]:
    with open(path) as f:
        source = f.read()
    try:
        return _FileLint(path, source, root).run()
    except SyntaxError as e:
        return [
            Finding(
                rule="ast-syntax-error",
                severity="error",
                path=relpath(path, root),
                line=e.lineno or 0,
                message=f"cannot parse: {e.msg}",
                context="",
            )
        ]


def lint_paths(
    paths: "list[str]", root: "str | None" = None
) -> list[Finding]:
    """Lint ``paths`` (files or directory trees of ``.py`` files)."""
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [
                    d for d in dirnames if d != "__pycache__"
                ]
                files.extend(
                    os.path.join(dirpath, f)
                    for f in sorted(filenames)
                    if f.endswith(".py")
                )
        else:
            files.append(p)
    out: list[Finding] = []
    for f in sorted(set(files)):
        out.extend(lint_file(f, root))
    return out
