"""Layer 1: abstract-trace registered kernels and lint their jaxprs.

Every kernel registered through `repro.analysis.registry` carries a
representative-shape builder.  This layer calls the builder, abstract
traces the fresh jit wrapper with ``jax.make_jaxpr`` (no device work —
only the Python body runs, exactly as it would during a production
compile), and walks the resulting ClosedJaxpr recursively (into pjit /
scan / while / cond sub-jaxprs) checking the discipline contracts the
benches otherwise only catch at runtime:

``jaxpr-dtype-drift`` (error)
    A ``convert_element_type`` to float32/float16/bfloat16 inside an
    x64 kernel.  The engine's accuracy story is float64 end-to-end
    (``enable_x64``); a stray f32 literal or ``np.float32`` table column
    silently halves precision for the whole downstream dataflow.

``jaxpr-host-callback`` (error)
    A callback primitive (``pure_callback`` / ``io_callback`` /
    ``debug_callback``) inside the traced body.  Callbacks force a host
    round-trip per dispatch — the exact cost the one-trace discipline
    exists to avoid.

``jaxpr-baked-const`` (error)
    A constant captured by the jaxpr bigger than ``const_bytes``
    (default 64 KiB).  Large closed-over arrays are the recompile-hazard
    class PRs 3 and 8 removed by hand: they hash into the compile cache
    key, so every new table re-traces.  Pass them as operands instead.

``jaxpr-static-unhashable`` (error)
    A declared static argument whose example value is unhashable — jit
    would raise at call time; the registry catches it at lint time.

``jaxpr-donate-cpu`` (error)
    Donated buffers declared while the active backend is ``cpu``: XLA's
    CPU backend ignores donation and jax warns per call.  Production
    wrappers must gate donation on the backend (as ``_jit_fused`` does).

``jaxpr-counter-missing`` (error)
    Tracing the *fresh* wrapper did not bump the kernel's registered
    trace counter.  Because the builder returns a wrapper with an empty
    compile cache, tracing provably re-runs the Python body — so a
    missing bump means the body lost its ``TRACE_COUNTS[...] += 1`` /
    ``count_trace(...)`` first statement and the kernel is invisible to
    the one-compile-per-shape accounting.

``jaxpr-trace-error`` (error)
    The kernel failed to abstract-trace at its own representative
    shapes — whatever the cause, the example is broken and the kernel
    is unverifiable.
"""

from __future__ import annotations

import functools
from typing import Sequence

from .findings import Finding
from .registry import TRACE_COUNTS, KernelSpec, kernel_specs

#: Float dtypes that signal precision drift inside an x64 kernel.
_DRIFT_DTYPES = ("float32", "float16", "bfloat16")


def _finding(spec: KernelSpec, rule: str, detail: str, message: str) -> Finding:
    return Finding(
        rule=rule,
        severity="error",
        path=spec.module,
        line=0,
        message=f"kernel {spec.name!r}: {message}",
        context=f"{spec.name}: {detail}",
    )


def _walk_jaxprs(closed):
    """Yield ``closed`` and every sub-ClosedJaxpr reachable through eqn
    params (pjit bodies, scan/while carries, cond branches, ...)."""
    import jax.core  # noqa: F401  (ensures jax is importable here)

    seen: set[int] = set()
    stack = [closed]
    while stack:
        cj = stack.pop()
        if id(cj) in seen:
            continue
        seen.add(id(cj))
        yield cj
        jaxpr = getattr(cj, "jaxpr", cj)
        for eqn in jaxpr.eqns:
            for val in eqn.params.values():
                for sub in _iter_closed(val):
                    stack.append(sub)


def _iter_closed(val):
    if hasattr(val, "jaxpr") and hasattr(val, "consts"):
        yield val
    elif hasattr(val, "eqns"):  # open Jaxpr — wrap-free walk
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _iter_closed(v)


def _const_nbytes(const) -> int:
    nbytes = getattr(const, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    size = getattr(const, "size", None)
    itemsize = getattr(getattr(const, "dtype", None), "itemsize", None)
    if size is not None and itemsize is not None:
        return int(size) * int(itemsize)
    return 0


def lint_kernel(
    spec: KernelSpec, const_bytes: int = 65536
) -> list[Finding]:
    import jax
    from jax.experimental import enable_x64

    findings: list[Finding] = []

    try:
        example = spec.build()
    except Exception as e:  # registry builder itself broke
        return [
            _finding(
                spec,
                "jaxpr-trace-error",
                "build",
                f"representative-shape builder raised {type(e).__name__}: {e}",
            )
        ]

    # -- static hashability: jit would raise at dispatch; catch it here.
    for key, val in example.statics.items():
        try:
            hash(val)
        except TypeError:
            findings.append(
                _finding(
                    spec,
                    "jaxpr-static-unhashable",
                    f"static {key}",
                    f"static argument {key!r} has unhashable example "
                    f"value of type {type(val).__name__} — jit static "
                    f"arguments key the compile cache and must hash",
                )
            )

    # -- donation on a backend that ignores it.
    if example.donate_argnames and jax.default_backend() == "cpu":
        findings.append(
            _finding(
                spec,
                "jaxpr-donate-cpu",
                f"donate {','.join(example.donate_argnames)}",
                f"declares donated buffers "
                f"{example.donate_argnames} while the active backend "
                f"is cpu, which ignores donation (and jax warns per "
                f"call) — gate donation on the backend",
            )
        )

    if findings:
        # unhashable statics make the trace below raise confusingly;
        # report what we know and stop.
        if any(f.rule == "jaxpr-static-unhashable" for f in findings):
            return findings

    fn = example.fn
    if example.statics:
        fn = functools.partial(fn, **dict(example.statics))

    before = TRACE_COUNTS[spec.name]
    ctx = enable_x64() if spec.x64 else _null_ctx()
    try:
        with ctx:
            closed = jax.make_jaxpr(fn)(*example.args)
    except Exception as e:
        findings.append(
            _finding(
                spec,
                "jaxpr-trace-error",
                "trace",
                f"abstract trace failed with {type(e).__name__}: {e}",
            )
        )
        return findings

    if TRACE_COUNTS[spec.name] <= before:
        findings.append(
            _finding(
                spec,
                "jaxpr-counter-missing",
                "counter",
                "tracing a fresh wrapper did not bump "
                f"TRACE_COUNTS[{spec.name!r}] — the jitted body must "
                "increment its registered trace counter first",
            )
        )

    drift_seen: set[str] = set()
    callback_seen: set[str] = set()
    for cj in _walk_jaxprs(closed):
        jaxpr = getattr(cj, "jaxpr", cj)
        for const in getattr(cj, "consts", ()):
            nbytes = _const_nbytes(const)
            if nbytes > const_bytes:
                shape = getattr(const, "shape", ())
                dtype = getattr(const, "dtype", "?")
                detail = f"const {shape} {dtype}"
                findings.append(
                    _finding(
                        spec,
                        "jaxpr-baked-const",
                        detail,
                        f"bakes a {nbytes}-byte constant "
                        f"(shape {shape}, {dtype}) into the jaxpr — "
                        f"closed-over arrays key the compile cache and "
                        f"re-trace per table; pass as a traced operand",
                    )
                )
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if "callback" in prim and prim not in callback_seen:
                callback_seen.add(prim)
                findings.append(
                    _finding(
                        spec,
                        "jaxpr-host-callback",
                        prim,
                        f"contains host callback primitive {prim!r} — "
                        f"a host round-trip per dispatch defeats the "
                        f"one-trace pipeline",
                    )
                )
            if spec.x64 and prim == "convert_element_type":
                new_dtype = str(eqn.params.get("new_dtype", ""))
                if new_dtype in _DRIFT_DTYPES and new_dtype not in drift_seen:
                    drift_seen.add(new_dtype)
                    findings.append(
                        _finding(
                            spec,
                            "jaxpr-dtype-drift",
                            f"convert->{new_dtype}",
                            f"converts to {new_dtype} inside an x64 "
                            f"kernel — the engine is float64 end-to-end; "
                            f"a sub-f64 cast silently halves precision "
                            f"downstream",
                        )
                    )
    return findings


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def lint_kernels(
    modules: "Sequence[str] | None" = None, const_bytes: int = 65536
) -> list[Finding]:
    """Lint every kernel registered by ``modules`` (default: the real
    kernel modules)."""
    out: list[Finding] = []
    for spec in kernel_specs(modules):
        out.extend(lint_kernel(spec, const_bytes=const_bytes))
    return out
