"""Static analysis of the repo's jit discipline.

The exploration engine's performance contract is enforced at runtime by
trace counters and bench assertions; this package proves the same
invariants *before* runtime:

  * `registry`   — the unified kernel registry: the single TRACE_COUNTS
    counter every kernel module increments, per-kernel ownership
    metadata, and representative-shape builders for abstract tracing;
  * `jaxpr_lint` — layer 1: abstract-traces every registered kernel and
    walks the ClosedJaxpr for dtype drift off float64, host callbacks
    inside jit, oversized baked constants (recompile hazards), and
    donation / static-argnum problems;
  * `ast_lint`   — layer 2: walks source ASTs for repo-specific bug
    classes (unannotated host syncs, truthiness on `__len__`-bearing
    tables, jit wrappers that skip the trace counter);
  * `lint`       — the CLI (``python -m repro.analysis.lint``) with a
    checked-in baseline for grandfathered findings; CI fails on any new
    violation.
"""

from .registry import (  # noqa: F401 - re-exported API
    TRACE_COUNTS,
    count_trace,
    kernel_specs,
    register_counter,
    register_kernel,
    trace_counts,
)
