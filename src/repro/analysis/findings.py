"""Finding records, severity, and the grandfathered-findings baseline.

A finding is one discipline violation with a stable identity: the rule,
the repo-relative file (or the kernel's owning module for jaxpr-layer
findings), and a *context* string — the stripped source line for AST
findings, the kernel/detail pair for jaxpr findings.  Line numbers are
reported for navigation but excluded from the identity, so unrelated
edits moving code around don't churn the baseline.

The baseline file is a checked-in JSON list of finding keys.  The lint
CLI fails only on findings whose key is not baselined — new violations
fail CI immediately, grandfathered ones are visible (reported as
``baselined``) but don't block until someone burns them down.
"""

from __future__ import annotations

import dataclasses
import json
import os

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    severity: str        # "error" | "warning"
    path: str            # repo-relative file, or dotted module for kernels
    line: int            # 1-based; 0 = whole-module / registry finding
    message: str
    context: str = ""    # stripped source line / kernel detail (identity)

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.context)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.severity}: [{self.rule}] {self.message}"


def relpath(path: str, root: "str | None" = None) -> str:
    """Repo-relative POSIX-style path when ``path`` is under ``root``;
    the (normalized) input otherwise — keeps baseline keys stable across
    checkouts."""
    p = os.path.abspath(path)
    if root:
        r = os.path.abspath(root)
        if p == r or p.startswith(r + os.sep):
            p = os.path.relpath(p, r)
    return p.replace(os.sep, "/")


def load_baseline(path: "str | None") -> set[tuple[str, str, str]]:
    """The baselined finding keys; an absent/None file is an empty
    baseline (nothing grandfathered)."""
    if not path or not os.path.exists(path):
        return set()
    with open(path) as f:
        raw = json.load(f)
    out: set[tuple[str, str, str]] = set()
    for entry in raw:
        out.add((entry["rule"], entry["path"], entry.get("context", "")))
    return out


def write_baseline(path: str, findings: "list[Finding]") -> None:
    entries = sorted(
        {f.key() for f in findings}
    )
    with open(path, "w") as f:
        json.dump(
            [
                dict(rule=r, path=p, context=c)
                for r, p, c in entries
            ],
            f,
            indent=1,
        )
        f.write("\n")


def split_baselined(
    findings: "list[Finding]", baseline: set[tuple[str, str, str]]
) -> "tuple[list[Finding], list[Finding]]":
    """(new, grandfathered) partition of ``findings`` against a baseline."""
    new, old = [], []
    for f in findings:
        (old if f.key() in baseline else new).append(f)
    return new, old
