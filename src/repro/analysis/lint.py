"""CLI: ``python -m repro.analysis.lint [paths...]``.

Runs both analyzer layers — the jaxpr lint over the registered kernels
and the AST lint over the given paths (default ``src/``) — diffs the
findings against the checked-in baseline, and exits non-zero iff any
*new* (non-grandfathered) finding exists.

Flags:
  ``--format text|json``   output format (json includes counts + findings)
  ``--baseline PATH``      baseline file (default
                           ``src/repro/analysis/baseline.json``;
                           ``--baseline ""`` disables baselining)
  ``--write-baseline``     rewrite the baseline to grandfather the
                           current findings instead of failing
  ``--no-jaxpr``           skip layer 1 (no kernel imports / tracing)
  ``--no-ast``             skip layer 2
  ``--kernels-from M``     kernel module (dotted name or ``.py`` path)
    to lint instead of the default registry modules; repeatable
  ``--const-bytes N``      baked-constant size threshold (default 65536)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .findings import load_baseline, split_baselined, write_baseline

_DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def _repo_root() -> str:
    # src/repro/analysis/lint.py -> repo root is three dirs above src/
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jit-discipline static analyzer (jaxpr + AST layers)",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files/directories for the AST layer (default: src/)",
    )
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=_DEFAULT_BASELINE)
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--no-jaxpr", action="store_true")
    ap.add_argument("--no-ast", action="store_true")
    ap.add_argument(
        "--kernels-from",
        action="append",
        default=None,
        metavar="MODULE",
        help="kernel module (dotted or .py path) for the jaxpr layer",
    )
    ap.add_argument("--const-bytes", type=int, default=65536)
    args = ap.parse_args(argv)

    root = _repo_root()
    findings = []

    if not args.no_ast:
        from .ast_lint import lint_paths

        paths = args.paths or [os.path.join(root, "src")]
        findings.extend(lint_paths(paths, root=root))

    if not args.no_jaxpr:
        from .jaxpr_lint import lint_kernels

        findings.extend(
            lint_kernels(args.kernels_from, const_bytes=args.const_bytes)
        )

    baseline_path = args.baseline or None
    if args.write_baseline:
        if not baseline_path:
            print("--write-baseline requires --baseline", file=sys.stderr)
            return 2
        write_baseline(baseline_path, findings)
        print(
            f"baseline written: {len(findings)} finding(s) grandfathered "
            f"-> {baseline_path}"
        )
        return 0

    baseline = load_baseline(baseline_path)
    new, grandfathered = split_baselined(findings, baseline)

    if args.format == "json":
        print(
            json.dumps(
                {
                    "new": [f.as_dict() for f in new],
                    "baselined": [f.as_dict() for f in grandfathered],
                    "counts": {
                        "new": len(new),
                        "baselined": len(grandfathered),
                        "total": len(findings),
                    },
                },
                indent=1,
            )
        )
    else:
        for f in new:
            print(f.format())
        for f in grandfathered:
            print(f"{f.format()} [baselined]")
        print(
            f"{len(new)} new finding(s), "
            f"{len(grandfathered)} baselined, {len(findings)} total"
        )
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
