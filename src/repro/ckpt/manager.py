"""Fault-tolerant checkpointing with cross-mesh (elastic) restore.

Design (1000+-node posture):
  * atomic: write to ``step_N.tmp`` then os.rename -> a reader never sees a
    torn checkpoint; crash mid-save leaves the previous checkpoint intact.
  * keep-N GC with monotonic step metadata.
  * async: saves run on a writer thread (the train loop donates a host
    snapshot and keeps stepping); ``wait()`` joins before exit.
  * mesh-free format: arrays are saved as host numpy keyed by pytree path,
    so restore can apply a *different* mesh/sharding (elastic re-scale,
    pod loss) — restore takes target shardings and device_puts shard-wise.
  * integrity: a manifest (array name -> shape/dtype) is verified on load.

On a real multi-host cluster each host writes only the shards it owns
(process-local addressable shards); here (single host) jax.device_get
gathers fully — the format is identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


# numpy's savez cannot store ml_dtypes (bfloat16, fp8): view them as a
# same-width integer dtype and record the logical dtype in the manifest.
_ENCODE_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _ENCODE_VIEW:
        return arr.view(_ENCODE_VIEW[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _ENCODE_VIEW:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, name))
    return arr


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, meta: dict | None = None) -> None:
        """Snapshot ``tree`` to host memory and publish it as ``step``.

        With ``async_save`` the call returns immediately: each writer
        thread queues behind the previous in-flight writer (joining it
        before touching disk), so saves publish in call order, ``_gc``
        never races a half-published step, and ``wait()`` drains the
        whole chain by joining only the newest writer.  The handoff is
        lock-protected, so concurrent ``save()`` callers cannot lose a
        writer thread.
        """
        flat, _ = _flatten(tree)
        host_arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self.async_save:
            with self._lock:
                prev = self._thread
                t = threading.Thread(
                    target=self._write_after,
                    args=(prev, step, host_arrays, meta or {}),
                    daemon=True,
                )
                self._thread = t
                t.start()
        else:
            self._write(step, host_arrays, meta or {})

    def _write_after(self, prev: threading.Thread | None, step: int,
                     arrays: dict, meta: dict) -> None:
        if prev is not None:
            prev.join()  # queue behind the in-flight writer
        self._write(step, arrays, meta)

    def _write(self, step: int, arrays: dict, meta: dict) -> None:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        encoded, manifest = {}, {}
        for k, v in arrays.items():
            enc, name = _encode(v)
            encoded[k] = enc
            manifest[k] = dict(shape=list(v.shape), dtype=name)
        np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(dict(step=step, time=time.time(), meta=meta,
                           manifest=manifest), f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        """Join the newest writer; since every writer joins its
        predecessor first, this transitively drains every pending save."""
        with self._lock:
            t = self._thread
        if t is not None and t.is_alive():
            t.join()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like_tree, step: int | None = None, shardings=None):
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional pytree (same structure) of NamedShardings —
        arrays are device_put with them, enabling restore onto a different
        mesh than the one that saved (elastic scaling).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = _flatten(like_tree)
        vals = []
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten(shardings)
        for key, like in flat.items():
            if key not in data:
                raise KeyError(f"checkpoint missing array {key!r}")
            want = meta["manifest"][key]
            arr = _decode(data[key], want["dtype"])
            if list(arr.shape) != want["shape"]:
                raise ValueError(f"manifest mismatch for {key}")
            if hasattr(like, "shape") and tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs model {like.shape}"
                )
            if shard_flat is not None:
                vals.append(jax.device_put(arr, shard_flat[key]))
            else:
                vals.append(jax.numpy.asarray(arr))
        # preserve ordering of flatten
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_tree), vals
        ), meta

    def restore_or_none(self, like_tree, shardings=None):
        try:
            return self.restore(like_tree, shardings=shardings)
        except FileNotFoundError:
            return None
