"""Fault-tolerant checkpointing with cross-mesh (elastic) restore.

Design (1000+-node posture):
  * atomic: write to ``step_N.tmp`` then os.replace/os.rename -> a reader
    never sees a torn checkpoint; crash mid-save leaves the previous
    checkpoint intact.
  * keep-N GC with monotonic step metadata.
  * async: saves are enqueued to ONE persistent writer thread (the train
    loop donates a host snapshot and keeps stepping); ``wait()`` drains
    the queue.  A single long-lived writer matters for latency: spawning
    a thread per save makes ``Thread.start()`` block on the GIL behind
    the previous (CPU-bound) writer, which can cost a full switch
    interval per save.
  * three on-disk layouts:
      - **wal** (opt-in, ``wal=True``; the sweep journal): every publish
        is ONE append of a crc-framed record to ``journal.wal`` through
        a long-lived fd.  File creation and rename cost hundreds of
        microseconds on this class of filesystem while an append into an
        open fd costs tens, so the log is the only layout whose publish
        fits the sweep bench's <2% overhead budget.  Appends use
        ``O_APPEND`` (one ``write(2)`` per frame, safe across fds); a
        crash mid-append leaves a torn tail that the reader skips by
        re-syncing on the next frame magic, so records appended after a
        torn frame are still recovered.  ``remove`` appends a tombstone.
      - **compact** (small payloads when ``wal=False``): one ``step_N``
        *file* — magic + JSON header (meta + manifest + crc32) + raw
        ``np.lib.format`` array records — published with a single
        buffered write and ``os.replace`` of a pre-created spool file.
      - **directory** (large payloads, training states): ``step_N/``
        with ``arrays.npz`` + ``meta.json``, streamed by ``np.savez``.
    Readers are layout-agnostic: per-step files/dirs and the log are
    merged, and every layout validates a manifest (the wal/compact ones
    additionally a payload crc32) before trusting any array.
  * mesh-free format: arrays are saved as host numpy keyed by pytree path,
    so restore can apply a *different* mesh/sharding (elastic re-scale,
    pod loss) — restore takes target shardings and device_puts shard-wise.
  * integrity: a manifest (array name -> shape/dtype) is verified on load;
    the compact layout additionally carries a crc32 of the array payload.

On a real multi-host cluster each host writes only the shards it owns
(process-local addressable shards); here (single host) jax.device_get
gathers fully — the format is identical.
"""

from __future__ import annotations

import io
import json
import os
import queue
import shutil
import threading
import time
import zipfile
import zlib

import jax
import numpy as np

from repro.runtime import faults


class CheckpointCorruptError(RuntimeError):
    """A checkpoint/journal step exists on disk but cannot be trusted:
    unreadable metadata, unreadable arrays, or a manifest mismatch.
    Readers treat the step as absent (re-do the work) rather than
    consuming torn state."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


# numpy's savez cannot store ml_dtypes (bfloat16, fp8): view them as a
# same-width integer dtype and record the logical dtype in the manifest.
_ENCODE_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _ENCODE_VIEW:
        return arr.view(_ENCODE_VIEW[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _ENCODE_VIEW:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, name))
    return arr


_MAGIC = b"RCKPT1\n"
_WMAGIC = b"RJRNL1\n"  # frame magic of the append-only journal log
_COMPACT_LIMIT = 4 << 20  # payloads up to 4 MiB use the single-file layout
_IDLE_S = 60.0  # writer thread parks itself after this much idle time


class _DirWriter:
    """One async writer (queue + lazy thread) per checkpoint directory,
    shared process-wide.  Sharing per directory means a *later*
    `CheckpointManager` on the same directory drains publishes enqueued
    by an earlier one — the journal-resume scan does exactly that — so
    async saves need no drain barrier on the success path: the tail
    publish overlaps whatever the caller does next, and anyone who needs
    the entries on disk calls ``wait()`` first."""

    def __init__(self) -> None:
        self.q: queue.Queue = queue.Queue()
        self.thread: threading.Thread | None = None
        self.exc: BaseException | None = None

    def put(self, item) -> None:
        with _WRITERS_LOCK:
            self.q.put(item)
            if self.thread is None or not self.thread.is_alive():
                self.thread = threading.Thread(target=self._loop, daemon=True)
                self.thread.start()

    def _loop(self) -> None:
        while True:
            try:
                mgr, step, arrays, meta = self.q.get(timeout=_IDLE_S)
            except queue.Empty:
                with _WRITERS_LOCK:
                    if self.q.empty():
                        self.thread = None
                        return
                continue
            try:
                mgr._write(step, arrays, meta)
            except BaseException as e:  # surfaced at the next drain()
                self.exc = e
            finally:
                self.q.task_done()
            if self.q.empty():
                # Pre-create the next spool file only once the queue is
                # dry, after task_done: a wait()-ing caller is released
                # before we pay the file-create, and back-to-back
                # publishes are not serialized behind it.
                mgr._replenish_spool()

    def drain(self) -> None:
        self.q.join()
        exc, self.exc = self.exc, None
        if exc is not None:
            raise exc


_WRITERS: dict[str, _DirWriter] = {}
_WRITERS_LOCK = threading.Lock()


def _dir_writer(directory: str) -> _DirWriter:
    key = os.path.realpath(directory)
    with _WRITERS_LOCK:
        w = _WRITERS.get(key)
        if w is None:
            w = _WRITERS[key] = _DirWriter()
        return w


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_save: bool = True, wal: bool = False,
                 defer_snapshot: bool = False):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self.wal = wal
        # defer_snapshot: enqueue device arrays as-is and let the writer
        # thread run ``jax.device_get`` — the device wait releases the
        # GIL, so the transfer genuinely overlaps the caller's next
        # dispatch instead of stalling it (a synchronous device_get on
        # the save path forces each lazy payload eagerly).  Only safe
        # when the saved arrays are not donated/mutated afterwards;
        # functional pipelines like the sweep journal qualify, training
        # loops with buffer donation do not (keep the default).
        self.defer_snapshot = defer_snapshot
        os.makedirs(directory, exist_ok=True)
        # In-memory view of published steps so the per-publish GC does
        # not pay a listdir (syscalls dominate the compact publish).
        # Seeded from disk on first use; coherent for the single-writer
        # directories the manager owns.
        self._known: set[int] | None = None
        # Append-only log state (written when wal=True; *read* always,
        # so any manager on the directory sees log-published steps).
        self._wal_path = os.path.join(directory, "journal.wal")
        self._wal_fd = None
        self._wal_lock = threading.Lock()
        self._wal_cache: "dict[int, tuple[dict, bytes]] | None" = None
        # Compact publishes rename a pre-created spool file: creating a
        # file costs ~20x a write into an existing one on ext4 here, so
        # the spool is made ahead of time (here, and by the writer after
        # each publish) and the publish itself is truncate-write+rename.
        self._spool = os.path.join(directory, "journal.spool")
        self._replenish_spool()
        self._w = _dir_writer(directory) if async_save else None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, meta: dict | None = None) -> None:
        """Snapshot ``tree`` to host memory and publish it as ``step``.

        With ``async_save`` the call returns immediately: the snapshot is
        enqueued to the directory's shared writer thread, so saves
        publish in call order, ``_gc`` never races a half-published
        step, and ``wait()`` drains the queue.  The enqueue itself is
        just a host snapshot plus a queue put — no thread spawn, no
        join — so it stays off the caller's critical path.  A write
        failure is re-raised at the next ``wait()``.
        """
        flat, _ = _flatten(tree)
        if not (self.defer_snapshot and self._w is not None):
            flat = {
                k: (np.asarray(jax.device_get(v)) if isinstance(v, jax.Array)
                    else np.asarray(v))
                for k, v in flat.items()
            }
        if self._w is not None:
            self._w.put((self, step, flat, meta or {}))
        else:
            self._write(step, flat, meta or {})

    def _replenish_spool(self) -> None:
        if self.wal:
            return  # log appends reuse one fd; no spool file needed
        try:
            open(self._spool, "ab").close()
        except OSError:
            pass  # the publish open("wb") will create it instead

    def _write(self, step: int, arrays: dict, meta: dict) -> None:
        final = os.path.join(self.dir, f"step_{step}")
        faults.inject("journal.write", detail=final)
        tmp = final + ".tmp"
        # Deferred snapshots arrive as device arrays; materialize here
        # (on the writer thread the device wait releases the GIL).  One
        # batched device_get, not one per array: the per-call dispatch
        # overhead is a measurable slice of the publish budget.
        dev = {k: v for k, v in arrays.items() if isinstance(v, jax.Array)}
        got = jax.device_get(dev) if dev else {}
        arrays = {
            k: np.asarray(got[k] if k in got else v)
            for k, v in arrays.items()
        }
        encoded, manifest, total = {}, {}, 0
        for k, v in arrays.items():
            enc, name = _encode(v)
            encoded[k] = enc
            manifest[k] = dict(shape=list(v.shape), dtype=name)
            total += enc.nbytes
        doc = dict(step=step, time=time.time(), meta=meta, manifest=manifest)
        if total <= _COMPACT_LIMIT and self.wal:
            self._write_wal(step, encoded, doc, final)
        elif total <= _COMPACT_LIMIT:
            self._write_compact(final, encoded, doc)
            if self._w is None:  # sync mode: no writer to replenish it
                self._replenish_spool()
        else:
            self._write_dir(tmp, final, encoded, doc)
        self._gc(step)

    def _wal_append(self, frame: bytes) -> None:
        with self._wal_lock:
            if self._wal_fd is None:
                self._wal_fd = open(self._wal_path, "ab")
            self._wal_fd.write(frame)  # O_APPEND: one atomic write(2)
            self._wal_fd.flush()

    def _write_wal(self, step: int, encoded: dict, doc: dict,
                   final: str) -> None:
        order = list(encoded)
        # Raw C-order bytes, not np.lib.format records: shapes/dtypes
        # already live in the manifest, and skipping the per-array
        # header serialization keeps the whole publish ~100us.
        payload = b"".join(np.asarray(encoded[k]).tobytes() for k in order)
        head = dict(doc, format="wal1", order=order, plen=len(payload),
                    crc32=zlib.crc32(payload))
        hb = json.dumps(head).encode()
        self._wal_append(
            b"".join([_WMAGIC, len(hb).to_bytes(4, "little"), hb, payload])
        )
        with self._wal_lock:
            if self._wal_cache is not None:
                self._wal_cache[step] = (head, payload)
        # Chaos hook: a torn/corrupt append that survives the flush —
        # the reader must skip the damaged frame via the crc check and
        # re-sync on the next magic, never consume it.
        faults.corrupt_file("journal.write", self._wal_path, detail=final)

    def _wal_evict(self, step: int) -> None:
        hb = json.dumps(dict(evict=step, time=time.time())).encode()
        self._wal_append(
            b"".join([_WMAGIC, len(hb).to_bytes(4, "little"), hb])
        )
        with self._wal_lock:
            if self._wal_cache is not None:
                self._wal_cache.pop(step, None)

    def _scan_wal(self) -> "dict[int, tuple[dict, bytes]]":
        """Parse ``journal.wal`` into ``{step: (head, payload)}``.

        Torn or corrupt frames (crash mid-append, bad sector) are
        skipped by re-syncing on the next frame magic, so a damaged
        frame never hides records appended after it.  Tombstone frames
        drop earlier steps; the last record for a step wins.  The parse
        is cached — this manager's own appends keep it coherent."""
        if self._wal_cache is not None:
            return self._wal_cache
        out: "dict[int, tuple[dict, bytes]]" = {}
        try:
            with open(self._wal_path, "rb") as f:
                blob = f.read()
        except OSError:
            self._wal_cache = out
            return out
        i, n = 0, len(blob)
        while i < n:
            j = blob.find(_WMAGIC, i)
            if j < 0:
                break
            k = j + len(_WMAGIC)
            try:
                hlen = int.from_bytes(blob[k:k + 4], "little")
                if not 0 < hlen <= n - k - 4:
                    raise ValueError("torn header")
                head = json.loads(blob[k + 4:k + 4 + hlen].decode())
                plen = int(head.get("plen", 0))
                start = k + 4 + hlen
                if start + plen > n:
                    raise ValueError("torn payload")
                payload = blob[start:start + plen]
                if "evict" in head:
                    out.pop(int(head["evict"]), None)
                elif zlib.crc32(payload) != head.get("crc32"):
                    raise ValueError("payload crc mismatch")
                else:
                    out[int(head["step"])] = (head, payload)
                i = start + plen
            except (ValueError, KeyError, TypeError, UnicodeDecodeError,
                    json.JSONDecodeError):
                i = k  # damaged frame: re-sync at the next magic
        self._wal_cache = out
        return out

    def _read_wal_step(self, step: int) -> tuple[dict, dict]:
        rec = self._scan_wal().get(step)
        if rec is None:
            raise KeyError(f"step {step} not in {self._wal_path}")
        head, payload = rec
        raw, off = {}, 0
        for key in head["order"]:
            want = head["manifest"][key]
            edt = np.dtype(_ENCODE_VIEW.get(want["dtype"], want["dtype"]))
            count = int(np.prod(want["shape"], dtype=np.int64))
            raw[key] = np.frombuffer(
                payload, dtype=edt, count=count, offset=off
            ).reshape(want["shape"])
            off += count * edt.itemsize
        return raw, head

    def _write_compact(self, final: str, encoded: dict, doc: dict) -> None:
        body = io.BytesIO()
        order = []
        for k, enc in encoded.items():
            order.append(k)
            # NB: not ascontiguousarray — it promotes 0-d arrays to 1-d.
            np.lib.format.write_array(body, np.asarray(enc),
                                      allow_pickle=False)
        body = body.getvalue()
        doc = dict(doc, format="compact1", order=order, crc32=zlib.crc32(body))
        head = json.dumps(doc).encode()
        blob = b"".join([_MAGIC, len(head).to_bytes(8, "little"), head, body])
        spool = self._spool
        try:
            with open(spool, "wb") as f:
                f.write(blob)
        except IsADirectoryError:  # something squatted on the spool path
            shutil.rmtree(spool)
            with open(spool, "wb") as f:
                f.write(blob)
        # Chaos hook: a torn write that survives the atomic publish (bad
        # sector, partial flush) — readers must detect it via the crc /
        # manifest check in `load_arrays`, never consume it.
        faults.corrupt_file("journal.write", spool, detail=final)
        try:
            os.replace(spool, final)  # atomic publish
        except (IsADirectoryError, OSError):
            # replacing a legacy directory step (or a platform that
            # refuses file->dir rename): clear it and retry once
            if not os.path.isdir(final):
                raise
            shutil.rmtree(final)
            os.replace(spool, final)

    def _write_dir(self, tmp: str, final: str, encoded: dict,
                   doc: dict) -> None:
        if os.path.isfile(tmp):
            os.remove(tmp)
        elif os.path.isdir(tmp):  # stale crashed writer: start clean
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(doc, f)
        # Chaos hook — see _write_compact.
        faults.corrupt_file(
            "journal.write", os.path.join(tmp, "arrays.npz"), detail=final
        )
        if os.path.exists(final):
            self._rm(final)
        os.rename(tmp, final)  # atomic publish

    def wait(self) -> None:
        """Block until every save enqueued for this directory has
        published; re-raise the first writer failure since the last
        wait, if any."""
        if self._w is not None:
            self._w.drain()

    def _rm(self, path: str) -> None:
        if os.path.isdir(path):
            shutil.rmtree(path, ignore_errors=True)
        else:
            try:
                os.remove(path)
            except OSError:
                pass

    def _gc(self, published: int | None = None) -> None:
        if self._known is None:
            self._known = set(self.steps())
        if published is not None:
            self._known.add(published)
        if len(self._known) <= self.keep_n:
            return
        for s in sorted(self._known)[: -self.keep_n]:
            self.remove(s)

    # -- restore ---------------------------------------------------------------

    def steps(self) -> list[int]:
        out = set()
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.add(int(name.split("_")[1]))
                except ValueError:
                    pass
        out.update(self._scan_wal())
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def remove(self, step: int) -> None:
        """Drop one published step (used to evict corrupt journal
        entries so the work is redone instead of re-tripping on them)."""
        self._rm(os.path.join(self.dir, f"step_{step}"))
        if step in self._scan_wal():
            self._wal_evict(step)
        if self._known is not None:
            self._known.discard(step)

    def _read_compact(self, path: str) -> tuple[dict[str, np.ndarray], dict]:
        with open(path, "rb") as f:
            blob = f.read()
        if not blob.startswith(_MAGIC):
            raise ValueError("bad compact-checkpoint magic")
        off = len(_MAGIC)
        n = int.from_bytes(blob[off:off + 8], "little")
        meta = json.loads(blob[off + 8:off + 8 + n].decode())
        body = blob[off + 8 + n:]
        if zlib.crc32(body) != meta.get("crc32"):
            raise ValueError("compact-checkpoint payload crc mismatch")
        buf = io.BytesIO(body)
        raw = {}
        for key in meta["order"]:
            raw[key] = np.lib.format.read_array(buf, allow_pickle=False)
        return raw, meta

    def load_arrays(self, step: int) -> tuple[dict[str, np.ndarray], dict]:
        """Raw structure-free restore: ``(arrays, meta)`` for one step.

        Unlike `restore`, no ``like_tree`` is needed — this is the
        journal-consumer path (`core.sweep_runner`), where the reader
        discovers what was written rather than matching a known model
        structure.  Every array is validated against the step's manifest
        and materialized to host numpy; any unreadable or inconsistent
        state raises `CheckpointCorruptError` so callers can quarantine
        the step and redo its work.
        """
        path = os.path.join(self.dir, f"step_{step}")
        try:
            if os.path.isfile(path):
                raw, meta = self._read_compact(path)
            elif os.path.isdir(path):
                with open(os.path.join(path, "meta.json")) as f:
                    meta = json.load(f)
                raw = {}
                with np.load(os.path.join(path, "arrays.npz")) as data:
                    for key in data.files:
                        raw[key] = data[key]
            else:
                raw, meta = self._read_wal_step(step)
            manifest = meta["manifest"]
            if set(raw) != set(manifest):
                raise ValueError(
                    f"manifest names {sorted(manifest)} != stored "
                    f"{sorted(raw)}"
                )
            out: dict[str, np.ndarray] = {}
            for key, want in manifest.items():
                arr = _decode(raw[key], want["dtype"])
                if list(arr.shape) != want["shape"]:
                    raise ValueError(f"manifest shape mismatch for {key}")
                out[key] = np.array(arr)
        except (OSError, ValueError, KeyError, EOFError, UnicodeDecodeError,
                json.JSONDecodeError, zipfile.BadZipFile) as e:
            raise CheckpointCorruptError(
                f"checkpoint step {step} in {self.dir} is unreadable: "
                f"{type(e).__name__}: {e}"
            ) from e
        return out, meta

    def restore(self, like_tree, step: int | None = None, shardings=None):
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional pytree (same structure) of NamedShardings —
        arrays are device_put with them, enabling restore onto a different
        mesh than the one that saved (elastic scaling).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        data, meta = self.load_arrays(step)
        flat, treedef = _flatten(like_tree)
        vals = []
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten(shardings)
        for key, like in flat.items():
            if key not in data:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = data[key]
            if hasattr(like, "shape") and tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs model {like.shape}"
                )
            if shard_flat is not None:
                vals.append(jax.device_put(arr, shard_flat[key]))
            else:
                vals.append(jax.numpy.asarray(arr))
        # preserve ordering of flatten
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_tree), vals
        ), meta

    def restore_or_none(self, like_tree, shardings=None):
        try:
            return self.restore(like_tree, shardings=shardings)
        except FileNotFoundError:
            return None
