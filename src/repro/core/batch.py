"""Tensorized back half of Algorithm I — the rapid-assessment engine.

The paper's headline claim is a *rapid assessment mechanism*: 6900+
(recipe x topology) evaluations across the EPFL suite.  The scalar path
(`mapping.schedule_stats` + `sram.evaluate` inside `explorer.explore`)
walks that grid one Python dataclass at a time; this module batches it
into a structure-of-arrays program so the whole
ChaAIG -> Evaluate -> FilterEnergy sweep is one jitted `jax.numpy` pass:

  * ``TopologyTable``  — the SRAM topology library stacked into arrays
    (rows, cols, macro counts, total bits, sense-amp widths);
  * ``WorkloadTable``  — the characterized recipes stacked into a
    ``(n_recipes, n_levels, n_op_types)`` op-count tensor;
  * ``schedule_batch`` — `mapping.schedule_stats` (both the "list" and
    "levels" disciplines) over the full recipe x topology grid;
  * ``evaluate_batch`` — `sram.evaluate` (both "paper" and "physical"
    accounting modes) over the grid, yielding an ``ExplorationGrid`` —
    or, given a `sram.ModelTable`, a ``VariationGrid`` with a leading
    model-variant axis;
  * ``select_best`` / ``select_best_batch`` / ``select_best_worst`` —
    the shared capacity / latency admissibility filter + energy
    argmin/argmax used by `explorer`, `mesh_explorer`, and the
    benchmarks.  ``select_best_batch`` is the batched filter: winners
    for every (circuit, variant) cell of a variation sweep in one masked
    three-tier argmin pass (non-finite energies are inadmissible in
    every tier), so the selection stage scales with the evaluate stage
    instead of looping per variant in python;
  * ``evaluate_select_batch`` / ``evaluate_select_suite`` — the fused
    **device-resident** pipeline: the same three-tier argmin runs as
    pure-jnp ops inside the jitted evaluate kernel, the device returns a
    ``SelectionResult`` (winner indices + per-winner metrics, a few KB)
    instead of the full float64 metric tensors, and the returned grids
    are *lazy* (`_LazyArrays`) — their tensors stay on device until
    first access.  The variant axis optionally shards across devices
    (`_shard_variants`); ``select_best_batch`` stays as the host-side
    parity reference.  ``select_best_batch_device`` is the standalone
    jitted filter for precomputed metric arrays (mesh explorer).

Parity contract: every cycle/flag quantity is exact integer arithmetic,
and the energy expressions are the *same functions* the scalar path uses
(`sram.paper_power_mw` / `sram.physical_energy_nj`), evaluated in
float64 via `jax.experimental.enable_x64`, so ``backend="jax"`` matches
``backend="python"`` to float round-off.  Grid arrays are stored
``(n_topologies, n_recipes)`` and flattened topology-major — the exact
iteration order of the scalar loops — so argmin tie-breaking also
matches.

The energy-model constants are *traced* operands (`ModelParams`, a
pytree of float64 arrays vmapped over the variant axis), not jit
statics: the jitted core recompiles only per (grid shape, n_variants,
discipline, mode).  Changing model floats never retriggers tracing, and
one compile serves circuits x recipes x topologies x model-variants.
``WorkloadTable`` pads the level axis to a multiple of 64 to keep the
number of distinct shapes (and hence compiles) small across circuits;
`trace_counts` exposes per-kernel trace counters so tests can pin the
no-recompile contract.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Mapping, NamedTuple, Sequence

import numpy as np

from repro.analysis import registry as _registry

from .aig import AigStats
from .mapping import BITS_PER_GATE, macros_per_type
from .sram import (
    OP_TYPES,
    EnergyModel,
    ModelTable,
    SramTopology,
    area_mm2_arrays,
    paper_energy_nj,
    paper_power_mw,
    physical_energy_nj,
    table2_arrays,
)

# jax is imported lazily on the first batched call: the tables and
# select_best/select_best_worst are pure numpy, and eager `import jax`
# costs ~1s that numpy-only consumers (mesh_explorer, backend="python")
# should not pay.
jax = None
jnp = None
enable_x64 = None

LEVEL_PAD = 64  # pad the level axis to multiples of this to bound recompiles


def _load_jax() -> None:
    global jax, jnp, enable_x64
    if jnp is not None:
        return
    try:
        import jax as _jax
        import jax.numpy as _jnp
        from jax.experimental import enable_x64 as _enable_x64
    except Exception as e:  # pragma: no cover - container always ships jax
        raise RuntimeError(
            "the batched exploration engine requires jax; "
            "use backend='python' instead"
        ) from e
    jax, jnp, enable_x64 = _jax, _jnp, _enable_x64


def jax_available() -> bool:
    """Whether the jitted engine can run here — lets callers pick the
    device or host filter up front instead of catching mid-call errors
    (which would also swallow genuine jax failures)."""
    try:
        _load_jax()
    except RuntimeError:
        return False
    return True


# Per-kernel jit trace counters.  The counter lines inside the kernel
# bodies execute only while jax is *tracing* (never on cached dispatch),
# so a test can assert that an N-variant sweep — or a float-only model
# change — costs exactly one (or zero) compilations.  The Counter itself
# lives in the unified registry (`repro.analysis.registry`) so every
# kernel module shares one namespace and the static analyzer can verify
# the discipline; this module re-exports it under its historical name.
# repro: kernel-module
TRACE_COUNTS = _registry.TRACE_COUNTS


def trace_counts() -> dict[str, int]:
    """Snapshot of this module's per-kernel jit trace counters (the
    scope the helper has always had — other modules' kernels tracing in
    between does not perturb whole-snapshot comparisons)."""
    return _registry.trace_counts(module=__name__)


class ModelParams(NamedTuple):
    """The `EnergyModel` constants the evaluate kernels read, as float64
    arrays with a leading variant axis — the *traced* (dynamic) model
    operand.  A NamedTuple so it is a jax pytree and the `sram` mode
    helpers' ``model.<field>`` attribute reads work unchanged inside the
    kernel.

    Scalar fields are ``(V,)`` for uniform sweeps or ``(V, T)`` for
    correlated (topology-dependent) variation — per-op fields likewise
    ``(V, 3)`` or ``(V, T, 3)``: after the variant vmap each leaf is
    ``()`` / ``(T,)`` / ``(3,)`` / ``(T, 3)``, and the grid arithmetic
    (all ``(R, T)``-shaped) broadcasts either along its trailing
    topology axis — the same float ops, no new compile path."""

    f_clk_hz: np.ndarray            # (V,) or (V, T)
    e_op_marginal_fj: np.ndarray    # (V, 3) or (V, T, 3)
    p_ctrl_mw: np.ndarray           # (V,) or (V, T)
    e_macro_cycle_fj: np.ndarray    # (V,) or (V, T)
    e_col_cycle_fj: np.ndarray      # (V,) or (V, T)
    alpha_mw_per_level: np.ndarray  # (V,) or (V, T)
    pipeline_utilization: np.ndarray  # (V,) or (V, T)


def _model_params(table: ModelTable) -> ModelParams:
    return ModelParams(
        **{
            f: np.asarray(getattr(table, f), dtype=np.float64)
            for f in ModelParams._fields
        }
    )


def _as_table(model: "EnergyModel | ModelTable | None") -> tuple[ModelTable, bool]:
    """Normalize a model argument to a `ModelTable`; the bool flags
    whether the caller asked for a variant sweep (vs a single model)."""
    if isinstance(model, ModelTable):
        return model, True
    if model is None:
        model = EnergyModel()
    return ModelTable.from_models([model]), False


def _check_topo_axis(table: ModelTable, topos: "TopologyTable") -> None:
    """A correlated table's per-topology axis must match the topology
    table it is swept against (a `(V, 1)` axis broadcasts uniformly) —
    by width, and by *identity* when the table records which topologies
    its columns were generated for: a same-length but different/reordered
    topology list would silently land each column's variation on the
    wrong macro geometry."""
    if len(table) == 0:
        raise ValueError("empty ModelTable")
    t = table.n_topologies
    if t is not None and t != len(topos):
        raise ValueError(
            f"ModelTable per-topology axis has width {t}, but the sweep "
            f"covers {len(topos)} topologies"
        )
    if table.topology_names is not None:
        actual = tuple(tp.name for tp in topos.topologies)
        if table.topology_names != actual:
            raise ValueError(
                "ModelTable's per-topology columns were generated for "
                f"topologies {table.topology_names}, but the sweep covers "
                f"{actual} — regenerate the table for this topology list"
            )


def _per_topo(arr: np.ndarray) -> np.ndarray:
    """A scalar `ModelTable` field as a (V, 1)-or-(V, T) column view, so
    it broadcasts against (T,) topology arrays either way."""
    return arr[:, None] if arr.ndim == 1 else arr


# ---------------------------------------------------------------------------
# Structure-of-arrays tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologyTable:
    """The SRAM topology library as stacked arrays (one row per topology).

    Units: ``total_bits`` in bits (capacity check is
    ``mapping.BITS_PER_GATE`` = 4 bits/gate), ``ops_per_cycle`` in
    gate-ops per macro per clock cycle (``cols/2`` sense-amp slots).
    """

    topologies: tuple[SramTopology, ...]
    rows: np.ndarray            # (T,) bitcell rows per macro
    cols: np.ndarray            # (T,) bitcell columns per macro
    n_macros: np.ndarray        # (T,)
    total_bits: np.ndarray      # (T,) capacity in bits, all macros
    ops_per_cycle: np.ndarray   # (T,) sense-amp slots per macro per cycle
    macros_per_type: np.ndarray  # (T, 3) dedicated macros per op type
    is_single: np.ndarray       # (T,) bool — time-multiplexed single macro

    @classmethod
    def from_topologies(cls, topos: Sequence[SramTopology]) -> "TopologyTable":
        """Stack topologies (library entries and/or `sram.topology_grid`
        design points) into one table; rejects unsupported macro counts."""
        topos = tuple(topos)
        if not topos:
            raise ValueError("empty topology list")
        return cls(
            topologies=topos,
            rows=np.array([t.rows for t in topos], dtype=np.int32),
            cols=np.array([t.cols for t in topos], dtype=np.int32),
            n_macros=np.array([t.n_macros for t in topos], dtype=np.int32),
            total_bits=np.array([t.total_bits for t in topos], dtype=np.int32),
            ops_per_cycle=np.array(
                [t.ops_per_cycle_per_macro for t in topos], dtype=np.int32
            ),
            macros_per_type=np.array(
                [macros_per_type(t.n_macros) for t in topos], dtype=np.int32
            ),
            is_single=np.array([t.n_macros == 1 for t in topos], dtype=bool),
        )

    def __len__(self) -> int:
        return len(self.topologies)

    def area_mm2(self, model: "EnergyModel | ModelTable") -> np.ndarray:
        """Vectorized `SramTopology.area_mm2` — the same
        `sram.area_mm2_arrays` expression over the stacked ``total_bits``:
        ``(T,)`` for one `EnergyModel`, ``(V, T)`` for a `ModelTable`
        (whose area fields may themselves be per-topology ``(V, T)``)."""
        if isinstance(model, ModelTable):
            _check_topo_axis(model, self)
            return area_mm2_arrays(
                self.total_bits[None, :],
                _per_topo(model.bitcell_um2),
                _per_topo(model.periphery_overhead),
            )
        return area_mm2_arrays(
            self.total_bits.astype(np.float64),
            model.bitcell_um2,
            model.periphery_overhead,
        )


@dataclasses.dataclass(frozen=True)
class WorkloadTable:
    """Characterized recipes as a stacked op-count tensor.

    ``ops[r, l, k]`` is the number of ops of type ``OP_TYPES[k]`` in gate-
    netlist level ``l`` of recipe ``r``; levels beyond ``n_levels[r]`` are
    zero padding (the schedule kernels mask them out).
    """

    recipes: tuple[tuple[str, ...], ...]
    ops: np.ndarray        # (R, L_pad, 3)
    n_levels: np.ndarray   # (R,)
    op_totals: np.ndarray  # (R, 3)
    gates: np.ndarray      # (R,)

    @classmethod
    def from_stats(
        cls,
        items: Mapping[tuple[str, ...], AigStats]
        | Sequence[tuple[tuple[str, ...], AigStats]],
        pad_levels_to: int = LEVEL_PAD,
    ) -> "WorkloadTable":
        if isinstance(items, Mapping):
            items = list(items.items())
        items = list(items)
        if not items:
            raise ValueError("empty workload list")
        recipes = tuple(tuple(r) for r, _ in items)
        n_levels = np.array([s.n_levels for _, s in items], dtype=np.int32)
        max_l = int(n_levels.max(initial=1))
        pad = max(pad_levels_to, 1)
        l_pad = ((max(max_l, 1) + pad - 1) // pad) * pad
        ops = np.zeros((len(items), l_pad, len(OP_TYPES)), dtype=np.int32)
        for i, (_, s) in enumerate(items):
            m = s.ops_matrix()
            ops[i, : m.shape[0]] = m
        op_totals = ops.sum(axis=1)
        return cls(
            recipes=recipes,
            ops=ops,
            n_levels=n_levels,
            op_totals=op_totals,
            gates=op_totals.sum(axis=1),
        )

    def __len__(self) -> int:
        return len(self.recipes)


@dataclasses.dataclass(frozen=True)
class SuiteTable:
    """A whole benchmark suite's `WorkloadTable`s stacked on a leading
    circuit axis — the input of the circuits x recipes x topologies sweep.

    All circuits share one recipe list (Algorithm I applies the same 64
    recipes to every RTL input) and one padded level axis (the max over
    the suite, rounded up to `LEVEL_PAD`); levels beyond ``n_levels[c, r]``
    are zero padding which the schedule kernels mask out, so padded
    results are bit-identical to each circuit's own `WorkloadTable` run.

    ``ops[c, r, l, k]``: ops of type ``OP_TYPES[k]`` in level ``l`` of
    recipe ``r`` of circuit ``c``.
    """

    circuits: tuple[str, ...]
    recipes: tuple[tuple[str, ...], ...]
    ops: np.ndarray        # (C, R, L_pad, 3)
    n_levels: np.ndarray   # (C, R)
    op_totals: np.ndarray  # (C, R, 3)
    gates: np.ndarray      # (C, R)

    @classmethod
    def from_cha(
        cls,
        cha: Mapping[str, Mapping[tuple[str, ...], AigStats]],
        pad_levels_to: int = LEVEL_PAD,
    ) -> "SuiteTable":
        """Stack per-circuit characterizations (as produced by
        `transforms.characterize_suite` / `explorer.characterize_recipes`).
        Every circuit must cover the same recipe set."""
        if not cha:
            raise ValueError("empty suite")
        names = tuple(cha)
        recipes = tuple(cha[names[0]])
        for name in names:
            if tuple(cha[name]) != recipes:
                raise ValueError(
                    f"circuit {name!r} covers a different recipe set"
                )
        max_l = max(
            (s.n_levels for m in cha.values() for s in m.values()), default=1
        )
        pad = max(pad_levels_to, 1)
        l_pad = ((max(max_l, 1) + pad - 1) // pad) * pad
        tables = [
            WorkloadTable.from_stats(cha[name], pad_levels_to=l_pad)
            for name in names
        ]
        return cls.from_workloads(dict(zip(names, tables)))

    @classmethod
    def from_workloads(
        cls, works: Mapping[str, WorkloadTable]
    ) -> "SuiteTable":
        """Stack prebuilt workload tables, re-padding to a common level
        axis when they disagree."""
        if not works:
            raise ValueError("empty suite")
        names = tuple(works)
        recipes = works[names[0]].recipes
        for name in names:
            if works[name].recipes != recipes:
                raise ValueError(
                    f"circuit {name!r} covers a different recipe set"
                )
        l_pad = max(w.ops.shape[1] for w in works.values())
        ops = np.zeros(
            (len(names), len(recipes), l_pad, len(OP_TYPES)), dtype=np.int32
        )
        for i, name in enumerate(names):
            w = works[name].ops
            ops[i, :, : w.shape[1]] = w
        op_totals = ops.sum(axis=2)
        return cls(
            circuits=names,
            recipes=recipes,
            ops=ops,
            n_levels=np.stack([works[n].n_levels for n in names]),
            op_totals=op_totals,
            gates=op_totals.sum(axis=2),
        )

    def bucket_shape(self, n_topologies: int, n_variants: int = 1) -> tuple:
        """The jit-trace bucket this table compiles under (see
        `bucket_suite`): ``(C, R, L_pad, T, V)``.  Two suites with equal
        bucket shapes reuse one compiled `evaluate_suite` /
        `evaluate_select_suite` trace."""
        c, r, l, _ = self.ops.shape
        return (c, r, l, int(n_topologies), int(n_variants))

    def workload(self, circuit: str | int) -> WorkloadTable:
        """One circuit's rows as a standalone `WorkloadTable` view."""
        c = self.circuit_index(circuit)
        return WorkloadTable(
            recipes=self.recipes,
            ops=self.ops[c],
            n_levels=self.n_levels[c],
            op_totals=self.op_totals[c],
            gates=self.gates[c],
        )

    def circuit_index(self, circuit: str | int) -> int:
        if isinstance(circuit, int):
            return circuit
        return self.circuits.index(circuit)

    def __len__(self) -> int:
        return len(self.circuits)


# ---------------------------------------------------------------------------
# Bucket-shape helpers (continuous batching for the exploration service)
# ---------------------------------------------------------------------------
#
# The jitted suite kernels trace once per input *shape* — (C, R, L_pad)
# on the workload side, (T,) on the topology side, (V,) on the model
# side.  A long-lived service answering arbitrary circuits must therefore
# snap every batch onto a small set of canonical shapes, or each new
# request size pays a fresh multi-second compile.  The helpers below
# implement that snapping: the circuit axis pads up to a power of two
# and the (already LEVEL_PAD-quantized) level axis pads up to a
# power-of-two multiple of LEVEL_PAD, so the number of distinct traces
# grows logarithmically with the largest batch/circuit ever seen.
# Padding rows duplicate a real circuit (all cells stay finite, so the
# fused on-device selection never trips on them) and are named so
# callers can recognize and drop them.

#: Name prefix of padding rows introduced by `pad_suite`.
PAD_CIRCUIT_PREFIX = "__pad"


def ceil_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (and >= 1)."""
    n = int(n)
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def bucket_levels(n_levels: int, pad: int = LEVEL_PAD) -> int:
    """Canonical level-axis width for a suite whose deepest circuit has
    ``n_levels`` levels: the smallest power-of-two multiple of ``pad``
    that covers it (64, 128, 256, ... for the default `LEVEL_PAD`), so
    progressively deeper circuits step through O(log L) shapes instead
    of one shape per depth."""
    pad = max(int(pad), 1)
    return pad * ceil_pow2(_ceil_div(max(int(n_levels), 1), pad))


def pad_suite(
    suite: SuiteTable,
    n_circuits: int | None = None,
    pad_levels_to: int | None = None,
) -> SuiteTable:
    """Pad a `SuiteTable` into a canonical bucket shape.

    The circuit axis grows to ``n_circuits`` by *duplicating the first
    circuit's rows* under `PAD_CIRCUIT_PREFIX` names — real (finite)
    workloads rather than zeros, so every padded cell evaluates to
    finite metrics and the fused selection's all-non-finite guard never
    fires on padding.  The level axis grows to ``pad_levels_to`` with
    zero rows, which the schedule kernels mask out (``n_levels`` is
    unchanged) — padded results are bit-identical per real circuit.

    Defaults: ``n_circuits`` -> `ceil_pow2` of the current count,
    ``pad_levels_to`` -> `bucket_levels` of the current level width.
    """
    c, r, l, k = suite.ops.shape
    n_c = ceil_pow2(c) if n_circuits is None else int(n_circuits)
    l_pad = bucket_levels(l) if pad_levels_to is None else int(pad_levels_to)
    if n_c < c:
        raise ValueError(f"cannot pad {c} circuits down to {n_c}")
    if l_pad < l:
        raise ValueError(f"cannot pad level axis {l} down to {l_pad}")
    if n_c == c and l_pad == l:
        return suite
    names = list(suite.circuits)
    for i in range(n_c - c):
        names.append(f"{PAD_CIRCUIT_PREFIX}{i}")
    ops = np.zeros((n_c, r, l_pad, k), dtype=suite.ops.dtype)
    ops[:c, :, :l] = suite.ops
    ops[c:, :, :l] = suite.ops[0]
    n_levels = np.concatenate(
        [suite.n_levels, np.broadcast_to(suite.n_levels[0], (n_c - c, r))]
    )
    op_totals = ops.sum(axis=2)
    return SuiteTable(
        circuits=tuple(names),
        recipes=suite.recipes,
        ops=ops,
        n_levels=n_levels,
        op_totals=op_totals,
        gates=op_totals.sum(axis=2),
    )


def bucket_suite(
    suite: SuiteTable, n_topologies: int, n_variants: int = 1
) -> "tuple[SuiteTable, tuple]":
    """Snap a suite onto its canonical bucket: `pad_suite` with the
    default (power-of-two) targets, returning the padded table and its
    `SuiteTable.bucket_shape` key ``(C, R, L_pad, T, V)`` — the unit of
    jit-trace reuse for the exploration service."""
    padded = pad_suite(suite)
    return padded, padded.bucket_shape(n_topologies, n_variants)


# ---------------------------------------------------------------------------
# Jitted grid kernels
# ---------------------------------------------------------------------------


def _ceil_div(a, b):
    return -(-a // b)


def _schedule_core(ops, n_levels, width, mpt, is_single, total_bits, rows,
                   discipline):
    """Shared schedule math; mirrors mapping.schedule_stats exactly.

    Shapes: ops (R, L, 3); width (T,); mpt (T, 3); is_single (T,);
    total_bits (T,); rows (T,).  Returns (cycles, active_macro_cycles,
    fits), each (R, T) with integer dtype (bool for fits).
    """
    wt = width[None, :, None] * mpt[None, :, :]          # (1, T, 3)
    tot = ops.sum(axis=1)                                # (R, 3)
    gates = tot.sum(axis=-1)                             # (R,)

    if discipline == "list":
        # ASAP width-bound schedule: cycles = max(depth, width bound) + drain.
        b = _ceil_div(tot[:, None, :], wt)               # (R, T, 3)
        sum_b = b.sum(axis=-1)
        max_b = b.max(axis=-1)
        width_bound = jnp.where(is_single[None, :], sum_b, max_b)
        active = jnp.where(
            is_single[None, :], sum_b, (b * mpt[None, :, :]).sum(axis=-1)
        )
        cycles = jnp.maximum(n_levels[:, None], width_bound) + 1
        # Steady-state working set: ~width_bound/depth concurrent batches,
        # each needing 2 operand rows + 1 result row.
        rows_needed = 3 * _ceil_div(
            jnp.maximum(width_bound, 1), jnp.maximum(n_levels[:, None], 1)
        ) + 2
    elif discipline == "levels":
        # Lock-step: every real level pays max(1, per-type batch bound);
        # the single-macro case serializes the three op types.
        b = _ceil_div(ops[:, None, :, :], wt[:, :, None, :])   # (R, T, L, 3)
        real = jnp.arange(ops.shape[1])[None, :] < n_levels[:, None]  # (R, L)
        sum_b = b.sum(axis=-1)                           # (R, T, L)
        max_b = b.max(axis=-1)
        per_level = jnp.where(
            is_single[None, :, None],
            jnp.maximum(sum_b, 1),
            jnp.maximum(max_b, 1),
        )
        per_level = per_level * real[:, None, :]
        cycles = per_level.sum(axis=-1) + 1              # + pipeline drain
        active = jnp.where(
            is_single[None, :],
            b.sum(axis=(-1, -2)),
            (b * mpt[None, :, None, :]).sum(axis=(-1, -2)),
        )
        # The busiest level's batch schedule is the peak working set.
        rows_needed = 3 * per_level.max(axis=-1) + 2     # (R, T)
    else:
        raise ValueError(f"unknown discipline {discipline!r}")

    # Feasibility = bit capacity (Alg. I line 9) AND row budget — the
    # same two-term check as mapping.schedule_stats / _schedule_list.
    fits = (BITS_PER_GATE * gates[:, None] <= total_bits[None, :]) & (
        rows_needed <= rows[None, :]
    )
    return cycles, active, fits


def _make_schedule_grid():
    def fn(ops, n_levels, width, mpt, is_single, total_bits, rows, discipline):
        TRACE_COUNTS["schedule_grid"] += 1
        return _schedule_core(
            ops, n_levels, width, mpt, is_single, total_bits, rows, discipline
        )

    return jax.jit(fn, static_argnames=("discipline",))


def _evaluate_core(ops, n_levels, width, mpt, is_single, total_bits, rows,
                   cols, params, discipline, mode):
    """Schedule once, then evaluate every model variant over it.

    ``params`` is a `ModelParams` pytree of *traced* float64 arrays with a
    leading variant axis; the schedule (exact integers, model-free) is
    computed once and closed over by the vmapped per-variant metrics, so
    the variant axis only multiplies the cheap float arithmetic.

    Returns ``cycles`` / ``active_macro_cycles`` / ``fits`` as (R, T)
    arrays and each metric as a (V, R, T) array.
    """
    cycles, active, fits = _schedule_core(
        ops, n_levels, width, mpt, is_single, total_bits, rows, discipline
    )
    tot = ops.sum(axis=1)                                # (R, 3)
    gates = tot.sum(axis=-1)                             # (R,)
    n_lvl = n_levels.astype(jnp.float64)[:, None]
    # Explicit float64 casts so parity with the scalar path does not
    # hinge on int/weak-float promotion rules.
    cycles_f = cycles.astype(jnp.float64)

    def metrics(model):
        # `model` is one ModelParams row: scalar or (T,) leaves + a (3,)
        # or (T, 3) per-op vector.  The sram mode helpers read it via the
        # same attribute names as a scalar EnergyModel, so both paths
        # share one set of expressions.
        t_ns = cycles_f / model.f_clk_hz * 1e9
        e_marg = model.e_op_marginal_fj
        if e_marg.ndim == 2:  # (T, 3) correlated per-op energies
            e_ops_fj = (tot[:, None, :] * e_marg[None, :, :]).sum(axis=-1)
        else:
            e_ops_fj = (tot * e_marg[None, :]).sum(axis=-1)

        if mode == "paper":
            p_mw = paper_power_mw(n_lvl, model) * jnp.ones_like(t_ns)
            e_nj = paper_energy_nj(p_mw, t_ns)
        elif mode == "physical":
            e_nj = physical_energy_nj(
                t_ns, active,
                e_ops_fj if e_ops_fj.ndim == 2 else e_ops_fj[:, None],
                cols[None, :], model,
            )
            p_mw = jnp.where(t_ns > 0, e_nj / t_ns * 1e3, 0.0)
        else:
            raise ValueError(f"unknown mode {mode!r}")

        thr_gops = jnp.where(
            t_ns > 0,
            gates[:, None] / (t_ns * 1e-9) / 1e9 * model.pipeline_utilization,
            0.0,
        )
        tops_w = jnp.where(p_mw > 0, (thr_gops / 1e3) / (p_mw * 1e-3), 0.0)
        return dict(
            latency_ns=t_ns,
            energy_nj=e_nj,
            power_mw=p_mw,
            throughput_gops=thr_gops,
            tops_per_watt=tops_w,
        )

    out = jax.vmap(metrics)(params)                      # each (V, R, T)
    out.update(cycles=cycles, active_macro_cycles=active, fits=fits)
    return out


def _make_evaluate_grid():
    def fn(ops, n_levels, width, mpt, is_single, total_bits, rows, cols,
           params, discipline, mode):
        TRACE_COUNTS["evaluate_grid"] += 1
        return _evaluate_core(
            ops, n_levels, width, mpt, is_single, total_bits, rows, cols,
            params, discipline, mode,
        )

    return jax.jit(fn, static_argnames=("discipline", "mode"))


def _make_schedule_suite():
    def fn(ops, n_levels, width, mpt, is_single, total_bits, rows, discipline):
        TRACE_COUNTS["schedule_suite"] += 1

        def per_circuit(o, nl):
            return _schedule_core(
                o, nl, width, mpt, is_single, total_bits, rows, discipline
            )

        return jax.vmap(per_circuit)(ops, n_levels)

    return jax.jit(fn, static_argnames=("discipline",))


def _make_evaluate_suite():
    def fn(ops, n_levels, width, mpt, is_single, total_bits, rows, cols,
           params, discipline, mode):
        TRACE_COUNTS["evaluate_suite"] += 1

        def per_circuit(o, nl):
            return _evaluate_core(
                o, nl, width, mpt, is_single, total_bits, rows, cols,
                params, discipline, mode,
            )

        return jax.vmap(per_circuit)(ops, n_levels)

    return jax.jit(fn, static_argnames=("discipline", "mode"))


_SCHEDULE_GRID = None
_EVALUATE_GRID = None
_SCHEDULE_SUITE = None
_EVALUATE_SUITE = None


def _grids():
    global _SCHEDULE_GRID, _EVALUATE_GRID
    _load_jax()
    if _SCHEDULE_GRID is None:
        _SCHEDULE_GRID = _make_schedule_grid()
        _EVALUATE_GRID = _make_evaluate_grid()
    return _SCHEDULE_GRID, _EVALUATE_GRID


def _suite_grids():
    global _SCHEDULE_SUITE, _EVALUATE_SUITE
    _load_jax()
    if _SCHEDULE_SUITE is None:
        _SCHEDULE_SUITE = _make_schedule_suite()
        _EVALUATE_SUITE = _make_evaluate_suite()
    return _SCHEDULE_SUITE, _EVALUATE_SUITE


# ---------------------------------------------------------------------------
# Public batched API
# ---------------------------------------------------------------------------


_SCHED_KEYS = ("cycles", "active_macro_cycles", "fits")
_METRIC_KEYS = (
    "latency_ns", "energy_nj", "power_mw", "throughput_gops", "tops_per_watt"
)
# Grid fields that may hold device-resident (jax) arrays in lazy mode.
_LAZY_FIELDS = frozenset(_SCHED_KEYS + _METRIC_KEYS)


class _LazyArrays:
    """Mixin for the grid dataclasses: metric/schedule fields may hold
    *device* (jax) arrays instead of numpy — the lazy mode of the fused
    pipeline.  A field is materialized to numpy on first attribute access
    and cached in place (the dataclasses are frozen, so the swap goes
    through ``object.__setattr__``), which means a grid that is never
    inspected never pays the device->host transfer: the fused selection
    already moved the winners across, and the full (C, V, T, R) tensors
    stay where they were computed.

    View methods (``grid``/``variation``/``suite``) slice through
    ``_raw`` so child grids inherit the un-materialized device arrays —
    slicing a jax array is a device op, not a transfer.
    """

    def __getattribute__(self, name):
        val = object.__getattribute__(self, name)
        if name in _LAZY_FIELDS and not isinstance(val, np.ndarray):
            # repro: host-boundary — lazy-grid materialization on first access
            val = np.asarray(val)
            object.__setattr__(self, name, val)
        return val

    def _raw(self, name: str):
        """The stored array without materializing it (device or numpy)."""
        return object.__getattribute__(self, name)

    def _cell_scalar(self, name: str, idx: tuple) -> float:
        """One element of a (possibly device-resident) field.

        Indexing the raw array first keeps the gather on the device and
        moves a single scalar across the boundary — the full tensor is
        NOT materialized (and stays lazy for later accesses).
        """
        # repro: host-boundary — single-scalar device gather
        return float(np.asarray(self._raw(name)[idx]))


@dataclasses.dataclass(frozen=True)
class GridCell:
    """One design point of a sweep grid — the lazy per-cell gather result.

    Produced by the grids' ``cell(...)`` methods for post-hoc inspection
    of a single (circuit, variant, topology, recipe) choice without
    materializing the full device tensor: each field is a one-element
    device gather.  ``circuit``/``variant`` are None on grids without
    that axis.
    """

    recipe: tuple[str, ...]
    topology: SramTopology
    circuit: str | None
    variant: int | None
    cycles: int
    active_macro_cycles: int
    fits: bool
    feasible: bool
    latency_ns: float
    energy_nj: float
    power_mw: float
    throughput_gops: float
    tops_per_watt: float
    area_mm2: float


@dataclasses.dataclass(frozen=True)
class ExplorationGrid(_LazyArrays):
    """The full recipe x topology sweep as ``(n_topologies, n_recipes)``
    arrays — the batched analogue of ``ExplorationResult.evaluations``.

    Flattened (``.ravel()``) order is topology-major, matching the scalar
    loops ``for topo: for recipe:`` so argmin indices and tie-breaking
    line up with the Python path.
    """

    recipes: tuple[tuple[str, ...], ...]
    topologies: tuple[SramTopology, ...]
    cycles: np.ndarray               # (T, R) int
    active_macro_cycles: np.ndarray  # (T, R) int
    fits: np.ndarray                 # (T, R) bool
    latency_ns: np.ndarray           # (T, R)
    energy_nj: np.ndarray            # (T, R)
    power_mw: np.ndarray             # (T, R)
    throughput_gops: np.ndarray      # (T, R)
    tops_per_watt: np.ndarray        # (T, R)
    area_mm2: np.ndarray             # (T,)
    feasible: np.ndarray             # (T,) capacity-feasible (Alg. I line 9)
    mode: str
    discipline: str
    # The scalar model the grid was evaluated with; None when the grid is
    # a correlated-variant slice whose constants differ per topology (no
    # single EnergyModel exists — see ModelTable.uniform_row).
    model: EnergyModel | None

    @property
    def size(self) -> int:
        # _raw: a shape query must not materialize a lazy device tensor
        return self._raw("energy_nj").size

    def unravel(self, flat_index: int) -> tuple[int, int]:
        """Flat (topology-major) index -> (topology_idx, recipe_idx)."""
        n_r = len(self.recipes)
        return flat_index // n_r, flat_index % n_r

    def fit_energies(self) -> np.ndarray:
        return self.energy_nj[self.fits]

    def best_index(self, max_latency_ns: float | None = None) -> int:
        return select_best(
            self.energy_nj,
            self.fits,
            latency=self.latency_ns,
            max_latency=max_latency_ns,
            feasible=np.broadcast_to(self.feasible[:, None], self.fits.shape),
        )

    def best_worst_indices(self) -> tuple[int, int]:
        return select_best_worst(self.energy_nj, self.fits)

    def cell(self, t: int, r: int) -> GridCell:
        """One (topology, recipe) design point as a `GridCell` — lazy
        per-element gathers, never materializes the full grid."""
        g = self._cell_scalar
        return GridCell(
            recipe=self.recipes[r],
            topology=self.topologies[t],
            circuit=None,
            variant=None,
            cycles=int(g("cycles", (t, r))),
            active_macro_cycles=int(g("active_macro_cycles", (t, r))),
            fits=bool(g("fits", (t, r))),
            feasible=bool(np.asarray(self._raw("feasible")[t])),  # repro: host-boundary
            latency_ns=g("latency_ns", (t, r)),
            energy_nj=g("energy_nj", (t, r)),
            power_mw=g("power_mw", (t, r)),
            throughput_gops=g("throughput_gops", (t, r)),
            tops_per_watt=g("tops_per_watt", (t, r)),
            area_mm2=float(np.asarray(self._raw("area_mm2")[t])),  # repro: host-boundary
        )


@dataclasses.dataclass(frozen=True)
class VariationGrid(_LazyArrays):
    """One circuit's recipe x topology sweep across every `ModelTable`
    variant — the batched analogue of N `ExplorationGrid`s that cost one
    compile and one device call.

    Schedules (``cycles`` / ``active_macro_cycles`` / ``fits``) are
    model-free exact integers, stored once as ``(T, R)``; each metric
    carries a leading variant axis ``(V, T, R)``.  ``grid(v)`` slices
    variant ``v`` back out as a standard `ExplorationGrid` (numpy views).
    """

    recipes: tuple[tuple[str, ...], ...]
    topologies: tuple[SramTopology, ...]
    models: ModelTable
    cycles: np.ndarray               # (T, R) int
    active_macro_cycles: np.ndarray  # (T, R) int
    fits: np.ndarray                 # (T, R) bool
    latency_ns: np.ndarray           # (V, T, R)
    energy_nj: np.ndarray            # (V, T, R)
    power_mw: np.ndarray             # (V, T, R)
    throughput_gops: np.ndarray      # (V, T, R)
    tops_per_watt: np.ndarray        # (V, T, R)
    area_mm2: np.ndarray             # (V, T)
    feasible: np.ndarray             # (T,)
    mode: str
    discipline: str

    @property
    def n_variants(self) -> int:
        return len(self.models)

    def __len__(self) -> int:
        return len(self.models)

    def unravel(self, flat_index: int) -> tuple[int, int]:
        """Flat (topology-major) index -> (topology_idx, recipe_idx)."""
        n_r = len(self.recipes)
        return flat_index // n_r, flat_index % n_r

    def grid(self, v: int) -> ExplorationGrid:
        """Variant ``v``'s sweep as a standard `ExplorationGrid`.

        For a correlated table, a topology-dependent variant has no
        single scalar model: the slice still carries every per-variant
        metric (winners, energies, areas all work), but its ``model``
        field is None — materialize per-cell models via
        ``models.model(v, topology=...)`` instead."""
        return ExplorationGrid(
            recipes=self.recipes,
            topologies=self.topologies,
            cycles=self._raw("cycles"),
            active_macro_cycles=self._raw("active_macro_cycles"),
            fits=self._raw("fits"),
            latency_ns=self._raw("latency_ns")[v],
            energy_nj=self._raw("energy_nj")[v],
            power_mw=self._raw("power_mw")[v],
            throughput_gops=self._raw("throughput_gops")[v],
            tops_per_watt=self._raw("tops_per_watt")[v],
            area_mm2=self.area_mm2[v],
            feasible=self.feasible,
            mode=self.mode,
            discipline=self.discipline,
            model=(
                self.models.model(v) if self.models.uniform_row(v) else None
            ),
        )

    def best_indices(self, max_latency_ns: float | None = None) -> np.ndarray:
        """Per-variant `select_best` winners: ``(V,)`` flat
        (topology-major) indices, same tiering/tie-breaking as the
        static-model path on every variant — all variants in one
        `select_best_batch` array pass (the model-free fits/feasible
        masks broadcast across the variant axis)."""
        v = len(self.models)
        feas = np.broadcast_to(self.feasible[:, None], self.fits.shape)
        return select_best_batch(
            self.energy_nj.reshape(v, -1),
            self.fits.reshape(1, -1),
            latency=self.latency_ns.reshape(v, -1),
            max_latency=max_latency_ns,
            feasible=feas.reshape(1, -1),
        )

    def cell(self, v: int, t: int, r: int) -> GridCell:
        """One (variant, topology, recipe) design point as a `GridCell`
        — lazy per-element gathers, never materializes the full
        ``(V, T, R)`` tensors."""
        g = self._cell_scalar
        return GridCell(
            recipe=self.recipes[r],
            topology=self.topologies[t],
            circuit=None,
            variant=v,
            cycles=int(g("cycles", (t, r))),
            active_macro_cycles=int(g("active_macro_cycles", (t, r))),
            fits=bool(g("fits", (t, r))),
            feasible=bool(np.asarray(self._raw("feasible")[t])),  # repro: host-boundary
            latency_ns=g("latency_ns", (v, t, r)),
            energy_nj=g("energy_nj", (v, t, r)),
            power_mw=g("power_mw", (v, t, r)),
            throughput_gops=g("throughput_gops", (v, t, r)),
            tops_per_watt=g("tops_per_watt", (v, t, r)),
            area_mm2=float(np.asarray(self._raw("area_mm2")[v, t])),  # repro: host-boundary
        )


def schedule_batch(
    work: WorkloadTable,
    topos: TopologyTable,
    discipline: str = "list",
) -> dict[str, np.ndarray]:
    """``mapping.schedule_stats`` over the full grid in one jitted pass.

    Returns ``(n_topologies, n_recipes)`` arrays: ``cycles``,
    ``active_macro_cycles``, ``fits``.  (Pipelined writeback only — the
    scalar path's default.)  Schedules are model-free, so there is no
    variant axis here.
    """
    schedule_grid, _ = _grids()
    with enable_x64():
        cycles, active, fits = schedule_grid(
            work.ops, work.n_levels, topos.ops_per_cycle,
            topos.macros_per_type, topos.is_single, topos.total_bits,
            topos.rows, discipline,
        )
        return dict(
            cycles=np.asarray(cycles).T,  # repro: host-boundary
            active_macro_cycles=np.asarray(active).T,  # repro: host-boundary
            fits=np.asarray(fits).T,  # repro: host-boundary
        )


def _grid_feasible(topos, feasible) -> np.ndarray:
    if feasible is None:
        feasible = np.ones(len(topos), dtype=bool)
    return np.asarray(feasible, dtype=bool)


def _layout_outputs(out, lazy):
    """Kernel outputs ((..., R, T)-major) -> final (..., T, R) layout
    schedule/metric dicts; ``lazy`` keeps them device-resident."""
    conv = (lambda a: a) if lazy else np.asarray
    return (
        {k: conv(jnp.swapaxes(out[k], -1, -2)) for k in _SCHED_KEYS},
        {k: conv(jnp.swapaxes(out[k], -1, -2)) for k in _METRIC_KEYS},
    )


def _fused_outputs(res, lazy):
    """The fused kernels' schedule/metric dicts (already final-layout);
    ``lazy`` keeps them device-resident."""
    conv = (lambda a: a) if lazy else np.asarray
    return (
        {k: conv(res["sched"][k]) for k in _SCHED_KEYS},
        {k: conv(res["mets"][k]) for k in _METRIC_KEYS},
    )


def _build_grid(
    work, topos, table, model, is_sweep, mode, discipline, feasible,
    sched, mets,
) -> "ExplorationGrid | VariationGrid":
    """Assemble the single-circuit grid result from (possibly
    device-resident) schedule/metric arrays."""
    if not is_sweep:
        return ExplorationGrid(
            recipes=work.recipes,
            topologies=topos.topologies,
            area_mm2=topos.area_mm2(table.model(0)),
            feasible=feasible,
            mode=mode,
            discipline=discipline,
            model=model if isinstance(model, EnergyModel) else table.model(0),
            **sched,
            **{k: v[0] for k, v in mets.items()},
        )
    return VariationGrid(
        recipes=work.recipes,
        topologies=topos.topologies,
        models=table,
        area_mm2=topos.area_mm2(table),
        feasible=feasible,
        mode=mode,
        discipline=discipline,
        **sched,
        **mets,
    )


def evaluate_batch(
    work: WorkloadTable,
    topos: TopologyTable,
    model: "EnergyModel | ModelTable | None" = None,
    mode: str = "physical",
    discipline: str = "list",
    feasible: np.ndarray | None = None,
    lazy: bool = False,
) -> "ExplorationGrid | VariationGrid":
    """Schedule + evaluate the full recipe x topology grid in one jitted
    float64 pass; the batched ``sram.evaluate``.

    ``model`` may be a single `EnergyModel` (returns an
    `ExplorationGrid`, as before) or a `sram.ModelTable` of variants
    (returns a `VariationGrid` with a leading variant axis).  Either way
    the model constants are traced operands — the kernel never recompiles
    on a model change, only on a new (grid shape, n_variants,
    discipline, mode).

    ``lazy=True`` keeps the metric tensors device-resident: the grid's
    array fields materialize to numpy on first access instead of paying
    the device->host transfer eagerly (see `_LazyArrays`).
    """
    _, evaluate_grid = _grids()
    table, is_sweep = _as_table(model)
    _check_topo_axis(table, topos)
    feasible = _grid_feasible(topos, feasible)
    with enable_x64():
        out = evaluate_grid(
            work.ops, work.n_levels, topos.ops_per_cycle,
            topos.macros_per_type, topos.is_single, topos.total_bits,
            topos.rows, topos.cols, _model_params(table), discipline, mode,
        )
        sched, mets = _layout_outputs(out, lazy)
        return _build_grid(
            work, topos, table, model, is_sweep, mode, discipline,
            feasible, sched, mets,
        )


# ---------------------------------------------------------------------------
# Suite-level sweep: circuits x recipes x topologies in one jitted call
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SuiteGrid(_LazyArrays):
    """The whole-suite sweep as ``(n_circuits, n_topologies, n_recipes)``
    arrays — one `ExplorationGrid` per circuit, stacked.

    Produced by `evaluate_suite`; ``grid(circuit)`` slices one circuit
    back out as a standard `ExplorationGrid` (numpy views, no copies), so
    everything downstream of the per-circuit sweep (``best_index``,
    `select_best`, `explorer.best_worst`) works unchanged.
    """

    circuits: tuple[str, ...]
    recipes: tuple[tuple[str, ...], ...]
    topologies: tuple[SramTopology, ...]
    cycles: np.ndarray               # (C, T, R) int
    active_macro_cycles: np.ndarray  # (C, T, R) int
    fits: np.ndarray                 # (C, T, R) bool
    latency_ns: np.ndarray           # (C, T, R)
    energy_nj: np.ndarray            # (C, T, R)
    power_mw: np.ndarray             # (C, T, R)
    throughput_gops: np.ndarray      # (C, T, R)
    tops_per_watt: np.ndarray        # (C, T, R)
    area_mm2: np.ndarray             # (T,)
    feasible: np.ndarray             # (C, T) capacity-feasible per circuit
    mode: str
    discipline: str
    model: EnergyModel | None  # None for correlated-variant slices

    @property
    def size(self) -> int:
        """Total swept implementations (circuits x topologies x recipes)."""
        return self._raw("energy_nj").size

    def circuit_index(self, circuit: str | int) -> int:
        if isinstance(circuit, int):
            return circuit
        return self.circuits.index(circuit)

    def grid(self, circuit: str | int) -> ExplorationGrid:
        """One circuit's ``(T, R)`` slice as an `ExplorationGrid`."""
        c = self.circuit_index(circuit)
        return ExplorationGrid(
            recipes=self.recipes,
            topologies=self.topologies,
            cycles=self._raw("cycles")[c],
            active_macro_cycles=self._raw("active_macro_cycles")[c],
            fits=self._raw("fits")[c],
            latency_ns=self._raw("latency_ns")[c],
            energy_nj=self._raw("energy_nj")[c],
            power_mw=self._raw("power_mw")[c],
            throughput_gops=self._raw("throughput_gops")[c],
            tops_per_watt=self._raw("tops_per_watt")[c],
            area_mm2=self.area_mm2,
            feasible=self.feasible[c],
            mode=self.mode,
            discipline=self.discipline,
            model=self.model,
        )

    def grids(self) -> dict[str, ExplorationGrid]:
        return {name: self.grid(name) for name in self.circuits}

    def cell(self, circuit: str | int, t: int, r: int) -> GridCell:
        """One (circuit, topology, recipe) design point as a `GridCell`
        — lazy per-element gathers, never materializes the full
        ``(C, T, R)`` tensors."""
        c = self.circuit_index(circuit)
        g = self._cell_scalar
        return GridCell(
            recipe=self.recipes[r],
            topology=self.topologies[t],
            circuit=self.circuits[c],
            variant=None,
            cycles=int(g("cycles", (c, t, r))),
            active_macro_cycles=int(g("active_macro_cycles", (c, t, r))),
            fits=bool(g("fits", (c, t, r))),
            feasible=bool(np.asarray(self._raw("feasible")[c, t])),  # repro: host-boundary
            latency_ns=g("latency_ns", (c, t, r)),
            energy_nj=g("energy_nj", (c, t, r)),
            power_mw=g("power_mw", (c, t, r)),
            throughput_gops=g("throughput_gops", (c, t, r)),
            tops_per_watt=g("tops_per_watt", (c, t, r)),
            area_mm2=float(np.asarray(self._raw("area_mm2")[t])),  # repro: host-boundary
        )


def schedule_suite(
    suite: SuiteTable,
    topos: TopologyTable,
    discipline: str = "list",
) -> dict[str, np.ndarray]:
    """`schedule_batch` vmapped over the circuit axis: one jitted pass
    computing ``(n_circuits, n_topologies, n_recipes)`` ``cycles`` /
    ``active_macro_cycles`` / ``fits`` arrays for the whole suite."""
    schedule, _ = _suite_grids()
    with enable_x64():
        cycles, active, fits = schedule(
            suite.ops, suite.n_levels, topos.ops_per_cycle,
            topos.macros_per_type, topos.is_single, topos.total_bits,
            topos.rows, discipline,
        )
        return dict(
            cycles=np.swapaxes(np.asarray(cycles), 1, 2),  # repro: host-boundary
            active_macro_cycles=np.swapaxes(np.asarray(active), 1, 2),  # repro: host-boundary
            fits=np.swapaxes(np.asarray(fits), 1, 2),  # repro: host-boundary
        )


@dataclasses.dataclass(frozen=True)
class SuiteVariationGrid(_LazyArrays):
    """The whole suite swept across every model variant: circuits x
    model-variants x topologies x recipes from ONE compile and ONE device
    call — the fourth (variant) axis of the rapid-assessment engine.

    Schedules are model-free ``(C, T, R)`` exact integers; metrics are
    ``(C, V, T, R)``.  ``variation(circuit)`` slices one circuit's
    `VariationGrid`; ``suite(v)`` slices one variant's `SuiteGrid`.
    """

    circuits: tuple[str, ...]
    recipes: tuple[tuple[str, ...], ...]
    topologies: tuple[SramTopology, ...]
    models: ModelTable
    cycles: np.ndarray               # (C, T, R) int
    active_macro_cycles: np.ndarray  # (C, T, R) int
    fits: np.ndarray                 # (C, T, R) bool
    latency_ns: np.ndarray           # (C, V, T, R)
    energy_nj: np.ndarray            # (C, V, T, R)
    power_mw: np.ndarray             # (C, V, T, R)
    throughput_gops: np.ndarray      # (C, V, T, R)
    tops_per_watt: np.ndarray        # (C, V, T, R)
    area_mm2: np.ndarray             # (V, T)
    feasible: np.ndarray             # (C, T)
    mode: str
    discipline: str

    @property
    def n_variants(self) -> int:
        return len(self.models)

    @property
    def size(self) -> int:
        """Total swept implementations (C x V x T x R)."""
        return self._raw("energy_nj").size

    def circuit_index(self, circuit: str | int) -> int:
        if isinstance(circuit, int):
            return circuit
        return self.circuits.index(circuit)

    def variation(self, circuit: str | int) -> VariationGrid:
        """One circuit's ``(V, T, R)`` sweep as a `VariationGrid`."""
        c = self.circuit_index(circuit)
        return VariationGrid(
            recipes=self.recipes,
            topologies=self.topologies,
            models=self.models,
            cycles=self._raw("cycles")[c],
            active_macro_cycles=self._raw("active_macro_cycles")[c],
            fits=self._raw("fits")[c],
            latency_ns=self._raw("latency_ns")[c],
            energy_nj=self._raw("energy_nj")[c],
            power_mw=self._raw("power_mw")[c],
            throughput_gops=self._raw("throughput_gops")[c],
            tops_per_watt=self._raw("tops_per_watt")[c],
            area_mm2=self.area_mm2,
            feasible=self.feasible[c],
            mode=self.mode,
            discipline=self.discipline,
        )

    def suite(self, v: int) -> SuiteGrid:
        """One model variant's suite sweep as a standard `SuiteGrid`
        (``model`` is None for a topology-dependent correlated variant —
        see `VariationGrid.grid`)."""
        return SuiteGrid(
            circuits=self.circuits,
            recipes=self.recipes,
            topologies=self.topologies,
            cycles=self._raw("cycles"),
            active_macro_cycles=self._raw("active_macro_cycles"),
            fits=self._raw("fits"),
            latency_ns=self._raw("latency_ns")[:, v],
            energy_nj=self._raw("energy_nj")[:, v],
            power_mw=self._raw("power_mw")[:, v],
            throughput_gops=self._raw("throughput_gops")[:, v],
            tops_per_watt=self._raw("tops_per_watt")[:, v],
            area_mm2=self.area_mm2[v],
            feasible=self.feasible,
            mode=self.mode,
            discipline=self.discipline,
            model=(
                self.models.model(v) if self.models.uniform_row(v) else None
            ),
        )

    def best_indices(self, max_latency_ns: float | None = None) -> np.ndarray:
        """Winners for every (circuit, variant) cell — ``(C, V)`` flat
        (topology-major) indices from ONE `select_best_batch` pass over
        the whole hypercube, bit-identical to running the per-variant
        `select_best` loop on each circuit's `VariationGrid`."""
        c, v = len(self.circuits), len(self.models)
        feas = np.broadcast_to(
            self.feasible[:, :, None], self.fits.shape
        )  # (C, T, R)
        return select_best_batch(
            self.energy_nj.reshape(c, v, -1),
            self.fits.reshape(c, 1, -1),
            latency=self.latency_ns.reshape(c, v, -1),
            max_latency=max_latency_ns,
            feasible=feas.reshape(c, 1, -1),
        )

    def cell(self, circuit: str | int, v: int, t: int, r: int) -> GridCell:
        """One (circuit, variant, topology, recipe) point of the full
        hypercube as a `GridCell` — lazy per-element gathers, never
        materializes the ``(C, V, T, R)`` tensors."""
        c = self.circuit_index(circuit)
        g = self._cell_scalar
        return GridCell(
            recipe=self.recipes[r],
            topology=self.topologies[t],
            circuit=self.circuits[c],
            variant=v,
            cycles=int(g("cycles", (c, t, r))),
            active_macro_cycles=int(g("active_macro_cycles", (c, t, r))),
            fits=bool(g("fits", (c, t, r))),
            feasible=bool(np.asarray(self._raw("feasible")[c, t])),  # repro: host-boundary
            latency_ns=g("latency_ns", (c, v, t, r)),
            energy_nj=g("energy_nj", (c, v, t, r)),
            power_mw=g("power_mw", (c, v, t, r)),
            throughput_gops=g("throughput_gops", (c, v, t, r)),
            tops_per_watt=g("tops_per_watt", (c, v, t, r)),
            area_mm2=float(np.asarray(self._raw("area_mm2")[v, t])),  # repro: host-boundary
        )


def _suite_feasible(suite, topos, feasible) -> np.ndarray:
    if feasible is None:
        feasible = np.ones((len(suite), len(topos)), dtype=bool)
    feasible = np.asarray(feasible, dtype=bool)
    if feasible.shape != (len(suite), len(topos)):
        raise ValueError(
            f"feasible must be (n_circuits, n_topologies)="
            f"{(len(suite), len(topos))}, got {feasible.shape}"
        )
    return feasible


def _build_suite_grid(
    suite, topos, table, model, is_sweep, mode, discipline, feasible,
    sched, mets,
) -> "SuiteGrid | SuiteVariationGrid":
    """Assemble the suite grid result from (possibly device-resident)
    schedule/metric arrays."""
    if not is_sweep:
        return SuiteGrid(
            circuits=suite.circuits,
            recipes=suite.recipes,
            topologies=topos.topologies,
            area_mm2=topos.area_mm2(table.model(0)),
            feasible=feasible,
            mode=mode,
            discipline=discipline,
            model=model if isinstance(model, EnergyModel) else table.model(0),
            **sched,
            **{k: v[:, 0] for k, v in mets.items()},
        )
    return SuiteVariationGrid(
        circuits=suite.circuits,
        recipes=suite.recipes,
        topologies=topos.topologies,
        models=table,
        area_mm2=topos.area_mm2(table),
        feasible=feasible,
        mode=mode,
        discipline=discipline,
        **sched,
        **mets,
    )


def evaluate_suite(
    suite: SuiteTable,
    topos: TopologyTable,
    model: "EnergyModel | ModelTable | None" = None,
    mode: str = "physical",
    discipline: str = "list",
    feasible: np.ndarray | None = None,
    lazy: bool = False,
) -> "SuiteGrid | SuiteVariationGrid":
    """Schedule + evaluate circuits x recipes x topologies in one jitted
    float64 pass — the suite-level `evaluate_batch`.

    ``model`` may be a single `EnergyModel` (returns a `SuiteGrid`) or a
    `sram.ModelTable` (returns a `SuiteVariationGrid` with a leading
    variant axis on every metric): the model constants are traced
    operands, so the whole circuits x variants x topologies x recipes
    hypercube is one compile and one device call.

    ``feasible``: optional ``(n_circuits, n_topologies)`` bool mask of
    capacity-feasible topologies per circuit (Alg. I line 9); defaults to
    all-feasible, as in `evaluate_batch`.

    ``lazy=True`` keeps the metric tensors device-resident (materialized
    to numpy on first access — see `_LazyArrays`).
    """
    _, evaluate = _suite_grids()
    table, is_sweep = _as_table(model)
    _check_topo_axis(table, topos)
    feasible = _suite_feasible(suite, topos, feasible)
    with enable_x64():
        out = evaluate(
            suite.ops, suite.n_levels, topos.ops_per_cycle,
            topos.macros_per_type, topos.is_single, topos.total_bits,
            topos.rows, topos.cols, _model_params(table), discipline, mode,
        )
        sched, mets = _layout_outputs(out, lazy)
        return _build_suite_grid(
            suite, topos, table, model, is_sweep, mode, discipline,
            feasible, sched, mets,
        )


# ---------------------------------------------------------------------------
# Device-resident pipeline: fused evaluate + select, variant sharding
# ---------------------------------------------------------------------------
#
# The host-side `select_best_batch` below pulls the full (C, V, T, R)
# metric tensors off the device and reduces them to (C, V) winner
# indices — for a large Monte-Carlo sweep the dominant cost is the
# device->host transfer of data that is immediately thrown away.  The
# fused kernels run the same three-tier masked argmin *inside* the
# jitted evaluate pass, so only the winners + per-winner metrics cross
# the host boundary; the full tensors stay device-resident and back the
# lazy grids.  `select_best_batch` remains the parity reference the
# tests check the fused winners against.


def _select_core(energy, fits, feasible, latency, max_latency, use_latency):
    """`select_best_batch`'s three-tier masking as pure jnp ops.

    ``energy``/``latency`` are ``(..., V, N)``; ``fits``/``feasible``
    are model-free ``(..., 1, N)`` masks broadcast across the variant
    axis.  ``use_latency`` is a trace-time static (presence of the
    latency tier changes the graph); ``max_latency`` itself is traced so
    changing the bound never recompiles.  Returns per-cell winner
    indices and a per-cell any-finite flag (the all-non-finite error is
    raised host-side — the flag is part of the small payload).
    """
    finite = jnp.isfinite(energy)
    tier2 = fits & finite
    tier1 = tier2 & feasible
    if use_latency:
        tier1 = tier1 & (latency <= max_latency)
    idx = _masked_tier_argmin(energy, (tier1, tier2, finite), xp=jnp)
    return idx, finite.any(axis=-1)


def _fused_tail(out, feasible, max_latency, use_latency):
    """Select + gather appended to the evaluate kernels, rank-generic:
    ``out`` metrics are ``(V, R, T)`` (single circuit) or ``(C, V, R, T)``
    (suite); ``feasible`` is ``(T,)`` / ``(C, T)``.

    Returns the final-layout schedule/metric tensors (these stay on
    device for the lazy grids) plus the small selection payload: winner
    indices, per-winner metrics, each variant's latency and the capacity
    flag at the *nominal* (variant-0) winner cell — everything the yield
    summary needs without touching the full tensors.
    """
    sched = {k: jnp.swapaxes(out[k], -1, -2) for k in _SCHED_KEYS}
    mets = {k: jnp.swapaxes(out[k], -1, -2) for k in _METRIC_KEYS}
    fits = sched["fits"]                              # (..., T, R)
    n = fits.shape[-2] * fits.shape[-1]

    def flat(m):  # (..., T, R) -> (..., T*R), flat topology-major
        return m.reshape(m.shape[:-2] + (n,))

    energy, latency = flat(mets["energy_nj"]), flat(mets["latency_ns"])
    fits_f = flat(fits)[..., None, :]                 # (..., 1, N)
    feas = jnp.broadcast_to(feasible[..., :, None], fits.shape)
    feas_f = flat(feas)[..., None, :]
    idx, has_finite = _select_core(
        energy, fits_f, feas_f, latency, max_latency, use_latency
    )                                                 # (..., V)

    def take(m):  # metric value at each cell's winner
        return jnp.take_along_axis(flat(m), idx[..., None], axis=-1)[..., 0]

    winner_mets = {k: take(mets[k]) for k in _METRIC_KEYS}
    # Each variant's latency / the capacity flag at the variant-0 winner.
    idx0 = idx[..., :1]
    nominal_latency = jnp.take_along_axis(
        latency, jnp.broadcast_to(idx0[..., None], idx.shape + (1,)), axis=-1
    )[..., 0]
    nominal_fits = jnp.take_along_axis(flat(fits), idx0, axis=-1)[..., 0]
    return dict(
        sched=sched,
        mets=mets,
        winner_idx=idx.astype(jnp.int32),
        has_finite=has_finite,
        winner_mets=winner_mets,
        nominal_latency=nominal_latency,
        nominal_fits=nominal_fits,
    )


def _jit_fused(fn):
    # Donate the per-variant model operands: they are consumed by the
    # kernel and never reused, so on accelerator backends XLA may alias
    # their buffers into the outputs.  CPU cannot use donated buffers
    # (jax would warn on every call), so the gate is per-backend.
    donate = () if jax.default_backend() == "cpu" else ("params",)
    return jax.jit(
        fn,
        static_argnames=("discipline", "mode", "use_latency"),
        donate_argnames=donate,
    )


def _make_fused_grid():
    def fn(ops, n_levels, width, mpt, is_single, total_bits, rows, cols,
           params, feasible, max_latency, discipline, mode, use_latency):
        TRACE_COUNTS["fused_grid"] += 1
        out = _evaluate_core(
            ops, n_levels, width, mpt, is_single, total_bits, rows, cols,
            params, discipline, mode,
        )
        return _fused_tail(out, feasible, max_latency, use_latency)

    return _jit_fused(fn)


def _make_fused_suite():
    def fn(ops, n_levels, width, mpt, is_single, total_bits, rows, cols,
           params, feasible, max_latency, discipline, mode, use_latency):
        TRACE_COUNTS["fused_suite"] += 1

        def per_circuit(o, nl):
            return _evaluate_core(
                o, nl, width, mpt, is_single, total_bits, rows, cols,
                params, discipline, mode,
            )

        out = jax.vmap(per_circuit)(ops, n_levels)
        return _fused_tail(out, feasible, max_latency, use_latency)

    return _jit_fused(fn)


_FUSED_GRID = None
_FUSED_SUITE = None


def _fused_kernels():
    global _FUSED_GRID, _FUSED_SUITE
    _load_jax()
    if _FUSED_GRID is None:
        _FUSED_GRID = _make_fused_grid()
        _FUSED_SUITE = _make_fused_suite()
    return _FUSED_GRID, _FUSED_SUITE


def _shard_variants(
    params: ModelParams, shard: "bool | None"
) -> tuple[ModelParams, bool]:
    """Lay the per-variant model operands out across the available
    devices.  The variant axis is embarrassingly parallel (each variant
    reads the same schedule), so a `NamedSharding` over the leading axis
    of every `ModelParams` leaf is enough for XLA's GSPMD partitioner to
    shard the whole fused evaluate+select kernel along it.

    ``shard=None`` (auto): shard when more than one device is visible
    and the variant count divides evenly; ``False``: never; ``True``:
    force a mesh even on one device (a 1-device mesh is bit-identical to
    the unsharded path — the sharded-equals-unsharded contract the tests
    pin).  Indivisible variant counts fall back to fewer devices (worst
    case 1) rather than padding, keeping results exact.
    """
    if shard is False:
        return params, False
    devs = jax.devices()
    n = len(devs)
    if shard is None and n == 1:
        return params, False
    v = int(np.shape(params.f_clk_hz)[0])
    while n > 1 and v % n:
        n -= 1
    if shard is None and n == 1:
        return params, False
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.asarray(devs[:n]), ("variants",))  # repro: host-boundary
    spec = NamedSharding(mesh, PartitionSpec("variants"))
    return jax.device_put(params, spec), True


@dataclasses.dataclass(frozen=True)
class SelectionResult:
    """What the fused pipeline brings back across the host boundary: the
    winners of every (circuit, variant) cell plus their metrics — a few
    KB where the host-side filter transferred the full float64
    (C, V, T, R) tensors.

    ``winner_idx`` holds flat topology-major indices (``grid.unravel``
    decodes them), shaped ``(V,)`` for a single-circuit sweep and
    ``(C, V)`` for a suite (V=1 when a single `EnergyModel` was
    evaluated).  ``nominal_latency_ns`` / ``nominal_fits`` are each
    variant's latency / the capacity flag at the *nominal* (variant-0)
    winner cell — the inputs of the latency-yield figure.
    ``payload_bytes`` is the actual number of bytes materialized to
    host for this result.
    """

    winner_idx: np.ndarray            # (V,) or (C, V) int32
    winner_metrics: dict[str, np.ndarray]  # each (V,) or (C, V) float64
    nominal_latency_ns: np.ndarray    # (V,) or (C, V)
    nominal_fits: np.ndarray          # () or (C,) bool
    payload_bytes: int
    sharded: bool

    @property
    def winner_energy_nj(self) -> np.ndarray:
        return self.winner_metrics["energy_nj"]


def _fetch_selection(res, sharded: bool) -> SelectionResult:
    """Materialize the small selection payload (this is the only
    device->host transfer of the fused path) and apply the host-side
    all-non-finite check that `select_best_batch` raises eagerly."""
    has_finite = np.asarray(res["has_finite"])  # repro: host-boundary
    if not has_finite.all():
        raise ValueError(
            "fused selection: a batch cell has no finite energies"
        )
    winner_idx = np.asarray(res["winner_idx"])  # repro: host-boundary
    winner_mets = {k: np.asarray(v) for k, v in res["winner_mets"].items()}  # repro: host-boundary
    nominal_latency = np.asarray(res["nominal_latency"])  # repro: host-boundary
    nominal_fits = np.asarray(res["nominal_fits"])  # repro: host-boundary
    payload = (
        winner_idx.nbytes
        + has_finite.nbytes
        + nominal_latency.nbytes
        + nominal_fits.nbytes
        + sum(v.nbytes for v in winner_mets.values())
    )
    return SelectionResult(
        winner_idx=winner_idx,
        winner_metrics=winner_mets,
        nominal_latency_ns=nominal_latency,
        nominal_fits=nominal_fits,
        payload_bytes=payload,
        sharded=sharded,
    )


def evaluate_select_batch(
    work: WorkloadTable,
    topos: TopologyTable,
    model: "EnergyModel | ModelTable | None" = None,
    mode: str = "physical",
    discipline: str = "list",
    feasible: np.ndarray | None = None,
    max_latency_ns: float | None = None,
    lazy: bool = True,
    shard: "bool | None" = None,
) -> "tuple[ExplorationGrid | VariationGrid, SelectionResult]":
    """`evaluate_batch` with the FilterEnergy stage fused into the same
    jitted pass: schedule, evaluate, and the three-tier masked argmin run
    on device, and only the (V,) winner indices + per-winner metrics are
    transferred.  The grid is returned lazy by default — its full metric
    tensors stay device-resident until (unless) someone reads them.

    ``shard`` controls multi-device execution of the variant axis (see
    `_shard_variants`); the single-device path is bit-identical to
    `evaluate_batch` + `select_best_batch`.
    """
    fused_grid, _ = _fused_kernels()
    table, is_sweep = _as_table(model)
    _check_topo_axis(table, topos)
    feasible = _grid_feasible(topos, feasible)
    use_latency = max_latency_ns is not None
    with enable_x64():
        params, sharded = _shard_variants(_model_params(table), shard)
        res = fused_grid(
            work.ops, work.n_levels, topos.ops_per_cycle,
            topos.macros_per_type, topos.is_single, topos.total_bits,
            topos.rows, topos.cols, params, feasible,
            np.float64(max_latency_ns if use_latency else 0.0),
            discipline, mode, use_latency,
        )
        sel = _fetch_selection(res, sharded)
        sched, mets = _fused_outputs(res, lazy)
        grid = _build_grid(
            work, topos, table, model, is_sweep, mode, discipline,
            feasible, sched, mets,
        )
    return grid, sel


def evaluate_select_suite(
    suite: SuiteTable,
    topos: TopologyTable,
    model: "EnergyModel | ModelTable | None" = None,
    mode: str = "physical",
    discipline: str = "list",
    feasible: np.ndarray | None = None,
    max_latency_ns: float | None = None,
    lazy: bool = True,
    shard: "bool | None" = None,
) -> "tuple[SuiteGrid | SuiteVariationGrid, SelectionResult]":
    """The suite-level fused pipeline: circuits x variants x topologies x
    recipes evaluated AND filtered in one jitted device call.  Only the
    ``(C, V)`` winner indices + per-winner metrics cross the host
    boundary; the full metric tensors back the returned lazy grid and
    are materialized only on access.

    Winner parity with the host path (`evaluate_suite` +
    `SuiteVariationGrid.best_indices`) is exact — same tiering, same
    lowest-flat-index tie-breaking, same all-non-finite error — and is
    pinned by tests/test_fused.py.
    """
    _, fused_suite = _fused_kernels()
    table, is_sweep = _as_table(model)
    _check_topo_axis(table, topos)
    feasible = _suite_feasible(suite, topos, feasible)
    use_latency = max_latency_ns is not None
    with enable_x64():
        params, sharded = _shard_variants(_model_params(table), shard)
        res = fused_suite(
            suite.ops, suite.n_levels, topos.ops_per_cycle,
            topos.macros_per_type, topos.is_single, topos.total_bits,
            topos.rows, topos.cols, params, feasible,
            np.float64(max_latency_ns if use_latency else 0.0),
            discipline, mode, use_latency,
        )
        sel = _fetch_selection(res, sharded)
        sched, mets = _fused_outputs(res, lazy)
        grid = _build_suite_grid(
            suite, topos, table, model, is_sweep, mode, discipline,
            feasible, sched, mets,
        )
    return grid, sel


_SELECT_BATCH = None


def _make_select_batch():
    def fn(energy, fits, feasible, latency, max_latency, use_latency):
        TRACE_COUNTS["select_batch"] += 1
        return _select_core(
            energy, fits, feasible, latency, max_latency, use_latency
        )

    return jax.jit(fn, static_argnames=("use_latency",))


def select_best_batch_device(
    energy,
    fits,
    latency=None,
    max_latency: float | None = None,
    feasible=None,
) -> np.ndarray:
    """`select_best_batch` with the three-tier argmin run as a jitted
    device reduction — the standalone fused filter for callers whose
    metrics are already arrays (the mesh explorer's constant sweeps).

    Same semantics as the host version: tiering, lowest-flat-index
    tie-breaking, non-finite energies inadmissible everywhere, ValueError
    on an empty grid or an all-non-finite batch cell.  Absent
    latency/feasible constraints are passed as dummies that drop out of
    the masking algebra (``fits`` as feasible leaves tier 1 == tier 2),
    so only toggling the latency tier — not any operand value —
    retraces.
    """
    global _SELECT_BATCH
    _load_jax()
    if _SELECT_BATCH is None:
        _SELECT_BATCH = _make_select_batch()

    def host_cast(x, dtype):
        # Device arrays (the service's re-rank path) go straight into
        # the jitted reduction — forcing them through np.asarray here
        # would materialize the full (V, N) tensors per request, the
        # exact transfer the device-side selection exists to avoid.
        if isinstance(x, jax.Array):
            return x
        return np.asarray(x, dtype=dtype)  # repro: host-boundary

    energy = host_cast(energy, np.float64)
    if energy.size == 0 or energy.shape[-1] == 0:
        raise ValueError("select_best_batch on an empty grid")
    fits = host_cast(fits, bool)
    use_latency = max_latency is not None and latency is not None
    with enable_x64():
        idx, has_finite = _SELECT_BATCH(
            energy,
            fits,
            host_cast(feasible, bool) if feasible is not None else fits,
            # scalar dummy: the use_latency=False graph never reads it,
            # and a scalar avoids shipping the energy array twice
            host_cast(latency, np.float64)
            if use_latency
            else np.float64(0.0),
            np.float64(max_latency if use_latency else 0.0),
            use_latency,
        )
        # winner payload only — (…, V) indices + flags, never the grid
        idx = np.asarray(idx, dtype=np.int64)  # repro: host-boundary
        has_finite = np.asarray(has_finite)  # repro: host-boundary
    if not has_finite.all():
        raise ValueError(
            "select_best_batch: a batch cell has no finite energies"
        )
    return idx


# ---------------------------------------------------------------------------
# Shared admissibility filter + argmin (FilterEnergy)
# ---------------------------------------------------------------------------


def _masked_tier_argmin(energy, tiers, xp=np):
    """Per-batch-cell argmin over the first non-empty tier.

    ``energy``: (..., N); ``tiers``: bool arrays of the same shape, most
    restrictive first.  Each batch cell uses its own first tier with any
    admissible entry; ties break to the lowest index along the last axis
    (``argmin`` returns the first occurrence).  Pure array ops on the
    ``xp`` namespace (numpy by default, ``jax.numpy`` under jit), so the
    mesh/TPU path can fuse the filter after evaluate.
    """
    pool = tiers[-1]
    for tier in tiers[-2::-1]:
        pool = xp.where(tier.any(axis=-1, keepdims=True), tier, pool)
    return xp.argmin(xp.where(pool, energy, xp.inf), axis=-1)


def select_best_batch(
    energy,
    fits,
    latency=None,
    max_latency: float | None = None,
    feasible=None,
) -> np.ndarray:
    """Batched `select_best`: winners for every batch cell in one masked
    three-tier argmin pass — no per-variant python loop.

    ``energy`` is ``(..., N)`` with the candidate implementations along
    the LAST axis (flat C-order, e.g. a raveled topology-major (T, R)
    grid) and arbitrary batch axes in front — ``(V, T*R)`` for one
    circuit's variant sweep, ``(C, V, T*R)`` for a whole suite.
    ``fits`` / ``latency`` / ``feasible`` broadcast against ``energy``,
    so model-free masks are passed once (e.g. ``(C, 1, T*R)``) and serve
    every variant row.

    Tiering, tie-breaking (lowest flat index), and NaN handling are
    exactly `select_best`'s, applied independently per batch cell;
    raises if any batch cell has no finite energy at all.

    Returns int64 winner indices of shape ``energy.shape[:-1]``.
    """
    energy = np.asarray(energy, dtype=float)
    if energy.size == 0 or energy.shape[-1] == 0:
        raise ValueError("select_best_batch on an empty grid")
    finite = np.isfinite(energy)
    if not finite.any(axis=-1).all():
        raise ValueError(
            "select_best_batch: a batch cell has no finite energies"
        )
    tier2 = np.broadcast_to(np.asarray(fits, dtype=bool), energy.shape) & finite
    tier1 = tier2
    if feasible is not None:
        tier1 = tier1 & np.broadcast_to(
            np.asarray(feasible, dtype=bool), energy.shape
        )
    if max_latency is not None and latency is not None:
        tier1 = tier1 & (
            np.broadcast_to(np.asarray(latency, dtype=float), energy.shape)
            <= max_latency
        )
    return _masked_tier_argmin(energy, (tier1, tier2, finite))


def select_best(
    energy,
    fits,
    latency=None,
    max_latency: float | None = None,
    feasible=None,
) -> int:
    """Alg. I line 14 — lowest-energy admissible implementation.

    Args:
        energy: energies, any shape (nJ for the SRAM explorer, J for the
            mesh explorer — only the ordering matters).
        fits: bool mask, same shape — capacity check (4 bits/gate).
        latency: optional latencies (same unit as ``max_latency``; ns for
            the SRAM explorer, s for the mesh explorer).
        max_latency: optional admissibility bound on ``latency``.
        feasible: optional bool mask — Alg. I line 9 topology feasibility.

    Admissibility tiers, in order (first non-empty pool wins, matching
    both `explorer.explore` and `mesh_explorer.explore_mesh`):

      1. fits capacity AND (feasible if given) AND (latency constraint
         if given),
      2. fits capacity,
      3. everything with a finite energy.

    Non-finite energies (NaN / ±inf — e.g. a pathological Monte-Carlo
    variant) are inadmissible in every tier; if *all* energies are
    non-finite there is no winner and a ValueError is raised.

    Returns the flat C-order index of the winner; ties break to the
    lowest flat index, like ``min`` over the scalar evaluation list.

    The single-cell view of `select_best_batch` — one implementation of
    the filter serves the scalar explorers, the variation sweeps, and
    the mesh explorer alike.
    """
    energy = np.asarray(energy, dtype=float).ravel()
    if energy.size == 0:
        raise ValueError("select_best on an empty grid")
    return int(
        select_best_batch(
            energy[None, :],
            np.asarray(fits, dtype=bool).ravel()[None, :],
            latency=None
            if latency is None
            else np.asarray(latency, dtype=float).ravel()[None, :],
            max_latency=max_latency,
            feasible=None
            if feasible is None
            else np.asarray(feasible, dtype=bool).ravel()[None, :],
        )[0]
    )


def winner_summary(winner_keys: Sequence[str]) -> tuple[dict[str, float], float]:
    """Yield arithmetic shared by the SRAM and mesh variation summaries:
    the share of variants each winning implementation takes, and the
    fraction of variants agreeing with the nominal (first) winner."""
    if not winner_keys:
        raise ValueError("winner_summary on an empty sweep")
    counts = collections.Counter(winner_keys)
    n = len(winner_keys)
    share = {k: c / n for k, c in counts.items()}
    return share, counts[winner_keys[0]] / n


def select_best_worst(energy, fits) -> tuple[int, int]:
    """Table I companion: (argmin, argmax) energy over the fitting pool
    (or over everything when nothing fits).  Non-finite energies are
    inadmissible at both ends; all-non-finite raises."""
    energy = np.asarray(energy, dtype=float).ravel()
    if energy.size == 0:
        raise ValueError("select_best_worst on an empty grid")
    finite = np.isfinite(energy)
    if not finite.any():
        raise ValueError("select_best_worst: all energies are non-finite")
    pool = np.asarray(fits, dtype=bool).ravel() & finite
    if not pool.any():
        pool = finite
    best = int(np.argmin(np.where(pool, energy, np.inf)))
    worst = int(np.argmax(np.where(pool, energy, -np.inf)))
    return best, worst


# ---------------------------------------------------------------------------
# Batched Table II metrics (standalone per-topology figures)
# ---------------------------------------------------------------------------


class _BroadcastModel(NamedTuple):
    """`table2_arrays`-compatible view of a `ModelTable` with every field
    shaped (V, 1) — so the same expressions broadcast against (T,)
    topology arrays into (V, T) outputs."""

    f_clk_hz: np.ndarray
    e_op_fj: tuple
    p_ctrl_mw: np.ndarray
    pipeline_utilization: np.ndarray


def table2_batch(
    topos: TopologyTable,
    model: "EnergyModel | ModelTable | None" = None,
    nor_fraction: float = 0.5,
) -> dict[str, np.ndarray]:
    """Vectorized ``sram.table2_metrics`` over a TopologyTable — the same
    ``sram.table2_arrays`` expressions, one array pass.  Outputs are (T,)
    for a single `EnergyModel`, (V, T) for a `ModelTable` of variants
    (whose scalar fields may be per-topology ``(V, T)``)."""
    # `is None`, not falsiness — ModelTable defines __len__, so an `or`
    # here would silently swap a falsy table for the nominal model.
    if model is None:
        model = EnergyModel()
    w = topos.ops_per_cycle.astype(float) * topos.n_macros
    if isinstance(model, ModelTable):
        _check_topo_axis(model, topos)
        e3 = model.e_op_fj  # (V, 3) -> (V, 1) columns; (V, T, 3) -> (V, T)
        shim = _BroadcastModel(
            f_clk_hz=_per_topo(model.f_clk_hz),
            e_op_fj=tuple(
                (e3[:, :, k] if e3.ndim == 3 else e3[:, k: k + 1])
                for k in range(3)
            ),
            p_ctrl_mw=_per_topo(model.p_ctrl_mw),
            pipeline_utilization=_per_topo(model.pipeline_utilization),
        )
        return table2_arrays(
            w[None, :], topos.area_mm2(model), shim, nor_fraction
        )
    return table2_arrays(w, topos.area_mm2(model), model, nor_fraction)


# ---------------------------------------------------------------------------
# Kernel registration (static analyzer)
# ---------------------------------------------------------------------------
# Each builder returns a *fresh* jit wrapper plus small-but-representative
# operands; `repro.analysis.jaxpr_lint` abstract-traces through them (no
# device work) to verify the trace-counter, dtype, const, and donation
# discipline of every kernel at lint time.


def _example_operands() -> dict:
    """Tiny but shape-representative kernel operands: T=2 topologies,
    R=2 recipes, L=4 levels, V=2 model variants, C=2 circuits — the same
    dtypes and axis layout production tables carry."""
    _load_jax()
    lvl = np.array(
        [[2, 1, 0], [1, 0, 1], [1, 2, 1], [0, 1, 1]], dtype=np.int32
    )                                                    # (L, 3)
    ops = np.stack([lvl, lvl[::-1]])                     # (R, L, 3)
    v = 2
    params = ModelParams(
        f_clk_hz=np.full((v,), 1.0e9),
        e_op_marginal_fj=np.full((v, 3), 5.0),
        p_ctrl_mw=np.full((v,), 0.1),
        e_macro_cycle_fj=np.full((v,), 10.0),
        e_col_cycle_fj=np.full((v,), 1.0),
        alpha_mw_per_level=np.full((v,), 0.01),
        pipeline_utilization=np.full((v,), 0.9),
    )
    return dict(
        ops=ops,
        n_levels=np.array([4, 3], dtype=np.int32),
        width=np.array([4, 8], dtype=np.int32),
        mpt=np.array([[1, 1, 1], [2, 1, 1]], dtype=np.int32),
        is_single=np.array([True, False]),
        total_bits=np.array([1024, 4096], dtype=np.int32),
        rows=np.array([16, 32], dtype=np.int32),
        cols=np.array([16, 32], dtype=np.int32),
        params=params,
        suite_ops=np.stack([ops, ops]),                  # (C, R, L, 3)
        suite_n_levels=np.array([[4, 3], [3, 4]], dtype=np.int32),
        feasible=np.array([True, True]),
        suite_feasible=np.ones((2, 2), dtype=bool),
        max_latency=np.float64(1.0e6),
    )


def _sched_args(o, suite):
    ops = o["suite_ops"] if suite else o["ops"]
    nl = o["suite_n_levels"] if suite else o["n_levels"]
    return (
        ops, nl, o["width"], o["mpt"], o["is_single"], o["total_bits"],
        o["rows"],
    )


def _ex_schedule(maker, suite):
    def build():
        o = _example_operands()
        return _registry.KernelExample(
            fn=maker(),
            args=_sched_args(o, suite),
            statics={"discipline": "list"},
        )

    return build


def _ex_evaluate(maker, suite):
    def build():
        o = _example_operands()
        return _registry.KernelExample(
            fn=maker(),
            args=_sched_args(o, suite) + (o["cols"], o["params"]),
            statics={"discipline": "list", "mode": "physical"},
        )

    return build


def _ex_fused(maker, suite):
    def build():
        o = _example_operands()
        feas = o["suite_feasible"] if suite else o["feasible"]
        return _registry.KernelExample(
            fn=maker(),
            args=_sched_args(o, suite)
            + (o["cols"], o["params"], feas, o["max_latency"]),
            statics={
                "discipline": "list", "mode": "physical",
                "use_latency": True,
            },
            # mirror _jit_fused's backend gate: donation only declared
            # where XLA can use it
            donate_argnames=()
            if jax.default_backend() == "cpu"
            else ("params",),
        )

    return build


def _ex_select_batch():
    _load_jax()
    energy = np.array([[1.0, 2.0, 3.0], [3.0, 1.0, 2.0]])     # (V, N)
    masks = np.array([[True, True, False]])                    # (1, N)
    latency = np.full((2, 3), 5.0)
    return _registry.KernelExample(
        fn=_make_select_batch(),
        args=(energy, masks, masks, latency, np.float64(10.0)),
        statics={"use_latency": True},
    )


_registry.register_kernel(
    "schedule_grid", __name__, _ex_schedule(_make_schedule_grid, False)
)
_registry.register_kernel(
    "schedule_suite", __name__, _ex_schedule(_make_schedule_suite, True)
)
_registry.register_kernel(
    "evaluate_grid", __name__, _ex_evaluate(_make_evaluate_grid, False)
)
_registry.register_kernel(
    "evaluate_suite", __name__, _ex_evaluate(_make_evaluate_suite, True)
)
_registry.register_kernel(
    "fused_grid", __name__, _ex_fused(_make_fused_grid, False)
)
_registry.register_kernel(
    "fused_suite", __name__, _ex_fused(_make_fused_suite, True)
)
_registry.register_kernel("select_batch", __name__, _ex_select_batch)
