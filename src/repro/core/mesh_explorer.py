"""Beyond-paper: Algorithm I re-targeted at the TPU mesh/sharding space.

The paper's tool maps a *workload* (an AIG characterized per level) onto a
*memory-compute topology* (SRAM macro library) by sweeping an analytical
energy/latency model and returning the argmin.  The TPU instantiation maps
one (arch x shape) workload onto a topology library of mesh shapes and a
recipe library of step-lowering options, with the three-term roofline from
the compiled dry-run as the latency model and a bytes-moved energy proxy:

    paper                      | here
    ---------------------------+---------------------------------------
    AIG synthesis recipe (64)  | step recipe (remat, accum, chunking)
    SRAM topology library (12) | mesh library ((16,16), (32,8), ...)
    analytical power/latency   | roofline terms from lower().compile()
    capacity check (4b/gate)   | memory_analysis fits 16 GB HBM
    FilterEnergy -> argmin     | argmin(energy proxy) s.t. latency, HBM
    inductor sizing            | collective schedule report

Energy proxy constants (order-of-magnitude, vendor-typical for 5nm-class
accelerators): 0.6 pJ/flop (bf16), 10 pJ/byte HBM, 25 pJ/byte ICI.

Usage:
    PYTHONPATH=src python -m repro.core.mesh_explorer --arch gemma3-27b \
        --shape train_4k
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from .batch import (
    jax_available,
    select_best,
    select_best_batch,
    select_best_batch_device,
    winner_summary,
)

PJ_PER_FLOP = 0.6e-12
PJ_PER_HBM_BYTE = 10e-12
PJ_PER_LINK_BYTE = 25e-12
HBM_GB = 16.0

# The energy-proxy constants as a named variant (J/flop, J/byte) — the
# mesh analogue of `sram.ModelTable`'s nominal row.
NOMINAL_CONSTANTS = dict(
    pj_per_flop=PJ_PER_FLOP,
    pj_per_hbm_byte=PJ_PER_HBM_BYTE,
    pj_per_link_byte=PJ_PER_LINK_BYTE,
)


def constant_corners(spread: float = 0.25) -> list[dict]:
    """Nominal + low/high corners of the energy-proxy constants (vendor
    figures are order-of-magnitude; the corners bound how sensitive the
    argmin is to them).  Variant 0 is nominal, like `sram.ModelTable`."""

    def scaled(k: float) -> dict:
        return {n: v * k for n, v in NOMINAL_CONSTANTS.items()}

    return [dict(NOMINAL_CONSTANTS), scaled(1.0 - spread), scaled(1.0 + spread)]


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """One entry of the 'SRAM topology library' analogue."""

    name: str
    multi_pod: bool = False
    mesh_shape: tuple | None = None  # e.g. (32, 8) single-pod DPxTP


@dataclasses.dataclass(frozen=True)
class StepRecipe:
    """One entry of the 'synthesis recipe' analogue."""

    name: str
    remat: str = "full"
    grad_accum: int = 1
    q_chunk: int = 1024
    kv_chunk: int = 1024
    cast_bf16: bool = False
    shard_grads: bool = False

    def overrides(self) -> dict:
        return dict(remat=self.remat, grad_accum=self.grad_accum,
                    q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
                    cast_bf16=self.cast_bf16, shard_grads=self.shard_grads)


DEFAULT_RECIPES = (
    StepRecipe("base"),
    StepRecipe("bf16cast", cast_bf16=True),
    StepRecipe("bf16+rs", cast_bf16=True, shard_grads=True),
    StepRecipe("accum4", grad_accum=4),
    StepRecipe("chunk2048", q_chunk=2048, kv_chunk=2048),
    StepRecipe("remat-block", remat="block"),
)

DEFAULT_TOPOLOGIES = (
    MeshTopology("single-16x16"),
    MeshTopology("single-32x8", mesh_shape=(32, 8)),
    MeshTopology("single-64x4", mesh_shape=(64, 4)),
    MeshTopology("multi-2x16x16", multi_pod=True),
)


@dataclasses.dataclass
class MeshEvaluation:
    topo: str
    recipe: str
    latency_s: float
    energy_j: float
    hbm_gb: float
    fits: bool
    bottleneck: str
    record: dict


def energy_proxy(rec: dict) -> float:
    r = rec["roofline"]
    chips = rec["n_chips"]
    return chips * (
        r["flops"] * PJ_PER_FLOP
        + r["hbm_bytes"] * PJ_PER_HBM_BYTE
        + r["link_bytes"] * PJ_PER_LINK_BYTE
    )


def _sweep_workload(
    arch: str,
    shape: str,
    topologies,
    recipes,
    out_dir: str,
) -> list[MeshEvaluation]:
    """Evaluate the full topology x recipe grid for one (arch, shape)."""
    from repro.launch.dryrun import run_cell

    evals: list[MeshEvaluation] = []
    for topo in topologies:
        for rec in recipes:
            record = run_cell(
                arch, shape, topo.multi_pod, out_dir,
                overrides=rec.overrides(), tag=f"{topo.name}__{rec.name}",
                mesh_shape=topo.mesh_shape,
            )
            if "skipped" in record:
                continue
            r = record["roofline"]
            lat = max(r["compute_s"], r["memory_s"], r["collective_s"])
            hbm = record["hbm_per_device_gb"]
            evals.append(
                MeshEvaluation(
                    topo=topo.name, recipe=rec.name, latency_s=lat,
                    energy_j=energy_proxy(record), hbm_gb=hbm,
                    fits=hbm <= HBM_GB, bottleneck=r["bottleneck"],
                    record=record,
                )
            )
    return evals


def variation_summary(
    evals: list[MeshEvaluation],
    variants: "list[dict]",
    max_latency_s: float | None = None,
) -> dict:
    """Per-variant winners + yield over an energy-constant sweep — the
    mesh analogue of `explorer.VariationResult`.  One vectorized
    ``(V, N)`` energy matrix, then ONE shared selection pass for every
    variant's winner — the device reduction
    (`select_best_batch_device`) when jax is available, the host
    `select_best_batch` otherwise (identical winners either way; the
    parity is pinned in tests/test_selection.py).  Variant 0 is the
    nominal constants."""
    comp = np.array(
        [
            [
                e.record["roofline"]["flops"],
                e.record["roofline"]["hbm_bytes"],
                e.record["roofline"]["link_bytes"],
            ]
            for e in evals
        ]
    )  # (N, 3)
    chips = np.array([e.record["n_chips"] for e in evals], dtype=float)
    k = np.array(
        [
            [v["pj_per_flop"], v["pj_per_hbm_byte"], v["pj_per_link_byte"]]
            for v in variants
        ]
    )  # (V, 3)
    # Same operation order as `energy_proxy` — chips * (f*kf + h*kh + l*kl)
    # — so a nominal-constants variant ranks identically to the headline
    # `best` pick, last-ulp ties included.
    energy = chips[None, :] * (
        k[:, 0:1] * comp[None, :, 0]
        + k[:, 1:2] * comp[None, :, 1]
        + k[:, 2:3] * comp[None, :, 2]
    )  # (V, N)
    fits = np.array([e.fits for e in evals])
    lat = np.array([e.latency_s for e in evals])
    # Availability is probed up front (a mid-call except would also
    # swallow genuine jax failures).  The first device call per (V, N)
    # shape pays a jit trace — noise next to the dry-run compiles that
    # produced `evals` — and keeps the filter on device alongside the
    # SRAM explorer's fused path.
    select = select_best_batch_device if jax_available() else select_best_batch
    idx = select(
        energy, fits[None, :], latency=lat[None, :],
        max_latency=max_latency_s,
    )
    winners = [
        dict(topo=evals[int(i)].topo, recipe=evals[int(i)].recipe)
        for i in idx
    ]
    share, best_yield = winner_summary(
        [f"{w['topo']}/{w['recipe']}" for w in winners]
    )
    return dict(
        n_variants=len(variants),
        winners=winners,
        winner_share=share,
        best_yield=best_yield,
    )


def _pick_best(
    evals: list[MeshEvaluation], max_latency_s: float | None
) -> MeshEvaluation:
    # FilterEnergy: the same admissibility-filter + argmin the SRAM
    # explorer uses (core/batch.py), over the stacked evaluation arrays.
    return evals[
        select_best(
            np.array([e.energy_j for e in evals]),
            np.array([e.fits for e in evals]),
            latency=np.array([e.latency_s for e in evals]),
            max_latency=max_latency_s,
        )
    ]


def explore_mesh(
    arch: str,
    shape: str,
    topologies=DEFAULT_TOPOLOGIES,
    recipes=DEFAULT_RECIPES,
    out_dir: str = "runs/mesh_explorer",
    max_latency_s: float | None = None,
    constant_sweep: "list[dict] | None" = None,
) -> dict:
    """Algorithm I over the mesh/recipe space.  Returns the full sweep plus
    the min-energy admissible pick.  ``constant_sweep`` (a list of
    energy-constant dicts, e.g. `constant_corners()`) additionally
    reports per-variant winners + yield under a ``"variation"`` key."""
    evals = _sweep_workload(arch, shape, topologies, recipes, out_dir)
    best = _pick_best(evals, max_latency_s)
    out = dict(
        arch=arch, shape=shape,
        best=dict(topo=best.topo, recipe=best.recipe,
                  latency_s=best.latency_s, energy_j=best.energy_j,
                  bottleneck=best.bottleneck, hbm_gb=best.hbm_gb),
        sweep=[dataclasses.asdict(e) | {"record": None} for e in evals],
    )
    if constant_sweep:
        out["variation"] = variation_summary(
            evals, list(constant_sweep), max_latency_s
        )
    return out


def explore_mesh_suite(
    workloads: "list[tuple[str, str]]",
    topologies=DEFAULT_TOPOLOGIES,
    recipes=DEFAULT_RECIPES,
    out_dir: str = "runs/mesh_explorer",
    max_latency_s: float | None = None,
    constant_sweep: "list[dict] | None" = None,
) -> dict:
    """The suite path for the TPU instantiation: sweep several
    (arch, shape) workloads over one topology x recipe grid — the
    mesh analogue of `explorer.explore_suite`'s circuits axis.

    Compile records are shared through `run_cell`'s on-disk run directory
    (the dry-run layer's own persistent cache), so overlapping workloads
    across calls do not recompile.  Returns ``{"workloads": {"arch/shape":
    {best, sweep}}, "best": ...}`` with the global min-energy admissible
    pick across the whole suite.
    """
    out: dict = {"workloads": {}}
    tagged: list[tuple[str, MeshEvaluation]] = []
    for arch, shape in workloads:
        evals = _sweep_workload(arch, shape, topologies, recipes, out_dir)
        key = f"{arch}/{shape}"
        out["workloads"][key] = dict(
            best=dataclasses.asdict(_pick_best(evals, max_latency_s))
            | {"record": None},
            sweep=[dataclasses.asdict(e) | {"record": None} for e in evals],
        )
        if constant_sweep:
            out["workloads"][key]["variation"] = variation_summary(
                evals, list(constant_sweep), max_latency_s
            )
        tagged.extend((key, e) for e in evals)
    best_key, best = tagged[
        select_best(
            np.array([e.energy_j for _, e in tagged]),
            np.array([e.fits for _, e in tagged]),
            latency=np.array([e.latency_s for _, e in tagged]),
            max_latency=max_latency_s,
        )
    ]
    out["best"] = dataclasses.asdict(best) | {
        "record": None, "workload": best_key
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="architecture, or comma list for a suite sweep")
    ap.add_argument("--shape", default="train_4k",
                    help="shape, or comma list; a suite sweep covers the "
                         "full arch x shape product")
    ap.add_argument("--max-latency-s", type=float, default=None)
    ap.add_argument("--corner-spread", type=float, default=None,
                    help="sweep the energy-proxy constants over +-x "
                         "corners and report per-variant winners + yield")
    args = ap.parse_args()
    sweep = (
        constant_corners(args.corner_spread)
        if args.corner_spread is not None else None
    )
    archs = args.arch.split(",")
    shapes = args.shape.split(",")
    if len(archs) > 1 or len(shapes) > 1:
        workloads = [(a, s) for a in archs for s in shapes]
        res = explore_mesh_suite(workloads, max_latency_s=args.max_latency_s,
                                 constant_sweep=sweep)
        print(json.dumps(res["best"], indent=1))
        for key, wl in res["workloads"].items():
            b = wl["best"]
            print(f"  {key:28s} -> {b['topo']:16s} {b['recipe']:12s} "
                  f"lat={b['latency_s']:.4f}s E={b['energy_j']:.1f}J")
            if "variation" in wl:
                v = wl["variation"]
                print(f"    constants sweep: best_yield={v['best_yield']:.2f} "
                      f"share={v['winner_share']}")
        return
    res = explore_mesh(args.arch, args.shape, max_latency_s=args.max_latency_s,
                       constant_sweep=sweep)
    print(json.dumps(res["best"], indent=1))
    for e in res["sweep"]:
        print(f"  {e['topo']:16s} {e['recipe']:12s} lat={e['latency_s']:.4f}s "
              f"E={e['energy_j']:.1f}J hbm={e['hbm_gb']:.1f}GB {e['bottleneck']}")
    if "variation" in res:
        v = res["variation"]
        print(f"  constants sweep: best_yield={v['best_yield']:.2f} "
              f"share={v['winner_share']}")


if __name__ == "__main__":
    main()
