"""Algorithm I — mapping combinational logic workloads to the optimal
resonant cache architecture.

Faithful implementation of the paper's Algorithm I / Fig. 8 flow:

    1.  CreateAIG(RTL, AIGsyn_opt)          -> 64 recipe AIGs (prefix-cached)
    2.  ChaAIG(aig) per AIG                 -> levels + per-level op counts
    3.  IdentifyOptOpeAIG                   -> min total gate count
    4.  IdentifyOptLogAIG                   -> min level count
    5.  IdentifySRAM                        -> capacity-feasible topologies
    6.  Evaluate(aig, sram) for both AIGs   -> power/latency/energy metrics
    7.  FilterEnergy                        -> min-energy (AIG, topology)
    8.  CalculateInductor                   -> resonant L for chosen topology

The "RTL netlist" input is an `Aig` (our circuits.py generators play the
role of YOSYS elaboration).  ``explore`` additionally returns every
(recipe x topology) evaluation so the Fig 9 / Table I benchmarks can sweep
all 64 x 12 = 768 implementations per circuit (6912 over the 9-circuit
suite, matching the paper's 6900+ claim).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

from .aig import Aig, AigStats
from .mapping import MappingResult, schedule_stats
from .sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    Metrics,
    SramTopology,
    evaluate,
    inductor_size_nh,
)
from .transforms import RecipeRunner, enumerate_recipes


@dataclasses.dataclass
class Evaluation:
    recipe: tuple[str, ...]
    topo: SramTopology
    stats: AigStats
    schedule: MappingResult
    metrics: Metrics


@dataclasses.dataclass
class ExplorationResult:
    """Output of Algorithm I (+ the full sweep for the benchmarks)."""

    circuit: str
    best: Evaluation                 # min-energy feasible implementation
    inductor_nh: float
    opt_gate_recipe: tuple[str, ...]  # IdentifyOptOpeAIG
    opt_level_recipe: tuple[str, ...]  # IdentifyOptLogAIG
    evaluations: list[Evaluation]    # every (recipe, topo) pair evaluated
    n_recipes: int
    wall_s: float

    def table_row(self) -> dict:
        m = self.best.metrics
        s = self.best.stats
        return dict(
            benchmark=self.circuit,
            sram_macro_kb=self.best.topo.macro_kb,
            macro_count=self.best.topo.n_macros,
            recipe=",".join(self.best.recipe) or "(none)",
            levels=s.n_levels,
            nand=s.nand_count,
            nor=s.nor_count,
            inv=s.inv_count,
            power_mw=round(m.power_mw, 3),
            latency_ns=round(m.latency_ns, 3),
            energy_nj=round(m.energy_nj, 6),
            inductor_nh=round(self.inductor_nh, 3),
        )


def explore(
    rtl: Aig,
    sram_list: Sequence[SramTopology] = TOPOLOGY_LIBRARY,
    recipes: Sequence[tuple[str, ...]] | None = None,
    model: EnergyModel | None = None,
    mode: str = "physical",
    full_sweep: bool = True,
    max_latency_ns: float | None = None,
) -> ExplorationResult:
    """Algorithm I.  ``full_sweep=True`` evaluates every recipe x topology
    (what Fig 9 reports); ``False`` restricts line 10-13 to the two optimal
    AIGs exactly as the pseudocode does."""
    t0 = time.time()
    model = model or EnergyModel()
    recipes = list(recipes) if recipes is not None else enumerate_recipes()
    runner = RecipeRunner(rtl)

    # Lines 3-6: create + characterize.  Include the un-transformed AIG as
    # the implicit baseline recipe ().
    all_recipes: list[tuple[str, ...]] = [()] + [tuple(r) for r in recipes]
    cha: dict[tuple[str, ...], AigStats] = {}
    for r in all_recipes:
        aig = runner.run(r)
        cha[r] = aig.characterize()

    # Lines 7-8: optimal-ops and optimal-levels AIGs.
    opt_gate = min(cha, key=lambda r: (cha[r].total_gates, cha[r].n_levels))
    opt_level = min(cha, key=lambda r: (cha[r].n_levels, cha[r].total_gates))

    # Line 9: capacity-feasible topologies for the candidate AIGs.
    min_gates = min(cha[opt_gate].total_gates, cha[opt_level].total_gates)
    feasible = [t for t in sram_list if t.total_bits >= 4 * min_gates]
    if not feasible:
        feasible = [max(sram_list, key=lambda t: t.total_bits)]

    # Lines 10-13 (+ optional full sweep for Fig 9).
    sweep_recipes = all_recipes if full_sweep else [opt_gate, opt_level]
    evaluations: list[Evaluation] = []
    for topo in sram_list if full_sweep else feasible:
        for r in sweep_recipes:
            sched = schedule_stats(cha[r], topo)
            met = evaluate(sched, topo, model, mode=mode)
            evaluations.append(Evaluation(r, topo, cha[r], sched, met))

    # Line 14: lowest-energy among *feasible* implementations honoring the
    # caller's latency constraint (the tool's stated contract: "tailored to
    # the specified input memory and latency constraints").
    def admissible(e: Evaluation) -> bool:
        if not e.schedule.fits or e.topo not in feasible:
            return False
        if max_latency_ns is not None and e.metrics.latency_ns > max_latency_ns:
            return False
        return True

    pool = [e for e in evaluations if admissible(e)]
    if not pool:
        pool = [e for e in evaluations if e.schedule.fits] or evaluations
    best = min(pool, key=lambda e: e.metrics.energy_nj)

    # Line 15: inductor sizing for the chosen topology.
    l_nh = inductor_size_nh(best.topo, model)

    return ExplorationResult(
        circuit=rtl.name,
        best=best,
        inductor_nh=l_nh,
        opt_gate_recipe=opt_gate,
        opt_level_recipe=opt_level,
        evaluations=evaluations,
        n_recipes=len(all_recipes),
        wall_s=time.time() - t0,
    )


def best_worst(result: ExplorationResult) -> tuple[Evaluation, Evaluation]:
    """Table I companion: best- and worst-case feasible implementations."""
    pool = [e for e in result.evaluations if e.schedule.fits]
    pool = pool or result.evaluations
    best = min(pool, key=lambda e: e.metrics.energy_nj)
    worst = max(pool, key=lambda e: e.metrics.energy_nj)
    return best, worst
