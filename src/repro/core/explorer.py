"""Algorithm I — mapping combinational logic workloads to the optimal
resonant cache architecture.

Faithful implementation of the paper's Algorithm I / Fig. 8 flow:

    1.  CreateAIG(RTL, AIGsyn_opt)          -> 64 recipe AIGs (prefix-cached)
    2.  ChaAIG(aig) per AIG                 -> levels + per-level op counts
    3.  IdentifyOptOpeAIG                   -> min total gate count
    4.  IdentifyOptLogAIG                   -> min level count
    5.  IdentifySRAM                        -> capacity-feasible topologies
    6.  Evaluate(aig, sram) for both AIGs   -> power/latency/energy metrics
    7.  FilterEnergy                        -> min-energy (AIG, topology)
    8.  CalculateInductor                   -> resonant L for chosen topology

The "RTL netlist" input is an `Aig` (our circuits.py generators play the
role of YOSYS elaboration).  ``explore`` additionally returns every
(recipe x topology) evaluation so the Fig 9 / Table I benchmarks can sweep
all 64 x 12 = 768 implementations per circuit (6912 over the 9-circuit
suite, matching the paper's 6900+ claim).

Two backends drive the back half (ChaAIG -> Evaluate -> FilterEnergy):

  * ``backend="python"`` — the original per-pair scalar loop over
    `mapping.schedule_stats` + `sram.evaluate`; kept as the parity
    reference.  The sweep lands in ``ExplorationResult.evaluations``.
  * ``backend="jax"``    — the tensorized engine (`core/batch.py`): the
    full recipe x topology grid is scheduled, evaluated, and filtered in
    one jitted array pass.  The sweep lands in ``ExplorationResult.grid``
    and ``best`` is re-materialized through the scalar model for an
    exactly-comparable `Evaluation`.

Suite-level entry point: `explore_suite` runs Algorithm I over a whole
benchmark suite at once — the front half through
`transforms.characterize_suite` (shared-prefix DAG, on-disk cache,
process pool) and the back half through one `batch.evaluate_suite` call
vmapped over circuits x recipes x topologies.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Mapping, Sequence

import numpy as np

from .aig import Aig, AigStats
from .batch import (
    ExplorationGrid,
    SelectionResult,
    SuiteTable,
    TopologyTable,
    VariationGrid,
    WorkloadTable,
    evaluate_batch,
    evaluate_select_batch,
    evaluate_select_suite,
    evaluate_suite,
    winner_summary,
)
from .mapping import BITS_PER_GATE, MappingResult, schedule_stats
from .sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    Metrics,
    ModelTable,
    SramTopology,
    evaluate,
    inductor_size_nh,
)
from .transforms import (
    CharacterizationCache,
    enumerate_recipes,
    characterize_suite,
)


@dataclasses.dataclass
class Evaluation:
    recipe: tuple[str, ...]
    topo: SramTopology
    stats: AigStats
    schedule: MappingResult
    metrics: Metrics


#: Quantiles reported by `VariationResult.energy_quantiles` — median plus
#: the quartiles and the 5%/95% tails of the per-variant winner energy.
ENERGY_QUANTILES = (0.05, 0.25, 0.5, 0.75, 0.95)


@dataclasses.dataclass
class VariationResult:
    """Yield-style summary of a model-variant sweep for one circuit.

    Variant 0 of ``models`` is the nominal model (the `ModelTable`
    generators' convention); the yield figures measure how robust the
    nominal pick is across the other variants — the paper's fourth FoM.
    For large-N Monte-Carlo sweeps the winner shares alone hide the
    distribution tails, so the per-variant winner energy is summarized
    as quantiles (``energy_quantiles``) and as conditional
    value-at-risk (`cvar`).
    """

    models: ModelTable
    grid: VariationGrid              # the (V, T, R) sweep itself
    winners: list[tuple[tuple[str, ...], SramTopology]]  # per variant
    winner_share: dict[str, float]   # "topo/recipe" -> fraction of variants won
    best_yield: float    # fraction of variants where the nominal winner stays best
    latency_yield: float  # fraction where the nominal winner fits + meets
    #                       the latency constraint under that variant's clock
    winner_energy_nj: np.ndarray     # (V,) each variant's winning energy
    energy_quantiles: dict[float, float]  # ENERGY_QUANTILES of the above

    @property
    def n_variants(self) -> int:
        return len(self.models)

    def cvar(self, alpha: float = 0.9) -> float:
        """Conditional value-at-risk (expected shortfall) of the
        per-variant winner energy: the mean over the worst
        (highest-energy) ``1 - alpha`` tail of variants.  ``cvar(0.9)``
        answers "when silicon lands in the bad 10% of the model
        distribution, what energy do we expect?" — a tail figure winner
        shares cannot express."""
        if not 0.0 <= alpha < 1.0:
            raise ValueError(f"alpha must be in [0, 1), got {alpha}")
        e = np.sort(self.winner_energy_nj)
        k = max(1, int(np.ceil((1.0 - alpha) * e.size)))
        return float(e[-k:].mean())


@dataclasses.dataclass
class ExplorationResult:
    """Output of Algorithm I (+ the full sweep for the benchmarks)."""

    circuit: str
    best: Evaluation                 # min-energy feasible implementation
    inductor_nh: float
    opt_gate_recipe: tuple[str, ...]  # IdentifyOptOpeAIG
    opt_level_recipe: tuple[str, ...]  # IdentifyOptLogAIG
    evaluations: list[Evaluation]    # scalar sweep (backend="python")
    n_recipes: int
    wall_s: float
    backend: str = "python"
    grid: ExplorationGrid | None = None  # batched sweep (backend="jax")
    cha: dict[tuple[str, ...], AigStats] | None = None
    variation: VariationResult | None = None  # model_sweep summary

    @property
    def n_evaluations(self) -> int:
        return self.grid.size if self.grid is not None else len(self.evaluations)

    def sweep_energies(self, fits_only: bool = True) -> np.ndarray:
        """Energy of every swept implementation, from whichever sweep
        representation this result carries."""
        if self.grid is not None:
            return (
                self.grid.fit_energies()
                if fits_only
                else self.grid.energy_nj.ravel()
            )
        pool = [
            e.metrics.energy_nj
            for e in self.evaluations
            if e.schedule.fits or not fits_only
        ]
        return np.asarray(pool)

    def table_row(self) -> dict:
        m = self.best.metrics
        s = self.best.stats
        return dict(
            benchmark=self.circuit,
            sram_macro_kb=self.best.topo.macro_kb,
            macro_count=self.best.topo.n_macros,
            recipe=",".join(self.best.recipe) or "(none)",
            levels=s.n_levels,
            nand=s.nand_count,
            nor=s.nor_count,
            inv=s.inv_count,
            power_mw=round(m.power_mw, 3),
            latency_ns=round(m.latency_ns, 3),
            energy_nj=round(m.energy_nj, 6),
            inductor_nh=round(self.inductor_nh, 3),
        )


def characterize_recipes(
    rtl: Aig,
    recipes: Sequence[tuple[str, ...]] | None = None,
    cache: "CharacterizationCache | str | os.PathLike | None" = None,
    n_jobs: int | None = 1,
    cha_backend: str = "auto",
) -> dict[tuple[str, ...], AigStats]:
    """Alg. I lines 3-6: create + characterize every recipe AIG, including
    the un-transformed baseline recipe ``()`` first.

    Thin single-circuit wrapper over `transforms.characterize_suite`:
    ``cache`` (a `CharacterizationCache` or a directory path) makes the
    result persistent across runs, ``n_jobs`` > 1 characterizes
    independent prefix branches on a process pool (default serial — one
    circuit rarely amortizes worker startup).  ``cha_backend`` picks the
    transform engine: ``"device"`` (batched `kernels.aig_sim` truth
    tables), ``"python"`` (the bigint parity reference), or ``"auto"``.
    """
    return characterize_suite(
        {rtl.name: rtl},
        recipes,
        cache=cache,
        n_jobs=n_jobs,
        backend=cha_backend,
    )[rtl.name]


def _materialize(
    recipe: tuple[str, ...],
    topo: SramTopology,
    stats: AigStats,
    model: EnergyModel,
    mode: str,
    discipline: str,
) -> Evaluation:
    """Scalar-path Evaluation for one grid cell (used to surface the argmin
    of a batched sweep as a full dataclass, bit-identical to the python
    backend's pick)."""
    sched = schedule_stats(stats, topo, discipline=discipline)
    met = evaluate(sched, topo, model, mode=mode)
    return Evaluation(recipe, topo, stats, sched, met)


def _restrict_cha(
    cha: Mapping[tuple[str, ...], AigStats],
    recipes: Sequence[tuple[str, ...]] | None,
) -> dict[tuple[str, ...], AigStats]:
    """Validate a characterization map and honor a recipes restriction."""
    cha = dict(cha)
    if recipes is not None:
        wanted = list(dict.fromkeys([()] + [tuple(r) for r in recipes]))
        missing = [r for r in wanted if r not in cha]
        if missing:
            raise ValueError(f"cha is missing requested recipes {missing}")
        cha = {r: cha[r] for r in wanted}
    if () not in cha:
        raise ValueError("cha must include the baseline recipe ()")
    return cha


def _opt_and_feasible(
    cha: Mapping[tuple[str, ...], AigStats],
    sram_list: Sequence[SramTopology],
) -> tuple[tuple[str, ...], tuple[str, ...], list[SramTopology]]:
    """Alg. I lines 7-9: optimal-ops / optimal-levels recipes and the
    capacity-feasible topology subset for those candidates."""
    opt_gate = min(cha, key=lambda r: (cha[r].total_gates, cha[r].n_levels))
    opt_level = min(cha, key=lambda r: (cha[r].n_levels, cha[r].total_gates))
    min_gates = min(cha[opt_gate].total_gates, cha[opt_level].total_gates)
    feasible = [
        t for t in sram_list if t.total_bits >= BITS_PER_GATE * min_gates
    ]
    if not feasible:
        feasible = [max(sram_list, key=lambda t: t.total_bits)]
    return opt_gate, opt_level, feasible


def explore(
    rtl: Aig,
    sram_list: Sequence[SramTopology] = TOPOLOGY_LIBRARY,
    recipes: Sequence[tuple[str, ...]] | None = None,
    model: EnergyModel | None = None,
    mode: str = "physical",
    full_sweep: bool = True,
    max_latency_ns: float | None = None,
    backend: str = "python",
    discipline: str = "list",
    cha: Mapping[tuple[str, ...], AigStats] | None = None,
    cache: "CharacterizationCache | str | os.PathLike | None" = None,
    n_jobs: int | None = 1,
    fused: bool = True,
    cha_backend: str = "auto",
) -> ExplorationResult:
    """Algorithm I for one circuit.

    Args:
        rtl: the input AIG (circuits.py generators play YOSYS elaboration).
        sram_list: candidate topologies — the paper's 12-entry
            `TOPOLOGY_LIBRARY` or a programmatic `sram.topology_grid`.
        recipes: synthesis recipes to sweep (default: all 64 ordered
            permutations; the baseline ``()`` is always included).
        model: `EnergyModel` constants (default: paper-calibrated).
        mode: energy accounting — ``"physical"`` decomposition or the
            paper's Table-I ``"paper"`` arithmetic.
        full_sweep: ``True`` evaluates every recipe x topology (what Fig 9
            reports); ``False`` restricts lines 10-13 to the two optimal
            AIGs exactly as the pseudocode does.
        max_latency_ns: optional latency admissibility bound (ns).
        backend: ``"python"`` scalar reference loop or ``"jax"`` batched
            grid (`core/batch.py`).
        discipline: cycle schedule — ``"list"`` (ASAP, default) or the
            paper's lock-step ``"levels"``.
        cha: precomputed characterizations (`characterize_recipes` output,
            must include ``()``) so repeated sweeps skip the transforms.
        cache: persistent characterization cache (path or
            `CharacterizationCache`) consulted when ``cha`` is None.
        n_jobs: process-pool width for characterization (1 = serial).
        fused: with ``backend="jax"``, run FilterEnergy on device in the
            same jitted pass (`batch.evaluate_select_batch`) so only the
            winner crosses the host boundary and the grid stays lazy;
            ``False`` keeps the host-side `select_best` path.
        cha_backend: transform engine for the *front* half —
            ``"device"`` (batched `kernels.aig_sim` truth tables),
            ``"python"`` (bigint parity reference), or ``"auto"``
            (device when jax is importable).  Independent of
            ``backend``, which picks the back-half sweep engine.

    Returns:
        `ExplorationResult`: the min-energy admissible implementation
        (``best``, energies in nJ, latencies in ns, cycle counts exact
        ints), the chosen inductor size (nH), and the full sweep
        (``evaluations`` list or batched ``grid``).
    """
    if backend not in ("python", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    t0 = time.time()
    if model is None:
        model = EnergyModel()

    # Lines 3-6: create + characterize (or reuse the caller's cache).
    if cha is None:
        cha = characterize_recipes(
            rtl, recipes, cache=cache, n_jobs=n_jobs, cha_backend=cha_backend
        )
    cha = _restrict_cha(cha, recipes)
    all_recipes = list(cha)

    # Lines 7-9: optimal AIGs + capacity-feasible topologies.
    opt_gate, opt_level, feasible = _opt_and_feasible(cha, sram_list)

    # Lines 10-13 (+ optional full sweep for Fig 9).
    sweep_recipes = all_recipes if full_sweep else [opt_gate, opt_level]
    sweep_topos = list(sram_list) if full_sweep else list(feasible)

    evaluations: list[Evaluation] = []
    grid: ExplorationGrid | None = None
    if backend == "python":
        for topo in sweep_topos:
            for r in sweep_recipes:
                sched = schedule_stats(cha[r], topo, discipline=discipline)
                met = evaluate(sched, topo, model, mode=mode)
                evaluations.append(Evaluation(r, topo, cha[r], sched, met))

        # Line 14: lowest-energy among *feasible* implementations honoring
        # the caller's latency constraint (the tool's stated contract:
        # "tailored to the specified input memory and latency constraints").
        def admissible(e: Evaluation) -> bool:
            if not e.schedule.fits or e.topo not in feasible:
                return False
            if max_latency_ns is not None and e.metrics.latency_ns > max_latency_ns:
                return False
            return True

        pool = [e for e in evaluations if admissible(e)]
        if not pool:
            pool = [e for e in evaluations if e.schedule.fits] or evaluations
        best = min(pool, key=lambda e: e.metrics.energy_nj)
    else:
        work = WorkloadTable.from_stats([(r, cha[r]) for r in sweep_recipes])
        topo_table = TopologyTable.from_topologies(sweep_topos)
        feas = np.array([t in feasible for t in sweep_topos], dtype=bool)
        if fused:
            # Device-resident back half: evaluate + FilterEnergy in one
            # jitted pass; only the winner index leaves the device and
            # the grid materializes lazily if anyone reads it.
            grid, sel = evaluate_select_batch(
                work, topo_table, model, mode=mode, discipline=discipline,
                feasible=feas, max_latency_ns=max_latency_ns, lazy=True,
            )
            best_flat = int(sel.winner_idx[0])  # V=1: one winner
        else:
            grid = evaluate_batch(
                work, topo_table, model, mode=mode, discipline=discipline,
                feasible=feas,
            )
            best_flat = grid.best_index(max_latency_ns)
        # Line 14 on the grid; re-materialize the winner through the scalar
        # model so `best` is exactly the object the python backend returns.
        ti, ri = grid.unravel(best_flat)
        best = _materialize(
            sweep_recipes[ri], sweep_topos[ti], cha[sweep_recipes[ri]],
            model, mode, discipline,
        )

    # Line 15: inductor sizing for the chosen topology.
    l_nh = inductor_size_nh(best.topo, model)

    return ExplorationResult(
        circuit=rtl.name,
        best=best,
        inductor_nh=l_nh,
        opt_gate_recipe=opt_gate,
        opt_level_recipe=opt_level,
        evaluations=evaluations,
        n_recipes=len(all_recipes),
        wall_s=time.time() - t0,
        backend=backend,
        grid=grid,
        cha=cha,
    )


def _variation_result(
    vgrid: VariationGrid,
    max_latency_ns: float | None,
    idx: np.ndarray | None = None,
    winner_energy: np.ndarray | None = None,
    nominal_latency: np.ndarray | None = None,
    nominal_fits: "bool | None" = None,
) -> VariationResult:
    """Per-variant winners + yield summary for one circuit's sweep.

    ``idx``: precomputed ``(V,)`` winner indices.  The fused pipeline
    passes one row of the on-device `SelectionResult` — together with
    its per-winner energies (``winner_energy``) and the nominal-winner
    latencies/fits (``nominal_latency``/``nominal_fits``) the whole
    summary is computed without touching the full (V, T, R) tensors,
    which then stay device-resident.  Callers without a fused result
    (host fallback) omit them and the summary is derived from the grid.
    """
    if idx is None:
        idx = vgrid.best_indices(max_latency_ns)
    pairs = [vgrid.unravel(int(i)) for i in idx]
    winners = [(vgrid.recipes[ri], vgrid.topologies[ti]) for ti, ri in pairs]
    share, best_yield = winner_summary(
        [f"{topo.name}/{','.join(recipe) or '-'}" for recipe, topo in winners]
    )
    # Does the nominal (variant-0) winner stay admissible under each
    # variant?  Capacity is model-free; latency shifts with each
    # variant's achievable clock.
    ti0, ri0 = pairs[0]
    if nominal_fits is None:
        nominal_fits = bool(vgrid.fits[ti0, ri0])
    ok = np.full(len(idx), bool(nominal_fits))
    if max_latency_ns is not None:
        if nominal_latency is None:
            nominal_latency = vgrid.latency_ns[:, ti0, ri0]
        ok &= np.asarray(nominal_latency) <= max_latency_ns
    if winner_energy is None:
        flat = vgrid.energy_nj.reshape(len(idx), -1)
        winner_energy = flat[np.arange(len(idx)), np.asarray(idx)]
    winner_energy = np.asarray(winner_energy, dtype=float)
    quantiles = {
        q: float(np.quantile(winner_energy, q)) for q in ENERGY_QUANTILES
    }
    return VariationResult(
        models=vgrid.models,
        grid=vgrid,
        winners=winners,
        winner_share=share,
        best_yield=best_yield,
        latency_yield=float(np.mean(ok)),
        winner_energy_nj=winner_energy,
        energy_quantiles=quantiles,
    )


def explore_suite(
    circuits: Mapping[str, Aig],
    sram_list: Sequence[SramTopology] = TOPOLOGY_LIBRARY,
    recipes: Sequence[tuple[str, ...]] | None = None,
    model: EnergyModel | None = None,
    mode: str = "physical",
    max_latency_ns: float | None = None,
    backend: str = "jax",
    discipline: str = "list",
    cha: Mapping[str, Mapping[tuple[str, ...], AigStats]] | None = None,
    cache: "CharacterizationCache | str | os.PathLike | None" = None,
    n_jobs: int | None = None,
    model_sweep: ModelTable | None = None,
    fused: bool = True,
    shard: "bool | None" = None,
    cha_backend: str = "auto",
) -> dict[str, ExplorationResult]:
    """Algorithm I over a whole benchmark suite in two device-sized steps.

    Front half: one `transforms.characterize_suite` call — the 64-recipe
    prefix DAG per circuit with structural dedup, optional persistent
    ``cache``, and a process pool over independent branches and circuits
    (``n_jobs``, default ``min(4, cpu_count)``).  ``cha_backend`` picks
    its transform engine: ``"device"`` (batched `kernels.aig_sim` truth
    tables), ``"python"`` (bigint parity reference), or ``"auto"``.

    Back half (``backend="jax"``): the characterizations are stacked into
    a `batch.SuiteTable` and ONE `batch.evaluate_suite` call sweeps
    circuits x recipes x topologies; each circuit's `ExplorationGrid` is
    then a view into the stacked result.  ``backend="python"`` falls back
    to the scalar per-circuit loop (still sharing the suite front half).

    ``model_sweep``: a `sram.ModelTable` of energy-model variants
    (process corners, sensitivity grids, Monte-Carlo samples — variant 0
    is the nominal model).  Correlated (topology-dependent) tables —
    e.g. `ModelTable.bitcell_sigma_per_macro` keyed on ``sram_list``'s
    macro geometries — flow through the same kernels via their
    ``(V, T)`` fields.  The same single compile/device call then covers
    circuits x variants x topologies x recipes; the selection stage is
    one batched `select_best_batch` pass over every (circuit, variant)
    cell, and every result's ``variation`` field carries the
    per-variant winners and the yield summary (`VariationResult`).  The
    headline ``best``/``grid`` stay the nominal variant's, so downstream
    consumers are unchanged.  Mutually exclusive with ``model``;
    requires ``backend="jax"``.

    ``fused`` (default): the whole back half is device-resident — the
    three-tier FilterEnergy runs inside the same jitted pass
    (`batch.evaluate_select_suite`), only the (C, V) winner indices +
    per-winner metrics cross the host boundary, and each result's
    ``grid`` is a lazy view whose tensors materialize on first access.
    ``fused=False`` keeps the host-side `select_best_batch` path (the
    parity reference).  ``shard`` spreads the variant axis over the
    available devices (see `batch._shard_variants`; None = auto).

    Returns ``{circuit: ExplorationResult}`` in the input's order; each
    result's ``wall_s`` is the suite wall time divided evenly across
    circuits (the work is genuinely shared).
    """
    if backend not in ("python", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if model_sweep is not None:
        if model is not None:
            raise ValueError("pass either model or model_sweep, not both")
        if backend != "jax":
            raise ValueError("model_sweep requires backend='jax'")
        model = model_sweep.model(0)  # nominal, for best materialization
    t0 = time.time()
    if model is None:
        model = EnergyModel()

    if cha is None:
        cha = characterize_suite(
            circuits, recipes, cache=cache, n_jobs=n_jobs, backend=cha_backend
        )
    cha = {name: _restrict_cha(cha[name], recipes) for name in circuits}

    if backend == "python":
        out = {
            name: explore(
                rtl, sram_list, recipes, model, mode,
                max_latency_ns=max_latency_ns, backend="python",
                discipline=discipline, cha=cha[name],
            )
            for name, rtl in circuits.items()
        }
        wall = (time.time() - t0) / max(1, len(out))
        for res in out.values():
            res.wall_s = wall
        return out

    names = list(circuits)
    opt, feas_mask = {}, np.zeros((len(names), len(sram_list)), dtype=bool)
    sram_list = list(sram_list)
    for i, name in enumerate(names):
        opt_gate, opt_level, feasible = _opt_and_feasible(cha[name], sram_list)
        opt[name] = (opt_gate, opt_level)
        feas_mask[i] = [t in feasible for t in sram_list]

    suite = SuiteTable.from_cha(cha)
    topo_table = TopologyTable.from_topologies(sram_list)
    swept = model_sweep if model_sweep is not None else model
    sel: SelectionResult | None = None
    if fused:
        # Device-resident back half: evaluate + FilterEnergy fused into
        # one jitted (optionally variant-sharded) pass — only (C, V)
        # winner indices + per-winner metrics are transferred, and the
        # grids below are lazy device views.
        sg, sel = evaluate_select_suite(
            suite, topo_table, swept, mode=mode, discipline=discipline,
            feasible=feas_mask, max_latency_ns=max_latency_ns, lazy=True,
            shard=shard,
        )
    else:
        sg = evaluate_suite(
            suite, topo_table, swept,
            mode=mode, discipline=discipline, feasible=feas_mask,
        )

    out = {}
    wall = (time.time() - t0) / max(1, len(names))
    if sel is not None:
        suite_winners = sel.winner_idx  # (C, V) — computed on device
    elif model_sweep is not None:
        # Host selection stage for the whole hypercube: every (circuit,
        # variant) winner from ONE batched masked-argmin pass.
        suite_winners = sg.best_indices(max_latency_ns)  # (C, V)
    for i, name in enumerate(names):
        variation = None
        if model_sweep is not None:
            vgrid = sg.variation(name)
            variation = _variation_result(
                vgrid, max_latency_ns, idx=suite_winners[i],
                winner_energy=(
                    None if sel is None else sel.winner_energy_nj[i]
                ),
                nominal_latency=(
                    None if sel is None else sel.nominal_latency_ns[i]
                ),
                nominal_fits=(
                    None if sel is None else bool(sel.nominal_fits[i])
                ),
            )
            grid = vgrid.grid(0)  # nominal variant, the headline result
            # the batched pass already holds variant 0's winner under
            # the same tiers — no per-circuit re-selection needed
            best_flat = int(suite_winners[i, 0])
        elif sel is not None:
            grid = sg.grid(name)
            best_flat = int(sel.winner_idx[i, 0])  # V=1 hypercube
        else:
            grid = sg.grid(name)
            best_flat = grid.best_index(max_latency_ns)
        ti, ri = grid.unravel(best_flat)
        recipe, topo = grid.recipes[ri], sram_list[ti]
        best = _materialize(
            recipe, topo, cha[name][recipe], model, mode, discipline
        )
        out[name] = ExplorationResult(
            circuit=circuits[name].name,
            best=best,
            inductor_nh=inductor_size_nh(topo, model),
            opt_gate_recipe=opt[name][0],
            opt_level_recipe=opt[name][1],
            evaluations=[],
            n_recipes=len(cha[name]),
            wall_s=wall,
            backend=backend,
            grid=grid,
            cha=cha[name],
            variation=variation,
        )
    return out


def explore_request(
    rtl: Aig,
    sram_list: Sequence[SramTopology] = TOPOLOGY_LIBRARY,
    recipes: Sequence[tuple[str, ...]] | None = None,
    *,
    model: EnergyModel | None = None,
    model_sweep: ModelTable | None = None,
    max_memory_kb: float | None = None,
    max_latency_ns: float | None = None,
    mode: str = "physical",
    discipline: str = "list",
    cha: Mapping[tuple[str, ...], AigStats] | None = None,
    cache: "CharacterizationCache | str | os.PathLike | None" = None,
    n_jobs: int | None = 1,
    fused: bool = True,
    shard: "bool | None" = None,
    cha_backend: str = "auto",
) -> ExplorationResult:
    """Algorithm I for ONE production-style query: (circuit, memory
    budget, latency bound, variation spec) -> winner.

    This is the request-sized entry point the exploration service
    (`repro.serve.explore_service.ExplorationService`) answers at scale;
    calling it directly is the offline reference the service's
    padded/bucketed fast path is pinned bit-identical to (tier-1
    ``tests/test_service.py``).

    ``max_memory_kb`` is a *hard* memory budget: the candidate topology
    list is restricted to designs whose total capacity fits it before
    Algorithm I runs (capacity feasibility, tie-breaking, and the
    fallback tiers then all operate inside the budget).  An empty
    in-budget pool raises ``ValueError`` — the service surfaces that as
    a structured ``infeasible-memory`` error.  Everything else is
    `explore_suite` on the single-circuit suite.
    """
    pool = list(sram_list)
    if not pool:
        raise ValueError("empty sram_list")
    if max_memory_kb is not None:
        pool = [t for t in pool if t.total_kb <= max_memory_kb]
        if not pool:
            smallest = min(t.total_kb for t in sram_list)
            raise ValueError(
                f"no candidate topology fits the {max_memory_kb} KB memory "
                f"budget (smallest candidate is {smallest} KB)"
            )
    out = explore_suite(
        {rtl.name: rtl},
        pool,
        recipes,
        model=model,
        mode=mode,
        max_latency_ns=max_latency_ns,
        backend="jax",
        discipline=discipline,
        cha=None if cha is None else {rtl.name: cha},
        cache=cache,
        n_jobs=n_jobs,
        model_sweep=model_sweep,
        fused=fused,
        shard=shard,
        cha_backend=cha_backend,
    )
    return out[rtl.name]


def best_worst(result: ExplorationResult) -> tuple[Evaluation, Evaluation]:
    """Table I companion: best- and worst-case feasible implementations."""
    if result.grid is not None:
        if result.cha is None:
            raise ValueError(
                "grid-backed ExplorationResult needs .cha to materialize "
                "Evaluations (explore() always sets it)"
            )
        g = result.grid
        if g.model is None:
            raise ValueError(
                "this grid is a correlated-variant slice with no single "
                "scalar model; materialize cells via "
                "ModelTable.model(v, topology=...) instead"
            )
        i_best, i_worst = g.best_worst_indices()
        out = []
        for i in (i_best, i_worst):
            ti, ri = g.unravel(i)
            recipe, topo = g.recipes[ri], g.topologies[ti]
            out.append(
                _materialize(recipe, topo, result.cha[recipe],
                             g.model, g.mode, g.discipline)
            )
        return out[0], out[1]
    pool = [e for e in result.evaluations if e.schedule.fits]
    pool = pool or result.evaluations
    best = min(pool, key=lambda e: e.metrics.energy_nj)
    worst = max(pool, key=lambda e: e.metrics.energy_nj)
    return best, worst
