"""Algorithm I — mapping combinational logic workloads to the optimal
resonant cache architecture.

Faithful implementation of the paper's Algorithm I / Fig. 8 flow:

    1.  CreateAIG(RTL, AIGsyn_opt)          -> 64 recipe AIGs (prefix-cached)
    2.  ChaAIG(aig) per AIG                 -> levels + per-level op counts
    3.  IdentifyOptOpeAIG                   -> min total gate count
    4.  IdentifyOptLogAIG                   -> min level count
    5.  IdentifySRAM                        -> capacity-feasible topologies
    6.  Evaluate(aig, sram) for both AIGs   -> power/latency/energy metrics
    7.  FilterEnergy                        -> min-energy (AIG, topology)
    8.  CalculateInductor                   -> resonant L for chosen topology

The "RTL netlist" input is an `Aig` (our circuits.py generators play the
role of YOSYS elaboration).  ``explore`` additionally returns every
(recipe x topology) evaluation so the Fig 9 / Table I benchmarks can sweep
all 64 x 12 = 768 implementations per circuit (6912 over the 9-circuit
suite, matching the paper's 6900+ claim).

Two backends drive the back half (ChaAIG -> Evaluate -> FilterEnergy):

  * ``backend="python"`` — the original per-pair scalar loop over
    `mapping.schedule_stats` + `sram.evaluate`; kept as the parity
    reference.  The sweep lands in ``ExplorationResult.evaluations``.
  * ``backend="jax"``    — the tensorized engine (`core/batch.py`): the
    full recipe x topology grid is scheduled, evaluated, and filtered in
    one jitted array pass.  The sweep lands in ``ExplorationResult.grid``
    and ``best`` is re-materialized through the scalar model for an
    exactly-comparable `Evaluation`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

import numpy as np

from .aig import Aig, AigStats
from .batch import ExplorationGrid, TopologyTable, WorkloadTable, evaluate_batch
from .mapping import BITS_PER_GATE, MappingResult, schedule_stats
from .sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    Metrics,
    SramTopology,
    evaluate,
    inductor_size_nh,
)
from .transforms import RecipeRunner, enumerate_recipes


@dataclasses.dataclass
class Evaluation:
    recipe: tuple[str, ...]
    topo: SramTopology
    stats: AigStats
    schedule: MappingResult
    metrics: Metrics


@dataclasses.dataclass
class ExplorationResult:
    """Output of Algorithm I (+ the full sweep for the benchmarks)."""

    circuit: str
    best: Evaluation                 # min-energy feasible implementation
    inductor_nh: float
    opt_gate_recipe: tuple[str, ...]  # IdentifyOptOpeAIG
    opt_level_recipe: tuple[str, ...]  # IdentifyOptLogAIG
    evaluations: list[Evaluation]    # scalar sweep (backend="python")
    n_recipes: int
    wall_s: float
    backend: str = "python"
    grid: ExplorationGrid | None = None  # batched sweep (backend="jax")
    cha: dict[tuple[str, ...], AigStats] | None = None

    @property
    def n_evaluations(self) -> int:
        return self.grid.size if self.grid is not None else len(self.evaluations)

    def sweep_energies(self, fits_only: bool = True) -> np.ndarray:
        """Energy of every swept implementation, from whichever sweep
        representation this result carries."""
        if self.grid is not None:
            return (
                self.grid.fit_energies()
                if fits_only
                else self.grid.energy_nj.ravel()
            )
        pool = [
            e.metrics.energy_nj
            for e in self.evaluations
            if e.schedule.fits or not fits_only
        ]
        return np.asarray(pool)

    def table_row(self) -> dict:
        m = self.best.metrics
        s = self.best.stats
        return dict(
            benchmark=self.circuit,
            sram_macro_kb=self.best.topo.macro_kb,
            macro_count=self.best.topo.n_macros,
            recipe=",".join(self.best.recipe) or "(none)",
            levels=s.n_levels,
            nand=s.nand_count,
            nor=s.nor_count,
            inv=s.inv_count,
            power_mw=round(m.power_mw, 3),
            latency_ns=round(m.latency_ns, 3),
            energy_nj=round(m.energy_nj, 6),
            inductor_nh=round(self.inductor_nh, 3),
        )


def characterize_recipes(
    rtl: Aig, recipes: Sequence[tuple[str, ...]] | None = None
) -> dict[tuple[str, ...], AigStats]:
    """Alg. I lines 3-6: create + characterize every recipe AIG, including
    the un-transformed baseline recipe ``()`` first."""
    recipes = list(recipes) if recipes is not None else enumerate_recipes()
    runner = RecipeRunner(rtl)
    cha: dict[tuple[str, ...], AigStats] = {}
    for r in [()] + [tuple(x) for x in recipes]:
        if r not in cha:
            cha[r] = runner.run(r).characterize()
    return cha


def _materialize(
    recipe: tuple[str, ...],
    topo: SramTopology,
    stats: AigStats,
    model: EnergyModel,
    mode: str,
    discipline: str,
) -> Evaluation:
    """Scalar-path Evaluation for one grid cell (used to surface the argmin
    of a batched sweep as a full dataclass, bit-identical to the python
    backend's pick)."""
    sched = schedule_stats(stats, topo, discipline=discipline)
    met = evaluate(sched, topo, model, mode=mode)
    return Evaluation(recipe, topo, stats, sched, met)


def explore(
    rtl: Aig,
    sram_list: Sequence[SramTopology] = TOPOLOGY_LIBRARY,
    recipes: Sequence[tuple[str, ...]] | None = None,
    model: EnergyModel | None = None,
    mode: str = "physical",
    full_sweep: bool = True,
    max_latency_ns: float | None = None,
    backend: str = "python",
    discipline: str = "list",
    cha: Mapping[tuple[str, ...], AigStats] | None = None,
) -> ExplorationResult:
    """Algorithm I.  ``full_sweep=True`` evaluates every recipe x topology
    (what Fig 9 reports); ``False`` restricts line 10-13 to the two optimal
    AIGs exactly as the pseudocode does.

    ``cha`` may supply precomputed characterizations (as returned by
    `characterize_recipes`; must include the baseline recipe ``()``) so
    repeated sweeps — e.g. backend benchmarking — skip the transform runs.
    """
    if backend not in ("python", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    t0 = time.time()
    model = model or EnergyModel()

    # Lines 3-6: create + characterize (or reuse the caller's cache).
    if cha is None:
        cha = characterize_recipes(rtl, recipes)
    else:
        cha = dict(cha)
        if recipes is not None:
            # honor the recipes restriction even with a larger cache
            wanted = list(dict.fromkeys([()] + [tuple(r) for r in recipes]))
            missing = [r for r in wanted if r not in cha]
            if missing:
                raise ValueError(f"cha is missing requested recipes {missing}")
            cha = {r: cha[r] for r in wanted}
    if () not in cha:
        raise ValueError("cha must include the baseline recipe ()")
    all_recipes = list(cha)

    # Lines 7-8: optimal-ops and optimal-levels AIGs.
    opt_gate = min(cha, key=lambda r: (cha[r].total_gates, cha[r].n_levels))
    opt_level = min(cha, key=lambda r: (cha[r].n_levels, cha[r].total_gates))

    # Line 9: capacity-feasible topologies for the candidate AIGs.
    min_gates = min(cha[opt_gate].total_gates, cha[opt_level].total_gates)
    feasible = [t for t in sram_list if t.total_bits >= BITS_PER_GATE * min_gates]
    if not feasible:
        feasible = [max(sram_list, key=lambda t: t.total_bits)]

    # Lines 10-13 (+ optional full sweep for Fig 9).
    sweep_recipes = all_recipes if full_sweep else [opt_gate, opt_level]
    sweep_topos = list(sram_list) if full_sweep else list(feasible)

    evaluations: list[Evaluation] = []
    grid: ExplorationGrid | None = None
    if backend == "python":
        for topo in sweep_topos:
            for r in sweep_recipes:
                sched = schedule_stats(cha[r], topo, discipline=discipline)
                met = evaluate(sched, topo, model, mode=mode)
                evaluations.append(Evaluation(r, topo, cha[r], sched, met))

        # Line 14: lowest-energy among *feasible* implementations honoring
        # the caller's latency constraint (the tool's stated contract:
        # "tailored to the specified input memory and latency constraints").
        def admissible(e: Evaluation) -> bool:
            if not e.schedule.fits or e.topo not in feasible:
                return False
            if max_latency_ns is not None and e.metrics.latency_ns > max_latency_ns:
                return False
            return True

        pool = [e for e in evaluations if admissible(e)]
        if not pool:
            pool = [e for e in evaluations if e.schedule.fits] or evaluations
        best = min(pool, key=lambda e: e.metrics.energy_nj)
    else:
        work = WorkloadTable.from_stats([(r, cha[r]) for r in sweep_recipes])
        topo_table = TopologyTable.from_topologies(sweep_topos)
        grid = evaluate_batch(
            work,
            topo_table,
            model,
            mode=mode,
            discipline=discipline,
            feasible=np.array([t in feasible for t in sweep_topos], dtype=bool),
        )
        # Line 14 on the grid; re-materialize the winner through the scalar
        # model so `best` is exactly the object the python backend returns.
        ti, ri = grid.unravel(grid.best_index(max_latency_ns))
        best = _materialize(
            sweep_recipes[ri], sweep_topos[ti], cha[sweep_recipes[ri]],
            model, mode, discipline,
        )

    # Line 15: inductor sizing for the chosen topology.
    l_nh = inductor_size_nh(best.topo, model)

    return ExplorationResult(
        circuit=rtl.name,
        best=best,
        inductor_nh=l_nh,
        opt_gate_recipe=opt_gate,
        opt_level_recipe=opt_level,
        evaluations=evaluations,
        n_recipes=len(all_recipes),
        wall_s=time.time() - t0,
        backend=backend,
        grid=grid,
        cha=cha,
    )


def best_worst(result: ExplorationResult) -> tuple[Evaluation, Evaluation]:
    """Table I companion: best- and worst-case feasible implementations."""
    if result.grid is not None:
        if result.cha is None:
            raise ValueError(
                "grid-backed ExplorationResult needs .cha to materialize "
                "Evaluations (explore() always sets it)"
            )
        g = result.grid
        i_best, i_worst = g.best_worst_indices()
        out = []
        for i in (i_best, i_worst):
            ti, ri = g.unravel(i)
            recipe, topo = g.recipes[ri], g.topologies[ti]
            out.append(
                _materialize(recipe, topo, result.cha[recipe],
                             g.model, g.mode, g.discipline)
            )
        return out[0], out[1]
    pool = [e for e in result.evaluations if e.schedule.fits]
    pool = pool or result.evaluations
    best = min(pool, key=lambda e: e.metrics.energy_nj)
    worst = max(pool, key=lambda e: e.metrics.energy_nj)
    return best, worst
