"""EPFL-like combinational benchmark circuit generators.

The paper evaluates Algorithm I on the EPFL combinational benchmark suite
(Amaru et al., IWLS'15).  The EPFL netlists are not redistributable here, so
we generate gate-accurate circuits of the same nine families used in the
paper's Table I / Fig 9:

    adder-128, barrel-shifter, multiplier, sine, max, divisor,
    square-root, square, log2

Arithmetic circuits (adder / shifter / multiplier / max / divisor / sqrt /
square) are exact constructions with verified semantics (tests check them
against Python integer arithmetic).  ``sine`` is a fixed-point CORDIC
construction and ``log2`` a priority-encoder + polynomial-fraction
construction — same circuit *family* and comparable gate/level structure as
the EPFL versions (documented deviation; the exploration tool is agnostic to
the exact netlist).

Default bit-widths are scaled down from the EPFL sizes so the 64-recipe sweep
runs in CPU-minutes (the paper used server-class runs); ``scale="paper"``
restores full sizes.  Gate-count ORDER matches the paper (multiplier/divisor/
log2 largest; adder/shifter smallest).
"""

from __future__ import annotations

from typing import Callable, Sequence

from .aig import CONST0, CONST1, Aig, lit_not

Word = list[int]  # literals, LSB first


# ---------------------------------------------------------------------------
# Word-level builder helpers
# ---------------------------------------------------------------------------


def new_inputs(aig: Aig, n: int) -> Word:
    return [aig.add_pi() for _ in range(n)]


def full_adder(aig: Aig, a: int, b: int, c: int) -> tuple[int, int]:
    s = aig.g_xor(aig.g_xor(a, b), c)
    co = aig.g_maj(a, b, c)
    return s, co


def ripple_add(aig: Aig, a: Word, b: Word, cin: int = CONST0) -> tuple[Word, int]:
    assert len(a) == len(b)
    out: Word = []
    c = cin
    for x, y in zip(a, b):
        s, c = full_adder(aig, x, y, c)
        out.append(s)
    return out, c


def ripple_sub(aig: Aig, a: Word, b: Word) -> tuple[Word, int]:
    """a - b; returns (diff, no_borrow) where no_borrow=1 iff a >= b."""
    nb = [lit_not(x) for x in b]
    diff, c = ripple_add(aig, a, nb, CONST1)
    return diff, c


def brent_kung_add(aig: Aig, a: Word, b: Word, cin: int = CONST0) -> tuple[Word, int]:
    """Parallel-prefix (Brent-Kung) adder: depth O(log n), wide levels.

    This matches the level structure of the paper's benchmarks (Table I
    reports few, *wide* levels — e.g. adder-128 with ~350 ops/level),
    unlike a ripple adder whose AIG is deep and narrow.
    """
    n = len(a)
    assert len(b) == n
    g = [aig.g_and(x, y) for x, y in zip(a, b)]
    p = [aig.g_xor(x, y) for x, y in zip(a, b)]
    if cin != CONST0:
        g[0] = aig.g_or(g[0], aig.g_and(p[0], cin))
    # Up-sweep.
    gg = list(g)
    pp = list(p)
    span = 1
    while span < n:
        for i in range(2 * span - 1, n, 2 * span):
            j = i - span
            gg[i] = aig.g_or(gg[i], aig.g_and(pp[i], gg[j]))
            pp[i] = aig.g_and(pp[i], pp[j])
        span *= 2
    # Down-sweep.
    span //= 2
    while span >= 1:
        for i in range(3 * span - 1, n, 2 * span):
            j = i - span
            gg[i] = aig.g_or(gg[i], aig.g_and(pp[i], gg[j]))
        span //= 2
    # Sum bits: s_i = p_i ^ carry_{i-1}; carry_{i-1} = gg[i-1] (prefix G).
    s: Word = [p[0] if cin == CONST0 else aig.g_xor(p[0], cin)]
    for i in range(1, n):
        s.append(aig.g_xor(p[i], gg[i - 1]))
    return s, gg[n - 1]


def bk_sub(aig: Aig, a: Word, b: Word) -> tuple[Word, int]:
    """Parallel-prefix a - b; returns (diff, no_borrow)."""
    nb = [lit_not(x) for x in b]
    return brent_kung_add(aig, a, nb, CONST1)


def csa_reduce(aig: Aig, rows: list[Word], width: int) -> tuple[Word, Word]:
    """Wallace/CSA 3:2 reduction of addend rows down to two (wide levels)."""
    rows = [list(r[:width]) + [CONST0] * (width - len(r)) for r in rows]
    while len(rows) > 2:
        nxt: list[Word] = []
        i = 0
        while i + 2 < len(rows):
            x, y, z = rows[i], rows[i + 1], rows[i + 2]
            s_row: Word = []
            c_row: Word = [CONST0]
            for k in range(width):
                s, c = full_adder(aig, x[k], y[k], z[k])
                s_row.append(s)
                if k + 1 < width:
                    c_row.append(c)
            nxt.append(s_row)
            nxt.append(c_row[:width])
            i += 3
        nxt.extend(rows[i:])
        rows = nxt
    return rows[0], rows[1]


def mux_word(aig: Aig, sel: int, t: Word, f: Word) -> Word:
    assert len(t) == len(f)
    return [aig.g_mux(sel, x, y) for x, y in zip(t, f)]


def shift_left_const(w: Word, k: int) -> Word:
    n = len(w)
    return ([CONST0] * k + w)[:n]


def shift_right_const(w: Word, k: int, fill: int = CONST0) -> Word:
    n = len(w)
    return (w[k:] + [fill] * k)[:n]


def greater_equal(aig: Aig, a: Word, b: Word) -> int:
    _, ge = bk_sub(aig, a, b)
    return ge


def const_word(value: int, n: int) -> Word:
    return [CONST1 if (value >> i) & 1 else CONST0 for i in range(n)]


# ---------------------------------------------------------------------------
# Circuit generators
# ---------------------------------------------------------------------------


def gen_adder(n: int = 128) -> Aig:
    """Parallel-prefix (Brent-Kung) adder — few wide levels, like the
    paper's adder-128 (Table I: 4 levels, ~1400 gates)."""
    aig = Aig(name=f"adder-{n}")
    a = new_inputs(aig, n)
    b = new_inputs(aig, n)
    s, c = brent_kung_add(aig, a, b)
    for x in s:
        aig.add_po(x)
    aig.add_po(c)
    return aig.clone()


def gen_barrel_shifter(n: int = 64) -> Aig:
    """Logical right barrel shifter, log2(n) mux stages."""
    import math

    k = int(math.log2(n))
    assert (1 << k) == n
    aig = Aig(name=f"bar-{n}")
    data = new_inputs(aig, n)
    shamt = new_inputs(aig, k)
    w = data
    for i in range(k):
        shifted = shift_right_const(w, 1 << i)
        w = mux_word(aig, shamt[i], shifted, w)
    for x in w:
        aig.add_po(x)
    return aig.clone()


def gen_multiplier(n: int = 16) -> Aig:
    """n x n Wallace-tree multiplier (CSA reduction + prefix final add)."""
    aig = Aig(name=f"mult-{n}")
    a = new_inputs(aig, n)
    b = new_inputs(aig, n)
    rows: list[Word] = []
    for i in range(n):
        pp = [aig.g_and(a[j], b[i]) for j in range(n)]
        rows.append([CONST0] * i + pp)
    s, c = csa_reduce(aig, rows, 2 * n)
    out, _ = brent_kung_add(aig, s, c)
    for x in out:
        aig.add_po(x)
    return aig.clone()


def gen_square(n: int = 24) -> Aig:
    """Squarer: Wallace tree over shared partial products."""
    aig = Aig(name=f"square-{n}")
    a = new_inputs(aig, n)
    rows: list[Word] = []
    for i in range(n):
        pp = [aig.g_and(a[j], a[i]) for j in range(n)]
        rows.append([CONST0] * i + pp)
    s, c = csa_reduce(aig, rows, 2 * n)
    out, _ = brent_kung_add(aig, s, c)
    for x in out:
        aig.add_po(x)
    return aig.clone()


def gen_max(n: int = 32, k: int = 4) -> Aig:
    """Max of k unsigned n-bit words (tournament of compare+mux)."""
    aig = Aig(name=f"max-{k}x{n}")
    words = [new_inputs(aig, n) for _ in range(k)]
    cur = words[0]
    for w in words[1:]:
        ge = greater_equal(aig, cur, w)
        cur = mux_word(aig, ge, cur, w)
    for x in cur:
        aig.add_po(x)
    return aig.clone()


def gen_divisor(n: int = 16) -> Aig:
    """Restoring divider: n-bit dividend / n-bit divisor → quotient, rem."""
    aig = Aig(name=f"div-{n}")
    num = new_inputs(aig, n)
    den = new_inputs(aig, n)
    rem: Word = const_word(0, n)
    quo: Word = [CONST0] * n
    for i in range(n - 1, -1, -1):
        # rem = (rem << 1) | num[i]
        rem = [num[i]] + rem[: n - 1]
        diff, ge = bk_sub(aig, rem, den)
        rem = mux_word(aig, ge, diff, rem)
        quo[i] = ge
    for x in quo:
        aig.add_po(x)
    for x in rem:
        aig.add_po(x)
    return aig.clone()


def gen_sqrt(n: int = 32) -> Aig:
    """Restoring square root: n-bit radicand → n/2-bit root."""
    assert n % 2 == 0
    aig = Aig(name=f"sqrt-{n}")
    x = new_inputs(aig, n)
    h = n // 2
    rem: Word = const_word(0, h + 2)
    root: Word = [CONST0] * h
    for i in range(h - 1, -1, -1):
        # bring down two bits of x
        two = [x[2 * i], x[2 * i + 1]]
        rem = two + rem[: h]
        # trial = (root << 2) | 01
        trial: Word = [CONST1, CONST0] + root[: h]
        diff, ge = bk_sub(aig, rem, trial)
        rem = mux_word(aig, ge, diff, rem)
        root = [ge] + root[: h - 1]
    for r in root:
        aig.add_po(r)
    return aig.clone()


def gen_sine(n: int = 12, iters: int | None = None) -> Aig:
    """Fixed-point sine via CORDIC (rotation mode).

    Input: n-bit angle in [0, pi/2) as fraction of pi/2.  Output: n-bit
    sin value.  Built purely from adders/subtractors/shifts/muxes — the
    same adder-dominated structure as the EPFL ``sin`` netlist.
    """
    import math

    iters = iters or n - 2
    w = n + 2  # internal width (guard bits)
    aig = Aig(name=f"sine-{n}")
    theta = new_inputs(aig, n)

    # angle accumulator in units of (pi/2)/2^n, widened
    z: Word = theta[:] + [CONST0] * (w - n)
    # x = K (CORDIC gain compensated), y = 0
    K = 0.6072529350088813
    x: Word = const_word(int(K * (1 << n)), w)
    y: Word = const_word(0, w)
    for i in range(iters):
        ang = math.atan(2.0**-i) / (math.pi / 2) * (1 << n)
        ang_w = const_word(int(round(ang)), w)
        d = lit_not(z[w - 1])  # rotate +1 if z >= 0 (sign bit clear)
        xs = shift_right_const(x, i, fill=x[w - 1])
        ys = shift_right_const(y, i, fill=y[w - 1])
        x_plus, _ = ripple_sub(aig, x, ys)
        x_minus, _ = ripple_add(aig, x, ys)
        y_plus, _ = ripple_add(aig, y, xs)
        y_minus, _ = ripple_sub(aig, y, xs)
        z_plus, _ = ripple_sub(aig, z, ang_w)
        z_minus, _ = ripple_add(aig, z, ang_w)
        x = mux_word(aig, d, x_plus, x_minus)
        y = mux_word(aig, d, y_plus, y_minus)
        z = mux_word(aig, d, z_plus, z_minus)
    for i in range(n):
        aig.add_po(y[i])
    return aig.clone()


def gen_log2(n: int = 32, frac_bits: int = 8) -> Aig:
    """log2: integer part via priority encoder + normalized-mantissa
    polynomial fraction (log2(1+m) ≈ m - m^2/2 + m^3/4 truncated)."""
    import math

    aig = Aig(name=f"log2-{n}")
    x = new_inputs(aig, n)
    k = max(1, int(math.ceil(math.log2(n))))

    # Leading-one position (priority encoder, MSB first).
    pos: Word = [CONST0] * k
    found = CONST0
    for i in range(n - 1, -1, -1):
        here = aig.g_and(x[i], lit_not(found))
        for b in range(k):
            if (i >> b) & 1:
                pos[b] = aig.g_or(pos[b], here)
        found = aig.g_or(found, x[i])

    # Normalize: barrel-shift left by (n-1-pos) == shift right by pos then ...
    # Simpler: barrel shift right by pos, giving mantissa bits below leading 1.
    w = x
    for b in range(k):
        shifted = shift_right_const(w, 1 << b)
        w = mux_word(aig, pos[b], shifted, w)
    # w now has leading one at bit 0; mantissa m = next frac_bits bits... they
    # are ABOVE bit0 only if we shifted fully; take bits [1..frac_bits].
    # Recompute: after shifting right by pos, leading 1 sits at bit 0 and the
    # fraction is lost.  Instead shift LEFT by (n-1-pos): do via mux on ~pos.
    w = x
    for b in range(k):
        shifted = shift_left_const(w, 1 << b)
        w = mux_word(aig, lit_not(pos[b]), shifted, w)
    # Now leading one is at bit n-1 (when x != 0); mantissa = bits below it.
    m: Word = [w[n - 1 - frac_bits + i] for i in range(frac_bits)]  # top frac bits

    # fraction ≈ m - m^2/2 (+ m^2 terms keep the circuit mult-flavored)
    rows: list[Word] = []
    for i in range(frac_bits):
        pp = [aig.g_and(m[j], m[i]) for j in range(frac_bits)]
        rows.append([CONST0] * i + pp)
    s_r, c_r = csa_reduce(aig, rows, 2 * frac_bits)
    sq, _ = brent_kung_add(aig, s_r, c_r)
    half_sq = shift_right_const(sq[frac_bits:], 1)  # m^2/2, top bits
    frac, _ = bk_sub(aig, m, half_sq[:frac_bits])

    for b in pos:
        aig.add_po(b)
    for f in frac:
        aig.add_po(f)
    return aig.clone()


# ---------------------------------------------------------------------------
# Suite assembly
# ---------------------------------------------------------------------------

_GENERATORS: dict[str, Callable[..., Aig]] = {
    "adder": gen_adder,
    "bar": gen_barrel_shifter,
    "mult": gen_multiplier,
    "sine": gen_sine,
    "max": gen_max,
    "div": gen_divisor,
    "sqrt": gen_sqrt,
    "square": gen_square,
    "log2": gen_log2,
}

# (kwargs_default, kwargs_paper) per circuit; paper sizes mirror EPFL.
_SIZES: dict[str, tuple[dict, dict]] = {
    "adder": (dict(n=128), dict(n=128)),
    "bar": (dict(n=64), dict(n=128)),
    "mult": (dict(n=16), dict(n=64)),
    "sine": (dict(n=12), dict(n=24)),
    "max": (dict(n=32, k=4), dict(n=128, k=4)),
    "div": (dict(n=16), dict(n=64)),
    "sqrt": (dict(n=32), dict(n=128)),
    "square": (dict(n=24), dict(n=64)),
    "log2": (dict(n=32), dict(n=32)),
}


def benchmark_suite(scale: str = "default", only: Sequence[str] | None = None) -> dict[str, Aig]:
    """The 9-circuit suite.  scale: "default" (CPU-friendly), "paper", "tiny"."""
    out: dict[str, Aig] = {}
    names = list(_GENERATORS) if only is None else list(only)
    for name in names:
        gen = _GENERATORS[name]
        kw_def, kw_paper = _SIZES[name]
        if scale == "paper":
            kw = kw_paper
        elif scale == "tiny":
            kw = {k: (max(4, v // 4) if isinstance(v, int) else v) for k, v in kw_def.items()}
            if name == "bar":
                kw = dict(n=16)
            if name == "sqrt":
                kw = dict(n=8)
            if name == "max":
                kw = dict(n=8, k=4)
            if name == "sine":
                kw = dict(n=8)  # below 8 bits CORDIC folds to constants
            if name == "log2":
                kw = dict(n=16, frac_bits=4)
        else:
            kw = kw_def
        out[name] = gen(**kw)
    return out
