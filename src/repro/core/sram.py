"""rCiM SRAM topology library + calibrated analytical energy/latency model.

The paper (§III-D, Alg. I lines 11-12) derives power/latency/energy "through
an analytical estimation approach combined with initial simulation data"
(post-layout Cadence characterization of each macro).  We cannot re-run
Spectre, so the per-op / per-cycle constants below are *calibrated against
the paper's published numbers*:

  * 65 fJ / NAND2, 116 fJ / NOR2 (Table II, §IV-D)
  * 1 GHz global clock, TSMC 28 nm, 1.66 um^2 / 10T bitcell
  * 8 KB single macro: 88.2-106.6 GOPS, 8.64-10.45 TOPS/W
  * Fig 9 / Table I relative trends (see tests/test_explorer.py)

Two accounting modes:

  * ``paper``   — reproduces the paper's own Table I arithmetic.  Reverse-
    engineering Table I shows its power column is almost exactly
    ``P[mW] = 1.157 mW x level_count`` for every benchmark/topology pair
    (adder L=4 -> 4.62 mW ... square L=21 -> 24.3 mW), with
    ``E = P x latency``.  This mode exists to replicate the paper's tables.
  * ``physical`` — a self-consistent decomposition
        E = T x P_ctrl + (active macro-cycles) x (k_macro + k_col x cols)
              + sum_ops E_op(type)
    with constants fitted to the paper's headline ratios.  NOTE (documented
    deviation): the paper's §IV-B six-macro claims are internally
    inconsistent (it states both "clock cycles remain the same as
    three-macro" and "47% lower latency than three-macro"); under the
    physical model six-macro energy lands between -40%..+6% of three-macro
    rather than the paper's +15%.  All other headline trends reproduce.

Geometry: one bank is 128x128 (2 KB) as in the paper ("a 2KB SRAM bank with
128x128 SRAM bit cells can perform 64 logical operations in a single
computational cycle").  Macro sizes follow Table II ((256x256)=8KB,
(512x256)=16KB):

    4 KB  = 256 rows x 128 cols      16 KB = 512 rows x 256 cols
    8 KB  = 256 rows x 256 cols      32 KB = 512 rows x 512 cols

``ops_per_cycle = cols / 2`` (one sense amplifier per column pair).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Topology library — 12 entries: {4, 8, 16, 32} KB x {1, 3, 6} macros
# ---------------------------------------------------------------------------

# (rows, cols) per macro size.  A macro is a grid of 128x128 (2 KB) banks
# organized WIDE (more columns -> more sense amplifiers -> more parallel
# ops), which is the only organization consistent with the paper's own
# numbers: Table II's (512x256)x3 = 16 KB macro delivers 2x the GOPS of
# (256x256)x3 = 8 KB (so the 512 counts columns), and Fig 9(b)'s latency
# drops on macro doubling require column count to grow with size.
_GEOMETRY = {
    4: (256, 128),
    8: (256, 256),
    16: (256, 512),
    32: (256, 1024),
}

MACRO_SIZES_KB = (4, 8, 16, 32)
MACRO_COUNTS = (1, 3, 6)

OP_TYPES = ("nand", "nor", "inv")


@dataclasses.dataclass(frozen=True)
class SramTopology:
    """One rCiM design point: ``n_macros`` macros of ``macro_kb`` KB each.

    Library entries derive (rows, cols) from ``macro_kb`` via the paper's
    geometry table; ``geometry=(rows, cols)`` overrides it for programmatic
    design points outside the table (see `topology_grid` /
    `from_geometry`).  Macro counts must be 1 (time-multiplexed op types)
    or a multiple of 3 (op types on dedicated macro groups) — see
    `mapping.macros_per_type`.
    """

    macro_kb: int
    n_macros: int
    geometry: tuple[int, int] | None = None

    @classmethod
    def from_geometry(
        cls, rows: int, cols: int, n_macros: int
    ) -> "SramTopology":
        """Topology from an explicit (rows, cols) macro geometry.

        The macro must hold a whole number of KB (rows*cols % 8192 == 0) so
        capacity bookkeeping stays exact.
        """
        bits = rows * cols
        if bits <= 0 or bits % 8192:
            raise ValueError(
                f"macro geometry {rows}x{cols} is not a whole number of KB"
            )
        return cls(bits // 8192, n_macros, geometry=(rows, cols))

    @property
    def rows(self) -> int:
        if self.geometry is not None:
            return self.geometry[0]
        return _GEOMETRY[self.macro_kb][0]

    @property
    def cols(self) -> int:
        if self.geometry is not None:
            return self.geometry[1]
        return _GEOMETRY[self.macro_kb][1]

    @property
    def total_kb(self) -> int:
        return self.macro_kb * self.n_macros

    @property
    def total_bits(self) -> int:
        return self.total_kb * 1024 * 8

    @property
    def ops_per_cycle_per_macro(self) -> int:
        return self.cols // 2

    @property
    def name(self) -> str:
        if self.geometry is not None:
            return f"({self.rows}x{self.cols})x{self.n_macros}"
        return f"({self.macro_kb}KB)x{self.n_macros}"

    @property
    def n_banks_per_macro(self) -> int:
        return max(1, self.macro_kb // 2)

    def area_mm2(self, model: "EnergyModel") -> float:
        return area_mm2_arrays(
            self.total_bits, model.bitcell_um2, model.periphery_overhead
        )


TOPOLOGY_LIBRARY: tuple[SramTopology, ...] = tuple(
    SramTopology(kb, m) for kb in MACRO_SIZES_KB for m in MACRO_COUNTS
)


def topology_grid(
    rows: Sequence[int] = (128, 256, 512),
    cols: Sequence[int] = (128, 256, 512, 1024),
    macro_counts: Sequence[int] = MACRO_COUNTS,
) -> tuple[SramTopology, ...]:
    """Programmatic (rows x cols x macros) topology space — the open design
    grid beyond the paper's 12-entry library.

    Every combination whose macro is a whole number of KB and whose macro
    count the mapping model supports (1 or a multiple of 3) becomes a
    design point; the batched engine sweeps the whole grid in one device
    call (``evaluate_batch`` / ``evaluate_suite``), so grid size is cheap.
    Deduplicates against geometry collisions and keeps the given order
    (rows-major, then cols, then macro count).
    """
    out: list[SramTopology] = []
    seen: set[tuple[int, int, int]] = set()
    for r in rows:
        for c in cols:
            if (r * c) % 8192:
                continue
            for m in macro_counts:
                if m != 1 and m % 3:
                    continue
                key = (r, c, m)
                if key in seen:
                    continue
                seen.add(key)
                out.append(SramTopology.from_geometry(r, c, m))
    if not out:
        raise ValueError("topology grid is empty")
    return tuple(out)


# ---------------------------------------------------------------------------
# Energy / latency model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Calibrated constants (TSMC 28 nm, 1 V, 1 GHz — paper §IV-A)."""

    f_clk_hz: float = 1e9
    # Per-op all-in energies (compute + resonant writeback), Table II.
    e_op_fj: tuple[float, float, float] = (65.0, 116.0, 65.0)  # nand, nor, inv
    # Marginal per-op energies used in the physical-mode TOTAL energy
    # decomposition.  NOTE: the paper's Table I totals are inconsistent with
    # its own 65 fJ/op figure (e.g. multiplier worst case: 35.6k gates x
    # 65 fJ = 2.3 nJ > the reported 0.90 nJ total), so total-energy
    # accounting cannot charge the standalone per-op energy per gate.  We
    # charge a calibrated post-recycling marginal energy instead; the
    # standalone figures above are still used for Table II-style per-op
    # metrics.
    e_op_marginal_fj: tuple[float, float, float] = (5.0, 9.0, 5.0)
    # Resonant write driver: fraction of writeback energy recycled (refs
    # [51][52]; exposed so the tool can report non-resonant baselines).
    writeback_fj_nonresonant: float = 80.0
    resonance_recycle_eta: float = 0.65
    # Physical-mode per-cycle terms (fit: see tests/test_explorer.py).
    p_ctrl_mw: float = 3.6          # design-constant control/clock power
    e_macro_cycle_fj: float = 90.0  # per active macro per cycle (decode/WL)
    e_col_cycle_fj: float = 0.45    # per column per active macro-cycle (PRE)
    # Paper-mode constant: P = alpha * levels  (reverse-engineered Table I).
    alpha_mw_per_level: float = 1.157
    # Area model
    bitcell_um2: float = 1.66
    periphery_overhead: float = 0.30
    # Throughput derating (writeback/pipeline bubbles) to match Table II GOPS.
    pipeline_utilization: float = 0.80

    def resonant_saving_fj(self) -> float:
        """Energy recycled per written bit vs a conventional driver."""
        return self.writeback_fj_nonresonant * self.resonance_recycle_eta


def area_mm2_arrays(total_bits, bitcell_um2, periphery_overhead):
    """Area model, array-agnostic (scalars, (T,) arrays, or (V, T) grids).

    `SramTopology.area_mm2` and the batched `TopologyTable.area_mm2` both
    call this, so the scalar and vectorized paths are the same float ops.
    """
    cell = total_bits * bitcell_um2 * 1e-6  # mm^2
    return cell * (1.0 + periphery_overhead)


# ---------------------------------------------------------------------------
# Model variation: stacked EnergyModel variants (the yield/variation axis)
# ---------------------------------------------------------------------------

# EnergyModel fields whose variation shifts the reported figures:
# everything the evaluate kernels, the area model, and the Table II
# arithmetic read.  The clock is included: corner silicon runs at a
# different achievable f_clk.  (writeback_fj_nonresonant /
# resonance_recycle_eta feed no metric path yet, so sweeping them would
# only emit inert variants that skew the yield fractions.)
SWEEPABLE_FIELDS = (
    "f_clk_hz",
    "e_op_fj",
    "e_op_marginal_fj",
    "p_ctrl_mw",
    "e_macro_cycle_fj",
    "e_col_cycle_fj",
    "alpha_mw_per_level",
    "bitcell_um2",
    "periphery_overhead",
    "pipeline_utilization",
)

# Fields scaled together by the process-corner generator: the switched
# (CV^2-like) energy/power constants.  Geometry/utilization constants are
# corner-independent.
_CORNER_ENERGY_FIELDS = (
    "e_op_fj",
    "e_op_marginal_fj",
    "writeback_fj_nonresonant",
    "p_ctrl_mw",
    "e_macro_cycle_fj",
    "e_col_cycle_fj",
    "alpha_mw_per_level",
)


def _scale_field(model: "EnergyModel", field: str, factor: float):
    v = getattr(model, field)
    if isinstance(v, tuple):
        return tuple(x * factor for x in v)
    return v * factor


_PER_OP_FIELDS = ("e_op_fj", "e_op_marginal_fj")


@dataclasses.dataclass(frozen=True, eq=False)
class ModelTable:
    """A stack of `EnergyModel` variants, one row per variant — the
    dynamic model axis of the batched engine.

    Every `EnergyModel` float field becomes a float64 array with a
    leading variant axis: ``(V,)`` for scalars, ``(V, 3)`` for the per-op
    tuples.  Fields may additionally carry a **per-topology axis** for
    *correlated* (topology-dependent) variation — ``(V, T)`` for scalars
    and ``(V, T, 3)`` for the per-op tuples (e.g. per-macro-geometry NOR
    discharge energy), as in `bitcell_sigma_per_macro`'s per-macro-
    geometry mismatch: the batched kernels broadcast such fields along
    the grid's topology axis, so variant ``v`` applies a different
    constant to each topology.  A ``(V, 1)`` / ``(V, 1, 3)`` field
    broadcasts uniformly and is bit-identical to the same values as a
    ``(V,)`` / ``(V, 3)`` field.

    The batched kernels (`batch.evaluate_batch` /
    `batch.evaluate_suite`) take these arrays as *traced* operands and
    vmap over the variant axis, so one jit compilation sweeps every
    variant — no per-model recompile, which is what makes corner /
    sensitivity / Monte-Carlo studies (the paper's yield FoM) cheap.

    Convention: **row 0 is the nominal model** — the generators below all
    put it first, and the yield summaries in `explorer` measure variants
    against it.
    """

    names: tuple[str, ...]
    f_clk_hz: np.ndarray                  # (V,) or (V, T)
    e_op_fj: np.ndarray                   # (V, 3) or (V, T, 3)
    e_op_marginal_fj: np.ndarray          # (V, 3) or (V, T, 3)
    writeback_fj_nonresonant: np.ndarray  # (V,) or (V, T)
    resonance_recycle_eta: np.ndarray     # (V,) or (V, T)
    p_ctrl_mw: np.ndarray                 # (V,) or (V, T)
    e_macro_cycle_fj: np.ndarray          # (V,) or (V, T)
    e_col_cycle_fj: np.ndarray            # (V,) or (V, T)
    alpha_mw_per_level: np.ndarray        # (V,) or (V, T)
    bitcell_um2: np.ndarray               # (V,) or (V, T)
    periphery_overhead: np.ndarray        # (V,) or (V, T)
    pipeline_utilization: np.ndarray      # (V,) or (V, T)
    # Identity of the per-topology columns (SramTopology.name per column,
    # set by the correlated generators): the batched paths refuse to
    # sweep such a table against a *different* topology list of the same
    # length, where each column's variation would silently land on the
    # wrong macro geometry.  None for uniform / hand-built tables.
    topology_names: "tuple[str, ...] | None" = None

    def __post_init__(self):
        v = len(self.names)
        if v == 0:
            raise ValueError("empty ModelTable")
        t = None
        for f in dataclasses.fields(EnergyModel):
            arr = getattr(self, f.name)
            if arr.shape[0] != v:
                raise ValueError(
                    f"field {f.name} has {arr.shape[0]} rows, expected {v}"
                )
            if f.name in _PER_OP_FIELDS:
                if arr.ndim not in (2, 3) or arr.shape[-1] != len(OP_TYPES):
                    raise ValueError(
                        f"per-op field {f.name} must be (V, {len(OP_TYPES)})"
                        f" or (V, T, {len(OP_TYPES)}), got {arr.shape}"
                    )
                if arr.ndim == 3 and arr.shape[1] > 1:
                    width = arr.shape[1]
                    if t is not None and width != t:
                        raise ValueError(
                            f"field {f.name} has per-topology width {width},"
                            f" but another field has {t}"
                        )
                    t = width
            elif arr.ndim == 2:
                width = arr.shape[1]
                if width > 1:
                    if t is not None and width != t:
                        raise ValueError(
                            f"field {f.name} has per-topology width {width},"
                            f" but another field has {t}"
                        )
                    t = width
            elif arr.ndim != 1:
                raise ValueError(
                    f"field {f.name} must be (V,) or (V, T), got {arr.shape}"
                )
        if (
            self.topology_names is not None
            and t is not None
            and len(self.topology_names) != t
        ):
            raise ValueError(
                f"topology_names has {len(self.topology_names)} entries "
                f"but the per-topology fields have width {t}"
            )

    @property
    def n_topologies(self) -> "int | None":
        """Width of the per-topology axis when any field carries one with
        T > 1 — ``(V, T)`` scalars or ``(V, T, 3)`` per-op tuples;
        ``None`` for uniform tables (including ``(V, 1)`` / ``(V, 1, 3)``
        broadcast fields)."""
        t = None
        for f in dataclasses.fields(EnergyModel):
            arr = getattr(self, f.name)
            per_op = f.name in _PER_OP_FIELDS
            if per_op and arr.ndim == 3 and arr.shape[1] > 1:
                t = arr.shape[1]
            elif not per_op and arr.ndim == 2 and arr.shape[1] > 1:
                t = arr.shape[1]
        return t

    def content_key(self) -> str:
        """Content hash over every field's bytes + shape, plus the name
        tuples — stable across processes (unlike ``id``/pickling), so it
        keys the service's grid cache and the sweep journal's config
        fingerprint: two tables with the same key produce bit-identical
        sweep results."""
        h = hashlib.sha1()
        for f in dataclasses.fields(EnergyModel):
            arr = np.ascontiguousarray(getattr(self, f.name))
            h.update(f.name.encode())
            h.update(str(arr.shape).encode())
            h.update(arr.tobytes())
        h.update(repr(self.names).encode())
        h.update(repr(self.topology_names).encode())
        return h.hexdigest()[:16]

    @classmethod
    def from_models(
        cls,
        models: "Sequence[EnergyModel]",
        names: Sequence[str] | None = None,
    ) -> "ModelTable":
        """Stack explicit `EnergyModel` variants (nominal first)."""
        models = list(models)
        if not models:
            raise ValueError("empty model list")
        if names is None:
            names = tuple(f"v{i}" for i in range(len(models)))
        arrays = {
            f.name: np.asarray(
                [getattr(m, f.name) for m in models], dtype=np.float64
            )
            for f in dataclasses.fields(EnergyModel)
        }
        return cls(names=tuple(names), **arrays)

    @classmethod
    def corners(
        cls, base: "EnergyModel | None" = None, spread: float = 0.10
    ) -> "ModelTable":
        """TT/FF/SS-style process corners: the switched energy/power
        constants scale by ``1 -+ spread`` while the achievable clock
        scales the opposite way (fast silicon: less energy per op, higher
        f_clk).  Row 0 is the typical (nominal) model."""
        # `is None`, not falsiness: a ModelTable passed by mistake defines
        # __len__, and an otherwise-falsy base must error loudly, not be
        # silently swapped for the nominal model.
        if base is None:
            base = EnergyModel()

        def corner(k_energy: float, k_clk: float) -> EnergyModel:
            kw = {f: _scale_field(base, f, k_energy)
                  for f in _CORNER_ENERGY_FIELDS}
            kw["f_clk_hz"] = base.f_clk_hz * k_clk
            return dataclasses.replace(base, **kw)

        return cls.from_models(
            [base, corner(1.0 - spread, 1.0 + spread),
             corner(1.0 + spread, 1.0 - spread)],
            names=("tt", "ff", "ss"),
        )

    @classmethod
    def sensitivity(
        cls,
        base: "EnergyModel | None" = None,
        fields: Sequence[str] | None = None,
        rel: float = 0.05,
    ) -> "ModelTable":
        """One-at-a-time ±``rel`` perturbation grid: the nominal model
        plus, for each swept field, a +rel and a -rel variant."""
        if base is None:
            base = EnergyModel()
        fields = tuple(fields) if fields is not None else SWEEPABLE_FIELDS
        unknown = [f for f in fields if f not in SWEEPABLE_FIELDS]
        if unknown:
            raise ValueError(f"not sweepable: {unknown}")
        models, names = [base], ["nominal"]
        for f in fields:
            for sign in (+1.0, -1.0):
                factor = 1.0 + sign * rel
                models.append(
                    dataclasses.replace(base, **{f: _scale_field(base, f, factor)})
                )
                names.append(f"{f}{'+' if sign > 0 else '-'}{rel:g}")
        return cls.from_models(models, names=names)

    @classmethod
    def monte_carlo(
        cls,
        base: "EnergyModel | None" = None,
        n: int = 16,
        sigma: float = 0.05,
        seed: int = 0,
        fields: Sequence[str] | None = None,
    ) -> "ModelTable":
        """``n`` seeded Monte-Carlo samples (row 0 is the nominal model,
        rows 1..n-1 scale each swept field by an independent
        ``N(1, sigma)`` factor, floored at 0.05 to keep the model in its
        physical regime; ``pipeline_utilization`` is additionally capped
        at 1.0 — more than one op per cycle slot is unphysical)."""
        if n < 1:
            raise ValueError("n must be >= 1")
        if base is None:
            base = EnergyModel()
        fields = tuple(fields) if fields is not None else SWEEPABLE_FIELDS
        unknown = [f for f in fields if f not in SWEEPABLE_FIELDS]
        if unknown:
            raise ValueError(f"not sweepable: {unknown}")
        rng = np.random.default_rng(seed)
        models, names = [base], ["nominal"]
        for i in range(1, n):
            kw = {}
            for f in fields:
                v = getattr(base, f)
                if isinstance(v, tuple):
                    factors = np.maximum(rng.normal(1.0, sigma, len(v)), 0.05)
                    kw[f] = tuple(float(x * k) for x, k in zip(v, factors))
                else:
                    kw[f] = v * float(
                        max(rng.normal(1.0, sigma), 0.05)
                    )
                    if f == "pipeline_utilization":
                        kw[f] = min(kw[f], 1.0)
            models.append(dataclasses.replace(base, **kw))
            names.append(f"mc{i}")
        return cls.from_models(models, names=names)

    @classmethod
    def bitcell_sigma_per_macro(
        cls,
        topologies: "Sequence[SramTopology]",
        base: "EnergyModel | None" = None,
        n: int = 16,
        sigma: float = 0.05,
        seed: int = 0,
        fields: Sequence[str] = (
            "bitcell_um2", "e_macro_cycle_fj", "e_col_cycle_fj"
        ),
        ref_cells: int = 128 * 128,
    ) -> "ModelTable":
        """Correlated (topology-dependent) Monte-Carlo: per-macro-geometry
        mismatch keyed on each topology's rows x cols.

        Local (bitcell-level) variation averages out over a macro
        Pelgrom-style, so the per-macro sigma shrinks with array size:
        ``sigma_t = sigma * sqrt(ref_cells / (rows_t * cols_t))`` with
        ``ref_cells`` the paper's 128x128 bank.  Each swept scalar field
        becomes a ``(V, T)`` array and each swept per-op field (e.g.
        ``e_op_fj`` — per-geometry NAND/NOR/INV discharge energy) a
        ``(V, T, 3)`` array — variant ``v`` scales topology ``t`` (and,
        for per-op fields, each op type independently) by an independent
        ``N(1, sigma_t)`` factor (floored at 0.05;
        ``pipeline_utilization`` capped at 1.0) — which the batched
        kernels broadcast along the grid's topology axis.  Row 0 is the
        nominal model.  ``topologies`` accepts a `SramTopology` sequence
        or a `batch.TopologyTable` and must match the topology table the
        sweep is evaluated against.
        """
        if n < 1:
            raise ValueError("n must be >= 1")
        if base is None:
            base = EnergyModel()
        topos = tuple(getattr(topologies, "topologies", topologies))
        if not topos:
            raise ValueError("empty topology list")
        fields = tuple(fields)
        bad = [f for f in fields if f not in SWEEPABLE_FIELDS]
        if bad:
            raise ValueError(f"not sweepable per topology: {bad}")
        cells = np.array([t.rows * t.cols for t in topos], dtype=np.float64)
        sigma_t = sigma * np.sqrt(ref_cells / cells)           # (T,)
        rng = np.random.default_rng(seed)
        names = ("nominal",) + tuple(f"corr{i}" for i in range(1, n))
        table = cls.from_models([base] * n, names=names)
        kw = {}
        n_t = len(topos)
        for f in fields:
            if f in _PER_OP_FIELDS:
                factors = np.ones((n, n_t, len(OP_TYPES)), dtype=np.float64)
                if n > 1:
                    factors[1:] = np.maximum(
                        rng.normal(
                            1.0, sigma_t[None, :, None],
                            (n - 1, n_t, len(OP_TYPES)),
                        ),
                        0.05,
                    )
                vals = np.asarray(getattr(base, f))[None, None, :] * factors
            else:
                factors = np.ones((n, n_t), dtype=np.float64)
                if n > 1:
                    factors[1:] = np.maximum(
                        rng.normal(1.0, sigma_t[None, :], (n - 1, n_t)),
                        0.05,
                    )
                vals = getattr(base, f) * factors
                if f == "pipeline_utilization":
                    vals = np.minimum(vals, 1.0)
            kw[f] = vals
        return dataclasses.replace(
            table, topology_names=tuple(t.name for t in topos), **kw
        )

    def uniform_row(self, i: int) -> bool:
        """True when variant ``i`` applies the same constants to every
        topology (always true for 1-D / ``(V, 1)`` fields), i.e. when
        ``model(i)`` can materialize it as a single `EnergyModel`."""
        for f in dataclasses.fields(EnergyModel):
            v = getattr(self, f.name)[i]
            if f.name in _PER_OP_FIELDS:
                # (T, 3): uniform iff every topology row is identical
                if v.ndim == 2 and not np.all(v == v[:1]):
                    return False
            elif np.ndim(v) and not np.all(v == v.flat[0]):
                return False
        return True

    def model(self, i: int, topology: "int | None" = None) -> "EnergyModel":
        """Row ``i`` re-materialized as a plain `EnergyModel` (exact:
        float64 -> python float round-trips bit-for-bit).

        For correlated tables, ``topology`` selects the column of each
        ``(V, T)`` field; without it, a row whose per-topology values
        differ has no single-`EnergyModel` representation and raises.
        """
        kw = {}
        for f in dataclasses.fields(EnergyModel):
            v = getattr(self, f.name)[i]
            if f.name in _PER_OP_FIELDS:
                if v.ndim == 2:  # (T, 3) per-topology row
                    if topology is not None:
                        v = v[topology if v.shape[0] > 1 else 0]
                    elif np.all(v == v[:1]):
                        v = v[0]
                    else:
                        raise ValueError(
                            f"variant {i} ({self.names[i]!r}) is topology-"
                            f"dependent in field {f.name}; pass topology= "
                            f"to materialize one column"
                        )
                kw[f.name] = tuple(float(x) for x in v)
            elif np.ndim(v):  # (T,) per-topology row
                if topology is not None:
                    kw[f.name] = float(v[topology if v.shape[0] > 1 else 0])
                elif np.all(v == v.flat[0]):
                    kw[f.name] = float(v.flat[0])
                else:
                    raise ValueError(
                        f"variant {i} ({self.names[i]!r}) is topology-"
                        f"dependent in field {f.name}; pass topology= to "
                        f"materialize one column"
                    )
            else:
                kw[f.name] = float(v)
        return EnergyModel(**kw)

    def models(self) -> "list[EnergyModel]":
        return [self.model(i) for i in range(len(self))]

    def __len__(self) -> int:
        return len(self.names)


@dataclasses.dataclass
class Metrics:
    power_mw: float
    latency_ns: float
    energy_nj: float
    cycles: int
    throughput_gops: float
    tops_per_watt: float
    gops_per_mm2: float
    area_mm2: float

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


# --- mode arithmetic, shared with the batched engine (core/batch.py) -------
#
# These helpers are written against plain arithmetic operators so they accept
# python floats, numpy arrays, and jax arrays alike.  ``evaluate`` (scalar)
# and ``evaluate_batch`` (grid) call the *same* expressions, so the two paths
# agree to floating-point round-off by construction.


def paper_power_mw(n_levels, model: EnergyModel):
    """Paper-mode power: P = alpha x level count (reverse-engineered Table I)."""
    return model.alpha_mw_per_level * n_levels


def paper_energy_nj(power_mw, latency_ns):
    return power_mw * latency_ns * 1e-3  # mW * ns = pJ; /1e3 -> nJ


def physical_energy_nj(latency_ns, active_macro_cycles, e_ops_fj, cols,
                       model: EnergyModel):
    """Physical-mode decomposition: control + active-macro + per-op terms."""
    e_ctrl_fj = model.p_ctrl_mw * 1e-3 * (latency_ns * 1e-9) * 1e15
    e_macro_fj = active_macro_cycles * (
        model.e_macro_cycle_fj + model.e_col_cycle_fj * cols
    )
    return (e_ctrl_fj + e_macro_fj + e_ops_fj) * 1e-6


def evaluate(
    schedule: "MappingResult",
    topo: SramTopology,
    model: EnergyModel | None = None,
    mode: str = "physical",
) -> Metrics:
    """Power/latency/energy for a scheduled workload on a topology.

    ``schedule`` comes from mapping.schedule_netlist (cycles + op counts).
    """
    from .mapping import MappingResult  # circular-import guard

    assert isinstance(schedule, MappingResult)
    if model is None:
        model = EnergyModel()
    cycles = schedule.total_cycles
    t_ns = cycles / model.f_clk_hz * 1e9
    n_ops = schedule.op_counts
    e_ops_fj = sum(n_ops[t] * e for t, e in zip(OP_TYPES, model.e_op_marginal_fj))

    if mode == "paper":
        p_mw = paper_power_mw(schedule.n_levels, model)
        e_nj = paper_energy_nj(p_mw, t_ns)
    elif mode == "physical":
        e_nj = physical_energy_nj(
            t_ns, schedule.active_macro_cycles, e_ops_fj, topo.cols, model
        )
        p_mw = e_nj / t_ns * 1e3 if t_ns > 0 else 0.0
    else:
        raise ValueError(f"unknown mode {mode!r}")

    total_ops = sum(n_ops.values())
    thr_gops = (
        total_ops / (t_ns * 1e-9) / 1e9 * model.pipeline_utilization
        if t_ns > 0
        else 0.0
    )
    area = topo.area_mm2(model)
    tops_w = (thr_gops / 1e3) / (p_mw * 1e-3) if p_mw > 0 else 0.0
    return Metrics(
        power_mw=p_mw,
        latency_ns=t_ns,
        energy_nj=e_nj,
        cycles=cycles,
        throughput_gops=thr_gops,
        tops_per_watt=tops_w,
        gops_per_mm2=thr_gops / area if area > 0 else 0.0,
        area_mm2=area,
    )


def table2_arrays(ops_per_cycle, area_mm2, model: EnergyModel,
                  nor_fraction: float = 0.5) -> dict:
    """Table II arithmetic over total sense-amp width + area.

    Array-agnostic like the mode helpers above: ``table2_metrics`` feeds
    it scalars, ``batch.table2_batch`` feeds it (T,) arrays — one set of
    expressions, no drift between the scalar and batched paths.
    """
    # NOR discharge (350 ps) utilizes the 1 ns cycle worse than NAND (150 ps)
    util = model.pipeline_utilization * (1.0 - 0.14 * nor_fraction)
    gops = ops_per_cycle * model.f_clk_hz / 1e9 * util
    e_mix_fj = (1 - nor_fraction) * model.e_op_fj[0] + nor_fraction * model.e_op_fj[1]
    p_mw = gops * e_mix_fj * 1e-3 + model.p_ctrl_mw * 0.4
    return dict(
        throughput_gops=gops,
        power_mw=p_mw,
        tops_per_watt=(gops / 1e3) / (p_mw * 1e-3),
        gops_per_mm2=gops / area_mm2,
        area_mm2=area_mm2,
    )


def table2_metrics(
    topo: SramTopology,
    model: EnergyModel | None = None,
    nor_fraction: float = 0.5,
) -> dict:
    """Table II-style standalone metrics (throughput, TOPS/W, GOPS/mm^2).

    Uses the *standalone* per-op energies (65/116 fJ) plus a control-power
    share — this is the accounting that reproduces the paper's published
    8 KB single-macro range (88.2-106.6 GOPS, 8.64-10.45 TOPS/W,
    551-666 GOPS/mm^2); the NAND/NOR mix sets where in the range we land.
    """
    if model is None:
        model = EnergyModel()
    w = topo.ops_per_cycle_per_macro * topo.n_macros
    return table2_arrays(w, topo.area_mm2(model), model, nor_fraction)


def peak_throughput_gops(topo: SramTopology, model: EnergyModel | None = None) -> float:
    if model is None:
        model = EnergyModel()
    return (
        topo.ops_per_cycle_per_macro
        * topo.n_macros
        * model.f_clk_hz
        / 1e9
        * model.pipeline_utilization
    )


def inductor_size_nh(
    topo: SramTopology,
    model: EnergyModel | None = None,
    c_bl_per_cell_ff: float = 0.08,
    f_res_hz: float | None = None,
) -> float:
    """Resonant inductor sizing (Alg. I line 15).

    Series LC: L = 1 / ((2 pi f_res)^2 * C_total).  One inductor is shared
    by all write drivers of a macro ("utilizing a shared inductor ... the
    bitline capacitance increases N times for N write drivers"), so
    C_total = cols x rows x C_cell.
    """
    if model is None:
        model = EnergyModel()
    f_res = f_res_hz or model.f_clk_hz
    c_total_f = topo.cols * topo.rows * c_bl_per_cell_ff * 1e-15
    l_h = 1.0 / ((2 * math.pi * f_res) ** 2 * c_total_f)
    return l_h * 1e9
