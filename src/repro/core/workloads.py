"""Workload lowering: NN layer primitives -> rCiM gate-op streams.

The paper's pipeline (Algorithm I) takes an RTL netlist, maps it to
NAND2/NOR2/NOT, and schedules the per-level op stream onto an SRAM
topology.  This module closes the loop from the *application* side
(Eva-CiM direction): it decomposes the NN layer blocks of the config zoo
(`repro.configs`) into counts of three exactly-constructed primitive
tiles, characterizes each tile once into the same `AigStats` shape the
schedule/evaluate kernels consume, and exposes the result as a
`SuiteTable` so the existing batched `evaluate_suite` /
`evaluate_select_suite` pipelines price a whole model per token.

Primitive tiles (exact gate-level constructions, verified against
integer arithmetic by tests/test_workloads.py):

  * ``mac8``  — 8x8 Wallace-tree multiplier + 16-bit accumulate add;
                one tile == one int8 MAC (matmul work unit).
  * ``add16`` — 16-bit Brent-Kung adder; one tile == one elementwise
                accumulate/residual/normalizer step.
  * ``max8``  — 8-bit compare-select (>= + mux); one tile == one
                gating / activation-select / running-max step.

Lowering contract (per token, per layer; mirrors the param counting of
`ModelConfig.n_params` so matmul MAC counts equal the active weight
count of that layer's matmuls, MoE-aware):

  * matmul MACs            -> ``mac8`` tiles (1 tile per MAC)
  * attention score/AV     -> ``mac8`` tiles, 2 * ctx * head_dim * heads
  * norms / residuals /
    softmax normalizers    -> ``add16`` tiles
  * activations / gates /
    softmax running max    -> ``max8`` tiles

Elementwise counts are architectural approximations (documented at each
site); the matmul term dominates by >99% for every config in the zoo.

Conservation invariant (CI-asserted for every config): for each
primitive, the per-level op stream sums to the tile's op totals, so any
per-token/per-layer total computed from level streams equals the same
total computed from `AigStats` totals x tile counts.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from functools import lru_cache
from typing import Mapping, Sequence

import numpy as np

from .aig import CONST0, Aig, AigStats
from .circuits import (Word, brent_kung_add, csa_reduce, greater_equal,
                       mux_word, new_inputs)
from .sram import TOPOLOGY_LIBRARY, EnergyModel, SramTopology

# ---------------------------------------------------------------------------
# Primitive tiles
# ---------------------------------------------------------------------------


def mac_tile(bits: int = 8) -> Aig:
    """``bits x bits`` multiplier + ``2*bits`` accumulate: one MAC.

    Wallace construction (partial products -> CSA 3:2 reduction ->
    Brent-Kung final add) — few, wide levels, the structure rCiM
    schedules well.  The accumulate is modular in ``2*bits`` (the final
    carry is dropped), matching a fixed-width accumulator register.
    """
    aig = Aig(name=f"mac{bits}")
    a = new_inputs(aig, bits)
    b = new_inputs(aig, bits)
    acc = new_inputs(aig, 2 * bits)
    rows: list[Word] = []
    for i in range(bits):
        rows.append([CONST0] * i + [aig.g_and(x, b[i]) for x in a])
    rows.append(acc)
    s_row, c_row = csa_reduce(aig, rows, 2 * bits)
    out, _ = brent_kung_add(aig, s_row, c_row)
    for lit in out:
        aig.add_po(lit)
    return aig


def add_tile(bits: int = 16) -> Aig:
    """``bits``-wide Brent-Kung adder: one elementwise accumulate."""
    aig = Aig(name=f"add{bits}")
    a = new_inputs(aig, bits)
    b = new_inputs(aig, bits)
    out, _ = brent_kung_add(aig, a, b)
    for lit in out:
        aig.add_po(lit)
    return aig


def max_tile(bits: int = 8) -> Aig:
    """``bits``-wide compare-select (max): one gating/activation step."""
    aig = Aig(name=f"max{bits}")
    a = new_inputs(aig, bits)
    b = new_inputs(aig, bits)
    ge = greater_equal(aig, a, b)
    for lit in mux_word(aig, ge, a, b):
        aig.add_po(lit)
    return aig


_TILE_BUILDERS = {"mac": mac_tile, "add": add_tile, "max": max_tile}

# Canonical primitive set: name -> (family, bit width).
PRIMITIVES: tuple[tuple[str, str, int], ...] = (
    ("mac8", "mac", 8),
    ("add16", "add", 16),
    ("max8", "max", 8),
)


@lru_cache(maxsize=None)
def primitive_aigs() -> "dict[str, Aig]":
    return {name: _TILE_BUILDERS[fam](bits) for name, fam, bits in PRIMITIVES}


@lru_cache(maxsize=None)
def primitive_stats() -> "dict[str, AigStats]":
    """Characterized (`ChaAIG`) per-tile op streams, built once."""
    return {name: aig.characterize() for name, aig in primitive_aigs().items()}


# ---------------------------------------------------------------------------
# Layer lowering
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerLowering:
    """Tile counts for ONE layer of ``kind`` (per token); the model has
    ``count`` such layers."""

    kind: str
    count: int
    tiles: Mapping[str, int]  # primitive name -> tiles per token per layer


def _ffn_active_macs(cfg) -> int:
    """MACs/token of one FFN block — the *active* weight count (mirrors
    `ModelConfig.n_active_params`: top_k + shared experts + router)."""
    d = cfg.d_model
    if cfg.is_moe:
        e_ff = cfg.moe_d_ff
        return (cfg.top_k + cfg.n_shared_experts) * 3 * d * e_ff + d * cfg.n_experts
    return 3 * d * cfg.d_ff


def _ffn_act_width(cfg) -> int:
    """Elementwise width of the FFN gate activation (active experts)."""
    if cfg.is_moe:
        return (cfg.top_k + cfg.n_shared_experts) * cfg.moe_d_ff
    return cfg.d_ff


def _context_len(cfg, shape, kind: str) -> int:
    """Effective attended context per token: full ``seq_len`` at decode,
    the causal average ``seq_len/2`` in train/prefill; local attention
    caps at the window."""
    ctx = shape.seq_len if shape.kind == "decode" else max(1, shape.seq_len // 2)
    if kind == "local" and cfg.window:
        ctx = min(ctx, cfg.window)
    return ctx


def _lower_layer(cfg, shape, kind: str) -> dict[str, int]:
    """Per-token tile counts for one layer of ``kind`` (see module doc)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    mac = add = mx = 0
    if kind in ("attn", "local", "xattn"):
        ctx = _context_len(cfg, shape, kind)
        mac += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)  # qkv proj
        mac += cfg.n_heads * hd * d                          # o proj
        mac += 2 * ctx * hd * cfg.n_heads                    # QK^T + AV
        mac += _ffn_active_macs(cfg)
        # softmax: running max + normalizer accumulate per (head, key)
        mx += cfg.n_heads * ctx
        add += cfg.n_heads * ctx
        mx += _ffn_act_width(cfg)                            # gate activation
        # 2 norms (sum-of-squares accumulate + scale) + 2 residuals
        add += 2 * (2 * d) + 2 * d
        if kind == "xattn":
            # decoder cross-attention sub-block over the encoder output
            enc = max(1, cfg.enc_seq)
            mac += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
            mac += cfg.n_heads * hd * d
            mac += 2 * enc * hd * cfg.n_heads
            mx += cfg.n_heads * enc
            add += cfg.n_heads * enc
            add += 2 * d + d  # extra norm + residual
    elif kind == "ssm":
        di = cfg.d_inner or 2 * d
        nh = di // cfg.ssm_head_dim
        mac += d * (2 * di + 2 * cfg.ssm_state + nh)         # in proj
        mac += di * d                                        # out proj
        mac += di * cfg.conv_width                           # depthwise conv
        mac += 2 * di * cfg.ssm_state                        # state update
        mx += di                                             # silu gate
        add += 2 * d + d                                     # 1 norm + residual
    elif kind == "rglru":
        w = cfg.lru_width or d
        mac += d * w * 2 + w * d + w * 3                     # gates + proj
        mac += _ffn_active_macs(cfg)
        mx += w + _ffn_act_width(cfg)                        # recurrence + ffn gates
        add += w                                             # recurrence blend
        add += 2 * (2 * d) + 2 * d                           # 2 norms + 2 residuals
    else:  # pragma: no cover - config zoo only emits the four kinds
        raise ValueError(f"unknown layer kind {kind!r}")
    return {"mac8": mac, "add16": add, "max8": mx}


@dataclasses.dataclass(frozen=True)
class LoweredModel:
    """A model config lowered to primitive-tile counts per token."""

    arch: str
    shape: str
    layers: tuple[LayerLowering, ...]
    prims: Mapping[str, AigStats]

    def tiles_per_token(self) -> dict[str, int]:
        out: dict[str, int] = {name: 0 for name in self.prims}
        for layer in self.layers:
            for name, n in layer.tiles.items():
                out[name] += layer.count * n
        return out

    def macs_per_token(self) -> int:
        return self.tiles_per_token().get("mac8", 0)

    def ops_per_token(self) -> dict[str, int]:
        """Total NAND/NOR/NOT executions per token, from stats totals."""
        tiles = self.tiles_per_token()
        out = {"nand": 0, "nor": 0, "inv": 0}
        for name, n in tiles.items():
            s = self.prims[name]
            out["nand"] += n * s.nand_count
            out["nor"] += n * s.nor_count
            out["inv"] += n * s.inv_count
        return out

    def ops_per_token_from_levels(self) -> dict[str, int]:
        """Same totals recomputed from the per-level streams — must equal
        `ops_per_token` exactly (the conservation invariant)."""
        tiles = self.tiles_per_token()
        out = {"nand": 0, "nor": 0, "inv": 0}
        for name, n in tiles.items():
            for lvl in self.prims[name].ops_per_level:
                for k in out:
                    out[k] += n * lvl.get(k, 0)
        return out


def lower_config(cfg, shape) -> LoweredModel:
    """Lower ``cfg``'s layer stack under input shape ``shape`` into
    per-token primitive-tile counts (see module docstring)."""
    kinds = collections.Counter(cfg.layer_kinds)
    layers = tuple(
        LayerLowering(kind=k, count=c, tiles=_lower_layer(cfg, shape, k))
        for k, c in sorted(kinds.items())
    )
    return LoweredModel(arch=cfg.name, shape=shape.name, layers=layers,
                        prims=primitive_stats())


def conservation_report(lowered: LoweredModel) -> dict:
    """Check the lowering conservation invariant (CI asserts ``ok``).

    Per primitive: the per-level stream sums to the (nand, nor, inv)
    totals AND to ``n_ands``-consistent gate counts; per model: totals
    computed from level streams equal totals from stats totals.
    """
    per_prim = {}
    for name, s in lowered.prims.items():
        mat = s.ops_matrix()  # (n_levels, 3) in (nand, nor, inv) order
        level_sums = mat.sum(axis=0)
        totals = np.array([s.nand_count, s.nor_count, s.inv_count])
        per_prim[name] = dict(
            levels_match_totals=bool((level_sums == totals).all()),
            n_levels=int(s.n_levels),
            total_gates=int(s.total_gates),
        )
    by_totals = lowered.ops_per_token()
    by_levels = lowered.ops_per_token_from_levels()
    ok = all(p["levels_match_totals"] for p in per_prim.values()) and \
        by_totals == by_levels
    return dict(ok=bool(ok), per_primitive=per_prim,
                ops_per_token=by_totals, ops_per_token_from_levels=by_levels)


# ---------------------------------------------------------------------------
# Evaluation through the batched suite kernels
# ---------------------------------------------------------------------------


def primitive_suite():
    """The primitive tiles as a `SuiteTable` (one trivial recipe per
    tile), the input shape `evaluate_suite`/`evaluate_select_suite`
    consume."""
    from .batch import SuiteTable  # local import: keep workloads jax-free

    return SuiteTable.from_cha(
        {name: {(): stats} for name, stats in primitive_stats().items()}
    )


@dataclasses.dataclass(frozen=True)
class SystemResult:
    """rCiM cost of one lowered model across a topology set."""

    arch: str
    shape: str
    n_units: int
    winners: Mapping[str, str]            # primitive -> winning topology name
    tile_energy_nj: Mapping[str, float]   # per single tile
    tile_latency_ns: Mapping[str, float]
    tiles_per_token: Mapping[str, int]
    per_layer: tuple[dict, ...]           # per layer-kind energy/latency
    energy_per_token_j: float
    latency_per_token_s: float

    def as_dict(self) -> dict:
        return dict(
            arch=self.arch, shape=self.shape, n_units=self.n_units,
            winners=dict(self.winners),
            tile_energy_nj=dict(self.tile_energy_nj),
            tile_latency_ns=dict(self.tile_latency_ns),
            tiles_per_token={k: int(v) for k, v in self.tiles_per_token.items()},
            per_layer=list(self.per_layer),
            energy_per_token_j=self.energy_per_token_j,
            latency_per_token_s=self.latency_per_token_s,
        )


def evaluate_lowered(
    lowered: LoweredModel,
    topologies: "Sequence[SramTopology] | None" = None,
    model: "EnergyModel | None" = None,
    mode: str = "physical",
    discipline: str = "list",
    n_units: int = 8192,
) -> SystemResult:
    """Price a lowered model on rCiM: pick the best topology per
    primitive tile via the fused device pipeline, then scale by tile
    counts.

    ``n_units``: rCiM macro arrays operating in parallel (a chip-scale
    deployment instantiates thousands of small macros); energy is
    parallelism-invariant, latency divides by ``n_units``.
    """
    from .batch import TopologyTable, evaluate_select_suite

    topos = tuple(topologies) if topologies is not None else TOPOLOGY_LIBRARY
    suite = primitive_suite()
    table = TopologyTable.from_topologies(topos)
    _, sel = evaluate_select_suite(
        suite, table, model=model, mode=mode, discipline=discipline
    )
    # winner_idx is (C, V) flat topology-major over (T, R); R == 1 here.
    idx = np.asarray(sel.winner_idx).reshape(len(suite.circuits), -1)[:, 0]
    energy = np.asarray(sel.winner_metrics["energy_nj"]).reshape(idx.shape[0], -1)[:, 0]
    latency = np.asarray(sel.winner_metrics["latency_ns"]).reshape(idx.shape[0], -1)[:, 0]
    winners = {c: topos[int(idx[i])].name for i, c in enumerate(suite.circuits)}
    e_nj = {c: float(energy[i]) for i, c in enumerate(suite.circuits)}
    t_ns = {c: float(latency[i]) for i, c in enumerate(suite.circuits)}

    per_layer = []
    total_e = 0.0
    total_t = 0.0
    for layer in lowered.layers:
        le = sum(n * e_nj[p] for p, n in layer.tiles.items()) * 1e-9
        lt = sum(n * t_ns[p] for p, n in layer.tiles.items()) * 1e-9 / n_units
        per_layer.append(dict(
            kind=layer.kind, count=layer.count,
            tiles={k: int(v) for k, v in layer.tiles.items()},
            energy_per_token_j=le * layer.count,
            latency_per_token_s=lt * layer.count,
        ))
        total_e += le * layer.count
        total_t += lt * layer.count

    return SystemResult(
        arch=lowered.arch, shape=lowered.shape, n_units=n_units,
        winners=winners, tile_energy_nj=e_nj, tile_latency_ns=t_ns,
        tiles_per_token=lowered.tiles_per_token(), per_layer=tuple(per_layer),
        energy_per_token_j=total_e, latency_per_token_s=total_t,
    )
