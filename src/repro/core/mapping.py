"""Mapping a NAND2/NOR2/NOT netlist onto rCiM SRAM topologies (§III-D).

The paper maps AIG levels onto SRAM rows: level i's operands occupy rows,
outputs are written to subsequent rows, and execution proceeds one level
per computational cycle — subject to two architectural limits:

  * width: one macro executes ``cols/2`` ops of ONE type per cycle
    (one sense-amp per column pair);
  * concurrency: a single-macro topology runs one op TYPE per cycle
    (NAND2 *or* NOR2 *or* NOT — the pulse generator is programmed per
    cycle), a three-macro topology runs the three types concurrently
    (one type per macro), a six-macro topology gives each type two macros.

This module turns a characterized netlist (ops per level per type) into a
cycle-accurate schedule plus capacity checks (Alg. I line 9: bits >= 4x
gates — 2 operand bits + 2 output bits per gate, "accounting for cases
where complementary outputs are required").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .aig import AigStats
from .sram import OP_TYPES, SramTopology

# Alg. I line 9 capacity rule: 2 operand bits + 2 output bits per gate
# ("accounting for cases where complementary outputs are required").
BITS_PER_GATE = 4

# Sense-amp groups per op type by macro count (§III-D): a single macro
# time-multiplexes the three types, three macros dedicate one macro per
# type, six macros dedicate two.  Shared with the batched engine
# (core/batch.py), which stacks these rows into a per-topology array.
MACROS_PER_TYPE: dict[int, tuple[int, int, int]] = {
    1: (1, 1, 1),
    3: (1, 1, 1),
    6: (2, 2, 2),
}


def macros_per_type(n_macros: int) -> tuple[int, int, int]:
    """Dedicated macros per op type (nand, nor, inv) for a macro count.

    Generalizes the paper's three points (1: time-multiplexed single
    macro, 3: one macro per type, 6: two per type) to any multiple of
    three — the rule `topology_grid` design points follow.  Counts that
    are neither 1 nor a multiple of 3 have no mapping under §III-D's
    type-per-macro-group discipline and are rejected.
    """
    got = MACROS_PER_TYPE.get(n_macros)
    if got is not None:
        return got
    if n_macros > 0 and n_macros % 3 == 0:
        k = n_macros // 3
        return (k, k, k)
    raise ValueError(
        f"unsupported macro count {n_macros}: must be 1 or a multiple of 3"
    )


@dataclasses.dataclass
class MappingResult:
    topo: SramTopology
    n_levels: int
    total_cycles: int
    active_macro_cycles: int  # sum over cycles of #macros doing useful work
    op_counts: dict[str, int]
    rows_used: int
    fits: bool
    per_level_cycles: list[int]

    @property
    def utilization(self) -> float:
        cap = self.total_cycles * self.topo.n_macros * self.topo.ops_per_cycle_per_macro
        return sum(self.op_counts.values()) / cap if cap else 0.0


def _macros_per_type(topo: SramTopology) -> dict[str, int]:
    return dict(zip(OP_TYPES, macros_per_type(topo.n_macros)))


def schedule_stats(
    stats: AigStats,
    topo: SramTopology,
    writeback_pipelined: bool = True,
    discipline: str = "list",
) -> MappingResult:
    """Cycle schedule for a characterized AIG on a topology.

    ``discipline``:
      * "levels" — lock-step, one AIG level at a time (the paper's Fig 7
        mapping narrative).  Conservative: every level pays at least one
        cycle per op type present.
      * "list" (default) — ASAP list scheduling enabled by the paper's
        flexible operand placement (§III-D: dual row decoders, operands
        "placed flexibly within the two columns, not strictly confined to
        a single row or column").  Ops issue as soon as their operands are
        written and a sense-amp slot of the right type is free, giving the
        Brent bound  cycles = max(depth, width_bound) + drain.  This is the
        regime in which the paper's §IV-B scaling claims (47% energy drop
        on macro doubling, 38%/47% latency drops for 3-/6-macro) hold.
    """
    if discipline == "list":
        return _schedule_list(stats, topo)
    assert discipline == "levels"
    w = topo.ops_per_cycle_per_macro
    mpt = _macros_per_type(topo)
    per_level_cycles: list[int] = []
    active_macro_cycles = 0
    op_counts = {t: 0 for t in OP_TYPES}

    for level in stats.ops_per_level:
        for t in OP_TYPES:
            op_counts[t] += level.get(t, 0)
        if topo.n_macros == 1:
            # Types serialize on the single macro.
            c = 0
            for t in OP_TYPES:
                n = level.get(t, 0)
                batches = math.ceil(n / w) if n else 0
                c += batches
                active_macro_cycles += batches
            c = max(c, 1)
        else:
            # Types run concurrently, each on its dedicated macro group.
            c = 1
            for t in OP_TYPES:
                n = level.get(t, 0)
                width_t = w * mpt[t]
                batches = math.ceil(n / width_t) if n else 0
                c = max(c, batches)
                # each busy macro of the group is active for `batches` cycles
                active_macro_cycles += batches * mpt[t]
        per_level_cycles.append(c)

    total = sum(per_level_cycles)
    if not writeback_pipelined:
        total += len(per_level_cycles)  # +1 writeback cycle per level
    else:
        total += 1  # pipeline drain for the final writeback

    # Capacity check (Alg. I line 9): 4 bits per gate.
    gates = sum(op_counts.values())
    # Row schedule: each level batch needs 2 operand rows + 1 result row;
    # rows are recycled every other level (outputs become next operands).
    # The working set of the busiest level must actually fit in the row
    # budget — bit capacity alone is not feasibility (a wide, shallow
    # netlist can satisfy the 4-bits/gate rule while its peak level
    # needs more rows than the macro has).
    max_batches = max(per_level_cycles) if per_level_cycles else 0
    rows_needed = 3 * max_batches + 2
    fits = BITS_PER_GATE * gates <= topo.total_bits and rows_needed <= topo.rows
    rows_used = min(topo.rows, rows_needed)

    return MappingResult(
        topo=topo,
        n_levels=stats.n_levels,
        total_cycles=total,
        active_macro_cycles=active_macro_cycles,
        op_counts=op_counts,
        rows_used=rows_used,
        fits=fits,
        per_level_cycles=per_level_cycles,
    )


def _schedule_list(stats: AigStats, topo: SramTopology) -> MappingResult:
    """ASAP width-bound schedule: cycles = max(depth, width bound) + drain."""
    w = topo.ops_per_cycle_per_macro
    mpt = _macros_per_type(topo)
    op_counts = {t: 0 for t in OP_TYPES}
    for level in stats.ops_per_level:
        for t in OP_TYPES:
            op_counts[t] += level.get(t, 0)

    depth_bound = stats.n_levels
    active_macro_cycles = 0
    if topo.n_macros == 1:
        # one op type per cycle on the single macro: issue-slot bound is the
        # sum over types.
        width_bound = sum(math.ceil(op_counts[t] / w) for t in OP_TYPES if op_counts[t])
        active_macro_cycles = width_bound
    else:
        per_type = [
            math.ceil(op_counts[t] / (w * mpt[t])) for t in OP_TYPES if op_counts[t]
        ]
        width_bound = max(per_type) if per_type else 0
        active_macro_cycles = sum(
            math.ceil(op_counts[t] / (w * mpt[t])) * mpt[t]
            for t in OP_TYPES
            if op_counts[t]
        )

    total = max(depth_bound, width_bound) + 1  # +1 writeback drain

    gates = sum(op_counts.values())
    # Feasibility = bit capacity (Alg. I line 9) AND row budget: the
    # steady-state working set holds ~width_bound/depth_bound concurrent
    # batches, each needing 2 operand rows + 1 result row.
    rows_needed = 3 * math.ceil(max(1, width_bound) / max(1, depth_bound)) + 2
    fits = BITS_PER_GATE * gates <= topo.total_bits and rows_needed <= topo.rows
    rows_used = min(topo.rows, rows_needed)

    return MappingResult(
        topo=topo,
        n_levels=stats.n_levels,
        total_cycles=total,
        active_macro_cycles=active_macro_cycles,
        op_counts=op_counts,
        rows_used=rows_used,
        fits=fits,
        per_level_cycles=[],
    )
