"""AIG sub-graph optimizations — Balance, Rewrite, Refactor, Resub.

Re-implementations of the four ABC transforms the paper uses to generate
its 64 unique synthesis recipes (ordered permutations of non-empty subsets
of {B_a, R_f, R_w, R_s}: sum_{i=1..4} P(4,i) = 4+12+24+24 = 64).

All transforms are *semantics-preserving*: tests/test_transforms.py checks
functional equivalence by exhaustive truth tables (small circuits) and by
bit-parallel random simulation (large circuits).

Faithfulness notes vs ABC:
  * ``balance``  — AND-tree collapse + level-greedy rebuild (ABC `balance`).
  * ``rewrite``  — 4-feasible-cut enumeration + truth-table resynthesis with
    memoized Shannon/decomposition plans (ABC `rewrite` uses precomputed
    NPN-class subgraphs; ours synthesizes plans on the fly, same contract:
    replace a cut cone if the new cone adds fewer nodes than the old MFFC).
  * ``refactor`` — reconvergence-driven cuts up to 10 leaves, ISOP
    (Minato–Morreale) + quick algebraic factoring (ABC `refactor`).
  * ``resub``    — window-exact resubstitution: truth tables over a shared
    structural cut; replaces a node by an equivalent existing divisor or an
    AND/OR of two divisors (ABC `resub` k=0/1).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import json
import os
import tempfile
import time
from functools import lru_cache, partial
from pathlib import Path
from random import Random
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.runtime import faults

from .aig import (
    CONST0,
    CONST1,
    Aig,
    AigStats,
    lit,
    lit_node,
    lit_not,
    lit_phase,
)

TRANSFORM_NAMES = ("Ba", "Rf", "Rw", "Rs")

#: Version of the transform implementations.  Any change that can alter a
#: transform's output (even a tie-break) MUST bump this: it keys the
#: persistent characterization cache, so a bump invalidates every on-disk
#: entry (CharacterizationCache stores under a per-version directory).
TRANSFORM_VERSION = 2


# ===========================================================================
# Truth-table plan synthesis (shared by rewrite/refactor)
# ===========================================================================
#
# A "plan" is a nested tuple expression over leaf indices:
#   ("leaf", i) | ("const", 0|1) | ("not", p) | ("and", p, q) | ("or", p, q)
#   | ("xor", p, q) | ("mux", i, p_then, p_else)
# Cost = number of AIG AND nodes the plan lowers to.

_PLAN_CACHE: dict[tuple[int, int], tuple[int, tuple]] = {}


def _tt_mask(k: int) -> int:
    return (1 << (1 << k)) - 1


@lru_cache(maxsize=None)
def _elem_tt(i: int, k: int) -> int:
    """Truth table of variable i over k vars (LSB-first pattern order)."""
    acc = 0
    for p in range(1 << k):
        if (p >> i) & 1:
            acc |= 1 << p
    return acc


def _cofactors(tt: int, i: int, k: int) -> tuple[int, int]:
    """Negative/positive cofactors w.r.t. var i, each over the same k vars
    (cofactor truth tables are var-i-independent).

    Patterns p and p|(1<<i) sit 2^i bit positions apart, so each cofactor is
    a mask + one shift — O(1) big-int ops instead of a per-block loop.
    """
    e = _elem_tt(i, k)  # positions with var_i = 1
    full = _tt_mask(k)
    step = 1 << i
    lo = tt & (e ^ full)
    hi = tt & e
    neg = lo | (lo << step)
    pos = hi | (hi >> step)
    return neg, pos


def synth_plan(tt: int, k: int) -> tuple[int, tuple]:
    """Memoized (cost, plan) synthesis of a k-var truth table."""
    tt &= _tt_mask(k)
    key = (tt, k)
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        return hit
    full = _tt_mask(k)
    if tt == 0:
        res = (0, ("const", 0))
    elif tt == full:
        res = (0, ("const", 1))
    else:
        res = None
        for i in range(k):
            e = _elem_tt(i, k)
            if tt == e:
                res = (0, ("leaf", i))
                break
            if tt == (e ^ full):
                res = (0, ("not", ("leaf", i)))
                break
        if res is None:
            best: tuple[int, tuple] | None = None
            for i in range(k):
                neg, pos = _cofactors(tt, i, k)
                if neg == pos:
                    # tt does not depend on var i — nothing to split on.
                    continue
                if neg == 0:
                    c, p = synth_plan(pos, k)
                    cand = (c + 1, ("and", ("leaf", i), p))
                elif pos == 0:
                    c, p = synth_plan(neg, k)
                    cand = (c + 1, ("and", ("not", ("leaf", i)), p))
                elif neg == full:
                    c, p = synth_plan(pos, k)
                    cand = (c + 1, ("or", ("not", ("leaf", i)), p))
                elif pos == full:
                    c, p = synth_plan(neg, k)
                    cand = (c + 1, ("or", ("leaf", i), p))
                elif neg == (pos ^ full):
                    c, p = synth_plan(neg, k)
                    cand = (c + 3, ("xor", ("leaf", i), p))
                else:
                    c0, p0 = synth_plan(neg, k)
                    c1, p1 = synth_plan(pos, k)
                    cand = (c0 + c1 + 3, ("mux", i, p1, p0))
                if best is None or cand[0] < best[0]:
                    best = cand
            res = best
    _PLAN_CACHE[key] = res
    return res


def build_plan(aig: Aig, plan: tuple, leaves: Sequence[int]) -> int:
    """Lower a plan to AIG nodes; ``leaves`` are literals."""
    op = plan[0]
    if op == "const":
        return CONST1 if plan[1] else CONST0
    if op == "leaf":
        return leaves[plan[1]]
    if op == "not":
        return lit_not(build_plan(aig, plan[1], leaves))
    if op == "and":
        return aig.g_and(build_plan(aig, plan[1], leaves), build_plan(aig, plan[2], leaves))
    if op == "or":
        return aig.g_or(build_plan(aig, plan[1], leaves), build_plan(aig, plan[2], leaves))
    if op == "xor":
        return aig.g_xor(build_plan(aig, plan[1], leaves), build_plan(aig, plan[2], leaves))
    if op == "mux":
        sel = leaves[plan[1]]
        return aig.g_mux(sel, build_plan(aig, plan[2], leaves), build_plan(aig, plan[3], leaves))
    raise ValueError(f"bad plan op {op}")


# ===========================================================================
# Balance (B_a)
# ===========================================================================


def balance(aig: Aig) -> Aig:
    """Depth-oriented AND-tree rebalancing (ABC ``balance``).

    Collapses maximal AND trees (through non-complemented AND edges) and
    rebuilds each as a balanced tree, combining lowest-level leaves first.
    """
    new = Aig(aig.n_pis, name=aig.name)
    mapping: dict[int, int] = {0: CONST0}
    for i in range(1, 1 + aig.n_pis):
        mapping[i] = lit(i)
    level: dict[int, int] = {}

    def new_level(l: int) -> int:
        n = lit_node(l)
        return level.get(n, 0)

    fanout = aig.fanout_counts()

    def collect_leaves(n: int, leaves: list[int]) -> None:
        """Leaves of the maximal AND tree rooted at node n."""
        for f in aig.fanins(n):
            fn = lit_node(f)
            if (
                lit_phase(f) == 0
                and aig.is_and(fn)
                and fanout[fn] == 1
            ):
                collect_leaves(fn, leaves)
            else:
                leaves.append(f)

    reach = _reachable(aig)
    order = [n for n in range(aig.n_pis + 1, aig.n_nodes) if reach[n]]
    processed: set[int] = set()

    def map_lit(f: int) -> int:
        return mapping[lit_node(f)] ^ lit_phase(f)

    for n in order:
        if n in processed:
            continue
        # Only build roots: nodes that are not absorbed into a parent tree.
        # A node is absorbed if it has a single fanout which consumes it
        # through a non-complemented edge from another AND node — but since
        # we map every reachable node anyway (cheap), just build all.
        leaves: list[int] = []
        collect_leaves(n, leaves)
        # Map leaves into the new AIG and combine by level (two lowest first).
        heap = sorted((new_level(map_lit(f)), i, map_lit(f)) for i, f in enumerate(leaves))
        import heapq

        h = [(lv, i, l) for i, (lv, _, l) in enumerate(heap)]
        heapq.heapify(h)
        cnt = len(h)
        while len(h) > 1:
            lv_a, _, a = heapq.heappop(h)
            lv_b, _, b = heapq.heappop(h)
            out = new.g_and(a, b)
            lv = max(lv_a, lv_b) + 1
            level[lit_node(out)] = lv
            cnt += 1
            heapq.heappush(h, (lv, cnt, out))
        mapping[n] = h[0][2] if h else CONST1
        processed.add(n)

    for p in aig.pos:
        new.add_po(mapping[lit_node(p)] ^ lit_phase(p))
    return new.clone()


def _reachable(aig: Aig) -> np.ndarray:
    reach = np.zeros(aig.n_nodes, dtype=bool)
    stack = [lit_node(p) for p in aig.pos]
    while stack:
        n = stack.pop()
        if reach[n] or not aig.is_and(n):
            continue
        reach[n] = True
        a, b = aig.fanins(n)
        stack.append(a >> 1)
        stack.append(b >> 1)
    return reach


# ===========================================================================
# Cut enumeration (shared by rewrite)
# ===========================================================================


def _enumerate_cuts(
    aig: Aig, k: int = 4, max_cuts: int = 8
) -> list[list[frozenset[int]]]:
    """Bottom-up k-feasible cut enumeration; cuts[n] = list of leaf sets."""
    cuts: list[list[frozenset[int]]] = [[] for _ in range(aig.n_nodes)]
    for n in range(1, 1 + aig.n_pis):
        cuts[n] = [frozenset((n,))]
    for n in range(aig.n_pis + 1, aig.n_nodes):
        fa, fb = aig.fanins(n)
        na, nb = fa >> 1, fb >> 1
        got: set[frozenset[int]] = set()
        merged: list[frozenset[int]] = []
        ca = cuts[na] if na else [frozenset()]
        cb = cuts[nb] if nb else [frozenset()]
        for c1 in ca:
            for c2 in cb:
                u = c1 | c2
                if len(u) <= k and u not in got:
                    got.add(u)
                    merged.append(u)
        merged.sort(key=len)
        trivial = frozenset((n,))
        cuts[n] = merged[: max_cuts - 1] + [trivial]
    return cuts


def _mffc_size(
    aig: Aig,
    root: int,
    leaves: frozenset[int],
    fanout: np.ndarray,
    cone: list[int] | None = None,
) -> int:
    """Nodes in the cone of ``root`` (stopping at leaves) whose every fanout
    stays inside the cone — i.e. nodes freed if the root is replaced.
    ``cone`` may supply a precomputed ``cone_nodes`` walk."""
    if cone is None:
        cone = aig.cone_nodes(root, set(leaves))
    cone_set = set(cone)
    # Count fanout references from inside the cone.
    internal_refs: dict[int, int] = {}
    for n in cone:
        for f in aig.fanins(n):
            fn = f >> 1
            internal_refs[fn] = internal_refs.get(fn, 0) + 1
    freed = 0
    for n in cone:
        if n == root:
            freed += 1
        elif internal_refs.get(n, 0) >= fanout[n]:
            freed += 1
    return freed


# ===========================================================================
# Rewrite (R_w)
# ===========================================================================


def rewrite(aig: Aig, k: int = 4, max_cuts: int = 8, backend: str = "python") -> Aig:
    """DAG-aware cut rewriting (ABC ``rewrite``): for every node, try to
    replace its best k-cut cone with a smaller synthesized cone.

    ``backend="device"`` batches the truth-table/MFFC queries through
    `kernels.aig_sim` with bit-identical output (the python path is the
    parity reference); ``auto`` picks device when jax is available.
    """
    if resolve_backend(backend) == "device":
        return _rewrite_device(aig, k=k, max_cuts=max_cuts)
    cuts = _enumerate_cuts(aig, k=k, max_cuts=max_cuts)
    fanout = aig.fanout_counts()
    new = Aig(aig.n_pis, name=aig.name)
    mapping: dict[int, int] = {0: CONST0}
    for i in range(1, 1 + aig.n_pis):
        mapping[i] = lit(i)

    reach = _reachable(aig)
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if not reach[n]:
            continue
        fa, fb = aig.fanins(n)
        default = new.g_and(
            mapping[fa >> 1] ^ (fa & 1), mapping[fb >> 1] ^ (fb & 1)
        )
        mapping[n] = default
        best_gain = 0
        best: tuple[tuple, list[int]] | None = None
        for cut in cuts[n]:
            if len(cut) < 2 or n in cut:
                continue
            if any(m not in mapping for m in cut):
                continue
            support = sorted(cut)
            cone = aig.cone_nodes(n, set(cut))
            tt = aig.truth_table(lit(n), support, cone=cone)
            cost, plan = synth_plan(tt, len(support))
            old_cost = _mffc_size(aig, n, frozenset(cut), fanout, cone=cone)
            gain = old_cost - cost
            if gain > best_gain:
                best_gain = gain
                best = (plan, [mapping[m] for m in support])
        if best is not None:
            plan, leaf_lits = best
            mapping[n] = build_plan(new, plan, leaf_lits)

    for p in aig.pos:
        new.add_po(mapping[lit_node(p)] ^ lit_phase(p))
    out = new.clone()
    return out if out.n_ands <= aig.n_ands else aig


# ===========================================================================
# Refactor (R_f)
# ===========================================================================


def _reconv_cut(aig: Aig, root: int, max_leaves: int = 10) -> list[int]:
    """Reconvergence-driven cut (ABC ``abcReconv``-style greedy expansion)."""
    leaves = {root}
    while True:
        # pick expandable leaf with minimal "cost" = #new leaves added
        best_leaf, best_cost, best_new = None, None, None
        for lf in leaves:
            if not aig.is_and(lf):
                continue
            fa, fb = aig.fanins(lf)
            cand = {fa >> 1, fb >> 1}
            newset = (leaves - {lf}) | cand
            cost = len(newset) - len(leaves)
            if len(newset) > max_leaves:
                continue
            if best_cost is None or cost < best_cost:
                best_leaf, best_cost, best_new = lf, cost, newset
        if best_leaf is None:
            break
        leaves = best_new
        if best_cost is not None and best_cost >= 0 and len(leaves) >= max_leaves:
            break
    return sorted(leaves)


#: Global memo for `_isop` — the Minato–Morreale recursion re-derives the
#: same (tt, care) subproblems across cones, circuits, and recipes (it is
#: the single hottest part of a cold ``refactor`` pass).  The function is
#: a pure map from (tt, care, k) to its cube list, so memoization cannot
#: change any transform output (TRANSFORM_VERSION stays put).  Entries are
#: capped to bound memory; the cap is far above a full-suite run.
_ISOP_CACHE: dict[tuple[int, int, int], tuple[tuple[int, int], ...]] = {}
_ISOP_CACHE_MAX = 1_000_000


def _isop(tt: int, care: int, k: int) -> list[tuple[int, int]]:
    """Minato–Morreale irredundant SOP.  Returns cubes as (pos_mask, neg_mask)
    over variable indices; cube covers patterns where all pos vars=1, neg=0."""
    full = _tt_mask(k)
    tt &= full
    care &= full
    key = (tt, care, k)
    hit = _ISOP_CACHE.get(key)
    if hit is not None:
        return list(hit)
    res = _isop_uncached(tt, care, k)
    if len(_ISOP_CACHE) < _ISOP_CACHE_MAX:
        _ISOP_CACHE[key] = tuple(res)
    return res


def _isop_uncached(tt: int, care: int, k: int) -> list[tuple[int, int]]:
    if care == 0:
        return []
    if tt & care == 0:
        return []
    if (tt & care) == care:
        return [(0, 0)]

    # pick the top variable on which (tt, care) actually depends; if none,
    # the base cases above would have fired (tt&care constant over care).
    i = -1
    for j in range(k - 1, -1, -1):
        t0, t1 = _cofactors(tt, j, k)
        c0, c1 = _cofactors(care, j, k)
        if t0 != t1 or c0 != c1:
            i = j
            break
    if i < 0:
        # tt constant within care but mixed outside: cover all care points.
        return [(0, 0)] if (tt & care) else []
    t0, t1 = _cofactors(tt, i, k)
    c0, c1 = _cofactors(care, i, k)
    # cubes needed only in the 0-half / 1-half
    isop0 = _isop(t0 & ~(t1 & c1), c0, k)
    isop1 = _isop(t1 & ~(t0 & c0), c1, k)
    cov0 = _cover_tt(isop0, k)
    cov1 = _cover_tt(isop1, k)
    rem = (t0 & c0 & ~cov0) | (t1 & c1 & ~cov1)
    isop2 = _isop(rem, (c0 & ~cov0) | (c1 & ~cov1), k)
    cubes = (
        [(p, nmask | (1 << i)) for (p, nmask) in isop0]
        + [(p | (1 << i), nmask) for (p, nmask) in isop1]
        + isop2
    )
    return cubes


def _cover_tt(cubes: list[tuple[int, int]], k: int) -> int:
    full = _tt_mask(k)
    acc = 0
    for pos, neg in cubes:
        cube_tt = full
        for i in range(k):
            if pos & (1 << i):
                cube_tt &= _elem_tt(i, k)
            elif neg & (1 << i):
                cube_tt &= full ^ _elem_tt(i, k)
        acc |= cube_tt
    return acc


def _factor_cubes(aig: Aig, cubes: list[tuple[int, int]], leaves: list[int]) -> int:
    """Quick algebraic factoring of an SOP (most-common-literal division)."""
    if not cubes:
        return CONST0
    if cubes == [(0, 0)]:
        return CONST1

    def cube_lits(c: tuple[int, int]) -> list[int]:
        pos, neg = c
        out = []
        for i in range(len(leaves)):
            if pos & (1 << i):
                out.append(leaves[i])
            elif neg & (1 << i):
                out.append(lit_not(leaves[i]))
        return out

    if len(cubes) == 1:
        return aig.g_and_multi(cube_lits(cubes[0]))

    # most common literal across cubes
    count: dict[int, int] = {}
    for pos, neg in cubes:
        for i in range(len(leaves)):
            if pos & (1 << i):
                count[lit(i + 1)] = count.get(lit(i + 1), 0) + 1  # key only
            elif neg & (1 << i):
                count[lit(i + 1) ^ 1] = count.get(lit(i + 1) ^ 1, 0) + 1
    best_key, best_cnt = None, 1
    for key, c in count.items():
        if c > best_cnt:
            best_key, best_cnt = key, c
    if best_key is None:
        # no sharing: balanced OR of cube ANDs
        terms = [aig.g_and_multi(cube_lits(c)) for c in cubes]
        return aig.g_or_multi(terms)
    var_i = (best_key >> 1) - 1
    is_neg = best_key & 1
    with_lit, without = [], []
    for pos, neg in cubes:
        has = (neg if is_neg else pos) & (1 << var_i)
        if has:
            if is_neg:
                with_lit.append((pos, neg & ~(1 << var_i)))
            else:
                with_lit.append((pos & ~(1 << var_i), neg))
        else:
            without.append((pos, neg))
    lit_l = lit_not(leaves[var_i]) if is_neg else leaves[var_i]
    quot = _factor_cubes(aig, with_lit, leaves)
    rest = _factor_cubes(aig, without, leaves) if without else CONST0
    return aig.g_or(aig.g_and(lit_l, quot), rest)


def refactor(aig: Aig, max_leaves: int = 10, backend: str = "python") -> Aig:
    """Collapse + refactor large cones (ABC ``refactor``).

    ``backend="device"`` batches cone truth tables through
    `kernels.aig_sim`; output is bit-identical to the python path.
    """
    if resolve_backend(backend) == "device":
        return _refactor_device(aig, max_leaves=max_leaves)
    fanout = aig.fanout_counts()
    new = Aig(aig.n_pis, name=aig.name)
    mapping: dict[int, int] = {0: CONST0}
    for i in range(1, 1 + aig.n_pis):
        mapping[i] = lit(i)
    reach = _reachable(aig)
    lv = aig.levels()

    for n in range(aig.n_pis + 1, aig.n_nodes):
        if not reach[n]:
            continue
        fa, fb = aig.fanins(n)
        default = new.g_and(mapping[fa >> 1] ^ (fa & 1), mapping[fb >> 1] ^ (fb & 1))
        mapping[n] = default
        # Refactor only at "root-ish" nodes: multi-fanout or PO drivers, and
        # deep enough to have a real cone.
        if fanout[n] < 2 and lv[n] % 3 != 0:
            continue
        leaves = _reconv_cut(aig, n, max_leaves)
        if len(leaves) < 3 or n in leaves:
            continue
        k = len(leaves)
        if k > 12:
            continue
        cone = aig.cone_nodes(n, set(leaves))
        tt = aig.truth_table(lit(n), leaves, cone=cone)
        cubes = _isop(tt, _tt_mask(k), k)
        old_cost = _mffc_size(aig, n, frozenset(leaves), fanout, cone=cone)
        # Estimate new cost: literals-1 per cube + cubes-1 ORs (upper bound).
        est = sum(bin(p | q).count("1") for p, q in cubes) + max(0, len(cubes) - 1)
        if est >= old_cost + 2:
            continue
        before = new.n_ands
        cand = _factor_cubes(new, cubes, [mapping[m] for m in leaves])
        added = new.n_ands - before
        if added <= old_cost:
            mapping[n] = cand
    for p in aig.pos:
        new.add_po(mapping[lit_node(p)] ^ lit_phase(p))
    out = new.clone()
    return out if out.n_ands <= aig.n_ands else aig


# ===========================================================================
# Resub (R_s)
# ===========================================================================


def resub(aig: Aig, n_words: int = 32, seed: int = 7, backend: str = "python") -> Aig:
    """Simulation-guided, window-exact resubstitution (ABC ``resub``).

    1. Global random simulation produces a signature per node.
    2. Signature-equal (or complement) node pairs are *candidate* equivalences,
       verified exactly over the union of structural supports (≤14 PIs) —
       verified pairs merge (0-resub / functional reduction).

    ``backend="device"`` runs signatures and verification truth tables
    through `kernels.aig_sim`; output is bit-identical to the python path.
    """
    if resolve_backend(backend) == "device":
        return _resub_device(aig, n_words=n_words, seed=seed)
    rng = np.random.default_rng(seed)
    if aig.n_pis == 0 or aig.n_ands == 0:
        return aig
    patterns = rng.integers(0, 1 << 63, size=(aig.n_pis, n_words), dtype=np.int64).astype(np.uint64)
    # include "elementary-ish" structured patterns for better separation
    sig = _node_signatures(aig, patterns)

    # Bucket by signature (and complemented signature).
    buckets: dict[bytes, list[int]] = {}
    for n in range(1, aig.n_nodes):
        buckets.setdefault(sig[n].tobytes(), []).append(n)

    supports = _supports(aig, cap=14)
    replace: dict[int, int] = {}  # node -> literal of replacement
    full = np.uint64(0xFFFFFFFFFFFFFFFF)
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if n in replace:
            continue
        cands = buckets.get(sig[n].tobytes(), [])
        comp = (sig[n] ^ full).tobytes()
        cands = [m for m in cands if m < n] + [m for m in buckets.get(comp, []) if m < n]
        for m in cands:
            neg = sig[m].tobytes() != sig[n].tobytes()
            if supports[n] is None or supports[m] is None:
                continue
            sup = sorted(supports[n] | supports[m])
            if len(sup) > 14:
                continue
            tt_n = aig.truth_table(lit(n), sup)
            tt_m = aig.truth_table(lit(m), sup)
            if tt_n == tt_m and not neg:
                replace[n] = lit(m)
                break
            if tt_n == (tt_m ^ _tt_mask(len(sup))) and neg:
                replace[n] = lit_not(lit(m))
                break

    if not replace:
        return aig
    new = Aig(aig.n_pis, name=aig.name)
    mapping: dict[int, int] = {0: CONST0}
    for i in range(1, 1 + aig.n_pis):
        mapping[i] = lit(i)
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if n in replace:
            r = replace[n]
            mapping[n] = mapping[lit_node(r)] ^ lit_phase(r)
        else:
            fa, fb = aig.fanins(n)
            mapping[n] = new.g_and(mapping[fa >> 1] ^ (fa & 1), mapping[fb >> 1] ^ (fb & 1))
    for p in aig.pos:
        new.add_po(mapping[lit_node(p)] ^ lit_phase(p))
    out = new.clone()
    return out if out.n_ands <= aig.n_ands else aig


def _node_signatures(aig: Aig, patterns: np.ndarray) -> np.ndarray:
    n_words = patterns.shape[1]
    vals = np.zeros((aig.n_nodes, n_words), dtype=np.uint64)
    vals[1 : 1 + aig.n_pis] = patterns
    full = np.uint64(0xFFFFFFFFFFFFFFFF)
    for n in range(aig.n_pis + 1, aig.n_nodes):
        fa, fb = aig.fanins(n)
        va = vals[fa >> 1] ^ (full if (fa & 1) else np.uint64(0))
        vb = vals[fb >> 1] ^ (full if (fb & 1) else np.uint64(0))
        vals[n] = va & vb
    return vals


def _supports(aig: Aig, cap: int = 14) -> list[set[int] | None]:
    """Structural PI support per node; None if larger than cap."""
    sup: list[set[int] | None] = [set() for _ in range(aig.n_nodes)]
    for n in range(1, 1 + aig.n_pis):
        sup[n] = {n}
    for n in range(aig.n_pis + 1, aig.n_nodes):
        fa, fb = aig.fanins(n)
        sa, sb = sup[fa >> 1], sup[fb >> 1]
        if sa is None or sb is None:
            sup[n] = None
            continue
        u = sa | sb
        sup[n] = None if len(u) > cap else u
    return sup


# ===========================================================================
# Device backend (kernels/aig_sim) — batched truth-table characterization
# ===========================================================================
#
# The device variants below are *bit-identical* re-stagings of the python
# transforms: every decision (truth table, MFFC size, plan, ISOP cubes,
# resub candidate order) is a pure function of the ORIGINAL AIG, so each
# transform splits into a precompute phase — one batched device call per
# query family instead of per-node python cone walks — and a sequential
# rebuild phase that replays the python path's decisions in its exact
# order.  Because outputs are identical, TRANSFORM_VERSION does not bump
# and on-disk cache entries stay valid across backends (CI asserts this).


def resolve_backend(backend: str | None) -> str:
    """Resolve a characterization backend name to ``python`` or ``device``.

    ``auto`` (or None) picks ``device`` when jax imports, else ``python``
    — same discipline as the sweep backends in `core.batch`.
    """
    if backend is None or backend == "auto":
        from repro.kernels.aig_sim import jax_available

        return "device" if jax_available() else "python"
    if backend not in ("python", "device"):
        raise ValueError(f"unknown characterization backend {backend!r}")
    return backend


def _cone_matrix(
    aig: Aig, roots: Sequence[int], leaves_list: Sequence[Sequence[int]]
) -> np.ndarray:
    """(B, n_nodes) bool cone membership for a batch of (root, leaves)
    queries — the vectorized counterpart of `Aig.cone_nodes` (AND nodes
    only, stopping at and excluding the leaves).

    One descending-index scan over the node array serves the whole batch:
    node indices are topological, so by the time the scan reaches ``n``
    every cone that contains ``n`` has already marked it.
    """
    n = aig.n_nodes
    n_b = len(roots)
    roots_a = np.asarray(roots, dtype=np.int64)
    f0 = np.asarray(aig._f0, dtype=np.int64)
    f1 = np.asarray(aig._f1, dtype=np.int64)
    # (n_nodes, batch) scan layout: node rows are contiguous (see
    # `aig_sim._cone_members`), transposed back on return.
    vis = np.zeros((n, n_b), dtype=bool)
    leaf = np.zeros((n, n_b), dtype=bool)
    for i, lvs in enumerate(leaves_list):
        leaf[list(lvs), i] = True
    vis[roots_a, np.arange(n_b)] = True
    for node in range(int(roots_a.max()), aig.n_pis, -1):
        act = vis[node] & ~leaf[node]
        if act.any():
            vis[f0[node] >> 1][act] = True
            vis[f1[node] >> 1][act] = True
    members = vis & ~leaf
    members[: aig.n_pis + 1] = False
    return np.ascontiguousarray(members.T)


def _mffc_sizes_batch(
    aig: Aig,
    roots: Sequence[int],
    members: np.ndarray,
    fanout: np.ndarray,
) -> np.ndarray:
    """(B,) MFFC sizes matching `_mffc_size` for each (root, cone) row of
    ``members`` (from `_cone_matrix`): cone nodes whose every fanout
    reference comes from inside the cone, the root always counted."""
    n = aig.n_nodes
    n_b = members.shape[0]
    f0 = np.asarray(aig._f0, dtype=np.int64) >> 1
    f1 = np.asarray(aig._f1, dtype=np.int64) >> 1
    # Cones are tiny relative to the graph, so work on the sparse member
    # entries: bincount the two fanin edges of every (item, cone node)
    # pair into per-item reference counts, then test each member entry.
    b_idx, node_idx = np.nonzero(members)
    keys = np.concatenate([b_idx * n + f0[node_idx], b_idx * n + f1[node_idx]])
    refs = np.bincount(keys, minlength=n_b * n)
    mkeys = b_idx * n + node_idx
    freed_mask = refs[mkeys] >= fanout[node_idx]
    freed = np.bincount(b_idx[freed_mask], minlength=n_b)
    roots_a = np.asarray(roots, dtype=np.int64)
    root_pass = refs[np.arange(n_b) * n + roots_a] >= fanout[roots_a]
    return freed - root_pass.astype(np.int64) + 1


def _rewrite_device(aig: Aig, k: int = 4, max_cuts: int = 8) -> Aig:
    """`rewrite` with batched device truth tables + vectorized MFFC."""
    from repro.kernels import aig_sim

    cuts = _enumerate_cuts(aig, k=k, max_cuts=max_cuts)
    fanout = aig.fanout_counts()
    reach = _reachable(aig)

    # Phase A — precompute: every (node, cut) query in python iteration
    # order; all decisions below depend only on the original AIG.
    items: list[tuple[int, list[int]]] = []
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if not reach[n]:
            continue
        for cut in cuts[n]:
            if len(cut) < 2 or n in cut:
                continue
            items.append((n, sorted(cut)))

    best_for: dict[int, tuple[tuple, list[int]]] = {}
    if items:
        prog = aig_sim.compile_aig(aig)
        members = _cone_matrix(aig, [n for n, _ in items], [s for _, s in items])
        tts = aig_sim.eval_tts(
            aig,
            [((lit(n),), sup) for n, sup in items],
            program=prog,
            members=members,
        )
        old_costs = _mffc_sizes_batch(aig, [n for n, _ in items], members, fanout)
        best_gain: dict[int, int] = {}
        for (n, sup), (tt,), old_cost in zip(items, tts, old_costs):
            cost, plan = synth_plan(tt, len(sup))
            gain = int(old_cost) - cost
            if gain > best_gain.get(n, 0):
                best_gain[n] = gain
                best_for[n] = (plan, sup)

    # Phase B — sequential rebuild, replaying the python path's choices.
    new = Aig(aig.n_pis, name=aig.name)
    mapping: dict[int, int] = {0: CONST0}
    for i in range(1, 1 + aig.n_pis):
        mapping[i] = lit(i)
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if not reach[n]:
            continue
        fa, fb = aig.fanins(n)
        mapping[n] = new.g_and(
            mapping[fa >> 1] ^ (fa & 1), mapping[fb >> 1] ^ (fb & 1)
        )
        hit = best_for.get(n)
        if hit is not None:
            plan, support = hit
            mapping[n] = build_plan(new, plan, [mapping[m] for m in support])
    for p in aig.pos:
        new.add_po(mapping[lit_node(p)] ^ lit_phase(p))
    out = new.clone()
    return out if out.n_ands <= aig.n_ands else aig


def _refactor_device(aig: Aig, max_leaves: int = 10) -> Aig:
    """`refactor` with batched device truth tables + vectorized MFFC.

    The `_factor_cubes` trial must stay in the sequential phase: rejected
    trials still leave strashed nodes in the new AIG, which later nodes'
    ``added`` accounting observes — so only the cone/tt/ISOP/estimate work
    moves to the precompute phase.
    """
    from repro.kernels import aig_sim

    fanout = aig.fanout_counts()
    reach = _reachable(aig)
    lv = aig.levels()

    cand_items: list[tuple[int, list[int]]] = []
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if not reach[n]:
            continue
        if fanout[n] < 2 and lv[n] % 3 != 0:
            continue
        leaves = _reconv_cut(aig, n, max_leaves)
        if len(leaves) < 3 or n in leaves:
            continue
        if len(leaves) > 12:
            continue
        cand_items.append((n, leaves))

    plans: dict[int, tuple[list[tuple[int, int]], list[int], int]] = {}
    if cand_items:
        prog = aig_sim.compile_aig(aig)
        members = _cone_matrix(
            aig, [n for n, _ in cand_items], [l for _, l in cand_items]
        )
        tts = aig_sim.eval_tts(
            aig,
            [((lit(n),), lvs) for n, lvs in cand_items],
            program=prog,
            members=members,
        )
        old_costs = _mffc_sizes_batch(
            aig, [n for n, _ in cand_items], members, fanout
        )
        for (n, leaves), (tt,), old_cost in zip(cand_items, tts, old_costs):
            kk = len(leaves)
            cubes = _isop(tt, _tt_mask(kk), kk)
            est = sum(bin(p | q).count("1") for p, q in cubes) + max(0, len(cubes) - 1)
            if est >= int(old_cost) + 2:
                continue
            plans[n] = (cubes, leaves, int(old_cost))

    new = Aig(aig.n_pis, name=aig.name)
    mapping: dict[int, int] = {0: CONST0}
    for i in range(1, 1 + aig.n_pis):
        mapping[i] = lit(i)
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if not reach[n]:
            continue
        fa, fb = aig.fanins(n)
        mapping[n] = new.g_and(mapping[fa >> 1] ^ (fa & 1), mapping[fb >> 1] ^ (fb & 1))
        hit = plans.get(n)
        if hit is None:
            continue
        cubes, leaves, old_cost = hit
        before = new.n_ands
        cand = _factor_cubes(new, cubes, [mapping[m] for m in leaves])
        added = new.n_ands - before
        if added <= old_cost:
            mapping[n] = cand
    for p in aig.pos:
        new.add_po(mapping[lit_node(p)] ^ lit_phase(p))
    out = new.clone()
    return out if out.n_ands <= aig.n_ands else aig


def _resub_device(aig: Aig, n_words: int = 32, seed: int = 7) -> Aig:
    """`resub` with device node signatures + round-batched verification.

    The python path verifies each node's candidate list in order and stops
    at the first match.  Candidate lists are independent across nodes, so
    rounds preserve that order exactly: round ``i`` verifies the first
    still-untried candidate of every unresolved node as one batched device
    call; a node drops out when it matches or exhausts its list.
    """
    from repro.kernels import aig_sim

    rng = np.random.default_rng(seed)
    if aig.n_pis == 0 or aig.n_ands == 0:
        return aig
    patterns = rng.integers(0, 1 << 63, size=(aig.n_pis, n_words), dtype=np.int64).astype(np.uint64)
    prog = aig_sim.compile_aig(aig)
    sig = aig_sim.node_signatures(aig, patterns, program=prog)

    buckets: dict[bytes, list[int]] = {}
    for n in range(1, aig.n_nodes):
        buckets.setdefault(sig[n].tobytes(), []).append(n)

    supports = _supports(aig, cap=14)
    full = np.uint64(0xFFFFFFFFFFFFFFFF)
    cand_lists: dict[int, list[tuple[int, bool, list[int]]]] = {}
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if supports[n] is None:
            continue
        cands = buckets.get(sig[n].tobytes(), [])
        comp = (sig[n] ^ full).tobytes()
        cands = [m for m in cands if m < n] + [m for m in buckets.get(comp, []) if m < n]
        flist: list[tuple[int, bool, list[int]]] = []
        for m in cands:
            if supports[m] is None:
                continue
            neg = sig[m].tobytes() != sig[n].tobytes()
            sup = sorted(supports[n] | supports[m])
            if len(sup) > 14:
                continue
            flist.append((m, neg, sup))
        if flist:
            cand_lists[n] = flist

    replace: dict[int, int] = {}
    pos_i = {n: 0 for n in cand_lists}
    active = sorted(cand_lists)
    while active:
        batch = [(n,) + cand_lists[n][pos_i[n]] for n in active]
        tts = aig_sim.eval_tts(
            aig,
            [((lit(n), lit(m)), sup) for n, m, _, sup in batch],
            program=prog,
        )
        nxt: list[int] = []
        for (n, m, neg, sup), (tt_n, tt_m) in zip(batch, tts):
            if tt_n == tt_m and not neg:
                replace[n] = lit(m)
            elif neg and tt_n == (tt_m ^ _tt_mask(len(sup))):
                replace[n] = lit_not(lit(m))
            else:
                pos_i[n] += 1
                if pos_i[n] < len(cand_lists[n]):
                    nxt.append(n)
        active = nxt

    if not replace:
        return aig
    new = Aig(aig.n_pis, name=aig.name)
    mapping: dict[int, int] = {0: CONST0}
    for i in range(1, 1 + aig.n_pis):
        mapping[i] = lit(i)
    for n in range(aig.n_pis + 1, aig.n_nodes):
        if n in replace:
            r = replace[n]
            mapping[n] = mapping[lit_node(r)] ^ lit_phase(r)
        else:
            fa, fb = aig.fanins(n)
            mapping[n] = new.g_and(mapping[fa >> 1] ^ (fa & 1), mapping[fb >> 1] ^ (fb & 1))
    for p in aig.pos:
        new.add_po(mapping[lit_node(p)] ^ lit_phase(p))
    out = new.clone()
    return out if out.n_ands <= aig.n_ands else aig


# ===========================================================================
# Recipes — Algorithm I line 3 (CreateAIG)
# ===========================================================================

_TRANSFORM_FNS: dict[str, Callable[[Aig], Aig]] = {
    "Ba": balance,
    "Rf": refactor,
    "Rw": rewrite,
    "Rs": resub,
}


def transform_fns(backend: str = "python") -> dict[str, Callable[[Aig], Aig]]:
    """Transform-name -> callable map for a characterization backend.

    ``balance`` has no truth-table inner loop, so it is shared; the other
    three dispatch to their `kernels.aig_sim`-batched variants under the
    ``device`` backend (bit-identical outputs either way).
    """
    resolved = resolve_backend(backend)
    if resolved == "python":
        return dict(_TRANSFORM_FNS)
    return {
        "Ba": balance,
        "Rf": partial(refactor, backend=resolved),
        "Rw": partial(rewrite, backend=resolved),
        "Rs": partial(resub, backend=resolved),
    }


def enumerate_recipes(
    names: Sequence[str] = TRANSFORM_NAMES,
) -> list[tuple[str, ...]]:
    """All ordered permutations of non-empty subsets — 64 for 4 transforms."""
    out: list[tuple[str, ...]] = []
    for r in range(1, len(names) + 1):
        out.extend(itertools.permutations(names, r))
    return out


def prefix_nodes(recipes: Sequence[tuple[str, ...]]) -> list[tuple[str, ...]]:
    """Non-empty prefixes of ``recipes``, deduplicated and ordered by depth
    — the nodes of the shared-prefix DAG in a valid evaluation order (a
    node's parent always precedes it)."""
    seen: set[tuple[str, ...]] = set()
    out: list[tuple[str, ...]] = []
    for r in recipes:
        for i in range(1, len(r) + 1):
            p = tuple(r[:i])
            if p not in seen:
                seen.add(p)
                out.append(p)
    out.sort(key=lambda p: (len(p), p))
    return out


class RecipeRunner:
    """Applies recipes over the shared-prefix DAG of the recipe set.

    Two memo layers:

      * *prefix* — recipes share prefixes (``Ba,Rf,Rw`` reuses the ``Ba,Rf``
        intermediate), so the 64-recipe sweep needs at most 64 transform
        applications instead of 129 chained ones;
      * *structural* — ``(input fingerprint, transform) -> output
        fingerprint``.  The transforms are deterministic functions of AIG
        structure, so when two prefixes converge to the identical AIG
        (common: transforms hit fixpoints and return their input), their
        entire subtrees coincide and are computed once.  On the tiny suite
        this cuts the 64 applications per circuit to 4-55 (`n_applied`).

    Characterizations (`stats`) are memoized per distinct structure, so a
    circuit whose recipes converge to D distinct AIGs pays D ``ChaAIG``
    passes, not 65.
    """

    def __init__(
        self,
        base: Aig,
        backend: str = "python",
        on_apply: "Callable[[str, str, str, Aig, AigStats | None], None] | None" = None,
    ):
        self.base = base
        self.backend = resolve_backend(backend)
        self._fns = transform_fns(self.backend)
        #: Called after every *fresh* application (not preloads) with
        #: (src_fp, transform, out_fp, out AIG, stats-or-None) — the hook
        #: `characterize_suite` uses for incremental cache persistence.
        self.on_apply = on_apply
        base_fp = base.fingerprint()
        self._node_fp: dict[tuple[str, ...], str] = {(): base_fp}
        self._store: dict[str, Aig] = {base_fp: base}
        self._applied: dict[tuple[str, str], str] = {}
        self._stats: dict[str, AigStats] = {}
        self.n_applied = 0  # real transform runs (structural misses)
        self.n_preloaded = 0  # applications installed from the disk cache

    # -- DAG resolution ------------------------------------------------------

    def run_fp(self, recipe: Sequence[str]) -> str:
        """Fingerprint of the recipe's result, applying transforms as needed."""
        recipe = tuple(recipe)
        hit = self._node_fp.get(recipe)
        if hit is not None:
            return hit
        src_fp = self.run_fp(recipe[:-1])
        out_fp = self.apply_fp(src_fp, recipe[-1])
        self._node_fp[recipe] = out_fp
        return out_fp

    def apply_fp(self, src_fp: str, transform: str) -> str:
        """Structural-memo transform application on a stored AIG."""
        key = (src_fp, transform)
        hit = self._applied.get(key)
        if hit is not None:
            return hit
        out = self._fns[transform](self._store[src_fp])
        self.n_applied += 1
        out_fp = out.fingerprint()
        self._applied[key] = out_fp
        self._store.setdefault(out_fp, out)
        if self.on_apply is not None:
            self.on_apply(src_fp, transform, out_fp, out, None)
        return out_fp

    def record(
        self, src_fp: str, transform: str, out: Aig,
        stats: AigStats | None = None,
    ) -> str:
        """Install an externally computed application (process-pool path)."""
        out_fp = out.fingerprint()
        self.n_applied += 1
        self._applied[(src_fp, transform)] = out_fp
        self._store.setdefault(out_fp, out)
        if stats is not None:
            self._stats.setdefault(out_fp, stats)
        if self.on_apply is not None:
            self.on_apply(src_fp, transform, out_fp, out, stats)
        return out_fp

    def preload_application(
        self, src_fp: str, transform: str, out: Aig,
        stats: AigStats | None = None,
    ) -> str:
        """Install a cached application as a warm start: does not count as
        work (`n_applied`) and does not re-notify ``on_apply``."""
        out_fp = out.fingerprint()
        self._applied.setdefault((src_fp, transform), out_fp)
        self._store.setdefault(out_fp, out)
        if stats is not None:
            self._stats.setdefault(out_fp, stats)
        self.n_preloaded += 1
        return out_fp

    def aig_for(self, fp: str) -> Aig:
        return self._store[fp]

    def has_applied(self, src_fp: str, transform: str) -> bool:
        return (src_fp, transform) in self._applied

    # -- public API ----------------------------------------------------------

    def run(self, recipe: Sequence[str]) -> Aig:
        """The recipe's result AIG (Alg. I line 3, ``CreateAIG``)."""
        return self._store[self.run_fp(recipe)]

    def stats(self, recipe: Sequence[str]) -> AigStats:
        """The recipe's characterization (Alg. I line 4, ``ChaAIG``),
        memoized per distinct result structure."""
        fp = self.run_fp(recipe)
        hit = self._stats.get(fp)
        if hit is None:
            hit = self._stats[fp] = self._store[fp].characterize()
        return hit


def apply_recipe(aig: Aig, recipe: Sequence[str]) -> Aig:
    return RecipeRunner(aig).run(tuple(recipe))


# ===========================================================================
# Persistent characterization cache
# ===========================================================================


def _recipe_key(recipe: tuple[str, ...]) -> str:
    return ",".join(recipe)


def _atomic_json(path: Path, payload: dict) -> None:
    """Write JSON via tempfile + ``os.replace`` (crash/concurrency safe)."""
    path.parent.mkdir(parents=True, exist_ok=True)
    # Serialize first, write bytes: the chaos harness can then model a
    # torn write (truncated payload surviving the atomic replace) that
    # the tolerant load paths below must absorb as a cache miss.
    data = faults.corrupt(
        "cache.store", json.dumps(payload).encode(), detail=str(path)
    )
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class CharacterizationCache:
    """On-disk ``ChaAIG`` cache keyed by (circuit, recipe, transform version).

    Layout: one JSON file per circuit fingerprint under
    ``{root}/v{TRANSFORM_VERSION}/{fp}.json``, mapping recipe keys
    (``"Ba,Rf"``; ``""`` is the baseline) to `AigStats` dicts.  The
    transform version is both the directory name and embedded in each file,
    so bumping `TRANSFORM_VERSION` orphans every stale entry instead of
    serving results from outdated transform implementations.

    Writes are atomic (tempfile + ``os.replace``), so concurrent
    characterizations at worst redo work — they never corrupt the cache.
    ``hits`` / ``misses`` count circuit-level lookups (for tests and the
    cold/warm benchmark reporting).
    """

    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        self.hits = 0
        self.misses = 0

    def _path(self, circuit_fp: str) -> Path:
        return self.root / f"v{TRANSFORM_VERSION}" / f"{circuit_fp}.json"

    def load(self, circuit_fp: str) -> dict[tuple[str, ...], AigStats]:
        """All cached characterizations for a circuit (empty dict on miss).

        Corruption-tolerant: a truncated or otherwise unparseable file is
        a whole-circuit miss, and a schema-corrupt *entry* (wrong keys /
        types inside valid JSON) is an entry-level miss — either way the
        caller re-characterizes and `store` atomically rewrites the file,
        so a torn write never wedges the cache."""
        path = self._path(circuit_fp)
        try:
            with open(path) as f:
                raw = json.load(f)
            if raw.get("transform_version") != TRANSFORM_VERSION:
                return {}
            items = list(raw.get("recipes", {}).items())
        except (OSError, json.JSONDecodeError, TypeError, AttributeError):
            return {}
        out: dict[tuple[str, ...], AigStats] = {}
        for key, d in items:
            try:
                recipe = tuple(key.split(",")) if key else ()
                out[recipe] = AigStats.from_dict(d)
            except (KeyError, TypeError, ValueError, AttributeError):
                continue  # corrupt entry -> miss for that recipe only
        return out

    def store(
        self, circuit_fp: str, cha: Mapping[tuple[str, ...], AigStats]
    ) -> None:
        """Merge ``cha`` into the circuit's cache file (atomic replace)."""
        merged = self.load(circuit_fp)
        merged.update(cha)
        payload = dict(
            transform_version=TRANSFORM_VERSION,
            circuit=circuit_fp,
            recipes={
                _recipe_key(r): s.to_dict() for r, s in sorted(merged.items())
            },
        )
        _atomic_json(self._path(circuit_fp), payload)

    # -- per-application persistence (partial warm starts) -------------------
    #
    # Recipe-endpoint stats alone only help once a whole circuit finished:
    # a run that dies mid-suite redoes every transform.  The application
    # index below persists each (src fingerprint, transform) -> output as
    # soon as it is computed, with the output AIG *structure* stored once
    # per distinct fingerprint — the next run preloads them into the
    # `RecipeRunner` memo and only runs the applications it never reached.

    def _apps_path(self, circuit_fp: str) -> Path:
        return self.root / f"v{TRANSFORM_VERSION}" / f"{circuit_fp}.apps.json"

    def _aig_path(self, fp: str) -> Path:
        return self.root / f"v{TRANSFORM_VERSION}" / "aigs" / f"{fp}.json"

    def load_applications(
        self, circuit_fp: str
    ) -> dict[tuple[str, str], tuple[str, AigStats | None]]:
        """Persisted applications for a circuit:
        ``{(src_fp, transform): (out_fp, stats-or-None)}``."""
        try:
            with open(self._apps_path(circuit_fp)) as f:
                raw = json.load(f)
            if raw.get("transform_version") != TRANSFORM_VERSION:
                return {}
            items = list(raw.get("apps", {}).items())
        except (OSError, json.JSONDecodeError, TypeError, AttributeError):
            return {}
        out: dict[tuple[str, str], tuple[str, AigStats | None]] = {}
        for key, d in items:
            try:
                src_fp, _, transform = key.rpartition(":")
                if not src_fp or transform not in TRANSFORM_NAMES:
                    continue
                stats = (
                    AigStats.from_dict(d["stats"]) if d.get("stats") else None
                )
                out[(src_fp, transform)] = (d["out"], stats)
            except (KeyError, TypeError, ValueError, AttributeError):
                continue  # corrupt application entry -> redo that one
        return out

    def load_aig(self, fp: str) -> Aig | None:
        """A persisted AIG structure by fingerprint (None on miss/corruption)."""
        try:
            with open(self._aig_path(fp)) as f:
                raw = json.load(f)
            aig = Aig.from_dict(raw)
        except (OSError, json.JSONDecodeError, KeyError, ValueError,
                IndexError, TypeError, AttributeError):
            return None
        return aig if aig.fingerprint() == fp else None

    def store_application(
        self,
        circuit_fp: str,
        src_fp: str,
        transform: str,
        out: Aig,
        stats: AigStats | None = None,
    ) -> None:
        """Persist one transform application and its output structure.

        The AIG file is written first so a crash between the two writes
        leaves at worst an unreferenced structure, never a dangling index
        entry."""
        out_fp = out.fingerprint()
        aig_path = self._aig_path(out_fp)
        if not aig_path.exists():
            _atomic_json(aig_path, out.to_dict())
        apps_path = self._apps_path(circuit_fp)
        try:
            with open(apps_path) as f:
                raw = json.load(f)
            if raw.get("transform_version") != TRANSFORM_VERSION:
                raw = {}
        except (OSError, json.JSONDecodeError, TypeError, AttributeError):
            raw = {}
        apps = raw.get("apps", {})
        if not isinstance(apps, dict):
            apps = {}
        entry = apps.get(f"{src_fp}:{transform}", {})
        if not isinstance(entry, dict):
            entry = {}
        apps[f"{src_fp}:{transform}"] = dict(
            out=out_fp,
            stats=stats.to_dict() if stats is not None else entry.get("stats"),
        )
        _atomic_json(
            apps_path,
            dict(
                transform_version=TRANSFORM_VERSION,
                circuit=circuit_fp,
                apps=apps,
            ),
        )


def _as_cache(
    cache: "CharacterizationCache | str | os.PathLike | None",
) -> "CharacterizationCache | None":
    if cache is None or isinstance(cache, CharacterizationCache):
        return cache
    return CharacterizationCache(cache)


# ===========================================================================
# Suite-level characterization (parallel front half of Algorithm I)
# ===========================================================================


def _characterize_task(task):
    """Process-pool worker: apply one transform and characterize the result.

    ``task`` = (circuit name, input fingerprint, transform, input Aig,
    backend).  Returns (name, input fingerprint, transform, result Aig,
    AigStats) — the parent installs it via `RecipeRunner.record`.
    """
    name, src_fp, transform, aig, backend = task
    faults.inject("pool.task", detail=f"{name}:{transform}")
    out = transform_fns(backend)[transform](aig)
    return name, src_fp, transform, out, out.characterize()


@dataclasses.dataclass(frozen=True)
class PoolPolicy:
    """Fault posture of the characterization pool scheduler.

    ``task_deadline_s``: wall-clock budget per dispatched application;
    exceeding it counts as one failed attempt and — since a running
    `ProcessPoolExecutor` task cannot be cancelled — forces a pool
    rebuild so the stuck worker is actually killed.  ``max_retries`` is
    *additional* attempts after the first (so 2 means up to 3 runs);
    retries wait ``backoff_s * 2**attempt`` seconds (capped) scaled by a
    deterministic per-(task, attempt) jitter in [0.5, 1.5) keyed on
    ``seed``, so a chaos failure replays exactly.
    """

    task_deadline_s: float | None = None
    max_retries: int = 2
    backoff_s: float = 0.05
    backoff_cap_s: float = 2.0
    seed: int = 0

    def backoff(self, key: str, attempt: int) -> float:
        base = min(self.backoff_cap_s, self.backoff_s * (2.0 ** attempt))
        return base * (0.5 + Random(f"{self.seed}:{key}:{attempt}").random())


class CharacterizationError(RuntimeError):
    """A circuit's characterization failed permanently (poisoned task:
    retries exhausted, or repeated worker crashes/hangs attributed to
    it).  Carries the circuit so suite-level callers can quarantine it
    instead of aborting the whole sweep."""

    def __init__(self, circuit: str, message: str):
        super().__init__(f"{circuit}: {message}")
        self.circuit = circuit


def _resolve_jobs(n_jobs: int | None, backend: str = "python") -> int:
    if n_jobs is None:
        env = os.environ.get("REPRO_CHA_JOBS")
        if env is not None:
            n_jobs = int(env)
        elif backend == "device":
            # The device path is already batched; spawn workers would each
            # pay a fresh jax import + jit warm-up, so default to serial.
            n_jobs = 1
        else:
            n_jobs = min(4, os.cpu_count() or 1)
    if n_jobs > 1 and not _spawn_safe():
        n_jobs = 1
    return max(1, n_jobs)


def _spawn_safe() -> bool:
    """The ``spawn`` start method re-runs ``__main__`` in each worker; when
    the parent was fed from a pipe/stdin (``__file__`` points nowhere) that
    re-run crashes, so fall back to serial execution in that case."""
    import sys

    main_file = getattr(sys.modules.get("__main__"), "__file__", None)
    return main_file is None or os.path.exists(main_file)


def characterize_suite(
    circuits: Mapping[str, Aig],
    recipes: Sequence[tuple[str, ...]] | None = None,
    cache: "CharacterizationCache | str | os.PathLike | None" = None,
    n_jobs: int | None = None,
    backend: str = "auto",
    policy: "PoolPolicy | None" = None,
    failures: "dict[str, CharacterizationError] | None" = None,
) -> dict[str, dict[tuple[str, ...], AigStats]]:
    """Front half of Algorithm I (lines 3-6) over a whole benchmark suite.

    For every circuit, creates and characterizes the recipe AIGs (baseline
    ``()`` included) and returns ``{circuit: {recipe: AigStats}}`` — the
    input `core.batch.SuiteTable.from_cha` stacks for the vmapped sweep.

    Three cost-reduction layers over naive per-recipe runs:

      * the shared-prefix DAG with structural dedup (`RecipeRunner`);
      * a persistent on-disk cache (``cache``: a `CharacterizationCache`
        or a directory path) keyed by (circuit fingerprint, recipe,
        `TRANSFORM_VERSION`) — warm lookups skip the transforms entirely;
      * a process pool (``n_jobs`` workers, default
        ``min(4, cpu_count)``, env override ``REPRO_CHA_JOBS``; ``1``
        disables) driven by an *as-completed futures scheduler*: a
        transform application is submitted the moment its parent's
        fingerprint is known, so independent prefix branches and
        circuits overlap freely and a deep chain (the sine-dominated
        tail) no longer waits for the rest of its DAG level.

    The pool uses the ``spawn`` start method: characterization is pure
    numpy/python, but the parent may have jax/XLA threads loaded (the
    batched back half), and forking such a process is unsafe.

    ``backend`` selects the transform implementation (`resolve_backend`):
    ``device`` batches the truth-table inner loops through
    `kernels.aig_sim` (bit-identical outputs, so cache entries are shared
    across backends); the default ``auto`` uses it whenever jax imports.
    Cache-backed runs also persist every *application* as it completes
    (`CharacterizationCache.store_application`), so a run that dies
    mid-suite warm-starts from the applications it already did.

    ``policy`` sets the pool's fault posture (`PoolPolicy`: per-task
    deadlines, bounded retry with deterministic backoff + jitter, pool
    rebuild on worker loss).  ``failures``: pass a dict to opt into
    *quarantine* mode — a circuit whose characterization fails
    permanently is dropped from the returned mapping and recorded there
    as ``{name: CharacterizationError}`` instead of aborting the whole
    suite; with the default ``None`` the first permanent failure raises.
    """
    recipes = [
        tuple(r) for r in (recipes if recipes is not None else enumerate_recipes())
    ]
    wanted = list(dict.fromkeys([()] + recipes))
    cache = _as_cache(cache)
    backend = resolve_backend(backend)
    failed: dict[str, CharacterizationError] = {}

    out: dict[str, dict[tuple[str, ...], AigStats]] = {}
    runners: dict[str, RecipeRunner] = {}
    fps: dict[str, str] = {}
    for name, rtl in circuits.items():
        try:
            faults.inject("cha.backend", detail=f"{backend}:{name}")
            fps[name] = rtl.fingerprint()
            cached = cache.load(fps[name]) if cache is not None else {}
            if cached and all(r in cached for r in wanted):
                if cache is not None:
                    cache.hits += 1
                out[name] = {r: cached[r] for r in wanted}
                continue
            if cache is not None:
                cache.misses += 1
            runner = RecipeRunner(rtl, backend=backend)
            if cache is not None:
                # Partial warm start: replay persisted applications into the
                # structural memo, then persist every fresh one incrementally.
                for (src_fp, t), (out_fp, st) in cache.load_applications(
                    fps[name]
                ).items():
                    out_aig = cache.load_aig(out_fp)
                    if out_aig is not None:
                        runner.preload_application(src_fp, t, out_aig, st)
                runner.on_apply = partial(
                    _persist_application, cache, fps[name], runner
                )
            runners[name] = runner
        except Exception as e:  # noqa: BLE001 — quarantine, don't abort
            err = CharacterizationError(name, f"{type(e).__name__}: {e}")
            if failures is None:
                raise err from e
            failed[name] = err

    if runners:
        _run_suite_dag(runners, wanted, n_jobs, backend, policy=policy,
                       failed=failed if failures is not None else None)
        for name, runner in runners.items():
            if name in failed:
                continue
            try:
                cha = {r: runner.stats(r) for r in wanted}
            except Exception as e:  # noqa: BLE001
                err = CharacterizationError(name, f"{type(e).__name__}: {e}")
                if failures is None:
                    raise err from e
                failed[name] = err
                continue
            out[name] = cha
            if cache is not None:
                cache.store(fps[name], cha)

    if failed:
        if failures is None:
            raise next(iter(failed.values()))
        failures.update(failed)
    # Preserve the caller's circuit order; quarantined circuits are absent.
    return {name: out[name] for name in circuits if name in out}


def _persist_application(
    cache: CharacterizationCache,
    circuit_fp: str,
    runner: RecipeRunner,
    src_fp: str,
    transform: str,
    out_fp: str,
    out: Aig,
    stats: AigStats | None,
) -> None:
    """`RecipeRunner.on_apply` hook: persist the application immediately.

    Characterizes the output if the pool didn't already, seeding the
    runner's stats memo so `RecipeRunner.stats` never repeats the work.
    """
    if stats is None:
        stats = runner._stats.get(out_fp)
        if stats is None:
            stats = out.characterize()
        runner._stats.setdefault(out_fp, stats)
    cache.store_application(circuit_fp, src_fp, transform, out, stats)


def _run_suite_dag(
    runners: Mapping[str, RecipeRunner],
    wanted: Sequence[tuple[str, ...]],
    n_jobs: int | None,
    backend: str = "python",
    policy: "PoolPolicy | None" = None,
    failed: "dict[str, CharacterizationError] | None" = None,
) -> None:
    """Evaluate every prefix node of ``wanted`` in all runners on an
    as-completed futures scheduler.

    A transform application is dispatched to the process pool the moment
    its parent prefix's fingerprint is known — there is no level barrier,
    so while one worker grinds through a deep chain (sine's recipes
    dominate the cold front half) the others drain every independent
    branch and circuit instead of idling at the end of each DAG depth.
    Structural dedup is preserved: distinct nodes that resolve to the
    same (circuit, input fingerprint, transform) application share one
    in-flight future, and applications a runner already knows resolve
    instantly and cascade into their children.

    Fault posture (``policy``, default `PoolPolicy`):

      * a task raising in the worker is retried up to ``max_retries``
        times with deterministic exponential backoff + jitter;
      * a task exceeding ``task_deadline_s`` forces a **pool rebuild**
        (running `ProcessPoolExecutor` tasks cannot be cancelled, so the
        stuck workers are terminated) and counts as a failed attempt;
      * `BrokenProcessPool` — a worker died (OOM-kill, hard crash) —
        also rebuilds the pool; every other in-flight task is
        re-dispatched at its current attempt count, the task whose
        future broke is charged one attempt;
      * a task out of attempts poisons its *circuit*: with ``failed``
        provided the circuit is quarantined there
        (`CharacterizationError`) and the rest of the suite proceeds;
        otherwise the error raises.
    """
    nodes = prefix_nodes(wanted)
    if not nodes:
        return
    policy = policy or PoolPolicy()
    n_jobs = _resolve_jobs(n_jobs, backend)
    if n_jobs == 1:
        # Serial: the memoized DAG walk itself (depth order from
        # prefix_nodes guarantees parents resolve first).  Quarantine is
        # per circuit here too — one poisoned netlist cannot sink the
        # suite when the caller opted in.
        for name, runner in runners.items():
            try:
                for node in nodes:
                    runner.run_fp(node)
            except Exception as e:  # noqa: BLE001
                err = CharacterizationError(name, f"{type(e).__name__}: {e}")
                if failed is None:
                    raise err from e
                failed[name] = err
        return

    import multiprocessing as mp
    from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
    from concurrent.futures.process import BrokenProcessPool

    # DAG edges: parent prefix -> the nodes it unblocks.  prefix_nodes
    # includes every non-empty prefix, so each node's parent is () or
    # another node and the roots are exactly children[()].
    children: dict[tuple[str, ...], list[tuple[str, ...]]] = {}
    for node in nodes:
        children.setdefault(node[:-1], []).append(node)

    # (circuit, src_fp, transform) -> nodes whose resolution awaits the
    # in-flight application's result.
    waiting: dict[tuple[str, str, str], list[tuple[str, ...]]] = {}

    def advance(name, runner, node, tasks):
        """Node's parent fp is known: resolve through the memo, or queue
        the one application it is blocked on; cascades into children of
        instantly-resolved nodes."""
        src_fp = runner.run_fp(node[:-1])
        t = node[-1]
        if runner.has_applied(src_fp, t):
            runner.run_fp(node)
            for child in children.get(node, []):
                advance(name, runner, child, tasks)
            return
        key = (name, src_fp, t)
        if key in waiting:
            waiting[key].append(node)
            return
        waiting[key] = [node]
        tasks.append((name, src_fp, t, runner.aig_for(src_fp), backend))

    def task_key(task) -> str:
        return f"{task[0]}:{task[1]}:{task[2]}"

    dead: set[str] = set()

    def quarantine(name: str, reason: str) -> None:
        err = CharacterizationError(name, reason)
        if failed is None:
            raise err
        failed[name] = err
        dead.add(name)
        # Nothing waiting on a dead circuit resolves; drop its
        # bookkeeping so the scheduler can drain.
        for key in [k for k in waiting if k[0] == name]:
            del waiting[key]

    ex = ProcessPoolExecutor(
        max_workers=n_jobs, mp_context=mp.get_context("spawn")
    )
    # fut -> (task, attempt, dispatch wall time)
    inflight: dict = {}
    # min-heap of (ready_at, seq, task, attempt) retry reservations — the
    # scheduler sleeps in `wait` timeouts instead of blocking on backoff.
    retries: list = []
    seq = 0

    def submit(task, attempt):
        inflight[ex.submit(_characterize_task, task)] = (
            task, attempt, time.monotonic(),
        )

    def schedule_retry(task, attempt, reason):
        nonlocal seq
        if attempt > policy.max_retries:
            quarantine(task[0], f"task {task[2]} failed permanently: {reason}")
            return
        ready = time.monotonic() + policy.backoff(task_key(task), attempt - 1)
        heapq.heappush(retries, (ready, seq, task, attempt))
        seq += 1

    def rebuild_pool():
        """Terminate every worker and start a fresh pool; the caller
        re-dispatches whatever was in flight."""
        nonlocal ex
        for p in list(getattr(ex, "_processes", {}).values()):
            try:
                p.terminate()
            except OSError:
                pass
        ex.shutdown(wait=False, cancel_futures=True)
        ex = ProcessPoolExecutor(
            max_workers=n_jobs, mp_context=mp.get_context("spawn")
        )

    def redispatch_inflight(charge: dict) -> None:
        """Move every in-flight task onto the fresh pool.  ``charge``
        maps a task key to the failure reason for tasks that burned an
        attempt (broken future, expired deadline); the rest resubmit at
        their current attempt count."""
        moved = list(inflight.values())
        inflight.clear()
        for task, attempt, _ in moved:
            if task[0] in dead:
                continue
            reason = charge.get(task_key(task))
            if reason is not None:
                schedule_retry(task, attempt + 1, reason)
            else:
                submit(task, attempt)

    try:
        tasks: list[tuple] = []
        for name, runner in runners.items():
            for node in children.get((), []):
                advance(name, runner, node, tasks)
        for t in tasks:
            submit(t, 0)
        while inflight or retries:
            now = time.monotonic()
            # Launch due retries; the earliest pending one bounds the wait.
            while retries and retries[0][0] <= now:
                _, _, task, attempt = heapq.heappop(retries)
                if task[0] not in dead:
                    submit(task, attempt)
            timeout = None
            if retries:
                timeout = max(0.0, retries[0][0] - now)
            if policy.task_deadline_s is not None and inflight:
                oldest = min(t0 for _, _, t0 in inflight.values())
                expiry = oldest + policy.task_deadline_s - now
                timeout = expiry if timeout is None else min(timeout, expiry)
            if not inflight:
                if timeout:
                    time.sleep(timeout)
                continue
            done, _ = wait(
                inflight, timeout=timeout, return_when=FIRST_COMPLETED
            )
            tasks = []
            broken: list[tuple] = []
            for fut in done:
                task, attempt, _ = inflight.pop(fut)
                try:
                    name, src_fp, t, aig, stats = fut.result()
                except BrokenProcessPool as e:
                    broken.append((task, attempt, f"worker died: {e}"))
                    continue
                except Exception as e:  # noqa: BLE001 — task raised in worker
                    schedule_retry(
                        task, attempt + 1, f"{type(e).__name__}: {e}"
                    )
                    continue
                if name in dead:
                    continue
                runner = runners[name]
                runner.record(src_fp, t, aig, stats)
                for node in waiting.pop((name, src_fp, t), []):
                    runner.run_fp(node)
                    for child in children.get(node, []):
                        advance(name, runner, child, tasks)
            if broken:
                rebuild_pool()
                redispatch_inflight({})
                for task, attempt, reason in broken:
                    if task[0] not in dead:
                        schedule_retry(task, attempt + 1, reason)
            elif policy.task_deadline_s is not None:
                now = time.monotonic()
                expired = {
                    task_key(task): f"deadline {policy.task_deadline_s}s "
                    f"exceeded"
                    for task, _, t0 in inflight.values()
                    if now - t0 > policy.task_deadline_s
                }
                if expired:
                    rebuild_pool()
                    redispatch_inflight(expired)
            for t in tasks:
                if t[0] not in dead:
                    submit(t, 0)
    finally:
        for p in list(getattr(ex, "_processes", {}).values()):
            try:
                p.terminate()
            except OSError:
                pass
        ex.shutdown(wait=False, cancel_futures=True)
