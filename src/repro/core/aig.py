"""And-Inverter Graph (AIG) engine.

This is the substrate of the paper's Algorithm I: the ABC tool is not
available offline, so we re-implement the parts the paper uses —

  * an AIG DAG with structural hashing ("strash"),
  * bit-parallel simulation (the CiM engine's functional oracle),
  * truth-table extraction for small cones (used by rewrite/refactor),
  * level / per-level op-count characterization ("ChaAIG" in Alg. I),
  * conversion to a NAND2/NOR2/NOT gate netlist — the op types the rCiM
    macro executes natively (§III-B of the paper).

Representation: ABC-style literals.  A literal is ``2*node + phase`` where
``phase=1`` means complemented.  Node 0 is the constant-FALSE node, so
literal 0 = const0 and literal 1 = const1.  Primary inputs are nodes
1..n_pi; AND nodes follow.
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import lru_cache
from typing import Callable, Iterable, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Literal helpers
# ---------------------------------------------------------------------------

CONST0 = 0
CONST1 = 1


def lit(node: int, phase: int = 0) -> int:
    return (node << 1) | phase


def lit_node(l: int) -> int:
    return l >> 1


def lit_phase(l: int) -> int:
    return l & 1


def lit_not(l: int) -> int:
    return l ^ 1


def lit_regular(l: int) -> int:
    return l & ~1


@dataclasses.dataclass
class AigStats:
    """Characterization record — ``ChaAIG`` of Algorithm I."""

    n_pis: int
    n_pos: int
    n_ands: int
    n_levels: int
    # ops_per_level[i] = dict(nand=?, nor=?, inv=?) for gate-netlist level i.
    ops_per_level: list[dict[str, int]]
    nand_count: int
    nor_count: int
    inv_count: int

    @property
    def total_gates(self) -> int:
        """Total mapped gate count (NAND2 + NOR2 + NOT)."""
        return self.nand_count + self.nor_count + self.inv_count

    def to_dict(self) -> dict:
        """JSON-safe form (used by the on-disk characterization cache)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AigStats":
        d = dict(d)
        d["ops_per_level"] = [
            {k: int(v) for k, v in lvl.items()} for lvl in d["ops_per_level"]
        ]
        return cls(**d)

    @property
    def max_ops_in_level(self) -> int:
        if not self.ops_per_level:
            return 0
        return max(sum(d.values()) for d in self.ops_per_level)

    def ops_matrix(self) -> np.ndarray:
        """Per-level op counts as an ``(n_levels, 3)`` int array in
        (nand, nor, inv) order — the row format the batched exploration
        engine (core/batch.py) stacks into its workload tensor."""
        out = np.zeros((len(self.ops_per_level), 3), dtype=np.int64)
        for i, level in enumerate(self.ops_per_level):
            out[i, 0] = level.get("nand", 0)
            out[i, 1] = level.get("nor", 0)
            out[i, 2] = level.get("inv", 0)
        return out


class Aig:
    """A mutable AIG with structural hashing.

    Nodes are stored in topological order (fanins always precede fanouts);
    all graph surgery goes through rebuilding (`rebuild_mapped`) which
    re-strashes, so the invariant is preserved by construction.
    """

    def __init__(self, n_pis: int = 0, name: str = "aig"):
        self.name = name
        # fanin literal arrays; entry i corresponds to node i.
        # Nodes 0..n_pis are const/PI and have fanins (-1, -1).
        self._f0: list[int] = [-1] * (1 + n_pis)
        self._f1: list[int] = [-1] * (1 + n_pis)
        self.n_pis = n_pis
        self.pos: list[int] = []  # output literals
        self._strash: dict[tuple[int, int], int] = {}

    # -- construction -------------------------------------------------------

    def add_pi(self) -> int:
        """Append one primary input; returns its (positive) literal."""
        self._f0.append(-1)
        self._f1.append(-1)
        self.n_pis += 1
        node = len(self._f0) - 1
        # PIs must precede AND nodes; enforce.
        if self.n_ands:
            raise ValueError("add_pi after AND nodes were created")
        return lit(node)

    @property
    def n_nodes(self) -> int:
        return len(self._f0)

    @property
    def n_ands(self) -> int:
        return self.n_nodes - 1 - self.n_pis

    def is_pi(self, node: int) -> bool:
        return 1 <= node <= self.n_pis

    def is_and(self, node: int) -> bool:
        return node > self.n_pis

    def fanins(self, node: int) -> tuple[int, int]:
        return self._f0[node], self._f1[node]

    def g_and(self, a: int, b: int) -> int:
        """Strashed AND of two literals (with constant folding)."""
        # Constant / trivial folding.
        if a == CONST0 or b == CONST0:
            return CONST0
        if a == CONST1:
            return b
        if b == CONST1:
            return a
        if a == b:
            return a
        if a == lit_not(b):
            return CONST0
        if a > b:
            a, b = b, a
        key = (a, b)
        hit = self._strash.get(key)
        if hit is not None:
            return hit
        self._f0.append(a)
        self._f1.append(b)
        node = len(self._f0) - 1
        out = lit(node)
        self._strash[key] = out
        return out

    # Derived gates --------------------------------------------------------

    def g_or(self, a: int, b: int) -> int:
        return lit_not(self.g_and(lit_not(a), lit_not(b)))

    def g_nand(self, a: int, b: int) -> int:
        return lit_not(self.g_and(a, b))

    def g_nor(self, a: int, b: int) -> int:
        return self.g_and(lit_not(a), lit_not(b))

    def g_xor(self, a: int, b: int) -> int:
        return self.g_or(self.g_and(a, lit_not(b)), self.g_and(lit_not(a), b))

    def g_xnor(self, a: int, b: int) -> int:
        return lit_not(self.g_xor(a, b))

    def g_mux(self, sel: int, t: int, f: int) -> int:
        """sel ? t : f"""
        return self.g_or(self.g_and(sel, t), self.g_and(lit_not(sel), f))

    def g_maj(self, a: int, b: int, c: int) -> int:
        return self.g_or(
            self.g_and(a, b), self.g_or(self.g_and(b, c), self.g_and(a, c))
        )

    def g_and_multi(self, lits: Sequence[int]) -> int:
        acc = CONST1
        for l in lits:
            acc = self.g_and(acc, l)
        return acc

    def g_or_multi(self, lits: Sequence[int]) -> int:
        acc = CONST0
        for l in lits:
            acc = self.g_or(acc, l)
        return acc

    def add_po(self, l: int) -> None:
        self.pos.append(l)

    # -- analysis -----------------------------------------------------------

    def levels(self) -> np.ndarray:
        """AIG level per node (PIs/const at level 0)."""
        lv = np.zeros(self.n_nodes, dtype=np.int32)
        f0, f1 = self._f0, self._f1
        for n in range(self.n_pis + 1, self.n_nodes):
            lv[n] = 1 + max(lv[f0[n] >> 1], lv[f1[n] >> 1])
        return lv

    def depth(self) -> int:
        if self.n_nodes == 1 + self.n_pis:
            return 0
        lv = self.levels()
        if not self.pos:
            return int(lv.max(initial=0))
        return int(max(lv[lit_node(p)] for p in self.pos))

    def fanout_counts(self) -> np.ndarray:
        fo = np.zeros(self.n_nodes, dtype=np.int64)
        for n in range(self.n_pis + 1, self.n_nodes):
            fo[self._f0[n] >> 1] += 1
            fo[self._f1[n] >> 1] += 1
        for p in self.pos:
            fo[lit_node(p)] += 1
        return fo

    # -- simulation ---------------------------------------------------------

    def simulate(self, pi_values: np.ndarray) -> np.ndarray:
        """Bit-parallel simulation.

        ``pi_values``: uint64 array of shape (n_pis, W) — W 64-bit pattern
        words per input.  Returns (n_pos, W) uint64 of output patterns.
        This is the functional oracle the Pallas CiM kernel is checked
        against (kernels/ref.py reuses it).
        """
        pi_values = np.asarray(pi_values, dtype=np.uint64)
        if pi_values.ndim == 1:
            pi_values = pi_values[:, None]
        n_pis, width = pi_values.shape
        if n_pis != self.n_pis:
            raise ValueError(f"expected {self.n_pis} PI rows, got {n_pis}")
        vals = np.zeros((self.n_nodes, width), dtype=np.uint64)
        vals[1 : 1 + self.n_pis] = pi_values
        f0 = np.asarray(self._f0[self.n_pis + 1 :], dtype=np.int64)
        f1 = np.asarray(self._f1[self.n_pis + 1 :], dtype=np.int64)
        full = np.uint64(0xFFFFFFFFFFFFFFFF)
        # Vectorized level-order evaluation: nodes are already topologically
        # sorted, but python-loop per node is slow for big graphs; evaluate
        # in topological "waves" using the level structure.
        lv = self.levels()
        order = np.arange(self.n_pis + 1, self.n_nodes)
        if order.size:
            node_lv = lv[order]
            for level in range(1, node_lv.max(initial=0) + 1):
                ns = order[node_lv == level]
                if not ns.size:
                    continue
                i = ns - (self.n_pis + 1)
                a = vals[f0[i] >> 1] ^ np.where((f0[i] & 1).astype(bool), full, np.uint64(0))[:, None]
                b = vals[f1[i] >> 1] ^ np.where((f1[i] & 1).astype(bool), full, np.uint64(0))[:, None]
                vals[ns] = a & b
        out = np.zeros((len(self.pos), width), dtype=np.uint64)
        for k, p in enumerate(self.pos):
            v = vals[lit_node(p)]
            out[k] = (v ^ full) if lit_phase(p) else v
        return out

    def eval_ints(self, pi_bits: Sequence[int]) -> list[int]:
        """Single-pattern convenience evaluation (0/1 per PI)."""
        pv = np.array([[np.uint64(0xFFFFFFFFFFFFFFFF if b else 0)] for b in pi_bits],
                      dtype=np.uint64)
        out = self.simulate(pv)
        return [int(v[0] & np.uint64(1)) for v in out]

    # -- cone / truth-table utilities ---------------------------------------

    def cone_nodes(self, root: int, leaves: set[int]) -> list[int]:
        """Topo-ordered AND nodes of the cone of ``root`` stopping at leaves."""
        seen: set[int] = set()
        out: list[int] = []

        stack = [root]
        while stack:
            n = stack.pop()
            if n in seen or n in leaves or not self.is_and(n):
                continue
            a, b = self._f0[n] >> 1, self._f1[n] >> 1
            need = [m for m in (a, b) if m not in seen and m not in leaves and self.is_and(m)]
            if need:
                stack.append(n)
                stack.extend(need)
            else:
                seen.add(n)
                out.append(n)
        return out

    def truth_table(
        self,
        root_lit: int,
        support: Sequence[int],
        cone: Sequence[int] | None = None,
    ) -> int:
        """Exact truth table of ``root_lit`` over ``support`` node ids.

        Supports up to 16 inputs; returns an int with 2**k bits (pattern p
        is bit p, LSB-first, support[i] driving bit i of the pattern index).
        Assumes the cone of root_lit is fully covered by ``support``.
        ``cone`` may supply a precomputed ``cone_nodes(root, set(support))``
        topo order so callers that also need the cone walk it only once.

        The whole simulation runs on arbitrary-precision python ints (one
        int per node), which beats per-node numpy word arrays by a wide
        margin for the k <= 16 cones the transforms use.
        """
        k = len(support)
        if k > 16:
            raise ValueError("truth_table limited to 16 inputs")
        n_pat = 1 << k
        full = (1 << n_pat) - 1
        vals: dict[int, int] = {0: 0}
        for i, s in enumerate(support):
            vals[s] = _elementary_int(i, k)

        if cone is None:
            cone = self.cone_nodes(lit_node(root_lit), set(support))
        f0, f1 = self._f0, self._f1
        for n in cone:
            fa, fb = f0[n], f1[n]
            va = vals[fa >> 1] ^ (full if (fa & 1) else 0)
            vb = vals[fb >> 1] ^ (full if (fb & 1) else 0)
            vals[n] = va & vb
        root_node = lit_node(root_lit)
        if root_node not in vals:
            raise ValueError("support does not cover the cone")
        v = vals[root_node]
        if lit_phase(root_lit):
            v ^= full
        return v

    # -- rebuilding ---------------------------------------------------------

    def rebuild_mapped(
        self, build: Callable[["Aig", "Aig", dict[int, int]], None] | None = None
    ) -> "Aig":
        """Create a compacted, re-strashed copy containing only the nodes
        reachable from the POs.  ``build`` may customize the copy.
        """
        new = Aig(self.n_pis, name=self.name)
        mapping: dict[int, int] = {0: CONST0}
        for i in range(1, 1 + self.n_pis):
            mapping[i] = lit(i)
        if build is not None:
            build(self, new, mapping)
        else:
            self._copy_cones(new, mapping)
        return new

    def _copy_cones(self, new: "Aig", mapping: dict[int, int]) -> None:
        # Mark reachable nodes.
        reach = np.zeros(self.n_nodes, dtype=bool)
        stack = [lit_node(p) for p in self.pos]
        while stack:
            n = stack.pop()
            if reach[n] or not self.is_and(n):
                continue
            reach[n] = True
            stack.append(self._f0[n] >> 1)
            stack.append(self._f1[n] >> 1)
        for n in range(self.n_pis + 1, self.n_nodes):
            if not reach[n]:
                continue
            fa, fb = self._f0[n], self._f1[n]
            a = mapping[fa >> 1] ^ (fa & 1)
            b = mapping[fb >> 1] ^ (fb & 1)
            mapping[n] = new.g_and(a, b)
        for p in self.pos:
            new.add_po(mapping[lit_node(p)] ^ lit_phase(p))

    def clone(self) -> "Aig":
        return self.rebuild_mapped()

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-safe exact structure (fanin literal arrays + POs).

        Round-trips through `from_dict` node-for-node, so the
        `fingerprint` is preserved — the property the persistent
        characterization cache relies on to warm-start the recipe DAG
        from on-disk intermediate structures."""
        return dict(
            n_pis=self.n_pis,
            f0=[int(x) for x in self._f0],
            f1=[int(x) for x in self._f1],
            pos=[int(p) for p in self.pos],
            name=self.name,
        )

    @classmethod
    def from_dict(cls, d: dict) -> "Aig":
        """Rebuild the exact structure (same node order, same fingerprint)."""
        aig = cls(int(d["n_pis"]), name=d.get("name", "aig"))
        aig._f0 = [int(x) for x in d["f0"]]
        aig._f1 = [int(x) for x in d["f1"]]
        aig.pos = [int(p) for p in d["pos"]]
        for node in range(aig.n_pis + 1, aig.n_nodes):
            aig._strash[(aig._f0[node], aig._f1[node])] = lit(node)
        return aig

    def fingerprint(self) -> str:
        """Hex digest of the exact structure (PIs, fanin arrays, POs).

        Two AIGs share a fingerprint iff they are node-for-node identical,
        so — the transforms being deterministic functions of structure —
        equal fingerprints imply equal transform results and equal
        characterizations.  This is the key of the shared-prefix DAG
        (transforms.RecipeRunner) and of the on-disk characterization
        cache (transforms.CharacterizationCache).  ``name`` is excluded.
        """
        h = hashlib.sha256()
        h.update(np.asarray([self.n_pis], dtype=np.int64).tobytes())
        h.update(np.asarray(self._f0, dtype=np.int64).tobytes())
        h.update(np.asarray(self._f1, dtype=np.int64).tobytes())
        h.update(np.asarray(self.pos, dtype=np.int64).tobytes())
        return h.hexdigest()

    # -- gate netlist (NAND2 / NOR2 / NOT) -----------------------------------

    def to_gate_netlist(self) -> "GateNetlist":
        return GateNetlist.from_aig(self)

    def characterize(self) -> AigStats:
        """``ChaAIG`` of Algorithm I: stage counts + ops per stage."""
        net = self.to_gate_netlist()
        return AigStats(
            n_pis=self.n_pis,
            n_pos=len(self.pos),
            n_ands=self.n_ands,
            n_levels=net.n_levels,
            ops_per_level=net.ops_per_level(),
            nand_count=net.counts["nand"],
            nor_count=net.counts["nor"],
            inv_count=net.counts["inv"],
        )


@lru_cache(maxsize=None)
def _elementary_int(i: int, k: int) -> int:
    """Truth table of variable i over k vars as a 2**k-bit int (bit p set
    iff pattern p has var i = 1).  Built by block doubling: O(k) int ops."""
    half = 1 << i
    block = ((1 << half) - 1) << half  # 2**i zeros then 2**i ones
    width = half * 2
    n_pat = 1 << k
    while width < n_pat:
        block |= block << width
        width *= 2
    return block


def _elementary_tables(k: int) -> np.ndarray:
    """Elementary truth tables for k vars as uint64 word arrays."""
    n_pat = 1 << k
    words = max(1, n_pat // 64)
    out = np.zeros((k, words), dtype=np.uint64)
    masks64 = [
        np.uint64(0xAAAAAAAAAAAAAAAA),
        np.uint64(0xCCCCCCCCCCCCCCCC),
        np.uint64(0xF0F0F0F0F0F0F0F0),
        np.uint64(0xFF00FF00FF00FF00),
        np.uint64(0xFFFF0000FFFF0000),
        np.uint64(0xFFFFFFFF00000000),
    ]
    for i in range(k):
        if i < 6:
            out[i, :] = masks64[i]
        else:
            stride = 1 << (i - 6)
            w = np.arange(words)
            sel = (w // stride) % 2 == 1
            out[i, sel] = np.uint64(0xFFFFFFFFFFFFFFFF)
    if n_pat < 64:
        mask = np.uint64((1 << n_pat) - 1)
        out &= mask
    return out


# ---------------------------------------------------------------------------
# NAND2/NOR2/NOT netlist — the ops the rCiM macro executes natively
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Gate:
    kind: str  # "nand" | "nor" | "inv"
    a: int  # signal ids
    b: int  # == a for inv
    out: int
    level: int


class GateNetlist:
    """Polarity-aware mapping of an AIG onto {NAND2, NOR2, NOT}.

    Each AND node ``v = f(a,b)`` is realized by exactly one 2-input gate:

      * both fanin edges complemented  → NOR2(a,b)  computes v directly,
      * no fanin edge complemented     → NAND2(a,b) computes v̄,
      * mixed                          → NOT on the complemented side,
                                          then NAND2 computes v̄.

    A phase-demand pass then inserts the minimum number of NOT gates so that
    every consumer sees the phase it needs.  This mirrors how the paper's
    macro executes an AIG level: NAND2/NOR2/NOT are the only primitive ops
    (§III-B), and Table I reports exactly these three gate counts.
    """

    def __init__(self) -> None:
        self.gates: list[Gate] = []
        self.n_signals = 0
        self.pi_signals: list[int] = []
        self.po_signals: list[int] = []
        self.counts = {"nand": 0, "nor": 0, "inv": 0}
        self.n_levels = 0

    def _new_signal(self) -> int:
        self.n_signals += 1
        return self.n_signals - 1

    def _emit(self, kind: str, a: int, b: int, level: int) -> int:
        out = self._new_signal()
        self.gates.append(Gate(kind, a, b, out, level))
        self.counts[kind] += 1
        self.n_levels = max(self.n_levels, level + 1)
        return out

    @classmethod
    def from_aig(cls, aig: Aig) -> "GateNetlist":
        net = cls()
        # signal/level bookkeeping per (node, phase) demand
        sig: dict[tuple[int, int], int] = {}
        sig_level: dict[tuple[int, int], int] = {}

        # Constants: model as signals at level 0 (tied cells, no gate cost).
        c0 = net._new_signal()
        c1 = net._new_signal()
        sig[(0, 0)] = c0
        sig_level[(0, 0)] = 0
        sig[(0, 1)] = c1
        sig_level[(0, 1)] = 0
        for n in range(1, 1 + aig.n_pis):
            s = net._new_signal()
            net.pi_signals.append(s)
            sig[(n, 0)] = s
            sig_level[(n, 0)] = 0

        def get(node: int, phase: int) -> tuple[int, int]:
            """Return (signal, level) for node in the given phase, inserting
            a NOT if only the opposite phase is realized."""
            key = (node, phase)
            if key in sig:
                return sig[key], sig_level[key]
            okey = (node, phase ^ 1)
            if okey not in sig:
                raise KeyError(f"signal for node {node} not realized yet")
            src, lv = sig[okey], sig_level[okey]
            s = net._emit("inv", src, src, lv)
            sig[key] = s
            sig_level[key] = lv + 1
            return s, lv + 1

        for n in range(aig.n_pis + 1, aig.n_nodes):
            fa, fb = aig.fanins(n)
            na, pa = fa >> 1, fa & 1
            nb, pb = fb >> 1, fb & 1
            if pa and pb:
                # v = ā·b̄ = NOR(a,b)
                sa, la = get(na, 0)
                sb, lb = get(nb, 0)
                lv = max(la, lb)
                s = net._emit("nor", sa, sb, lv)
                sig[(n, 0)] = s
                sig_level[(n, 0)] = lv + 1
            elif not pa and not pb:
                # v̄ = NAND(a,b)
                sa, la = get(na, 0)
                sb, lb = get(nb, 0)
                lv = max(la, lb)
                s = net._emit("nand", sa, sb, lv)
                sig[(n, 1)] = s
                sig_level[(n, 1)] = lv + 1
            else:
                # mixed: v = ā·b  →  NOR(a, b̄); realize b̄ via phase demand.
                if pa:
                    s_pos, l_pos = get(nb, 0)
                    s_neg, l_neg = get(na, 1)
                else:
                    s_pos, l_pos = get(na, 0)
                    s_neg, l_neg = get(nb, 1)
                # v = s_neg AND s_pos = NAND + INV; cheaper: NOR(s_neg', s_pos')
                # needs two inverters.  Use NAND producing v̄.
                lv = max(l_pos, l_neg)
                s = net._emit("nand", s_neg, s_pos, lv)
                sig[(n, 1)] = s
                sig_level[(n, 1)] = lv + 1

        for p in aig.pos:
            s, _ = get(lit_node(p), lit_phase(p))
            net.po_signals.append(s)
        return net

    def ops_per_level(self) -> list[dict[str, int]]:
        out = [dict(nand=0, nor=0, inv=0) for _ in range(self.n_levels)]
        for g in self.gates:
            out[g.level][g.kind] += 1
        return out

    @property
    def n_gates(self) -> int:
        return len(self.gates)

    def simulate(self, pi_values: np.ndarray) -> np.ndarray:
        """Bit-parallel gate-netlist simulation (oracle for the CiM kernel)."""
        pi_values = np.asarray(pi_values, dtype=np.uint64)
        if pi_values.ndim == 1:
            pi_values = pi_values[:, None]
        width = pi_values.shape[1]
        full = np.uint64(0xFFFFFFFFFFFFFFFF)
        vals = np.zeros((self.n_signals, width), dtype=np.uint64)
        vals[1] = full  # const1 signal
        for i, s in enumerate(self.pi_signals):
            vals[s] = pi_values[i]
        for g in self.gates:
            if g.kind == "nand":
                vals[g.out] = (vals[g.a] & vals[g.b]) ^ full
            elif g.kind == "nor":
                vals[g.out] = (vals[g.a] | vals[g.b]) ^ full
            else:
                vals[g.out] = vals[g.a] ^ full
        return vals[np.asarray(self.po_signals, dtype=np.int64)]

    def level_schedule(self) -> list[list[Gate]]:
        sched: list[list[Gate]] = [[] for _ in range(self.n_levels)]
        for g in self.gates:
            sched[g.level].append(g)
        return sched


# ---------------------------------------------------------------------------
# Random AIG generation (for property tests)
# ---------------------------------------------------------------------------


def random_aig(
    n_pis: int, n_ands: int, n_pos: int, seed: int = 0
) -> Aig:
    rng = np.random.default_rng(seed)
    aig = Aig(n_pis)
    lits = [lit(i) for i in range(1, 1 + n_pis)]
    for _ in range(n_ands):
        a = int(rng.integers(0, len(lits)))
        b = int(rng.integers(0, len(lits)))
        pa = int(rng.integers(0, 2))
        pb = int(rng.integers(0, 2))
        l = aig.g_and(lits[a] ^ pa, lits[b] ^ pb)
        lits.append(l)
    for _ in range(n_pos):
        p = int(rng.integers(0, len(lits)))
        ph = int(rng.integers(0, 2))
        aig.add_po(lits[p] ^ ph)
    return aig.clone()
