"""Journaled, resumable (circuit x variant) sweeps — the survivability
layer under the ROADMAP's "planet-scale sweeps" item.

`evaluate_select_suite` answers a whole circuits x variants x topologies
x recipes sweep in one device call, but a week-long million-design run
is many such calls — and a `kill -9` (preemption, OOM, node loss)
anywhere in the sequence used to lose everything.  `SweepRunner`
partitions the circuit axis into fixed-size shards, evaluates each
through the fused device pipeline, and journals every completed shard's
`SelectionResult` rows through the atomic-rename `ckpt.CheckpointManager`
— so a killed sweep resumes from the journal, re-running only the shards
that never published, and the assembled result is **bit-identical** to
an uninterrupted run (pinned by tests/test_sweep_runner.py).

Why sharding preserves bit-identity:

  * only the *circuit* axis is sharded.  Every `SelectionResult` row —
    winner index, winner metrics, and the ``nominal_*`` fields (defined
    at that circuit's variant-0 winner) — depends on its own circuit's
    rows alone, so a row computed inside a 4-circuit shard equals the
    same row inside the full suite.  The variant axis is never split:
    splitting it would detach ``nominal_latency_ns`` from the global
    variant-0 winner cell.
  * every shard is padded to one fixed bucket shape
    ``(shard_size, R, L_suite, T, V)`` via `batch.pad_suite` (pad rows
    duplicate the shard's first circuit, so they stay finite and never
    trip the fused all-non-finite guard).  All shards therefore share a
    single jit trace, and level padding is masked out by the schedule
    kernels — `pad_suite`'s per-real-circuit bit-identity contract.

Journal format (one `CheckpointManager` step per shard, atomic
tmp-dir + rename publish):

  * ``arrays.npz`` — ``winner_idx`` (c, V) int32, ``nominal_latency_ns``
    (c, V) float64, ``nominal_fits`` (c,) bool, and one ``met_<key>``
    (c, V) float64 per `batch._METRIC_KEYS` entry, where ``c`` counts
    the shard's *real* circuits (padding is sliced off before
    journaling).
  * ``meta.json`` — the sweep ``config`` fingerprint (`sweep_config_key`),
    the shard's ``circuits`` (row order), ``n_variants``, and the
    device-``sharded`` flag.

Resume is keyed **per circuit**, not per shard boundary: a journal entry
contributes every circuit row whose name is still wanted, so a resumed
run may re-chunk the remaining circuits differently (or a later caller
may change ``shard_size``) and still assemble the identical result.  A
journal entry that fails `CheckpointManager.load_arrays`'s manifest
check (torn write surviving the rename — simulated by the
``journal.write`` fault point) is evicted and its shard re-run; an entry
whose ``config`` fingerprint differs is ignored (a different sweep
sharing the directory).

CLI (the kill-9 test harness)::

    python -m repro.core.sweep_runner --journal /tmp/j --out /tmp/sel.npz \
        --circuits adder,bar,max --scale tiny --recipes ";Rw;Ba,Rw" \
        --shard-size 2 --topos 5

prints ``shard <n> done: <names>`` after each published shard, so a
supervisor can SIGKILL it mid-sweep and re-invoke to resume.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Sequence

import numpy as np

from repro.ckpt.manager import CheckpointCorruptError, CheckpointManager
from repro.runtime import faults

from .aig import Aig
from .batch import (
    SelectionResult,
    SuiteTable,
    TopologyTable,
    _METRIC_KEYS,
    evaluate_select_suite,
    pad_suite,
)
from .explorer import _opt_and_feasible, _restrict_cha
from .sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    ModelTable,
    SramTopology,
)
from .transforms import (
    TRANSFORM_VERSION,
    CharacterizationError,
    PoolPolicy,
    characterize_suite,
)


def sweep_config_key(
    circuits: Mapping[str, Aig],
    recipes: "Sequence[tuple[str, ...]] | None",
    topos: Sequence[SramTopology],
    model: "EnergyModel | ModelTable | None",
    mode: str,
    discipline: str,
    max_latency_ns: "float | None",
) -> str:
    """Content fingerprint of everything that determines a sweep's
    numbers.  Journal entries carry it, and resume only consumes entries
    whose key matches — so a changed model table, recipe list, circuit
    definition, or transform implementation can never smuggle stale rows
    into a fresh sweep."""
    import hashlib

    h = hashlib.sha1()
    h.update(f"v{TRANSFORM_VERSION}:{mode}:{discipline}".encode())
    h.update(repr(max_latency_ns).encode())
    for name, rtl in circuits.items():
        h.update(f"{name}={rtl.fingerprint()};".encode())
    if recipes is None:
        h.update(b"recipes=all64")
    else:
        h.update(repr([tuple(r) for r in recipes]).encode())
    h.update(repr([(t.name, t.rows, t.cols, t.n_macros) for t in topos]).encode())
    if isinstance(model, ModelTable):
        h.update(model.content_key().encode())
    elif model is None:
        h.update(b"model=nominal")
    else:
        h.update(repr(dataclasses.astuple(model)).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class SweepOutcome:
    """What a journaled sweep hands back.

    ``selection`` is assembled per circuit in input order and is
    bit-identical to an uninterrupted `evaluate_select_suite` over the
    same (surviving) circuits.  ``failures`` carries quarantined
    characterization errors (`CharacterizationError`) for circuits that
    never reached the sweep; their rows are simply absent."""

    selection: SelectionResult
    circuits: tuple[str, ...]
    shards_run: int
    shards_resumed: int
    failures: dict[str, CharacterizationError]
    journal_dir: "str | None"
    config_key: str


def _slice_suite(suite: SuiteTable, lo: int, hi: int) -> SuiteTable:
    """A contiguous circuit-axis slice sharing the suite's level axis."""
    op_totals = suite.op_totals[lo:hi]
    return SuiteTable(
        circuits=suite.circuits[lo:hi],
        recipes=suite.recipes,
        ops=suite.ops[lo:hi],
        n_levels=suite.n_levels[lo:hi],
        op_totals=op_totals,
        gates=suite.gates[lo:hi],
    )


class SweepRunner:
    """Shard, evaluate, journal, resume — see the module docstring.

    ``journal_dir=None`` runs without a journal (pure sharded
    evaluation, still bit-identical); ``shard_size=None`` evaluates the
    whole suite as one shard."""

    def __init__(
        self,
        journal_dir: "str | os.PathLike | None" = None,
        shard_size: "int | None" = 4,
        on_shard=None,
    ):
        if shard_size is not None and shard_size < 1:
            raise ValueError("shard_size must be >= 1")
        self.journal_dir = os.fspath(journal_dir) if journal_dir else None
        self.shard_size = shard_size
        #: called as ``on_shard(index, circuit_names)`` after each shard
        #: publishes — the kill-9 harness's pacing signal.
        self.on_shard = on_shard

    def run(
        self,
        circuits: Mapping[str, Aig],
        sram_list: Sequence[SramTopology] = TOPOLOGY_LIBRARY,
        recipes: "Sequence[tuple[str, ...]] | None" = None,
        model: "EnergyModel | ModelTable | None" = None,
        mode: str = "physical",
        discipline: str = "list",
        max_latency_ns: "float | None" = None,
        cache=None,
        n_jobs: "int | None" = None,
        cha_backend: str = "auto",
        policy: "PoolPolicy | None" = None,
        shard: "bool | None" = None,
    ) -> SweepOutcome:
        if not circuits:
            raise ValueError("empty sweep")
        sram_list = list(sram_list)
        config = sweep_config_key(
            circuits, recipes, sram_list, model, mode, discipline,
            max_latency_ns,
        )

        # Front half, with per-circuit quarantine: a poisoned netlist is
        # reported in the outcome instead of sinking the sweep.
        failures: dict[str, CharacterizationError] = {}
        cha = characterize_suite(
            circuits, recipes, cache=cache, n_jobs=n_jobs,
            backend=cha_backend, policy=policy, failures=failures,
        )
        cha = {n: _restrict_cha(cha[n], recipes) for n in cha}
        names = [n for n in circuits if n in cha]
        if not names:
            raise CharacterizationError(
                "<suite>", f"every circuit failed characterization: "
                f"{sorted(failures)}"
            )

        feas_mask = np.zeros((len(names), len(sram_list)), dtype=bool)
        for i, name in enumerate(names):
            _, _, feasible = _opt_and_feasible(cha[name], sram_list)
            feas_mask[i] = [t in feasible for t in sram_list]

        suite = SuiteTable.from_cha(cha)
        topo_table = TopologyTable.from_topologies(sram_list)

        # Each shard publishes as ONE crc-framed append to the
        # directory's journal.wal (wal=True) through the shared async
        # writer.  The append layout is what keeps the journal inside
        # the <2% overhead gate in benchmarks/bench_faults.py: per-step
        # files pay a file-create + rename (hundreds of microseconds
        # each here) per shard, the log pays one buffered write into an
        # already-open fd.  Writers publish in call order;
        # `wait()` makes durability observable at the pacing callback
        # and on the crash path.  The success path does NOT drain: a
        # shard lost between return and its in-flight publish is simply
        # re-run on resume — and the resume scan below drains first, so
        # a same-process resume always sees every completed publish.
        manager = (
            CheckpointManager(self.journal_dir, keep_n=1 << 30,
                              async_save=True, wal=True,
                              defer_snapshot=True)
            if self.journal_dir is not None
            else None
        )

        # -- resume: adopt journaled rows (keyed per circuit) ---------------
        rows: dict[str, dict[str, np.ndarray]] = {}
        resumed_shards = 0
        dev_sharded: "bool | None" = None
        next_step = 0
        if manager is not None:
            try:
                manager.wait()  # adopt in-flight publishes of a prior run
            except Exception:
                pass  # a prior run's write failure: its shard is re-run
            for step in manager.steps():
                next_step = max(next_step, step + 1)
                try:
                    arrays, meta = manager.load_arrays(step)
                except CheckpointCorruptError:
                    manager.remove(step)  # torn entry: redo its shard
                    continue
                info = meta.get("meta", {})
                if info.get("config") != config:
                    continue  # some other sweep shares this journal dir
                entry_names = info.get("circuits", [])
                used = False
                for i, cname in enumerate(entry_names):
                    if cname not in cha or cname in rows:
                        continue
                    rows[cname] = {k: arrays[k][i] for k in arrays}
                    used = True
                if used:
                    resumed_shards += 1
                    dev_sharded = bool(info.get("sharded", False))

        # -- evaluate the missing circuits shard by shard -------------------
        todo = [n for n in names if n not in rows]
        size = self.shard_size or max(len(todo), 1)
        shards_run = 0
        try:
            for lo in range(0, len(todo), size):
                chunk = todo[lo : lo + size]
                faults.inject("sweep.shard", detail=",".join(chunk))
                idx = [names.index(n) for n in chunk]
                lo_i, hi_i = idx[0], idx[-1] + 1
                assert idx == list(range(lo_i, hi_i)), "todo is order-preserving"
                part = pad_suite(
                    _slice_suite(suite, lo_i, hi_i),
                    n_circuits=size,
                    pad_levels_to=suite.ops.shape[2],
                )
                feas = np.concatenate(
                    [
                        feas_mask[lo_i:hi_i],
                        np.broadcast_to(
                            feas_mask[lo_i],
                            (size - len(chunk), len(sram_list)),
                        ),
                    ]
                )
                _, sel = evaluate_select_suite(
                    part, topo_table, model, mode=mode, discipline=discipline,
                    feasible=feas, max_latency_ns=max_latency_ns, lazy=True,
                    shard=shard,
                )
                dev_sharded = sel.sharded
                payload = {
                    "winner_idx": sel.winner_idx[: len(chunk)],
                    "nominal_latency_ns": sel.nominal_latency_ns[: len(chunk)],
                    "nominal_fits": sel.nominal_fits[: len(chunk)],
                }
                for k in _METRIC_KEYS:
                    payload[f"met_{k}"] = sel.winner_metrics[k][: len(chunk)]
                if manager is not None:
                    manager.save(
                        next_step,
                        payload,
                        meta=dict(
                            config=config,
                            circuits=list(chunk),
                            n_variants=int(sel.winner_idx.shape[-1]),
                            sharded=bool(sel.sharded),
                        ),
                    )
                    next_step += 1
                for i, cname in enumerate(chunk):
                    rows[cname] = {k: payload[k][i] for k in payload}
                shards_run += 1
                if self.on_shard is not None:
                    if manager is not None:
                        # The pacing signal doubles as the durability
                        # signal (the kill-9 harness kills right after
                        # it), so drain the writer chain first.
                        manager.wait()
                    self.on_shard(shards_run - 1, tuple(chunk))
        except BaseException:
            # Drain the writer on the crash path so the journal is
            # consistent (every queued entry fully published) the moment
            # run() raises; a writer failure must not mask the crash.
            if manager is not None:
                try:
                    manager.wait()
                except Exception:
                    pass
            raise

        return SweepOutcome(
            selection=_assemble(names, rows, bool(dev_sharded)),
            circuits=tuple(names),
            shards_run=shards_run,
            shards_resumed=resumed_shards,
            failures=failures,
            journal_dir=self.journal_dir,
            config_key=config,
        )


def _assemble(
    names: Sequence[str],
    rows: Mapping[str, Mapping[str, np.ndarray]],
    dev_sharded: bool,
) -> SelectionResult:
    """Stack per-circuit rows (input order) into one `SelectionResult`.

    ``payload_bytes`` is recomputed with `batch._fetch_selection`'s
    formula (winner indices + the implicit (C, V) has-finite flags +
    nominal fields + winner metrics), so the assembled result equals a
    direct uninterrupted run field for field."""
    winner_idx = np.stack([rows[n]["winner_idx"] for n in names])
    nominal_latency = np.stack([rows[n]["nominal_latency_ns"] for n in names])
    nominal_fits = np.stack([rows[n]["nominal_fits"] for n in names])
    mets = {
        k: np.stack([rows[n][f"met_{k}"] for n in names])
        for k in _METRIC_KEYS
    }
    payload = (
        winner_idx.nbytes
        + winner_idx.size * np.dtype(bool).itemsize  # has_finite (C, V)
        + nominal_latency.nbytes
        + nominal_fits.nbytes
        + sum(v.nbytes for v in mets.values())
    )
    return SelectionResult(
        winner_idx=winner_idx,
        winner_metrics=mets,
        nominal_latency_ns=nominal_latency,
        nominal_fits=nominal_fits,
        payload_bytes=payload,
        sharded=dev_sharded,
    )


def run_sweep(
    circuits: Mapping[str, Aig],
    journal_dir: "str | os.PathLike | None" = None,
    shard_size: "int | None" = 4,
    **kwargs,
) -> SweepOutcome:
    """Convenience wrapper: ``SweepRunner(journal_dir, shard_size).run(...)``."""
    return SweepRunner(journal_dir, shard_size).run(circuits, **kwargs)


def _parse_recipes(spec: "str | None") -> "list[tuple[str, ...]] | None":
    """``";Rw;Ba,Rw"`` -> ``[(), ("Rw",), ("Ba", "Rw")]`` (None = all 64)."""
    if spec is None:
        return None
    out = []
    for part in spec.split(";"):
        part = part.strip()
        out.append(tuple(t for t in part.split(",") if t))
    return out


def main(argv: "Sequence[str] | None" = None) -> int:
    import argparse

    from .circuits import benchmark_suite

    ap = argparse.ArgumentParser(
        description="journaled resumable sweep (kill -9 safe)"
    )
    ap.add_argument("--journal", required=True, help="journal directory")
    ap.add_argument("--out", required=True, help="output .npz path")
    ap.add_argument("--circuits", default="adder,bar,max,sqrt",
                    help="comma-separated generator names")
    ap.add_argument("--scale", default="tiny")
    ap.add_argument("--recipes", default=";Rw;Ba,Rw;Rf",
                    help="';'-separated recipes, ','-separated transforms")
    ap.add_argument("--shard-size", type=int, default=2)
    ap.add_argument("--topos", type=int, default=5,
                    help="use the first N library topologies")
    ap.add_argument("--mode", default="physical")
    ap.add_argument("--discipline", default="list")
    ap.add_argument("--max-latency-ns", type=float, default=None)
    ap.add_argument("--cache", default=None)
    args = ap.parse_args(argv)

    circuits = benchmark_suite(args.scale, only=args.circuits.split(","))

    def on_shard(i, names):
        print(f"shard {i} done: {','.join(names)}", flush=True)

    runner = SweepRunner(args.journal, args.shard_size, on_shard=on_shard)
    outcome = runner.run(
        circuits,
        sram_list=TOPOLOGY_LIBRARY[: args.topos],
        recipes=_parse_recipes(args.recipes),
        mode=args.mode,
        discipline=args.discipline,
        max_latency_ns=args.max_latency_ns,
        cache=args.cache,
        n_jobs=1,
    )
    sel = outcome.selection
    np.savez(
        args.out,
        circuits=np.array(outcome.circuits),
        winner_idx=sel.winner_idx,
        nominal_latency_ns=sel.nominal_latency_ns,
        nominal_fits=sel.nominal_fits,
        payload_bytes=np.int64(sel.payload_bytes),
        shards_run=np.int64(outcome.shards_run),
        shards_resumed=np.int64(outcome.shards_resumed),
        **{f"met_{k}": v for k, v in sel.winner_metrics.items()},
    )
    print(
        f"sweep done: {len(outcome.circuits)} circuits, "
        f"{outcome.shards_run} shards run, "
        f"{outcome.shards_resumed} resumed, "
        f"{len(outcome.failures)} quarantined",
        flush=True,
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
