"""Logical-axis sharding rules with divisibility fallback.

Parameters and activations are annotated with *logical* axis names; the
rule table maps each logical axis to an ordered list of preferred mesh
axes.  A mesh axis is used only if it (a) exists in the mesh, (b) is not
already taken by an earlier tensor dim, and (c) divides the dim size —
several assigned configs have head counts / vocab sizes that do NOT divide
the 16-way model axis (minicpm 36 heads, qwen 20 heads, whisper 51865
vocab, ...), so static PartitionSpecs would fail to lower; the fallback
keeps those dims replicated (or lets a later-preference axis take over).

This mirrors MaxText's logical-axis machinery in miniature.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Default rule table.  Keys are logical axis names; values are preference-
# ordered mesh-axis groups (a tuple entry means "shard jointly over these").
def default_rules(pc) -> dict[str, list]:
    data = tuple(pc.all_data_axes)
    model = pc.model_axis
    fsdp = [data] if pc.fsdp else []
    return {
        # params
        "vocab": [model, data],          # embedding rows: TP first
        "embed": fsdp,                   # d_model dim of params: FSDP
        "heads": [model],                # attention q heads
        "kv_heads": [model],
        "head_dim": [],
        "qkv": [model],                  # fused head*dim output dim
        "mlp": [model, data],            # ffn hidden
        "experts": [model],              # MoE expert dim (EP)
        "expert_mlp": [],
        "ssm_inner": [model, data],
        "ssm_state": [],
        "ssm_heads": [model],
        "lru": [model, data],
        "conv": [],
        "layers": [],                    # stacked-scan leading dim
        # activations
        "batch": [data],
        "seq": [],
        "act_seq_shard": [model],        # sequence parallelism points
        "act_embed": [],
        "act_heads": [model],
        "act_mlp": [model],
        "act_experts": [model],
        "kv_seq": [model],               # decode KV sharded over model
        "pod_batch": [data],
    }


def rules_for_model(cfg, pc, mesh: Mesh) -> dict[str, list]:
    """Model-aware rule table: keeps weight and activation sharding
    *consistent* for attention (if heads don't divide the model axis we
    replicate both the fused-QKV weight dim and the activation head dim,
    instead of paying a reshard every layer), and enables decode-KV
    sequence sharding exactly when head sharding is impossible."""
    rules = default_rules(pc)
    model = pc.model_axis
    msize = mesh.shape.get(model, 1)
    hd = cfg.resolved_head_dim

    heads_ok = cfg.n_heads % msize == 0
    kv_ok = cfg.n_kv_heads % msize == 0
    if not heads_ok:
        # attention runs data-parallel; don't TP the qkv/o weights either
        rules["qkv"] = [tuple(pc.all_data_axes)] if pc.fsdp else []
        rules["act_heads"] = []
    if not kv_ok:
        rules["kv_heads"] = []
        # decode KV memory instead shards the sequence over the model axis
        rules["kv_seq"] = [model] if pc.seq_shard_kv else []
        # ... and q heads must NOT shard over model either: a head-sharded q
        # against seq-sharded KV forces a per-layer KV all-gather (measured
        # 48.7 GB/step on internvl2-2b decode_32k -> 0.7 GB with this rule;
        # §Perf).  Flash-decoding emerges instead: per-shard partial softmax
        # + psum.
        rules["act_heads"] = []
        rules["qkv"] = [tuple(pc.all_data_axes)] if pc.fsdp else []
    else:
        rules["kv_seq"] = []
    return rules


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for(
    mesh: Mesh,
    shape: Sequence[int],
    logical: Sequence[str | None],
    rules: Mapping[str, list],
) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names."""
    assert len(shape) == len(logical), (shape, logical)
    used: set[str] = set()
    out: list = []
    for dim, name in zip(shape, logical):
        chosen = None
        if name:
            for cand in rules.get(name, []):
                cand_axes = (cand,) if isinstance(cand, str) else tuple(cand)
                if not all(a in mesh.shape for a in cand_axes):
                    continue
                if any(a in used for a in cand_axes):
                    continue
                size = _axis_size(mesh, cand_axes)
                if size <= 1 or dim % size != 0:
                    continue
                chosen = cand_axes if len(cand_axes) > 1 else cand_axes[0]
                used.update(cand_axes)
                break
        out.append(chosen)
    # drop trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for(mesh, shape, logical, rules) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, shape, logical, rules))


def constrain(x: jax.Array, mesh: Mesh, logical: Sequence[str | None], rules) -> jax.Array:
    """with_sharding_constraint via logical names (no-op outside jit/mesh)."""
    spec = spec_for(mesh, x.shape, logical, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_specs(mesh: Mesh, params_logical, shapes, rules):
    """Map a pytree of logical-axis tuples + shapes -> pytree of specs."""
    return jax.tree.map(
        lambda lg, sh: spec_for(mesh, sh, lg, rules),
        params_logical,
        shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
