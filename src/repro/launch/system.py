"""System-level comparison: workload-lowered rCiM vs a conventional
accelerator roofline.

Two halves:

  * rCiM side — `repro.core.workloads` lowers a config-zoo model to
    primitive-tile counts per token and prices them through the batched
    suite kernels (`evaluate_select_suite`) across the topology library.
  * baseline side — an `AcceleratorModel` (roofline constants from
    `launch.roofline` plus pJ/op energy coefficients) priced on the
    model's per-token flops / HBM bytes / link bytes, either analytic
    (`token_cost`) or measured from a dry-run record
    (`token_cost_from_dryrun`).

The roofline evaluation is a *jitted sweep*: flops/bytes AND the
bandwidth parameters (HBM BW, link BW) are traced operands, so an
N-point bandwidth sweep is one compile per sweep *shape* and zero
recompiles on value changes — the PR-3 follow-up ("make roofline
parameters a traced axis through the dry-run layer").  Trace discipline
is pinned by ``TRACE_COUNTS["roofline_sweep"]`` (tests/test_workloads.py
and benchmarks/bench_system.py assert compiles == 1).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.analysis import registry as _registry

# The shared trace counter (see repro.analysis.registry); this module's
# sweep kernel bumps ``TRACE_COUNTS["roofline_sweep"]``.
# repro: kernel-module
TRACE_COUNTS = _registry.TRACE_COUNTS
from repro.core.workloads import (LoweredModel, SystemResult,
                                  conservation_report, evaluate_lowered,
                                  lower_config)
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.models.config import SHAPES, ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class AcceleratorModel:
    """Conventional-accelerator cost model (TPU-class defaults).

    Energy coefficients are architectural constants in the style of the
    Eva-CiM system baseline: ~0.3 pJ per bf16 flop (MXU), ~31 pJ per
    HBM byte, ~10 pJ per inter-chip link byte.
    """

    name: str = "tpu-like"
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    pj_per_flop: float = 0.3
    pj_per_hbm_byte: float = 31.2
    pj_per_link_byte: float = 10.0
    weight_dtype_bytes: int = 2


DEFAULT_ACCEL = AcceleratorModel()


# ---------------------------------------------------------------------------
# Per-token cost of a (config, shape) cell
# ---------------------------------------------------------------------------


def token_cost(cfg: ModelConfig, shape: ShapeConfig,
               accel: AcceleratorModel = DEFAULT_ACCEL) -> dict:
    """Analytic per-token flops / HBM bytes / link bytes.

    flops: 2*N_active fwd (6*N_active train).  HBM: weight streaming
    amortized over the batch (3x in train for fwd+bwd re-reads) plus the
    KV read at decode; activation traffic is the ~12*d*L/token residual-
    stream estimate.  Link bytes default to 0 (single chip) — use
    `token_cost_from_dryrun` for measured multi-chip numbers.
    """
    n_active = cfg.n_active_params()
    w_bytes = n_active * accel.weight_dtype_bytes
    flops = (6.0 if shape.is_train else 2.0) * n_active
    if shape.kind == "decode":
        hbm = w_bytes / shape.global_batch
        ctx = shape.seq_len
        kv_layers = sum(1 for k in cfg.layer_kinds if k in ("attn", "local"))
        hd = cfg.resolved_head_dim
        # local layers re-read only the window
        kv = 0
        for k in cfg.layer_kinds:
            if k in ("attn", "local"):
                c = min(ctx, cfg.window) if (k == "local" and cfg.window) else ctx
                kv += 2 * cfg.n_kv_heads * hd * c * 2  # K+V, bf16
        hbm += kv
        del kv_layers
    else:
        reread = 3.0 if shape.is_train else 1.0
        hbm = reread * w_bytes / (shape.global_batch * shape.seq_len)
        hbm += 12 * cfg.d_model * cfg.n_layers * 2  # activation traffic
    return dict(flops=float(flops), hbm_bytes=float(hbm), link_bytes=0.0)


def token_cost_from_dryrun(record: dict, shape: ShapeConfig) -> dict:
    """Per-token cost from a dry-run record (`launch.dryrun`): the
    HLO-measured flops/HBM/link bytes of one step, divided by the tokens
    that step processes — the hook that threads *measured* costs into
    the traced sweep below."""
    rl = record["roofline"]
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    n_chips = max(1, int(record.get("n_chips", 1)))
    return dict(
        flops=float(rl["flops"]) * n_chips / tokens,
        hbm_bytes=float(rl["hbm_bytes"]) * n_chips / tokens,
        link_bytes=float(rl["link_bytes"]) * n_chips / tokens,
    )


# ---------------------------------------------------------------------------
# Traced roofline sweep (the PR-3 follow-up)
# ---------------------------------------------------------------------------

_SWEEP_FN = None


def _make_sweep_kernel():
    """A fresh jit wrapper for the roofline sweep (fresh = empty trace
    cache, as the analyzer's counter check requires); production goes
    through `_sweep_kernel`'s process-wide cache."""
    import jax
    import jax.numpy as jnp

    def fn(flops, hbm_bytes, link_bytes, peak_flops, hbm_bw, link_bw):
        TRACE_COUNTS["roofline_sweep"] += 1
        compute = flops / peak_flops
        memory = hbm_bytes / hbm_bw
        coll = jnp.where(link_bw > 0,
                         link_bytes / jnp.maximum(link_bw, 1.0), 0.0)
        compute, memory, coll = jnp.broadcast_arrays(compute, memory, coll)
        token_s = jnp.maximum(jnp.maximum(compute, memory), coll)
        bottleneck = jnp.argmax(
            jnp.stack([compute, memory, coll], axis=-1), axis=-1
        )
        return dict(compute_s=compute, memory_s=memory,
                    collective_s=coll, token_s=token_s,
                    bottleneck=bottleneck)

    return jax.jit(fn)


def _sweep_kernel():
    global _SWEEP_FN
    if _SWEEP_FN is None:
        _SWEEP_FN = _make_sweep_kernel()
    return _SWEEP_FN


def _ex_roofline_sweep():
    from repro.core import batch

    batch._load_jax()
    bw = np.array([1.0e11, 2.0e11])
    return _registry.KernelExample(
        fn=_make_sweep_kernel(),
        args=(
            np.float64(1.0e12), np.float64(1.0e9), np.float64(0.0),
            np.float64(1.0e15), bw, bw,
        ),
    )


_registry.register_kernel("roofline_sweep", __name__, _ex_roofline_sweep)


BOTTLENECKS = ("compute", "memory", "collective")


def sweep_roofline(cost: dict,
                   hbm_bw: "float | Sequence[float]" = HBM_BW,
                   link_bw: "float | Sequence[float]" = LINK_BW,
                   peak_flops: float = PEAK_FLOPS) -> dict:
    """Roofline terms with every parameter a traced operand.

    ``hbm_bw`` / ``link_bw`` may be scalars or 1-D sweeps (broadcast
    against each other); the returned arrays have the broadcast shape.
    One jit trace per sweep shape; re-calling with different *values*
    (any cost or bandwidth) reuses the compiled kernel.
    """
    from repro.core import batch

    batch._load_jax()
    hbm = np.atleast_1d(np.asarray(hbm_bw, np.float64))  # repro: host-boundary
    link = np.atleast_1d(np.asarray(link_bw, np.float64))  # repro: host-boundary
    hbm, link = np.broadcast_arrays(hbm, link)
    with batch.enable_x64():
        out = _sweep_kernel()(
            np.float64(cost["flops"]), np.float64(cost["hbm_bytes"]),
            np.float64(cost["link_bytes"]), np.float64(peak_flops),
            hbm, link,
        )
        # roofline outputs are sweep-shaped (small): materialize for callers
        out = {k: np.asarray(v) for k, v in out.items()}  # repro: host-boundary
    out["hbm_bw"] = hbm.copy()
    out["link_bw"] = link.copy()
    return out


def baseline_cost(cost: dict, accel: AcceleratorModel = DEFAULT_ACCEL) -> dict:
    """Baseline per-token latency (roofline) + energy (pJ coefficients)."""
    sweep = sweep_roofline(cost, hbm_bw=accel.hbm_bw, link_bw=accel.link_bw,
                           peak_flops=accel.peak_flops)
    energy_j = (cost["flops"] * accel.pj_per_flop
                + cost["hbm_bytes"] * accel.pj_per_hbm_byte
                + cost["link_bytes"] * accel.pj_per_link_byte) * 1e-12
    return dict(
        accel=accel.name,
        flops_per_token=cost["flops"],
        hbm_bytes_per_token=cost["hbm_bytes"],
        link_bytes_per_token=cost["link_bytes"],
        latency_per_token_s=float(sweep["token_s"][0]),
        energy_per_token_j=float(energy_j),
        bottleneck=BOTTLENECKS[int(sweep["bottleneck"][0])],
        compute_s=float(sweep["compute_s"][0]),
        memory_s=float(sweep["memory_s"][0]),
        collective_s=float(sweep["collective_s"][0]),
    )


# ---------------------------------------------------------------------------
# End-to-end comparison
# ---------------------------------------------------------------------------


def compare_system(arch: str, shape_name: str = "decode_32k",
                   topologies=None, model=None, mode: str = "physical",
                   discipline: str = "list", n_units: int = 8192,
                   accel: AcceleratorModel = DEFAULT_ACCEL,
                   hbm_bw_sweep: "Sequence[float] | None" = None,
                   link_bw_sweep: "Sequence[float] | None" = None,
                   dryrun_record: "dict | None" = None) -> dict:
    """rCiM vs conventional roofline for one (arch, shape) cell.

    Returns a JSON-safe record: the lowering (+ conservation check), the
    rCiM per-layer/per-token cost, the baseline per-token cost, their
    ratios, and (optionally) a bandwidth sweep of the baseline with
    traced BW axes."""
    from repro.configs import get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    lowered: LoweredModel = lower_config(cfg, shape)
    cons = conservation_report(lowered)
    rcim: SystemResult = evaluate_lowered(
        lowered, topologies=topologies, model=model, mode=mode,
        discipline=discipline, n_units=n_units,
    )
    cost = (token_cost_from_dryrun(dryrun_record, shape)
            if dryrun_record is not None else token_cost(cfg, shape, accel))
    base = baseline_cost(cost, accel)

    rec = dict(
        arch=arch, shape=shape_name, mode=mode, discipline=discipline,
        macs_per_token=int(lowered.macs_per_token()),
        tiles_per_token={k: int(v) for k, v in lowered.tiles_per_token().items()},
        ops_per_token={k: int(v) for k, v in cons["ops_per_token"].items()},
        conserved=bool(cons["ok"]),
        rcim=rcim.as_dict(),
        baseline=base,
        energy_ratio_rcim_over_accel=(
            rcim.energy_per_token_j / base["energy_per_token_j"]
            if base["energy_per_token_j"] else float("inf")),
        latency_ratio_rcim_over_accel=(
            rcim.latency_per_token_s / base["latency_per_token_s"]
            if base["latency_per_token_s"] else float("inf")),
    )
    if hbm_bw_sweep is not None or link_bw_sweep is not None:
        sweep = sweep_roofline(
            cost,
            hbm_bw=hbm_bw_sweep if hbm_bw_sweep is not None else accel.hbm_bw,
            link_bw=link_bw_sweep if link_bw_sweep is not None else accel.link_bw,
            peak_flops=accel.peak_flops,
        )
        rec["bw_sweep"] = {k: v.tolist() for k, v in sweep.items()}
    return rec


def main() -> None:  # pragma: no cover - CLI convenience
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--shape", default="decode_32k", choices=sorted(SHAPES))
    ap.add_argument("--n-units", type=int, default=8192)
    ap.add_argument("--hbm-sweep", type=float, nargs="*", default=None,
                    help="HBM BW points (B/s) for the traced sweep")
    args = ap.parse_args()
    rec = compare_system(args.arch, args.shape, n_units=args.n_units,
                         hbm_bw_sweep=args.hbm_sweep)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":  # pragma: no cover
    main()
