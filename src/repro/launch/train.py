"""Training launcher (end-to-end driver).

Runs a real training loop on whatever devices are visible — the production
path is the same code under the production mesh; on this CPU container use
``--preset smoke`` (tiny) or ``--preset 100m`` (about 100M params).

Fault tolerance exercised here:
  * atomic keep-N checkpoints + auto-resume (``--resume``),
  * SIGTERM/SIGINT -> final checkpoint before exit (preemption handling),
  * deterministic data sharding (restart-safe),
  * step-time straggler monitor (EMA; logs hosts exceeding the threshold —
    on a multi-host cluster this feeds the re-balance policy).
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_model_config(arch: str, preset: str):
    from repro.configs import get_config, smoke_config

    if preset == "full":
        return get_config(arch)
    if preset == "smoke":
        return smoke_config(arch)
    if preset == "100m":
        base = get_config(arch)
        return dataclasses.replace(
            base,
            n_layers=max(4, min(8, base.n_layers)),
            d_model=768,
            n_heads=12,
            n_kv_heads=12 if base.n_kv_heads == base.n_heads else 4,
            head_dim=64,
            d_ff=2048,
            vocab_size=32_000,
            vocab_pad_multiple=128,
            n_experts=base.n_experts and 16,
            moe_d_ff=base.moe_d_ff and 512,
            d_inner=1536 if base.family == "ssm" else 0,
            lru_width=768 if base.lru_width else 0,
            enc_seq=256 if base.enc_seq else 0,
        )
    raise ValueError(preset)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--preset", choices=["smoke", "100m", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", choices=["wsd", "cosine", "const"], default="wsd")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--compression", choices=["none", "bf16", "int8_ef"], default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.ckpt.manager import CheckpointManager
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.launch.mesh import make_host_mesh
    from repro.models.config import ParallelConfig
    from repro.models.model import Model
    from repro.optim.adamw import (AdamWConfig, adamw_init, constant_schedule,
                                   cosine_schedule, wsd_schedule)
    from repro.parallel import sharding as sh
    from repro.train.steps import make_train_step

    cfg = build_model_config(args.arch, args.preset)
    mesh = make_host_mesh(model=args.model_parallel)
    pc = ParallelConfig(data_axes=("data",), remat="block")
    rules = sh.rules_for_model(cfg, pc, mesh)
    model = Model(cfg, pc, mesh=mesh, rules=rules, q_chunk=256, kv_chunk=256)

    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} preset={args.preset} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    sched = dict(
        wsd=wsd_schedule(args.lr, max(1, args.steps // 10), args.steps * 8 // 10,
                         max(1, args.steps // 10)),
        cosine=cosine_schedule(args.lr, max(1, args.steps // 10), args.steps),
        const=constant_schedule(args.lr),
    )[args.schedule]
    opt_cfg = AdamWConfig(compression=args.compression)
    opt_state = adamw_init(params, opt_cfg)

    ckpt = CheckpointManager(args.ckpt_dir, keep_n=3) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.restore(dict(p=params, o=opt_state)), None
        params, opt_state = params[0]["p"], params[0]["o"]
        start_step = int(np.asarray(opt_state["step"]))
        print(f"resumed from step {start_step}")

    data = Pipeline(
        DataConfig(batch_per_host=args.batch, seq_len=args.seq,
                   vocab_size=cfg.vocab_size, seed=args.seed),
        host=jax.process_index(), n_hosts=jax.process_count(),
    )

    step_fn = jax.jit(
        make_train_step(model, sched, opt_cfg, grad_accum=args.grad_accum),
        donate_argnums=(0, 1),
    )

    stop = {"now": False}
    def _sig(_s, _f):
        stop["now"] = True
    signal.signal(signal.SIGTERM, _sig)
    signal.signal(signal.SIGINT, _sig)

    ema = None
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(step).items()}
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.n_patches:
            batch["patches"] = jnp.zeros((args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if dt > 3.0 * ema and step > start_step + 2:
            print(f"[straggler-monitor] step {step} took {dt:.2f}s (ema {ema:.2f}s)")
        if step % max(1, args.steps // 20) == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt:.2f}s")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, dict(p=params, o=opt_state))
        if stop["now"]:
            print("signal received — checkpointing and exiting")
            if ckpt:
                ckpt.save(step + 1, dict(p=params, o=opt_state))
                ckpt.wait()
            return
    if ckpt:
        ckpt.save(args.steps, dict(p=params, o=opt_state))
        ckpt.wait()
    print("training complete")


if __name__ == "__main__":
    main()
