import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

For every cell this driver:
    1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
    2. builds ShapeDtypeStruct stand-ins (no allocation) for params,
       optimizer state, inputs, and KV caches,
    3. jit(step, in_shardings=...).lower(...).compile(),
    4. records memory_analysis / cost_analysis / collective schedule,
    5. derives the three roofline terms (launch/roofline.py) and appends a
       JSON record to runs/dryrun/ (idempotent: cells already recorded are
       skipped, so a killed run resumes where it left off).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 2-pod pass
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, overrides: dict | None = None,
             tag: str = "", mesh_shape: tuple | None = None) -> dict:
    import jax

    from repro.configs import SKIP_CELLS, get_config
    from repro.launch.hloparse import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import CollectiveStats, model_flops, roofline_terms
    from repro.launch.specs import CellSpec
    from repro.models.config import SHAPES

    mesh_name = "multi" if multi_pod else "single"
    key = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    if (arch, shape_name) in SKIP_CELLS:
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                   skipped=SKIP_CELLS[(arch, shape_name)])
        _write(path, rec)
        return rec

    t0 = time.time()
    if mesh_shape is not None:
        axes = ("pod", "data", "model") if len(mesh_shape) == 3 else ("data", "model")
        mesh = jax.make_mesh(tuple(mesh_shape), axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = CellSpec(arch, shape_name, mesh, **(overrides or {}))
    fn, args, shards, donate = cell.step_fn_and_args()

    with mesh:
        lowered = jax.jit(fn, in_shardings=shards, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # newer jax returns a single dict; older returned [dict] per program
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()

    # Trip-count-corrected HLO costs (XLA's cost_analysis counts while
    # bodies once — see launch/hloparse.py).
    hc = analyze(hlo, default_group=mesh.shape.get("model", 16))
    coll = CollectiveStats(total_link_bytes=hc.link_bytes,
                           by_kind=hc.coll_by_kind, n_ops=hc.n_collectives)
    mf = model_flops(cell.cfg, SHAPES[shape_name])
    rl = roofline_terms(
        {"flops": hc.flops, "bytes accessed": hc.hbm_bytes}, coll, n_chips, mf
    )

    mem_rec = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
        mem_rec[field] = getattr(mem, field, None)
    hbm_per_device = (
        (mem_rec.get("argument_size_in_bytes") or 0)
        + (mem_rec.get("temp_size_in_bytes") or 0)
        - (mem_rec.get("alias_size_in_bytes") or 0)  # donated buffers alias args
    )

    rec = dict(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        tag=tag,
        n_chips=int(n_chips),
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem_rec,
        hbm_per_device_gb=round(hbm_per_device / 2**30, 3),
        cost=dict(flops=float(cost.get("flops", 0.0)),
                  bytes_accessed=float(cost.get("bytes accessed", 0.0)),
                  note="raw XLA cost_analysis (while bodies counted once)"),
        roofline=rl.as_dict(),
        n_collectives=coll.n_ops,
        trip_counts=hc.trip_counts,
    )
    _write(path, rec)
    return rec


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=1)
    os.rename(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.models.config import SHAPES

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                label = f"{arch:22s} {shape:12s} {'multi' if multi else 'single'}"
                try:
                    rec = run_cell(arch, shape, multi, args.out, force=args.force)
                    if "skipped" in rec:
                        n_skip += 1
                        print(f"SKIP {label}: {rec['skipped']}", flush=True)
                    else:
                        n_ok += 1
                        r = rec["roofline"]
                        print(
                            f"OK   {label}: hbm/dev={rec['hbm_per_device_gb']:.2f}GB "
                            f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                            f"coll={r['collective_s']:.4f}s -> {r['bottleneck']} "
                            f"(compile {rec['compile_s']:.0f}s)",
                            flush=True,
                        )
                except Exception as e:  # noqa: BLE001 — a failed cell is a bug to report
                    n_fail += 1
                    print(f"FAIL {label}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
