"""Serving launcher: batched generation with the slot-based engine."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.launch.train import build_model_config
    from repro.models.config import ParallelConfig
    from repro.models.model import Model
    from repro.serve.engine import Request, ServeEngine

    cfg = build_model_config(args.arch, args.preset)
    model = Model(cfg, ParallelConfig(), q_chunk=64, kv_chunk=64)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)

    engine = ServeEngine(model, params, batch=args.batch,
                         max_seq=args.prompt_len + args.max_new,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=(args.prompt_len,)).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.serve(reqs, prompt_pad=args.prompt_len)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
