"""Serving launchers.

Two subcommands share this entry point:

  * ``llm`` — batched generation with the slot-based `serve.engine`
    (the original launcher; also the default when no subcommand is
    given, so existing invocations keep working unchanged);
  * ``explore`` — the rCiM exploration service
    (`serve.explore_service.ExplorationService`): spin up a warm
    persistent query engine, stream design queries at it, and print
    per-request winners + latency percentiles.

Examples::

    python -m repro.launch.serve explore --scale tiny --requests 16
    python -m repro.launch.serve explore --circuits adder,max \\
        --max-memory-kb 96 --max-latency-ns 400 --sweep mc --variants 8
    python -m repro.launch.serve llm --preset smoke --requests 8
"""

from __future__ import annotations

import argparse
import sys
import time


def _main_llm(args: argparse.Namespace) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.train import build_model_config
    from repro.models.config import ParallelConfig
    from repro.models.model import Model
    from repro.serve.engine import Request, ServeEngine

    cfg = build_model_config(args.arch, args.preset)
    model = Model(cfg, ParallelConfig(), q_chunk=64, kv_chunk=64)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)

    engine = ServeEngine(model, params, batch=args.batch,
                         max_seq=args.prompt_len + args.max_new,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=(args.prompt_len,)).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.serve(reqs, prompt_pad=args.prompt_len)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:8]}...")


def _main_explore(args: argparse.Namespace) -> None:
    import numpy as np

    from repro.core.circuits import benchmark_suite
    from repro.core.sram import TOPOLOGY_LIBRARY, ModelTable
    from repro.core.transforms import enumerate_recipes
    from repro.serve.explore_service import (
        ExplorationService,
        ExploreRequest,
    )

    only = args.circuits.split(",") if args.circuits else None
    circuits = list(benchmark_suite(scale=args.scale, only=only).values())
    recipes = enumerate_recipes()[: args.recipes]
    sweep = None
    if args.sweep == "corners":
        sweep = ModelTable.corners()
    elif args.sweep == "mc":
        sweep = ModelTable.monte_carlo(n=args.variants, seed=0)

    svc = ExplorationService(
        sram_list=TOPOLOGY_LIBRARY,
        recipes=recipes,
        cache=args.cache,
        max_batch=args.max_batch,
    )
    try:
        t0 = time.perf_counter()
        reqs = [
            ExploreRequest(
                circuit=circuits[i % len(circuits)],
                max_memory_kb=args.max_memory_kb,
                max_latency_ns=args.max_latency_ns,
                model_sweep=sweep,
                tag=f"q{i}",
            )
            for i in range(args.requests)
        ]
        futs = svc.submit_batch(reqs)
        resps = [f.result() for f in futs]
        wall = time.perf_counter() - t0
        lat = []
        for r in resps:
            if not r.ok:
                print(f"{r.request.tag:>6}  ERROR {r.error.code}: "
                      f"{r.error.message}")
                continue
            lat.append(r.service_ms)
            w = r.winner
            mark = "warm" if r.grid_cache_hit else "cold"
            line = (f"{r.request.tag:>6}  {r.request.circuit.name:<8} "
                    f"-> {w.topology.name:<12} recipe={','.join(w.recipe) or '-'} "
                    f"E={w.energy_nj:.4f} nJ  lat={w.latency_ns:.1f} ns "
                    f"[{mark} {r.service_ms:.1f} ms]")
            if r.variation is not None:
                line += (f"  yield={r.variation.best_yield:.2f} "
                         f"cvar90={r.variation.cvar():.4f}")
            print(line)
        ok = [r for r in resps if r.ok]
        print(f"\nserved {len(ok)}/{len(resps)} requests in {wall:.2f}s "
              f"({len(resps) / wall:.1f} rps)")
        if lat:
            print(f"service ms: p50={np.percentile(lat, 50):.1f} "
                  f"p99={np.percentile(lat, 99):.1f} "
                  f"max={max(lat):.1f}")
        st = svc.stats()
        print(f"cache: cha {st.get('cha_hits', 0)}/{st.get('cha_misses', 0)} "
              f"hit/miss, grid {st.get('grid_hits', 0)}/"
              f"{st.get('grid_misses', 0)} hit/miss, "
              f"{st['distinct_buckets']} trace bucket(s)")
    finally:
        svc.close()


def main(argv: "list[str] | None" = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    # Back-compat: bare `python -m repro.launch.serve --batch 4` still
    # routes to the LLM launcher.
    if not argv or argv[0] not in {"llm", "explore"} and argv[0] not in {"-h", "--help"}:
        argv = ["llm"] + argv

    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    llm = sub.add_parser("llm", help="batched LLM generation engine")
    llm.add_argument("--arch", default="minicpm-2b")
    llm.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    llm.add_argument("--batch", type=int, default=4)
    llm.add_argument("--prompt-len", type=int, default=32)
    llm.add_argument("--max-new", type=int, default=16)
    llm.add_argument("--requests", type=int, default=8)
    llm.add_argument("--temperature", type=float, default=0.0)

    ex = sub.add_parser(
        "explore", help="warm persistent rCiM exploration service"
    )
    ex.add_argument("--circuits", default=None,
                    help="comma-separated benchmark names (default: all)")
    ex.add_argument("--scale", choices=["tiny", "default", "paper"],
                    default="tiny")
    ex.add_argument("--recipes", type=int, default=8,
                    help="number of synthesis recipes to sweep")
    ex.add_argument("--requests", type=int, default=8)
    ex.add_argument("--max-memory-kb", type=float, default=None)
    ex.add_argument("--max-latency-ns", type=float, default=None)
    ex.add_argument("--sweep", choices=["none", "corners", "mc"],
                    default="none")
    ex.add_argument("--variants", type=int, default=8,
                    help="Monte-Carlo variants for --sweep mc")
    ex.add_argument("--cache", default=None,
                    help="characterization cache directory")
    ex.add_argument("--max-batch", type=int, default=8)

    args = ap.parse_args(argv)
    if args.cmd == "explore":
        _main_explore(args)
    else:
        _main_llm(args)


if __name__ == "__main__":
    main()
