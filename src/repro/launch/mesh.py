"""Production mesh builders.

A function (not a module-level constant) so importing never touches jax
device state.  Production target: TPU v5e, 256 chips/pod (16x16), two pods
= 512 chips for the multi-pod dry-run.
"""

from __future__ import annotations

import jax

from repro.models.config import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def parallel_config_for(mesh) -> ParallelConfig:
    data_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    return ParallelConfig(data_axes=data_axes)


def make_host_mesh(model: int = 1):
    """Single-process debug mesh over the visible devices."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
