"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts the body of every ``while`` loop
(lowered lax.scan / fori_loop) ONCE, regardless of trip count — so any
scanned program (layer stacks, attention chunk loops, grad accumulation,
chunked CE) under-reports flops / bytes / collective traffic by the loop
trip counts.  This module parses the optimized HLO text instead:

  1. split the module into named computations,
  2. recover each while loop's trip count from its condition computation
     (compare(iv, constant) pattern) or an explicit known_trip_count hint,
  3. build the call graph (while body/cond, fusion calls, call/map,
     conditional branches) and propagate execution *multiplicity* from
     ENTRY down,
  4. accumulate per-computation costs x multiplicity:
        - matmul flops from ``dot`` ops (2 * prod(result) * K),
        - collective link bytes with ring factors (all-gather /
          all-reduce / reduce-scatter / all-to-all / collective-permute),
        - HBM traffic proxy: top-level instruction result bytes x 2
          (read+write), fusion internals excluded (they live in
          registers/VMEM, not HBM).

The result is the corrected (flops, bytes, collective) triple used by the
§Roofline table.  Validated against hand-counted programs in
tests/test_hloparse.py.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALL_ATTR = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|true_computation=|false_computation=|"
    r"branch_computations=\{)%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)"
)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[str]
    header: str = ""
    is_fusion: bool = False

    _symbols: dict | None = None

    def symbols(self) -> dict[str, tuple[str, list[int]]]:
        """name -> (dtype, dims) for every value defined in this computation
        (including parameters from the header arg list)."""
        if self._symbols is not None:
            return self._symbols
        syms: dict[str, tuple[str, list[int]]] = {}
        for m in re.finditer(r"([\w.\-]+):\s*(\w+)\[([\d,]*)\]", self.header):
            if m.group(2) in _DTYPE_BYTES:
                syms[m.group(1)] = (
                    m.group(2), [int(d) for d in m.group(3).split(",") if d]
                )
        for line in self.lines:
            dm = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]", line)
            if dm and dm.group(2) in _DTYPE_BYTES:
                syms[dm.group(1)] = (
                    dm.group(2), [int(d) for d in dm.group(3).split(",") if d]
                )
        self._symbols = syms
        return syms


def split_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        if not raw.startswith((" ", "\t", "}")) and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(stripped)
            if m:
                name = m.group(1)
                cur = Computation(name, [], header=stripped,
                                  is_fusion="fused" in name or "wrapped" in name)
                comps[name] = cur
                if stripped.startswith("ENTRY"):
                    entry_name = name
                continue
        if cur is not None and stripped != "}":
            cur.lines.append(stripped)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


_TRIP_CMP = re.compile(r"compare\([^)]*\)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def while_trip_count(cond: Computation, default: int) -> int:
    """Heuristic: largest integer constant in the condition computation is
    the loop bound (lax.scan lowers to iv < constant(N))."""
    best = None
    for line in cond.lines:
        for m in _CONST_INT.finditer(line):
            v = int(m.group(1))
            if best is None or v > best:
                best = v
    return best if best and best > 0 else default


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    link_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    n_collectives: int = 0
    n_while: int = 0
    trip_counts: dict = dataclasses.field(default_factory=dict)


def _dot_flops(line: str, syms: dict) -> float:
    """2 * prod(result_dims) * K for a dot; K from the lhs operand's shape
    (resolved through the computation's symbol table)."""
    shapes = _shape_list(line.split("dot(")[0])
    if not shapes:
        return 0.0
    _, res_dims = shapes[0]
    n_res = 1
    for d in res_dims:
        n_res *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    # lhs operand: either "dot(%name, ..." or, in newer HLO text,
    # "dot(f32[64,32]{1,0} %name, ..." with the shape inlined.
    ops = re.search(
        r"dot\((?:(\w+)\[([\d,]*)\](?:\{[^}]*\})?\s+)?%?([\w.\-]+)\s*[,)]", line
    )
    if not m or not ops:
        return 2.0 * n_res  # degenerate (K unknown)
    if ops.group(2) is not None:
        lhs_dims = [int(d) for d in ops.group(2).split(",") if d]
    elif ops.group(3) in syms:
        _, lhs_dims = syms[ops.group(3)]
    else:
        return 2.0 * n_res  # degenerate (K unknown)
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2.0 * n_res * k


_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


def _collective_link_bytes(line: str, kind: str, default_group: int) -> float:
    result_bytes = _shape_bytes(line.split("=", 1)[1].split(kind)[0]) if "=" in line else 0
    if result_bytes == 0:
        return 0.0
    n = _group_size(line, default_group)
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if kind == "all-gather":
        return (n - 1) / n * result_bytes
    if kind == "reduce-scatter":
        return (n - 1) * result_bytes
    if kind == "all-to-all":
        return (n - 1) / n * result_bytes
    return float(result_bytes)  # collective-permute


def analyze(hlo: str, default_group: int = 16, default_trip: int = 1) -> HloCost:
    comps = split_computations(hlo)
    cost = HloCost()

    # ---- call graph with multiplicities -------------------------------------
    # edges: caller -> [(callee, kind)]
    edges: dict[str, list[tuple[str, str]]] = defaultdict(list)
    while_of_body: dict[str, tuple[str, str]] = {}  # body -> (caller, cond)
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        for line in comp.lines:
            mb = re.search(r"body=%?([\w.\-]+)", line)
            mc = re.search(r"condition=%?([\w.\-]+)", line)
            if mb and mc:
                edges[name].append((mb.group(1), "while"))
                while_of_body[mb.group(1)] = (name, mc.group(1))
                edges[name].append((mc.group(1), "while"))
                continue
            for attr in ("calls", "to_apply", "true_computation",
                         "false_computation"):
                for m in re.finditer(rf"{attr}=%?([\w.\-]+)", line):
                    edges[name].append((m.group(1), attr))
            m = re.search(r"branch_computations=\{([^}]*)\}", line)
            if m:
                for b in m.group(1).split(","):
                    edges[name].append((b.strip().lstrip("%"), "branch"))

    entry = comps.get("__entry__")
    if entry is None:
        return cost
    entry_name = entry.name

    mult: dict[str, float] = defaultdict(float)
    mult[entry_name] = 1.0
    # propagate breadth-first (HLO call graphs are acyclic)
    import collections

    q = collections.deque([entry_name])
    seen_order = []
    while q:
        cur = q.popleft()
        seen_order.append(cur)
        for callee, kind in edges.get(cur, []):
            if callee not in comps:
                continue
            m = mult[cur]
            if kind == "while":
                cond_name = while_of_body.get(callee, (None, None))[1]
                trip = default_trip
                if cond_name and cond_name in comps:
                    trip = while_trip_count(comps[cond_name], default_trip)
                elif callee in {c for _, c in while_of_body.values()}:
                    trip = 1  # condition computations run trip+1 times ~ trip
                if callee == cond_name:
                    trip = max(1, trip)
                m = m * max(1, trip)
                cost.trip_counts[callee] = max(1, trip)
            mult[callee] += m
            q.append(callee)

    # ---- accumulate costs ----------------------------------------------------
    for name, comp in comps.items():
        if name == "__entry__" or mult.get(name, 0.0) == 0.0:
            continue
        m = mult[name]
        syms = comp.symbols()
        for line in comp.lines:
            # dots (inside fusions or top level)
            if re.search(r"\bdot\(", line):
                cost.flops += m * _dot_flops(line, syms)
            # convolutions — treat like dots via output x kernel size (rare here)
            # collectives (never inside fusions)
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(?:-start)?\(", line) and not re.search(
                    rf"{kind}-done", line
                ):
                    lb = m * _collective_link_bytes(line, kind, default_group)
                    if lb > 0:
                        cost.link_bytes += lb
                        cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0.0) + lb
                        cost.n_collectives += 1
                    break
            if re.search(r"\bwhile\(", line):
                cost.n_while += 1
            # HBM proxy: top-level (non-fusion-internal) results, 2x for r+w
            if not comp.is_fusion and "=" in line and not line.startswith("ROOT tuple"):
                rhs = line.split("=", 1)[1]
                opm = re.match(r"\s*(?:\([^)]*\)|[\w\[\],{}\. ]+?)\s*([a-z][\w\-]*)\(", rhs)
                opname = opm.group(1) if opm else ""
                if opname not in ("parameter", "constant", "tuple",
                                  "get-tuple-element", "bitcast"):
                    shape_txt = rhs.split(opname + "(")[0] if opname else rhs
                    cost.hbm_bytes += 2.0 * m * _shape_bytes(shape_txt)
    return cost
