"""Roofline-term extraction from compiled dry-run artifacts.

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.

Sources:
  * ``compiled.cost_analysis()`` -> HLO flops / bytes accessed (per-device,
    the module is already SPMD-partitioned when lowered under a mesh).
  * collective bytes are NOT in cost_analysis: we parse the optimized HLO
    text and sum the shapes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute ops, converting each to *bytes crossing
    links per chip* with the standard ring factors:

        all-reduce       2 (n-1)/n x payload
        all-gather         (n-1)   x shard   (result = n shards)
        reduce-scatter     (n-1)/n x payload (payload = n x result)
        all-to-all         (n-1)/n x payload
        collective-permute       1 x payload

    where n is the replica-group size parsed from the op (iota or explicit
    group list), falling back to the model-axis size.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*"            # result name
    r"(?:\(([^)]*)\)|([a-z0-9_\[\]{},\. ]+?))\s*"  # result shape (maybe tuple)
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        # iota form [G, S] <= [N]: groups of size S
        return max(1, int(m.group(2)))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


@dataclasses.dataclass
class CollectiveStats:
    total_link_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=dict)
    n_ops: int = 0

    def add(self, kind: str, link_bytes: float):
        self.total_link_bytes += link_bytes
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + link_bytes
        self.n_ops += 1


def collective_bytes(hlo_text: str, default_group: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m:
            continue
        if ("-done" in line.split("=")[1][:40]) or ".clone" in m.group(1):
            pass  # -done ops carry no shape work; clones are fine to count once
        if re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)-done", line):
            continue
        kind = m.group(4)
        shape_str = m.group(2) or m.group(3) or ""
        result_bytes = _shape_bytes(shape_str)
        if result_bytes == 0:
            continue
        n = _group_size(line, default_group)
        if kind == "all-reduce":
            link = 2.0 * (n - 1) / n * result_bytes
        elif kind == "all-gather":
            link = (n - 1) / n * result_bytes  # result is the full gather
        elif kind == "reduce-scatter":
            link = (n - 1) * result_bytes  # result is one shard
        elif kind == "all-to-all":
            link = (n - 1) / n * result_bytes
        else:  # collective-permute
            link = float(result_bytes)
        stats.add(kind, link)
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    link_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float
    model_flops_per_chip: float
    useful_ratio: float
    coll_breakdown: dict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(
    cost: dict,
    coll: CollectiveStats,
    n_chips: int,
    model_flops_total: float,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll.total_link_bytes / LINK_BW
    terms = dict(compute=compute_s, memory=memory_s, collective=coll_s)
    bottleneck = max(terms, key=terms.get)
    per_chip_model = model_flops_total / n_chips
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        link_bytes=coll.total_link_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        model_flops_per_chip=per_chip_model,
        useful_ratio=(per_chip_model / flops) if flops else 0.0,
        coll_breakdown=dict(coll.by_kind),
    )


def model_flops(cfg, shape, n_layers_factor: float = 1.0) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference fwd) per the standard
    counting; N = active params (MoE-aware), D = tokens processed."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
