"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs(arch, shape)`` gives weak-type-correct, shardable SDS trees
for the step function of that cell — no device allocation, following the
shannon/kernels dry-run pattern.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.config import SHAPES, ModelConfig, ParallelConfig, ShapeConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel import sharding as sh

SDS = jax.ShapeDtypeStruct


def sds(shape, dtype):
    return SDS(tuple(int(x) for x in shape), dtype)


def _batch_inputs(cfg: ModelConfig, b: int, s: int, train: bool) -> dict:
    out = dict(tokens=sds((b, s), jnp.int32))
    if train:
        out["labels"] = sds((b, s), jnp.int32)
        out["mask"] = sds((b, s), jnp.float32)
    if cfg.is_encoder_decoder:
        out["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.n_patches:
        out["patches"] = sds((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return out


def batch_logical(cfg: ModelConfig, train: bool) -> dict:
    out = dict(tokens=("batch", None))
    if train:
        out["labels"] = ("batch", None)
        out["mask"] = ("batch", None)
    if cfg.is_encoder_decoder:
        out["frames"] = ("batch", None, None)
    if cfg.n_patches:
        out["patches"] = ("batch", None, None)
    return out


class CellSpec:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    def __init__(self, arch: str, shape_name: str, mesh, pc: ParallelConfig | None = None,
                 cfg: ModelConfig | None = None, q_chunk: int = 1024, kv_chunk: int = 1024,
                 remat: str = "full", grad_accum: int = 1,
                 cast_bf16: bool = False, shard_grads: bool = False,
                 rules_patch: dict | None = None):
        self.arch = arch
        self.shape = SHAPES[shape_name]
        self.cfg = cfg or get_config(arch)
        self.mesh = mesh
        from repro.launch.mesh import parallel_config_for

        self.pc = pc or parallel_config_for(mesh)
        if remat != self.pc.remat:
            import dataclasses

            self.pc = dataclasses.replace(self.pc, remat=remat)
        self.rules = sh.rules_for_model(self.cfg, self.pc, mesh)
        if rules_patch:
            self.rules.update(rules_patch)
        self.model = Model(self.cfg, self.pc, mesh=mesh, rules=self.rules,
                           q_chunk=q_chunk, kv_chunk=kv_chunk)
        self.grad_accum = grad_accum
        self.cast_bf16 = cast_bf16
        self.shard_grads = shard_grads

    # -- parameter / optimizer SDS + shardings -------------------------------

    def param_sds(self, dtype=jnp.float32):
        shapes = self.model.param_shapes()
        return jax.tree.map(
            lambda shp: sds(shp, dtype), shapes,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, int) for e in x),
        )

    def param_shardings(self):
        logical = self.model.logical()
        shapes = self.model.param_shapes()
        is_lg = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        )
        return jax.tree.map(
            lambda lg, shp: NamedSharding(self.mesh, sh.spec_for(self.mesh, shp, lg, self.rules)),
            logical, shapes, is_leaf=is_lg,
        )

    def opt_sds(self, opt_cfg: AdamWConfig):
        p = self.param_sds(jnp.float32)
        return jax.eval_shape(lambda pp: adamw_init(pp, opt_cfg), p)

    def opt_shardings(self, opt_cfg: AdamWConfig):
        ps = self.param_shardings()
        rep = NamedSharding(self.mesh, P())
        moments = dict(step=rep, m=ps, v=ps)
        if opt_cfg.compression == "int8_ef":
            moments["ef"] = ps
        return moments

    # -- inputs ---------------------------------------------------------------

    def input_sds(self):
        s = self.shape
        if s.kind == "train":
            return _batch_inputs(self.cfg, s.global_batch, s.seq_len, True)
        if s.kind == "prefill":
            return _batch_inputs(self.cfg, s.global_batch, s.seq_len, False)
        # decode: one token step against a seq_len cache
        return dict(
            token=sds((s.global_batch,), jnp.int32),
            pos=sds((), jnp.int32),
        )

    def cache_sds(self):
        s = self.shape
        caches = jax.eval_shape(
            lambda: self.model.init_cache(s.global_batch, s.seq_len)
        )
        return caches

    def cache_shardings(self):
        logical = self.model.cache_logical_tree()
        shapes = jax.tree.map(lambda x: x.shape, self.cache_sds())
        is_lg = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        )
        return jax.tree.map(
            lambda lg, shp: NamedSharding(self.mesh, sh.spec_for(self.mesh, shp, lg, self.rules)),
            logical, shapes, is_leaf=is_lg,
        )

    def batch_shardings(self):
        s = self.shape
        inp = self.input_sds()
        lg = (
            batch_logical(self.cfg, s.kind == "train")
            if s.kind in ("train", "prefill")
            else dict(token=("batch",), pos=())
        )
        return jax.tree.map(
            lambda l, v: NamedSharding(self.mesh, sh.spec_for(self.mesh, v.shape, l, self.rules)),
            lg, inp,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )

    # -- the step function to lower -------------------------------------------

    def step_fn_and_args(self, opt_cfg: AdamWConfig | None = None):
        """Returns (fn, arg_sds tuple, in_shardings tuple)."""
        s = self.shape
        m = self.model
        if s.kind == "train":
            from repro.optim.adamw import adamw_update
            from repro.train.steps import make_train_step
            from repro.optim.adamw import AdamWConfig as AC

            opt_cfg = opt_cfg or AC()
            from repro.optim.adamw import constant_schedule

            step = make_train_step(
                m, constant_schedule(1e-4), opt_cfg,
                grad_accum=self.grad_accum,
                cast_bf16=self.cast_bf16,
                grad_shardings=self.param_shardings() if self.shard_grads else None,
            )
            args = (self.param_sds(jnp.float32), self.opt_sds(opt_cfg), self.input_sds())
            shards = (self.param_shardings(), self.opt_shardings(opt_cfg),
                      self.batch_shardings())
            return step, args, shards, (0, 1)  # donate params + opt state
        if s.kind == "prefill":
            fn = lambda params, batch: m.prefill(params, batch)
            args = (self.param_sds(jnp.bfloat16), self.input_sds())
            shards = (self.param_shardings(), self.batch_shardings())
            return fn, args, shards, ()
        # decode: serve_step
        fn = lambda params, caches, token, pos: m.decode_step(params, caches, token, pos)
        inp = self.input_sds()
        args = (self.param_sds(jnp.bfloat16), self.cache_sds(), inp["token"], inp["pos"])
        bs = self.batch_shardings()
        shards = (self.param_shardings(), self.cache_shardings(), bs["token"], bs["pos"])
        return fn, args, shards, (1,)  # donate the KV caches (in-place update)
