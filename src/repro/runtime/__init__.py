"""Runtime hardening utilities shared by the long-running layers.

`repro.runtime.faults` is the deterministic fault-injection registry the
chaos tests and CI profile drive; it is strictly a no-op unless armed.
"""
