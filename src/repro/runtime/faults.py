"""Deterministic, seed-keyed fault injection for the exploration runtime.

The long-running layers (the sweep runner, the characterization pool,
the exploration service, the caches and the shard journal) carry *named
injection points* — single calls into this module at the places where
real deployments crash, hang, or corrupt state.  A chaos run arms a
`FaultPlan` (programmatically or through the ``REPRO_FAULTS`` env var,
which spawned pool workers and subprocess sweeps inherit) and every
matching hit then raises, sleeps, hard-exits the process, or truncates a
payload — deterministically, so a failing chaos scenario replays
exactly.

Contract, pinned by tests/test_faults.py and the CI chaos profile:

  * **disabled means invisible** — with no plan armed, `inject` returns
    immediately, `corrupt` returns its payload unchanged, and
    `corrupt_file` leaves the file alone.  The fast path is one module
    attribute read; production behavior is bit-identical with the
    module imported or not.
  * **deterministic** — firing is a pure function of (plan, seed,
    point, hit index).  Probabilistic rules (``prob < 1``) key their
    coin flips on the plan seed + hit index, never on global RNG state.
  * **named points only** — arming a plan validates every rule against
    the `POINTS` registry, so a typo'd point name fails loudly instead
    of silently never firing.

Env format (rules separated by ``;``, fields by ``:``)::

    REPRO_FAULTS="point:action[:match[:after[:count[:hang_s]]]]"
    REPRO_FAULTS_SEED=0

e.g. ``REPRO_FAULTS="pool.task:exit::1:1"`` hard-exits the pool worker
on the second matching task, once.  ``count`` of ``inf`` fires forever.

Cross-process budgets: rule state (hit counters) is per process, but a
chaos run over a spawn pool wants "fail exactly N times *globally*" —
otherwise a retried task landing on a fresh worker re-fires forever.
Setting ``REPRO_FAULTS_ONCE_DIR=<dir>`` coordinates ``count`` through
exclusive-create claim files in that directory: a rule only fires while
it can claim one of its ``count`` slots, no matter which process hits
it.  (``count=inf`` rules ignore the claim dir.)
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import Counter
from contextlib import contextmanager
from random import Random
from typing import Iterable, Sequence

#: The injection-point registry: every call site names one of these.
#: (Also the source of the ARCHITECTURE.md table and the chaos matrix.)
POINTS: dict[str, str] = {
    "pool.task": (
        "characterization pool worker, around one transform application "
        "(detail: 'circuit:transform')"
    ),
    "cha.backend": (
        "characterize_suite front half, per circuit, before the transform "
        "DAG runs (detail: resolved backend name)"
    ),
    "cache.store": (
        "CharacterizationCache JSON writes — stats, application index, "
        "persisted AIGs (detail: target path; corrupt truncates the payload)"
    ),
    "sweep.shard": (
        "sweep runner, before a shard is evaluated (detail: shard circuit "
        "names)"
    ),
    "journal.write": (
        "shard journal publish in ckpt.CheckpointManager (detail: journal "
        "step path; corrupt truncates the on-disk arrays)"
    ),
    "service.process": (
        "exploration service worker, at batch pickup (detail: batch size)"
    ),
}

ACTIONS = ("raise", "hang", "exit", "corrupt")


class FaultError(RuntimeError):
    """The exception an armed ``raise`` rule throws at its point."""


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One armed fault: fire ``action`` at ``point`` on matching hits.

    ``match`` is a substring filter on the call site's ``detail`` string
    ("" matches every hit).  ``after`` skips that many matching hits
    first; ``count`` bounds how many times the rule fires (None =
    forever).  ``prob`` keeps a seed-keyed coin flip per hit.
    """

    point: str
    action: str
    match: str = ""
    after: int = 0
    count: int | None = 1
    hang_s: float = 3600.0
    prob: float = 1.0

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r} "
                f"(known: {', '.join(sorted(POINTS))})"
            )
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown fault action {self.action!r} (known: {ACTIONS})"
            )


@dataclasses.dataclass
class FaultPlan:
    rules: tuple[FaultRule, ...]
    seed: int = 0
    #: matching hits per rule index (drives after/count accounting)
    hits: Counter = dataclasses.field(default_factory=Counter)
    #: times each point actually fired (observability for tests)
    fired: Counter = dataclasses.field(default_factory=Counter)


_PLAN: FaultPlan | None = None
_ENV_CHECKED = False


def parse_rules(spec: str) -> list[FaultRule]:
    """Parse the ``REPRO_FAULTS`` rule syntax (see module docstring)."""
    rules = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        if len(fields) < 2:
            raise ValueError(f"bad fault rule {part!r} (need point:action)")
        point, action = fields[0], fields[1]
        match = fields[2] if len(fields) > 2 else ""
        after = int(fields[3]) if len(fields) > 3 and fields[3] else 0
        count: int | None = 1
        if len(fields) > 4 and fields[4]:
            count = None if fields[4] == "inf" else int(fields[4])
        hang_s = float(fields[5]) if len(fields) > 5 and fields[5] else 3600.0
        rules.append(
            FaultRule(point, action, match=match, after=after, count=count,
                      hang_s=hang_s)
        )
    return rules


def _load_env() -> None:
    """Arm a plan from ``REPRO_FAULTS`` once (spawned workers inherit the
    env, so a chaos run reaches into pool subprocesses too)."""
    global _ENV_CHECKED, _PLAN
    if _ENV_CHECKED:
        return
    _ENV_CHECKED = True
    spec = os.environ.get("REPRO_FAULTS", "")
    if spec:
        _PLAN = FaultPlan(
            rules=tuple(parse_rules(spec)),
            seed=int(os.environ.get("REPRO_FAULTS_SEED", "0") or "0"),
        )


def configure(rules: "Iterable[FaultRule] | Sequence[FaultRule]",
              seed: int = 0) -> FaultPlan:
    """Arm a plan programmatically (replaces any previous plan)."""
    global _PLAN, _ENV_CHECKED
    _ENV_CHECKED = True  # explicit configuration wins over the env
    _PLAN = FaultPlan(rules=tuple(rules), seed=seed)
    return _PLAN


def disable() -> None:
    """Disarm: every injection point becomes a strict no-op again."""
    global _PLAN, _ENV_CHECKED
    _PLAN = None
    _ENV_CHECKED = True


def enabled() -> bool:
    _load_env()
    return _PLAN is not None


def active_plan() -> FaultPlan | None:
    _load_env()
    return _PLAN


@contextmanager
def injected(*rules: FaultRule, seed: int = 0):
    """Scoped arming for in-process tests; restores the previous plan."""
    global _PLAN
    _load_env()
    prev = _PLAN
    plan = configure(rules, seed=seed)
    try:
        yield plan
    finally:
        _PLAN = prev


def _claim_slot(rule: FaultRule, i: int) -> bool:
    """Global fire-budget coordination (``REPRO_FAULTS_ONCE_DIR``):
    atomically claim one of the rule's ``count`` slots via exclusive
    file creation; False once every slot is taken by any process."""
    once_dir = os.environ.get("REPRO_FAULTS_ONCE_DIR")
    if not once_dir or rule.count is None:
        return True
    os.makedirs(once_dir, exist_ok=True)
    stem = f"{rule.point}.{rule.action}.{i}".replace("/", "_")
    for k in range(rule.count):
        try:
            fd = os.open(
                os.path.join(once_dir, f"{stem}.{k}"),
                os.O_CREAT | os.O_EXCL | os.O_WRONLY,
            )
        except FileExistsError:
            continue
        os.write(fd, str(os.getpid()).encode())
        os.close(fd)
        return True
    return False


def _matching_rule(point: str, detail: str,
                   actions: tuple[str, ...]) -> FaultRule | None:
    """First armed rule due to fire at this hit, advancing hit counters."""
    plan = _PLAN
    assert plan is not None
    fire = None
    for i, rule in enumerate(plan.rules):
        if rule.point != point or rule.action not in actions:
            continue
        if rule.match and rule.match not in detail:
            continue
        n = plan.hits[i]
        plan.hits[i] = n + 1
        if n < rule.after:
            continue
        if rule.count is not None and n - rule.after >= rule.count:
            continue
        if rule.prob < 1.0:
            if Random(f"{plan.seed}:{point}:{n}").random() >= rule.prob:
                continue
        if fire is None and _claim_slot(rule, i):
            fire = rule
    if fire is not None:
        plan.fired[point] += 1
    return fire


def inject(point: str, detail: str = "") -> None:
    """The crash/hang injection point: a strict no-op unless a plan is
    armed and a ``raise``/``hang``/``exit`` rule matches this hit."""
    if _PLAN is None:
        if _ENV_CHECKED:
            return
        _load_env()
        if _PLAN is None:
            return
    rule = _matching_rule(point, detail, ("raise", "hang", "exit"))
    if rule is None:
        return
    if rule.action == "raise":
        raise FaultError(f"injected fault at {point} ({detail})")
    if rule.action == "hang":
        time.sleep(rule.hang_s)
        return
    # "exit": a hard crash — the pool-worker / kill-9 simulation.  Flush
    # nothing, run no handlers: exactly what SIGKILL looks like from the
    # parent's side.
    os._exit(42)


def corrupt(point: str, data: bytes, detail: str = "") -> bytes:
    """The corruption injection point for in-memory payloads: returns
    ``data`` unchanged unless an armed ``corrupt`` rule matches, in which
    case a seed-keyed truncated prefix is returned."""
    if _PLAN is None:
        if _ENV_CHECKED:
            return data
        _load_env()
        if _PLAN is None:
            return data
    rule = _matching_rule(point, detail, ("corrupt",))
    if rule is None:
        return data
    plan = _PLAN
    frac = 0.1 + 0.8 * Random(f"{plan.seed}:{point}:truncate").random()
    return data[: max(1, int(len(data) * frac))]


def corrupt_file(point: str, path: "str | os.PathLike",
                 detail: str = "") -> None:
    """Truncate an on-disk file in place when an armed ``corrupt`` rule
    matches (the torn-write / bad-sector simulation); no-op otherwise."""
    if _PLAN is None:
        if _ENV_CHECKED:
            return
        _load_env()
        if _PLAN is None:
            return
    rule = _matching_rule(point, str(detail) or str(path), ("corrupt",))
    if rule is None:
        return
    plan = _PLAN
    size = os.path.getsize(path)
    frac = 0.1 + 0.8 * Random(f"{plan.seed}:{point}:truncate").random()
    with open(path, "r+b") as f:
        f.truncate(max(1, int(size * frac)))
