"""Pure-jnp oracle for the CiM bit-plane logic engine.

Semantics contract shared with kernels/cim_logic.py:

  * Signals live in a register file of ``n_rows`` bit-plane rows; each row
    holds ``n_words`` int32 words = 32*n_words packed test vectors.
  * Primary inputs occupy rows [0, n_pis).
  * Instructions are int32 arrays (n_gates, 4): [kind, a_row, b_row, out_row]
    with kind 0 = NAND2, 1 = NOR2, 2 = NOT (b ignored, = a).
  * Outputs are gathered from ``po_rows`` after all instructions retire.

This mirrors the paper's execution model: one instruction = one sense-amp
op (two wordline activations + resonant writeback to a row).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cim_reference(
    instrs: jax.Array,  # (n_gates, 4) int32
    pi_planes: jax.Array,  # (n_pis, n_words) int32
    po_rows: jax.Array,  # (n_pos,) int32
    n_rows: int,
) -> jax.Array:
    """Evaluate the instruction stream; returns (n_pos, n_words) int32."""
    n_pis, n_words = pi_planes.shape
    regs = jnp.zeros((n_rows, n_words), dtype=jnp.int32)
    regs = regs.at[:n_pis].set(pi_planes.astype(jnp.int32))

    def step(i, regs):
        kind = instrs[i, 0]
        a = regs[instrs[i, 1]]
        b = regs[instrs[i, 2]]
        is_nor = kind == 1
        res = ~jnp.where(is_nor, a | b, a & b)
        return regs.at[instrs[i, 3]].set(res)

    regs = jax.lax.fori_loop(0, instrs.shape[0], step, regs)
    return regs[po_rows]


def pack_vectors(bits: np.ndarray) -> np.ndarray:
    """Pack (n_signals, n_vectors) {0,1} -> (n_signals, ceil(n/32)) int32.

    Vector v maps to bit (v % 32) of word (v // 32), LSB-first.
    """
    bits = np.asarray(bits, dtype=np.uint8)
    n_sig, n_vec = bits.shape
    n_words = -(-n_vec // 32)
    padded = np.zeros((n_sig, n_words * 32), dtype=np.uint8)
    padded[:, :n_vec] = bits
    out = np.zeros((n_sig, n_words), dtype=np.uint32)
    for b in range(32):
        out |= padded[:, b::32].astype(np.uint32) << np.uint32(b)
    return out.view(np.int32)


def unpack_vectors(words: np.ndarray, n_vec: int) -> np.ndarray:
    """Inverse of pack_vectors -> (n_signals, n_vec) uint8."""
    w = np.asarray(words).view(np.uint32)
    n_sig, n_words = w.shape
    bits = np.zeros((n_sig, n_words * 32), dtype=np.uint8)
    for b in range(32):
        bits[:, b::32] = ((w >> np.uint32(b)) & np.uint32(1)).astype(np.uint8)
    return bits[:, :n_vec]
