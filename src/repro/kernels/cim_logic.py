"""Pallas TPU kernel: CiM bit-plane boolean logic engine.

TPU adaptation of the paper's in-SRAM computing (§III-B): the entire
combinational evaluation happens inside VMEM — the TPU's on-chip SRAM —
with zero HBM round-trips between logic levels.  The memory-hierarchy
mapping is

    DRAM -> SRAM array -> bitlines      (paper)
    HBM  -> VMEM scratch -> VREGs       (here)

and the architectural knobs line up one-to-one with the paper's topology
space (core/mesh_explorer.py searches them the way Alg. I searches SRAM
topologies):

    grid tiles over packed test vectors  <->  parallel macros
    ``block_words`` (lanes per tile)     <->  bank column count M
    scratch rows (register file)         <->  SRAM rows
    instruction stream                   <->  wordline-activation schedule

One instruction = one macro op: two row reads (the dual read ports), a
NAND2/NOR2/NOT on 8x128-lane VREG tiles, one row writeback.  Row indices
come from ops.compile_netlist, which performs the paper's operand placement
(with linear-scan row reuse standing in for "operands placed flexibly
within the two columns").

Kernel layout:
  * instrs  (n_gates, 4) int32 in VMEM   — [kind, a_row, b_row, out_row]
  * pi      (n_rows_padded, block_words) — PI planes pre-placed in rows
  * out     (n_po_padded, block_words)   — gathered PO planes
  * scratch (n_rows_padded, block_words) VMEM — the "SRAM array"
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis import registry as _registry

# repro: kernel-module
TRACE_COUNTS = _registry.TRACE_COUNTS
_registry.register_counter("cim_pallas", __name__)

LANE = 128
SUBLANE = 8


def trace_counts() -> dict[str, int]:
    """Snapshot of this module's jit trace counters."""
    return _registry.trace_counts(module=__name__)


def _cim_kernel(instr_ref, pi_ref, out_ref, scratch_ref, *, n_gates: int, n_pos: int):
    # Load the PI planes (pre-placed into their rows by the host wrapper)
    # into the VMEM "SRAM array".
    scratch_ref[...] = pi_ref[...]

    def step(i, _):
        kind = instr_ref[i, 0]
        a_row = instr_ref[i, 1]
        b_row = instr_ref[i, 2]
        o_row = instr_ref[i, 3]
        a = pl.load(scratch_ref, (pl.dslice(a_row, 1), slice(None)))
        b = pl.load(scratch_ref, (pl.dslice(b_row, 1), slice(None)))
        is_nor = (kind == 1).astype(jnp.int32)
        and_ab = jnp.bitwise_and(a, b)
        or_ab = jnp.bitwise_or(a, b)
        res = jnp.bitwise_not(jnp.where(is_nor == 1, or_ab, and_ab))
        pl.store(scratch_ref, (pl.dslice(o_row, 1), slice(None)), res)
        return 0

    jax.lax.fori_loop(0, n_gates, step, 0)

    # Gather POs: instruction slots [n_gates, n_gates + n_pos) carry the PO
    # row index in column 3 (kind = 3 sentinel).
    def gather(j, _):
        row = instr_ref[n_gates + j, 3]
        v = pl.load(scratch_ref, (pl.dslice(row, 1), slice(None)))
        pl.store(out_ref, (pl.dslice(j, 1), slice(None)), v)
        return 0

    jax.lax.fori_loop(0, n_pos, gather, 0)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "n_gates", "n_pos", "block_words", "interpret"),
)
def cim_pallas_call(
    instrs: jax.Array,  # (n_gates + n_pos, 4) int32 (PO gather slots appended)
    pi_planes: jax.Array,  # (n_rows_padded, n_words) int32, PIs pre-placed
    n_rows: int,
    n_gates: int,
    n_pos: int,
    block_words: int = 512,
    interpret: bool = True,
):
    TRACE_COUNTS["cim_pallas"] += 1
    n_rows_p, n_words = pi_planes.shape
    assert n_rows_p == _round_up(n_rows, SUBLANE)
    assert n_words % block_words == 0, (n_words, block_words)
    n_pos_p = _round_up(n_pos, SUBLANE)
    grid = (n_words // block_words,)

    out = pl.pallas_call(
        functools.partial(_cim_kernel, n_gates=n_gates, n_pos=n_pos),
        grid=grid,
        in_specs=[
            pl.BlockSpec((instrs.shape[0], 4), lambda j: (0, 0)),
            pl.BlockSpec((n_rows_p, block_words), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_pos_p, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_pos_p, n_words), jnp.int32),
        scratch_shapes=[
            # VMEM scratch: the "SRAM array".
            _vmem((n_rows_p, block_words), jnp.int32)
        ],
        interpret=interpret,
    )(instrs, pi_planes)
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
