"""Device-resident AIG cone simulation — the front half's bit-packed engine.

The transforms (core/transforms.py) spend their time simulating small
cones: exact truth tables over a cut's leaves (rewrite / refactor /
resub verification) and whole-graph random signatures (resub).  The
python path computes these one cone at a time on arbitrary-precision
ints; this module moves them onto the device as *batched bit-packed
simulation*, reusing the instruction-stream layout of
``kernels/cim_logic.py``:

  * `compile_aig` lowers the (already topologically ordered) AIG once
    into a ``[kind, a_row, b_row, out_row]`` int32 instruction stream
    where ``kind`` packs the two fanin complement bits
    (``out = (a ^ pa) & (b ^ pb)``) and rows are node indices — plus a
    *wave-packed* variant (independent same-level nodes grouped so one
    scan step evaluates a whole wave) and the per-node AIG levels.
  * `eval_tts` evaluates a *batch* of (roots, support) queries.  On the
    jnp engine each word-tier's queries are assembled into chunked
    **mega-programs**: every query's cone is laid out in a shared flat
    row space (row 0 = const0, then per query its support rows — pinned
    to elementary truth tables, exactly `Aig.truth_table`'s semantics —
    followed by its cone rows), and the concatenated instructions are
    wave-packed by global AIG level.  Device work is therefore
    proportional to the *useful* cone work, not batch x whole-graph.
  * `node_signatures` runs the whole-graph wave stream over random
    uint64 pattern words (viewed as uint32 lanes) — bit-identical to
    ``transforms._node_signatures``.

Two device engines share the host wrapper: the pure-jnp ``lax.scan``
mega-program engine (the CPU-CI workhorse — Pallas interpret mode
would crawl) and a Pallas kernel with the cim_logic VMEM-scratch
layout (one grid step per query against the full graph, the scratch is
the "SRAM array" holding every node's packed table).  ``engine="auto"``
picks Pallas on TPU, jnp elsewhere; both are bit-exact against the
python-int reference, which CI and the property tests enforce.

Shape discipline: queries bucket into word tiers (k <= 5 / 10 / 14
support vars -> 1 / 32 / 512 uint32 words); mega-program chunks are
bounded by a per-tier instruction budget and padded to pow2 shapes so
the jit cache stays small.  Queries wider than `DEVICE_MAX_VARS` take
the host bigint path on the jnp engine — at 512 words per table
CPython's limb loops already run at memory speed.  `_jax_setup`
enables jax's persistent compilation cache (``REPRO_JAX_CACHE[_DIR]``)
so only the first process on a machine pays the XLA compiles — the
cross-process cold-start cost this module exists to kill.  A
`TRACE_COUNTS` counter (same idiom as core/batch.py) lets tests pin
the trace count.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from repro.analysis import registry as _registry
from repro.core.aig import Aig, _elementary_int, lit_node, lit_phase

#: Traced-call counters (incremented inside the traced function bodies, so
#: they count *compiles*, not calls) — same discipline as core/batch.py.
#: The Counter lives in the unified registry; this module re-exports it.
# repro: kernel-module
TRACE_COUNTS = _registry.TRACE_COUNTS


def trace_counts() -> dict[str, int]:
    """Snapshot of this module's jit trace counters (for tests /
    benchmarks) — scoped to the aig kernels, as it always was."""
    return _registry.trace_counts(module=__name__)


# (max vars, uint32 words) shape tiers for truth-table queries.  A query
# with k support vars lands in the smallest tier with 32 * words >= 2**k;
# its table occupies the low 2**k bits and the host masks the rest off.
_TIERS: tuple[tuple[int, int], ...] = ((5, 1), (10, 32), (14, 512))
#: Batch chunk per word tier (bounds the Pallas (chunk, n_pad) pin block).
_CHUNK = {1: 2048, 32: 128, 512: 16}

#: jnp mega-program shape knobs per word tier: instructions per wave and
#: the per-chunk instruction budget.  Wider waves amortize the per-step
#: scan overhead; the budget bounds carry memory and jit-shape diversity.
_MEGA_WAVE = {1: 1024, 32: 256}
_MEGA_BUDGET = {1: 1 << 17, 32: 1 << 14}
#: Queries with more support vars than this take the host bigint path on
#: the jnp engine: at 512 words per table, CPython's big-int AND/XOR (a C
#: loop over limbs) is already at memory speed and the device round trip
#: cannot win.  The Pallas engine keeps them (TPU lanes don't care).
DEVICE_MAX_VARS = 10

MAX_VARS = _TIERS[-1][0]


def jax_available() -> bool:
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - environment without jax
        return False
    return True


_JAX_SETUP_DONE = False


def _jax_setup() -> None:
    """One-time jax configuration for the characterization kernels.

    Enables the persistent compilation cache (the mega-program engine
    compiles a few dozen shape buckets; without the cache every fresh
    process pays ~10 s of XLA compiles, *the* cold-start cost this
    module exists to kill).  ``REPRO_JAX_CACHE=0`` disables it;
    ``REPRO_JAX_CACHE_DIR`` overrides the location.
    """
    global _JAX_SETUP_DONE
    if _JAX_SETUP_DONE:
        return
    _JAX_SETUP_DONE = True
    import os

    if os.environ.get("REPRO_JAX_CACHE", "1") == "0":
        return
    cache_dir = os.environ.get("REPRO_JAX_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro_jax_cache"
    )
    try:  # pragma: no cover - depends on jax version/backend support
        import jax

        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        try:
            jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        except Exception:
            pass
    except Exception:
        pass


#: Instructions per wave of the level-packed stream (see `compile_aig`).
WAVE_WIDTH = 128


@dataclasses.dataclass(frozen=True)
class AigProgram:
    """One AIG lowered to the shared instruction stream.

    ``instrs[i] = [kind, a_row, b_row, out_row]`` evaluates node
    ``n_pis + 1 + i``; rows are node indices (node 0 = const0, nodes
    1..n_pis = PIs).  ``kind`` = pa | (pb << 1) — the fanin complement
    bits.  Rows/instructions are padded to ``n_pad`` (power of two);
    padding instructions write the scratch row ``n_pad - 1``.

    ``waves`` is the same stream *level-packed* for the jnp engine:
    nodes grouped by AIG level (same-level nodes never depend on each
    other), each level split into `WAVE_WIDTH`-wide waves, so one scan
    step evaluates up to 128 independent nodes and the scan length is
    ~depth, not ~n_nodes.  Wave count pads to a power of two.
    """

    instrs: np.ndarray  # (n_pad, 4) int32 — flat, for the Pallas engine
    waves: np.ndarray  # (n_waves_pad, wave_w, 4) int32 — jnp sig engine
    lv: np.ndarray  # (n_nodes,) int64 — AIG levels (mega wave packing)
    n_nodes: int
    n_pis: int
    n_pad: int


def _next_pow2(x: int, floor: int = 3) -> int:
    return 1 << max(floor, (x - 1).bit_length())


def compile_aig(aig: Aig) -> AigProgram:
    """Lower an AIG to the level-ordered instruction stream (host, once)."""
    n_nodes = aig.n_nodes
    n_pad = _next_pow2(n_nodes + 1)
    f0 = np.asarray(aig._f0, dtype=np.int64)
    f1 = np.asarray(aig._f1, dtype=np.int64)
    instrs = np.zeros((n_pad, 4), dtype=np.int32)
    # No-op padding: AND of const0 with itself, parked in the scratch row.
    instrs[:, 3] = n_pad - 1
    lo = aig.n_pis + 1
    n_ands = n_nodes - lo
    if n_ands > 0:
        a, b = f0[lo:], f1[lo:]
        instrs[:n_ands, 0] = (a & 1) | ((b & 1) << 1)
        instrs[:n_ands, 1] = a >> 1
        instrs[:n_ands, 2] = b >> 1
        instrs[:n_ands, 3] = np.arange(lo, n_nodes)

    # Pack into waves by capacity-constrained ASAP list scheduling: a node
    # goes into the first non-full wave after both fanins' waves.  The wave
    # width adapts to the graph's average level width (deep carry-chain
    # circuits get narrow waves), so the stream stays *dense* — total slots
    # ~ n_ands, steps ~ depth — and the scan's memory traffic is bounded by
    # useful work, not padding.  Padding slots replay the no-op (scratch-row
    # write of const0 — duplicates within a wave all store the same value).
    lv = np.asarray(aig.levels(), dtype=np.int64)
    if n_ands > 0:
        depth = max(1, int(lv.max()))
        wave_w = _next_pow2(min(WAVE_WIDTH, max(8, -(-n_ands // depth))))
        wave_of = np.full(n_nodes, -1, dtype=np.int64)
        fill: list[int] = []
        wave_id = np.zeros(n_ands, dtype=np.int64)
        col = np.zeros(n_ands, dtype=np.int64)
        for i in range(n_ands):
            node = lo + i
            w = max(wave_of[f0[node] >> 1], wave_of[f1[node] >> 1]) + 1
            while w < len(fill) and fill[w] >= wave_w:
                w += 1
            while w >= len(fill):
                fill.append(0)
            wave_of[node] = w
            wave_id[i] = w
            col[i] = fill[w]
            fill[w] += 1
        n_waves = len(fill)
    else:
        wave_w = 8
        n_waves = 0
    n_waves_pad = _next_pow2(n_waves + 1, floor=1)
    waves = np.zeros((n_waves_pad, wave_w, 4), dtype=np.int32)
    waves[:, :, 3] = n_pad - 1
    if n_ands > 0:
        waves[wave_id, col] = instrs[:n_ands]
    return AigProgram(
        instrs=instrs,
        waves=waves,
        lv=lv,
        n_nodes=n_nodes,
        n_pis=aig.n_pis,
        n_pad=n_pad,
    )


@functools.lru_cache(maxsize=None)
def _elem_words(k_max: int) -> np.ndarray:
    """Elementary truth tables of ``k_max`` vars as (k_max, words) uint32,
    LSB-first pattern order — `Aig._elementary_int` bit-packed."""
    n_pat = 1 << k_max
    words = max(1, n_pat // 32)
    out = np.zeros((k_max, words), dtype=np.uint32)
    for i in range(k_max):
        v = _elementary_int(i, k_max)
        out[i] = np.frombuffer(v.to_bytes(words * 4, "little"), dtype="<u4")
    return out


@functools.lru_cache(maxsize=None)
def _dev_elem(k_max: int):
    """`_elem_words(k_max)` already resident on the device."""
    import jax.numpy as jnp

    return jnp.asarray(_elem_words(k_max))


def words_to_int(words: np.ndarray) -> int:
    """Little-endian uint32 words -> python int (LSB-first patterns)."""
    return int.from_bytes(np.ascontiguousarray(words, dtype="<u4").tobytes(), "little")


def _tier_for(k: int) -> tuple[int, int]:
    for k_max, w in _TIERS:
        if k <= k_max:
            return k_max, w
    raise ValueError(f"eval_tts limited to {MAX_VARS} support vars, got {k}")


# ---------------------------------------------------------------------------
# jnp engine — lax.scan over wave-packed instruction streams
# ---------------------------------------------------------------------------

_JNP_MEGA = None
_JNP_SIG = None


def _make_jnp_mega():
    """A fresh jit wrapper around the mega-program evaluator (fresh =
    empty trace cache, as the analyzer's counter check requires);
    production goes through `_jnp_mega_fn`'s process-wide cache."""
    _jax_setup()
    import jax
    import jax.numpy as jnp

    def eval_mega(waves, pin_rows, elem, rootp):
        """Evaluate one mega-program (many concatenated cone programs).

        waves (L,M,4) i32 over a flat row space; pin_rows (N,) i32
        var-index-or--1; elem (K,W) u32; rootp (Q,) i32 packs each root
        query as ``row << 1 | phase``.  Returns (Q,W) u32.  Support rows
        hold elementary tables and are never written (cone membership
        excludes pinned nodes), so the step body is just
        gather-AND-scatter.
        """
        TRACE_COUNTS["aig_eval"] += 1
        vals0 = jnp.where(
            (pin_rows >= 0)[:, None],
            elem[jnp.clip(pin_rows, 0, elem.shape[0] - 1)],
            jnp.uint32(0),
        )  # (N, W)
        full = jnp.uint32(0xFFFFFFFF)

        def step(vals, ins):
            # ins (M, 4): one wave of independent instructions.
            kind, a, b, o = ins[:, 0], ins[:, 1], ins[:, 2], ins[:, 3]
            va = vals[a] ^ (full * (kind & 1).astype(jnp.uint32))[:, None]
            vb = vals[b] ^ (full * ((kind >> 1) & 1).astype(jnp.uint32))[:, None]
            return vals.at[o].set(va & vb), None

        vals, _ = jax.lax.scan(step, vals0, waves)
        phase = (full * (rootp & 1).astype(jnp.uint32))[:, None]
        return vals[rootp >> 1] ^ phase

    return jax.jit(eval_mega)


def _jnp_mega_fn():
    global _JNP_MEGA
    if _JNP_MEGA is None:
        _JNP_MEGA = _make_jnp_mega()
    return _JNP_MEGA


def _make_jnp_sig():
    """Fresh jit wrapper for the signature evaluator (see
    `_make_jnp_mega`)."""
    _jax_setup()
    import jax
    import jax.numpy as jnp

    def sig_eval(waves, vals0):
        """waves (L,M,4) i32; vals0 (N,W) u32 with PI rows pre-placed."""
        TRACE_COUNTS["aig_sig"] += 1
        full = jnp.uint32(0xFFFFFFFF)

        def step(vals, ins):
            kind, a, b, o = ins[:, 0], ins[:, 1], ins[:, 2], ins[:, 3]
            va = vals[a] ^ (full * (kind & 1).astype(jnp.uint32))[:, None]
            vb = vals[b] ^ (full * ((kind >> 1) & 1).astype(jnp.uint32))[:, None]
            return vals.at[o].set(va & vb), None

        vals, _ = jax.lax.scan(step, vals0, waves)
        return vals

    return jax.jit(sig_eval)


def _jnp_sig_fn():
    global _JNP_SIG
    if _JNP_SIG is None:
        _JNP_SIG = _make_jnp_sig()
    return _JNP_SIG


# ---------------------------------------------------------------------------
# Pallas engine — cim_logic's VMEM-scratch layout, one grid step per query
# ---------------------------------------------------------------------------

_PALLAS_EVAL = None


def _pallas_fn():
    global _PALLAS_EVAL
    if _PALLAS_EVAL is not None:
        return _PALLAS_EVAL
    _jax_setup()
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(instr_ref, pin_ref, elem_ref, rootp_ref, out_ref, scratch_ref,
               *, n_instr: int, n_roots: int):
        n_rows, n_words = scratch_ref.shape

        def init_row(i, _):
            pv = pin_ref[0, i]
            erow = pl.load(
                elem_ref, (pl.dslice(jnp.maximum(pv, 0), 1), slice(None))
            )
            row = jnp.where(pv >= 0, erow, jnp.zeros_like(erow))
            pl.store(scratch_ref, (pl.dslice(i, 1), slice(None)), row)
            return 0

        jax.lax.fori_loop(0, n_rows, init_row, 0)

        def step(i, _):
            kind = instr_ref[i, 0]
            a = instr_ref[i, 1]
            b = instr_ref[i, 2]
            o = instr_ref[i, 3]
            va = pl.load(scratch_ref, (pl.dslice(a, 1), slice(None)))
            vb = pl.load(scratch_ref, (pl.dslice(b, 1), slice(None)))
            va = jnp.where((kind & 1) == 1, ~va, va)
            vb = jnp.where(((kind >> 1) & 1) == 1, ~vb, vb)
            res = va & vb
            old = pl.load(scratch_ref, (pl.dslice(o, 1), slice(None)))
            res = jnp.where(pin_ref[0, o] >= 0, old, res)
            pl.store(scratch_ref, (pl.dslice(o, 1), slice(None)), res)
            return 0

        jax.lax.fori_loop(0, n_instr, step, 0)

        def gather(j, _):
            r = rootp_ref[0, j]
            ph = rootp_ref[0, n_roots + j]
            v = pl.load(scratch_ref, (pl.dslice(r, 1), slice(None)))
            v = jnp.where(ph == 1, ~v, v)
            pl.store(out_ref, (slice(None), pl.dslice(j * n_words, n_words)), v)
            return 0

        jax.lax.fori_loop(0, n_roots, gather, 0)

    @functools.partial(
        jax.jit, static_argnames=("n_roots", "interpret")
    )
    def eval_batch(instrs, pin, elem, rootp, n_roots: int, interpret: bool):
        TRACE_COUNTS["aig_eval_pallas"] += 1
        n_b, n_rows = pin.shape
        n_words = elem.shape[1]
        out = pl.pallas_call(
            functools.partial(
                kernel, n_instr=instrs.shape[0], n_roots=n_roots
            ),
            grid=(n_b,),
            in_specs=[
                pl.BlockSpec(instrs.shape, lambda b: (0, 0)),
                pl.BlockSpec((1, n_rows), lambda b: (b, 0)),
                pl.BlockSpec(elem.shape, lambda b: (0, 0)),
                pl.BlockSpec((1, 2 * n_roots), lambda b: (b, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_roots * n_words), lambda b: (b, 0)),
            out_shape=jax.ShapeDtypeStruct(
                (n_b, n_roots * n_words), jnp.int32
            ),
            scratch_shapes=[_vmem((n_rows, n_words), jnp.int32)],
            interpret=interpret,
        )(instrs, pin, elem, rootp)
        return out

    _PALLAS_EVAL = eval_batch
    return _PALLAS_EVAL


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _resolve_engine(engine: str) -> str:
    if engine == "auto":
        import jax

        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if engine not in ("jnp", "pallas"):
        raise ValueError(f"unknown aig_sim engine {engine!r}")
    return engine


# ---------------------------------------------------------------------------
# Host API
# ---------------------------------------------------------------------------


def _cone_members(
    aig: Aig,
    items: Sequence[tuple[Sequence[int], Sequence[int]]],
    idxs: Sequence[int],
) -> np.ndarray:
    """(len(idxs), n_nodes) bool: AND nodes in each query's pinned cone(s).

    Descending-index scan (fanins always have smaller indices): a node
    active in a query (visited, not a leaf) marks both fanin nodes.
    Multi-root queries seed every root's node, so one row covers the
    union cone (resub's (n, m) pairs).
    """
    n = aig.n_nodes
    n_pis = aig.n_pis
    f0 = np.asarray(aig._f0, dtype=np.int64)
    f1 = np.asarray(aig._f1, dtype=np.int64)
    # (n_nodes, batch) layout: the scan touches whole node rows, which
    # are contiguous this way round (the (B, n) layout strides by n per
    # element and is several times slower).
    vis = np.zeros((n, len(idxs)), dtype=bool)
    leaf = np.zeros((n, len(idxs)), dtype=bool)
    hi = n_pis
    for row, i in enumerate(idxs):
        roots, support = items[i]
        leaf[list(support), row] = True
        for rl in roots:
            r = rl >> 1
            vis[r, row] = True
            if r > hi:
                hi = r
    for node in range(hi, n_pis, -1):
        act = vis[node] & ~leaf[node]
        if not act.any():
            continue
        vis[f0[node] >> 1][act] = True
        vis[f1[node] >> 1][act] = True
    members = vis & ~leaf
    members[: n_pis + 1] = False
    return np.ascontiguousarray(members.T)


def _eval_mega_tier(
    aig: Aig,
    prog: AigProgram,
    items: Sequence[tuple[Sequence[int], Sequence[int]]],
    idxs: list[int],
    w: int,
    mem: np.ndarray,
    results: list,
) -> None:
    """Run one word tier's queries as mega-programs on the jnp engine.

    Each chunk concatenates the per-query cone programs into one flat
    row space (row 0 = const0, then per query: k support rows pinned to
    elementary tables followed by its cone rows in topo order), so
    device work is proportional to the *useful* cone work — not to
    batch × whole-graph as a lock-step layout would be.  Instructions
    are wave-packed by global AIG level (fanins always have strictly
    smaller levels, and cross-query instructions are independent), which
    keeps waves dense: scan length ~ total instrs / wave width.
    """
    import jax.numpy as jnp

    k_max = next(km for km, tw in _TIERS if tw == w)
    dev_elem = _dev_elem(k_max)
    f0 = np.asarray(aig._f0, dtype=np.int64)  # repro: host-boundary
    f1 = np.asarray(aig._f1, dtype=np.int64)  # repro: host-boundary
    sizes = mem.sum(axis=1).astype(np.int64)
    budget = _MEGA_BUDGET[w]
    wave_m = _MEGA_WAVE[w]
    fn = _jnp_mega_fn()

    chunks: list[list[int]] = []
    cur: list[int] = []
    acc = 0
    for pos in range(len(idxs)):
        s = int(sizes[pos])
        if cur and acc + s > budget:
            chunks.append(cur)
            cur, acc = [], 0
        cur.append(pos)
        acc += s
    if cur:
        chunks.append(cur)

    import itertools

    for chunk in chunks:
        if len(chunk) == len(idxs):
            cm, counts = mem, sizes
        else:
            sel = np.asarray(chunk, dtype=np.int64)  # repro: host-boundary
            cm, counts = mem[sel], sizes[sel]
        it = [items[idxs[p]] for p in chunk]
        k_b = np.array([len(s) for _, s in it], dtype=np.int64)  # repro: host-boundary
        r_b = np.array([len(r) for r, _ in it], dtype=np.int64)  # repro: host-boundary
        row_base = 1 + np.concatenate(([0], np.cumsum(k_b + counts)[:-1]))
        n_rows = int(1 + (k_b + counts).sum())
        n_rows_pad = _next_pow2(n_rows + 1, floor=10)
        # Support rows: pinned to elementary tables via the pin map.
        tot_k = int(k_b.sum())
        sup_nodes = np.fromiter(
            itertools.chain.from_iterable(s for _, s in it),
            dtype=np.int64,
            count=tot_k,
        )
        item_of_sup = np.repeat(np.arange(len(it)), k_b)
        koff = np.concatenate(([0], np.cumsum(k_b)[:-1]))
        var_idx = np.arange(tot_k) - np.repeat(koff, k_b)
        sup_rows = row_base[item_of_sup] + var_idx
        pin_rows = np.full(n_rows_pad, -1, dtype=np.int32)
        pin_rows[sup_rows] = var_idx
        # node -> row per query; unmapped nodes fall through to row 0
        # (const0) — the python path would raise on such a read, and no
        # caller produces one (cones are closed over their supports).
        rowmap = np.zeros((len(it), aig.n_nodes), dtype=np.int32)
        rowmap[item_of_sup, sup_nodes] = sup_rows
        b_idx, node_idx = np.nonzero(cm)
        n_waves = 0
        if len(b_idx):
            starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
            local = np.arange(len(b_idx)) - np.repeat(starts, counts)
            cone_rows = row_base[b_idx] + k_b[b_idx] + local
            rowmap[b_idx, node_idx] = cone_rows
            f0n = f0[node_idx]
            f1n = f1[node_idx]
            kind = (f0n & 1) | ((f1n & 1) << 1)
            a_row = rowmap[b_idx, f0n >> 1]
            b_row = rowmap[b_idx, f1n >> 1]
            instr = np.stack([kind, a_row, b_row, cone_rows], axis=1).astype(
                np.int32
            )
            # Wave-pack by global level, chopping each level into
            # wave_m-wide groups (same-level instrs never depend).
            lvn = prog.lv[node_idx]
            order = np.argsort(lvn, kind="stable")
            slv = lvn[order]
            lstarts = np.searchsorted(slv, slv, side="left")
            pos_in_lv = np.arange(len(order)) - lstarts
            # (level, sub-group) keys are non-decreasing in `order`, so
            # consecutive-difference cumsum numbers the waves directly.
            key = slv * (len(order) + 1) + pos_in_lv // wave_m
            wid = np.concatenate(([0], np.cumsum(np.diff(key) > 0)))
            n_waves = int(wid[-1]) + 1
        n_waves_pad = _next_pow2(n_waves + 1, floor=2)
        waves = np.zeros((n_waves_pad, wave_m, 4), dtype=np.int32)
        waves[:, :, 3] = n_rows_pad - 1  # no-op padding: scratch row <- 0
        if len(b_idx):
            waves[wid, pos_in_lv % wave_m] = instr[order]
        # Root queries: one output row per root literal.
        q_item = np.repeat(np.arange(len(it)), r_b)
        root_lits = np.fromiter(
            itertools.chain.from_iterable(r for r, _ in it),
            dtype=np.int64,
            count=int(r_b.sum()),
        )
        root_rows = rowmap[q_item, root_lits >> 1]
        n_q = len(root_lits)
        n_q_pad = _next_pow2(n_q, floor=6)
        rootp = np.zeros(n_q_pad, dtype=np.int32)
        rootp[:n_q] = (root_rows.astype(np.int64) << 1) | (root_lits & 1)
        out = np.asarray(  # repro: host-boundary
            fn(
                jnp.asarray(waves),
                jnp.asarray(pin_rows),
                dev_elem,
                jnp.asarray(rootp),
            )
        )
        qoff = np.concatenate(([0], np.cumsum(r_b)))
        if w == 1:
            flat = out[:n_q, 0].tolist()
            for bi, p in enumerate(chunk):
                idx = idxs[p]
                roots, support = items[idx]
                mask = (1 << (1 << len(support))) - 1
                base = int(qoff[bi])
                results[idx] = tuple(
                    flat[base + ri] & mask for ri in range(len(roots))
                )
        else:
            buf = np.ascontiguousarray(out[:n_q]).tobytes()
            nb = w * 4
            for bi, p in enumerate(chunk):
                idx = idxs[p]
                roots, support = items[idx]
                mask = (1 << (1 << len(support))) - 1
                base = int(qoff[bi])
                results[idx] = tuple(
                    int.from_bytes(
                        buf[(base + ri) * nb : (base + ri + 1) * nb], "little"
                    )
                    & mask
                    for ri in range(len(roots))
                )


def eval_tts(
    aig: Aig,
    items: Sequence[tuple[Sequence[int], Sequence[int]]],
    engine: str = "auto",
    program: AigProgram | None = None,
    members: np.ndarray | None = None,
) -> list[tuple[int, ...]]:
    """Batched exact truth tables: ``items[i] = (root_lits, support)``.

    Returns, per item, one python-int truth table per root literal —
    bit-identical to ``aig.truth_table(root_lit, support)`` (same
    LSB-first pattern order, same pinned-support semantics).

    On the jnp engine, queries with <= `DEVICE_MAX_VARS` support vars
    are bucketed by word tier and evaluated as chunked *mega-programs*
    (see `_eval_mega_tier`); wider queries take the host bigint path,
    where CPython's limb loops already run at memory speed.  ``members``
    may supply precomputed cone membership rows aligned with ``items``
    (callers that already ran an MFFC sweep have them); otherwise
    membership is derived here with the same descending scan.

    The Pallas engine evaluates every query against the whole graph
    (one grid step per query, VMEM scratch = the packed node array).
    """
    if not items:
        return []
    engine = _resolve_engine(engine)
    prog = program if program is not None else compile_aig(aig)
    results: list[tuple[int, ...] | None] = [None] * len(items)
    if engine == "pallas":
        _eval_pallas(aig, prog, items, results)
        return results  # type: ignore[return-value]

    tiers: dict[int, list[int]] = {}
    for idx, (roots, support) in enumerate(items):
        k = len(support)
        if k > DEVICE_MAX_VARS:
            sup = list(support)
            results[idx] = tuple(aig.truth_table(rl, sup) for rl in roots)
        else:
            _, w = _tier_for(k)
            tiers.setdefault(w, []).append(idx)
    for w, idxs in tiers.items():
        if members is not None:
            mem = members[np.asarray(idxs, dtype=np.int64)]
        else:
            mem = _cone_members(aig, items, idxs)
        _eval_mega_tier(aig, prog, items, idxs, w, mem, results)
    return results  # type: ignore[return-value]


def _eval_pallas(
    aig: Aig,
    prog: AigProgram,
    items: Sequence[tuple[Sequence[int], Sequence[int]]],
    results: list,
) -> None:
    import jax.numpy as jnp

    groups: dict[tuple[int, int], list[int]] = {}
    for idx, (roots, support) in enumerate(items):
        _, w = _tier_for(len(support))
        groups.setdefault((w, len(roots)), []).append(idx)

    for (w, n_roots), idxs in groups.items():
        k_max = next(km for km, tw in _TIERS if tw == w)
        elem = _elem_words(k_max)
        chunk = _CHUNK[w]
        for lo in range(0, len(idxs), chunk):
            batch = idxs[lo : lo + chunk]
            n_b = len(batch)
            pin = np.full((chunk, prog.n_pad), -1, dtype=np.int32)
            # Scatter all supports at once: (item row, support node) -> var.
            sup_nodes = np.concatenate(
                [np.asarray(items[i][1], dtype=np.int64) for i in batch]  # repro: host-boundary
            )
            sup_lens = np.array([len(items[i][1]) for i in batch])  # repro: host-boundary
            item_rows = np.repeat(np.arange(n_b), sup_lens)
            var_idx = np.concatenate([np.arange(l) for l in sup_lens])
            pin[item_rows, sup_nodes] = var_idx
            root_lits_a = np.array([items[i][0] for i in batch], dtype=np.int64)  # repro: host-boundary
            roots_a = np.zeros((chunk, n_roots), dtype=np.int32)
            roots_a[:n_b] = root_lits_a >> 1
            phase_a = np.zeros((chunk, n_roots), dtype=np.int32)
            phase_a[:n_b] = root_lits_a & 1
            rootp = np.concatenate([roots_a, phase_a], axis=1)
            fn = _pallas_fn()
            out = fn(
                jnp.asarray(prog.instrs),
                jnp.asarray(pin),
                jnp.asarray(elem.view(np.int32)),
                jnp.asarray(rootp),
                n_roots=n_roots,
                interpret=_pallas_interpret(),
            )
            out = np.asarray(out).view(np.uint32)  # repro: host-boundary
            out = out.reshape(chunk, n_roots, w)
            for bi, idx in enumerate(batch):
                root_lits, support = items[idx]
                mask = (1 << (1 << len(support))) - 1
                results[idx] = tuple(
                    words_to_int(out[bi, ri]) & mask
                    for ri in range(len(root_lits))
                )


def _pallas_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"


def eval_tt(
    aig: Aig,
    root_lit: int,
    support: Sequence[int],
    engine: str = "auto",
    program: AigProgram | None = None,
) -> int:
    """Single-query convenience wrapper around `eval_tts`."""
    return eval_tts(aig, [((root_lit,), list(support))], engine, program)[0][0]


def node_signatures(
    aig: Aig,
    patterns: np.ndarray,
    engine: str = "auto",
    program: AigProgram | None = None,
) -> np.ndarray:
    """Per-node random-simulation signatures on the device.

    ``patterns``: (n_pis, n_words) uint64.  Returns (n_nodes, n_words)
    uint64, bit-identical to ``transforms._node_signatures`` (the uint64
    words are simulated as pairs of uint32 lanes).
    """
    _resolve_engine(engine)  # validate / pick (sig path is jnp on CPU+TPU)
    prog = program if program is not None else compile_aig(aig)
    import jax.numpy as jnp

    patterns = np.asarray(patterns, dtype=np.uint64)  # repro: host-boundary
    n_words = patterns.shape[1]
    vals0 = np.zeros((prog.n_pad, 2 * n_words), dtype=np.uint32)
    vals0[1 : 1 + prog.n_pis] = patterns.view("<u4")
    sig_fn = _jnp_sig_fn()
    out = np.asarray(sig_fn(jnp.asarray(prog.waves), jnp.asarray(vals0)))  # repro: host-boundary
    return np.ascontiguousarray(out[: prog.n_nodes]).view("<u8")


# ---------------------------------------------------------------------------
# Kernel registration (static analyzer)
# ---------------------------------------------------------------------------
# The jnp engines register representative-shape builders so
# `repro.analysis.jaxpr_lint` can abstract-trace them; the Pallas engine
# registers its counter only (tracing a pallas_call needs the TPU
# lowering machinery, and the AST layer already enforces its counter
# discipline statically).  ``x64=False``: these kernels are pure uint32
# bit algebra — there are no floats to drift.


def _ex_aig_eval():
    # plain numpy operands: jit traces them identically, and the builder
    # then holds no device arrays at all
    waves = np.zeros((2, 4, 4), dtype=np.int32)
    waves[:, :, 3] = 7  # padding instructions write the scratch row
    pin_rows = np.array([-1, 0, 1, -1, -1, -1, -1, -1], dtype=np.int32)
    elem = np.ones((2, 1), dtype=np.uint32)
    rootp = np.array([6 << 1, (5 << 1) | 1], dtype=np.int32)
    return _registry.KernelExample(
        fn=_make_jnp_mega(),
        args=(waves, pin_rows, elem, rootp),
    )


def _ex_aig_sig():
    waves = np.zeros((2, 4, 4), dtype=np.int32)
    waves[:, :, 3] = 7
    vals0 = np.zeros((8, 2), dtype=np.uint32)
    return _registry.KernelExample(
        fn=_make_jnp_sig(),
        args=(waves, vals0),
    )


_registry.register_kernel("aig_eval", __name__, _ex_aig_eval, x64=False)
_registry.register_kernel("aig_sig", __name__, _ex_aig_sig, x64=False)
_registry.register_counter("aig_eval_pallas", __name__)
