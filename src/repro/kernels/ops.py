"""Public ops for the CiM logic engine.

``compile_netlist`` lowers a GateNetlist to the kernel's instruction
stream, performing the paper's operand-placement step (§III-D): signals
are assigned SRAM rows, and rows are recycled once their last consumer has
executed (linear-scan liveness) — the software analogue of "operands can
be placed flexibly ... optimizing the use of available SRAM resources".

``cim_evaluate`` is the jit'd user-facing entry point; it packs test
vectors, pads shapes to TPU tiling (8 sublanes x 128 lanes), invokes the
Pallas kernel (interpret=True on CPU), and unpacks outputs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.aig import Aig, GateNetlist
from . import ref
from .cim_logic import LANE, SUBLANE, cim_pallas_call, _round_up


@dataclasses.dataclass
class CompiledCim:
    """Instruction stream + row map for one netlist."""

    instrs: np.ndarray  # (n_gates + n_pos, 4) int32; last n_pos are PO gathers
    n_rows: int  # register-file height (before sublane padding)
    n_gates: int
    n_pos: int
    pi_rows: np.ndarray  # (n_pis,) row of each primary input
    po_rows: np.ndarray  # (n_pos,) row holding each primary output
    n_signals: int  # before row reuse (for reporting)

    @property
    def n_rows_padded(self) -> int:
        return _round_up(max(self.n_rows, SUBLANE), SUBLANE)

    @property
    def reuse_factor(self) -> float:
        return self.n_signals / max(1, self.n_rows)


def compile_netlist(net: GateNetlist, reuse_rows: bool = True) -> CompiledCim:
    """Lower a NAND/NOR/NOT netlist to kernel instructions.

    With ``reuse_rows`` the register file height is the maximum number of
    simultaneously-live signals instead of the total signal count — this is
    what lets multi-thousand-gate circuits fit the VMEM "SRAM array".
    """
    kind_code = {"nand": 0, "nor": 1, "inv": 2}

    # Liveness: last use position of each signal (gate index, or +inf for POs).
    last_use = np.full(net.n_signals, -1, dtype=np.int64)
    for gi, g in enumerate(net.gates):
        last_use[g.a] = gi
        last_use[g.b] = gi
    for s in net.po_signals:
        last_use[s] = len(net.gates) + 1  # keep alive to the end
    for s in net.pi_signals:
        last_use[s] = max(last_use[s], 0)

    row_of: dict[int, int] = {}
    free_rows: list[int] = []
    next_row = 0

    def alloc(sig: int) -> int:
        nonlocal next_row
        if sig in row_of:
            return row_of[sig]
        if reuse_rows and free_rows:
            r = free_rows.pop()
        else:
            r = next_row
            next_row += 1
        row_of[sig] = r
        return r

    # PIs first so they occupy the leading rows contiguously — the kernel
    # writes pi_planes straight into the scratch.
    pi_rows = np.array([alloc(s) for s in net.pi_signals], dtype=np.int32)
    # constants: const0 row / const1 row (signals 0, 1 per GateNetlist)
    alloc(0)
    alloc(1)

    instrs = np.zeros((len(net.gates) + len(net.po_signals), 4), dtype=np.int32)
    for gi, g in enumerate(net.gates):
        ra = row_of[g.a]
        rb = row_of[g.b]
        # free rows whose signals die at this gate (before allocating out,
        # but an operand row must not be clobbered by this gate's own out —
        # dslice reads happen before the store, so in-place is actually
        # safe; still, keep SSA-ish: free only rows dead *strictly* before).
        ro = alloc(g.out)
        instrs[gi] = (kind_code[g.kind], ra, rb, ro)
        for s in (g.a, g.b):
            if last_use[s] == gi and s in row_of:
                free_rows.append(row_of.pop(s))

    po_rows = np.array([row_of[s] for s in net.po_signals], dtype=np.int32)
    for j, s in enumerate(net.po_signals):
        instrs[len(net.gates) + j] = (3, 0, 0, row_of[s])

    return CompiledCim(
        instrs=instrs,
        n_rows=next_row,
        n_gates=len(net.gates),
        n_pos=len(net.po_signals),
        pi_rows=pi_rows,
        po_rows=po_rows,
        n_signals=net.n_signals,
    )


def place_pi_planes(cc: CompiledCim, pi_words: np.ndarray, n_words: int) -> np.ndarray:
    """Scatter packed PI planes (n_pis, n_words) into the padded row layout,
    including the constant rows."""
    planes = np.zeros((cc.n_rows_padded, n_words), dtype=np.int32)
    planes[cc.pi_rows] = pi_words
    # const1 signal is id 1; find its row from the instruction stream usage:
    # GateNetlist guarantees signal 1 == const1; compile allocated it.
    return planes


def cim_evaluate(
    net_or_cc: GateNetlist | CompiledCim,
    vectors: np.ndarray,  # (n_pis, n_vectors) bits  OR packed int32 words
    packed: bool = False,
    block_words: int = 512,
    interpret: bool = True,
) -> np.ndarray:
    """Evaluate a netlist on test vectors via the Pallas CiM engine.

    Returns (n_pos, n_vectors) bits (or packed words if ``packed``).
    """
    cc = net_or_cc if isinstance(net_or_cc, CompiledCim) else compile_netlist(net_or_cc)
    if packed:
        pi_words = np.asarray(vectors, dtype=np.int32)
        n_vec = pi_words.shape[1] * 32
    else:
        n_vec = vectors.shape[1]
        pi_words = ref.pack_vectors(vectors)

    n_words = pi_words.shape[1]
    # pad lanes to a legal block
    bw = min(block_words, _round_up(n_words, LANE))
    n_words_p = _round_up(n_words, bw)
    if n_words_p != n_words:
        pi_words = np.pad(pi_words, ((0, 0), (0, n_words_p - n_words)))

    # const1 row must read all-ones
    planes = place_pi_planes(cc, pi_words, n_words_p)
    const1_row = _const1_row(cc)
    if const1_row is not None:
        planes[const1_row] = -1  # all ones

    out = cim_pallas_call(
        cc.instrs,
        planes,
        n_rows=cc.n_rows,
        n_gates=cc.n_gates,
        n_pos=cc.n_pos,
        block_words=bw,
        interpret=interpret,
    )
    out = np.asarray(out)[: cc.n_pos, :n_words]
    if packed:
        return out
    return ref.unpack_vectors(out, n_vec)


def _const1_row(cc: CompiledCim) -> int | None:
    # const1 is signal id 1; its row was allocated right after the PIs.
    # pi rows occupy [0, n_pis); const0 and const1 take the next two rows.
    return len(cc.pi_rows) + 1 if cc.n_rows > len(cc.pi_rows) + 1 else None


def cim_reference_evaluate(
    net: GateNetlist, vectors: np.ndarray, block_words: int = 512
) -> np.ndarray:
    """ref.py-backed oracle with the same packing path (for kernel tests)."""
    import jax.numpy as jnp

    cc = compile_netlist(net, reuse_rows=False)
    pi_words = ref.pack_vectors(vectors)
    planes = place_pi_planes(cc, pi_words, pi_words.shape[1])
    const1_row = _const1_row(cc)
    if const1_row is not None:
        planes[const1_row] = -1
    out = ref.cim_reference(
        jnp.asarray(cc.instrs[: cc.n_gates]),
        jnp.asarray(planes),
        jnp.asarray(cc.po_rows),
        n_rows=cc.n_rows_padded,
    )
    return ref.unpack_vectors(np.asarray(out), vectors.shape[1])
