"""internvl2-2b — VLM: InternViT frontend STUB (precomputed patch
embeddings) + InternLM2 backbone. [arXiv:2404.16821; hf]
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    pattern=("attn",),
    n_patches=256,
    tie_embeddings=True,
)
