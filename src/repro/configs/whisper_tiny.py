"""whisper-tiny — enc-dec audio; conv frontend is a STUB (input_specs
provides precomputed 1500-frame embeddings). [arXiv:2212.04356; unverified]
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    pattern=("xattn",),
    is_encoder_decoder=True,
    n_enc_layers=4,
    enc_seq=1500,
    tie_embeddings=True,
)
