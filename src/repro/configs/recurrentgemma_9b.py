"""recurrentgemma-9b — RG-LRU + local attention, 2 recurrent : 1 attn.
[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000, window 2048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
)
