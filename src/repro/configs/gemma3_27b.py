"""gemma3-27b — 5:1 local:global interleave, 128k context, qk-norm.
[hf:google/gemma-3-1b-pt; unverified]  62L d_model=5376 32H (kv=16)
d_ff=21504 vocab=262144, sliding window 1024."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
