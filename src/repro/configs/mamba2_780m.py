"""mamba2-780m — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  48L d_model=1536 d_ff=0 vocab=50280 state=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=24,        # unused (attn-free); kept for head_dim bookkeeping
    n_kv_heads=24,
    d_ff=0,
    vocab_size=50_280,
    pattern=("ssm",),
    ssm_state=128,
    d_inner=3072,      # 2 * d_model
    ssm_head_dim=64,   # -> 48 SSD heads
    conv_width=4,
    ssm_chunk=64,
    tie_embeddings=True,
)
