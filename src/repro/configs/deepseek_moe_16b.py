"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6,
first layer dense. [arXiv:2401.06066; hf]
28L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=102400."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10_944,          # dense FFN width of the first (non-MoE) layer
    vocab_size=102_400,
    pattern=("attn",),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    tie_embeddings=True,
)
