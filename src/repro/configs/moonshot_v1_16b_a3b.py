"""moonshot-v1-16b-a3b (kimi/moonlight) — MoE 64e top-6, 2 shared.
[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=11_264,          # dense FFN width of the first (non-MoE) layer
    vocab_size=163_840,
    pattern=("attn",),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    tie_embeddings=True,
)
