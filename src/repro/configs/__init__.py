"""Architecture config registry.

Each assigned architecture has its own module defining ``CONFIG`` (the
exact assignment card) and the registry exposes reduced smoke variants for
CPU tests.  ``--arch <id>`` in the launchers resolves through here.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = (
    "mamba2-780m",
    "minicpm-2b",
    "qwen1.5-4b",
    "gemma3-27b",
    "deepseek-coder-33b",
    "whisper-tiny",
    "recurrentgemma-9b",
    "internvl2-2b",
    "deepseek-moe-16b",
    "moonshot-v1-16b-a3b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


# Shape cells skipped per DESIGN.md §4 (sub-quadratic requirement for
# long_500k; whisper's decoder length cap).
SKIP_CELLS: dict[tuple[str, str], str] = {
    ("minicpm-2b", "long_500k"): "pure full attention — no sub-quadratic path",
    ("qwen1.5-4b", "long_500k"): "pure full attention — no sub-quadratic path",
    ("deepseek-coder-33b", "long_500k"): "pure full attention — no sub-quadratic path",
    ("internvl2-2b", "long_500k"): "pure full attention — no sub-quadratic path",
    ("deepseek-moe-16b", "long_500k"): "pure full attention — no sub-quadratic path",
    ("moonshot-v1-16b-a3b", "long_500k"): "pure full attention — no sub-quadratic path",
    ("whisper-tiny", "long_500k"): "enc-dec decoder max target length << 500k",
}


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for a in ARCH_IDS:
        for s in SHAPES:
            cells.append((a, s))
    return cells


def runnable_cells() -> list[tuple[str, str]]:
    return [c for c in all_cells() if c not in SKIP_CELLS]


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(arch)
    pat = cfg.pattern
    n_layers = len(pat) + max(1, cfg.first_dense_layers) if cfg.is_moe else max(
        2, len(pat)
    )
    kv = 1 if cfg.n_kv_heads == 1 else (4 if cfg.n_kv_heads == cfg.n_heads else 2)
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=16 if cfg.window else 0,
        n_experts=8 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=2 if cfg.top_k else 0,
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        # dropless capacity so decode == forward exactly in smoke tests
        # (production uses the paper-standard 1.25 with overflow dropping)
        capacity_factor=8.0 if cfg.n_experts else 1.25,
        ssm_state=16 if cfg.ssm_state else 0,
        d_inner=128 if cfg.family == "ssm" else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        lru_width=64 if cfg.lru_width else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        n_patches=8 if cfg.n_patches else 0,
        vocab_pad_multiple=1,
    )
