"""Training step factory: loss + grad + clip + AdamW, with microbatch
gradient accumulation (compute/communication overlap: per-microbatch grads
feed the accumulation while XLA schedules the reduce of earlier slices) and
optional gradient compression.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_update


def make_train_step(
    model: Model,
    schedule: Callable,
    opt_cfg: AdamWConfig,
    grad_accum: int = 1,
    cast_bf16: bool = False,
    grad_shardings=None,
):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    ``batch`` arrays have leading dim = global batch; with grad_accum > 1
    they are split into microbatches along axis 0 and grads accumulated in
    fp32 via lax.scan (bounded live memory; backward of microbatch i
    overlaps the accumulation collective of microbatch i-1 under XLA's
    async scheduling).

    Perf levers (§Perf iterations):
      * ``cast_bf16`` — cast the fp32 master params to bf16 ONCE per step
        before the layer stack, so every FSDP weight all-gather moves half
        the bytes (grads still flow to the fp32 masters via the cast's
        transpose).
      * ``grad_shardings`` — constrain gradients to the parameter sharding
        right after autodiff, which lets the SPMD partitioner lower the DP
        reduction as reduce-scatter(+local update) instead of a full
        all-reduce of the unsharded gradient.
    """

    def loss_fn(params, batch):
        if cast_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
                params,
            )
        return model.loss_fn(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
                batch,
            )

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                (loss, aux), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / grad_accum, g_acc, g
                )
                return (g_acc, loss_acc + loss / grad_accum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, 0.0), micro)
            aux = {}
        else:
            (loss, aux), grads = grad_fn(params, batch)

        if grad_shardings is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_shardings,
            )

        lr = schedule(opt_state["step"])
        new_params, new_state = adamw_update(grads, opt_state, params, lr, opt_cfg)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        metrics = dict(loss=loss, lr=lr, grad_norm=gnorm, step=new_state["step"])
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, aux = model.loss_fn(params, batch)
        return dict(loss=loss)

    return eval_step
