"""Deterministic, shardable token pipeline.

Two sources:
  * ``SyntheticSource`` — seeded token generation (Zipf-ish marginals so the
    loss curve is non-trivial); fully deterministic in (seed, step, host).
  * ``MemmapSource`` — flat binary token file (np.memmap), block-sharded by
    host: host h of H reads blocks [h::H] — restart-safe and elastic (a
    re-scale to H' hosts re-partitions deterministically from the step
    counter alone, no iterator state to checkpoint).

Straggler/fault posture: every batch is a pure function of (step, host
count, host id), so a restarted or re-assigned host reproduces exactly the
batch the failed host would have produced — no data-loss bookkeeping.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticSource:
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch(self, step: int, host: int, n_hosts: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host, n_hosts])
        )
        # Zipf-distributed ids clipped to vocab (cheap, heavy-tailed)
        z = rng.zipf(self.zipf_a, size=(batch, seq + 1)).astype(np.int64)
        return (z % self.vocab_size).astype(np.int32)


@dataclasses.dataclass
class MemmapSource:
    path: str
    vocab_size: int

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")

    def batch(self, step: int, host: int, n_hosts: int, batch: int, seq: int) -> np.ndarray:
        n_tok = seq + 1
        total = self._data.shape[0] // n_tok
        out = np.empty((batch, n_tok), np.int32)
        for i in range(batch):
            gidx = (step * n_hosts * batch + host * batch + i) % total
            out[i] = self._data[gidx * n_tok : (gidx + 1) * n_tok]
        return np.clip(out, 0, self.vocab_size - 1)


@dataclasses.dataclass
class DataConfig:
    batch_per_host: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    path: str | None = None


class Pipeline:
    """Yields {tokens, labels, mask} host-local batches."""

    def __init__(self, cfg: DataConfig, host: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host = host
        self.n_hosts = n_hosts
        self.source = (
            MemmapSource(cfg.path, cfg.vocab_size)
            if cfg.path
            else SyntheticSource(cfg.vocab_size, cfg.seed)
        )

    def get_batch(self, step: int) -> dict:
        c = self.cfg
        raw = self.source.batch(step, self.host, self.n_hosts, c.batch_per_host, c.seq_len)
        return dict(
            tokens=raw[:, :-1],
            labels=raw[:, 1:],
            mask=np.ones((c.batch_per_host, c.seq_len), np.float32),
        )
