"""Recurrent blocks: Mamba-2 (SSD, state-space duality) and RG-LRU (Griffin
/ RecurrentGemma).  Train paths use chunked-parallel forms (SSD chunk
algorithm; associative scan for RG-LRU); decode paths are O(1) recurrent
state updates — this is what makes the ``long_500k`` cells feasible.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import ParamSpec, act_fn, rms_norm


# ---------------------------------------------------------------------------
# Mamba-2 / SSD
# ---------------------------------------------------------------------------


def mamba2_specs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.d_inner or 2 * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    g = 1  # single B/C group (mamba2 default ngroups=1)
    d_in = 2 * di + 2 * g * n + nh
    return dict(
        in_proj=ParamSpec((d, d_in), ("embed", "ssm_inner")),
        conv_w=ParamSpec((cfg.conv_width, di + 2 * g * n), ("conv", "ssm_inner")),
        conv_b=ParamSpec((di + 2 * g * n,), ("ssm_inner",), init="zeros"),
        a_log=ParamSpec((nh,), ("ssm_heads",), init="ones"),
        dt_bias=ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        d_skip=ParamSpec((nh,), ("ssm_heads",), init="ones"),
        norm=ParamSpec((di,), ("ssm_inner",), init="zeros"),
        out_proj=ParamSpec((di, d), ("ssm_inner", "embed")),
    )


def _ssd_chunked(x, dt, a, b, c, chunk: int):
    """SSD chunked scan (Mamba-2, arXiv:2405.21060 §6).

    x: (B,S,H,P)  dt: (B,S,H)  a: (H,) negative decay rates
    b, c: (B,S,N)  (single group, broadcast over heads)
    Returns y: (B,S,H,P) and final state (B,H,P,N).

    Sequential lax.scan over chunks — one chunk's quadratic intra part
    ((B,Q,T,H) transient) lives at a time, so peak memory is O(B*Q^2*H)
    instead of O(B*S*Q*H).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    # chunk-major layout for scan: (nc, B, Q, ...)
    xc = jnp.moveaxis(x.reshape(bsz, nc, chunk, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(bsz, nc, chunk, h), 1, 0)
    bc = jnp.moveaxis(b.reshape(bsz, nc, chunk, n), 1, 0)
    cc = jnp.moveaxis(c.reshape(bsz, nc, chunk, n), 1, 0)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(state, inp):
        xq, dtq, bq, cq = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        da = dtq * a[None, None, :]  # (B,Q,H), negative
        cum = jnp.cumsum(da, axis=1)
        # intra-chunk: L[q,t] = exp(cum_q - cum_t) for q >= t.  Mask BEFORE
        # the exp: where(tri, exp(seg), 0) overflows to inf on the masked
        # upper triangle and its backward is inf*0 = NaN (the where-grad
        # trap); exp(-1e30) = 0 has a clean zero gradient.
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,T,H)
        l_mat = jnp.exp(jnp.where(tri[None, :, :, None], seg, -1e30))
        scores = jnp.einsum("bqn,btn->bqt", cq, bq)
        xdt = xq * dtq[..., None]  # (B,T,H,P)
        y = jnp.einsum("bqt,bqth,bthp->bqhp", scores, l_mat, xdt)
        # carried-in state contribution
        y = y + jnp.einsum("bqn,bqh,bhpn->bqhp", cq, jnp.exp(cum), state)
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
        s_new = jnp.einsum("btn,bth,bthp->bhpn", bq, decay_to_end * dtq, xq)
        state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + s_new
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final_state, ys = jax.lax.scan(body, init, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final_state


def mamba2_forward(p, x, cfg, chunk: int | None = None):
    """Training/prefill forward.  Returns (out, (conv_state, ssm_state))."""
    bsz, s, d = x.shape
    di = cfg.d_inner or 2 * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    # causal depthwise conv over (x, B, C)
    w = p["conv_w"].astype(x.dtype)  # (W, di+2n)
    pad = cfg.conv_width - 1
    xbc_pad = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + s, :] * w[i][None, None, :] for i in range(cfg.conv_width)
    ) + p["conv_b"].astype(x.dtype)
    conv = jax.nn.silu(conv)
    xs, b_, c_ = jnp.split(conv, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative
    xh = xs.reshape(bsz, s, nh, hd).astype(jnp.float32)

    y, state = _ssd_chunked(xh, dt, a, b_.astype(jnp.float32), c_.astype(jnp.float32),
                            chunk=min(chunk or cfg.ssm_chunk, s))
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    conv_state = xbc_pad[:, -pad:, :] if pad else jnp.zeros((bsz, 0, xbc.shape[-1]), x.dtype)
    return out, (conv_state, state.astype(jnp.float32))


def mamba2_decode(p, x, cfg, state):
    """Single-token decode.  state = (conv_state (B,W-1,di+2n), ssm (B,H,P,N))."""
    bsz, one, d = x.shape
    di = cfg.d_inner or 2 * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    conv_state, ssm_state = state

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    w = p["conv_w"].astype(x.dtype)
    hist = jnp.concatenate([conv_state, xbc], axis=1)  # (B, W, di+2n)
    conv = jnp.einsum("bwc,wc->bc", hist, w)[:, None, :] + p["conv_b"].astype(x.dtype)
    conv = jax.nn.silu(conv)
    xs, b_, c_ = jnp.split(conv, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])  # (B,1,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(bsz, nh, hd).astype(jnp.float32)

    decay = jnp.exp(dt[:, 0, :] * a[None, :])  # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhpn", b_[:, 0].astype(jnp.float32), dt[:, 0], xh)
    ssm_new = ssm_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c_[:, 0].astype(jnp.float32), ssm_new)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_conv = hist[:, 1:, :]
    return out, (new_conv, ssm_new)


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_specs(cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    return dict(
        in_x=ParamSpec((d, w), ("embed", "lru")),
        in_gate=ParamSpec((d, w), ("embed", "lru")),
        conv_w=ParamSpec((cfg.conv_width, w), ("conv", "lru")),
        conv_b=ParamSpec((w,), ("lru",), init="zeros"),
        wa=ParamSpec((w, w), ("lru", None)),
        ba=ParamSpec((w,), (None,), init="zeros"),
        wx=ParamSpec((w, w), ("lru", None)),
        bx=ParamSpec((w,), (None,), init="zeros"),
        lam=ParamSpec((w,), (None,), init="ones"),
        out_proj=ParamSpec((w, d), ("lru", "embed")),
    )


def _rglru_scan(a, b, chunk: int = 512):
    """Scan over h_t = a_t * h_{t-1} + b_t (diagonal recurrence).

    Hybrid chunked form: log-depth associative scan *within* chunks,
    sequential lax.scan *across* chunks.  A flat associative_scan over the
    whole sequence materializes O(S log S) intermediates — measured as the
    dominant HBM term on recurrentgemma-9b prefill_32k (§Perf cell 4); the
    hybrid bounds live memory to O(chunk log chunk) per step.
    Returns (cumulative_a, h) like the flat version.
    """

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    bsz, s, w = a.shape
    if s <= chunk or s % chunk != 0:
        return jax.lax.associative_scan(combine, (a, b), axis=1)

    nc = s // chunk
    ac = jnp.moveaxis(a.reshape(bsz, nc, chunk, w), 1, 0)  # (nc,B,C,W)
    bc = jnp.moveaxis(b.reshape(bsz, nc, chunk, w), 1, 0)

    def body(h_prev, inp):
        a_blk, b_blk = inp
        a_cum, b_cum = jax.lax.associative_scan(combine, (a_blk, b_blk), axis=1)
        h = a_cum * h_prev[:, None, :] + b_cum
        return h[:, -1, :], (a_cum, h)

    h0 = jnp.zeros((bsz, w), a.dtype)
    _, (a_all, h_all) = jax.lax.scan(body, h0, (ac, bc))
    a_all = jnp.moveaxis(a_all, 0, 1).reshape(bsz, s, w)
    h_all = jnp.moveaxis(h_all, 0, 1).reshape(bsz, s, w)
    return a_all, h_all


def rglru_forward(p, x, cfg):
    """Training/prefill forward.  Returns (out, (conv_state, h_state))."""
    bsz, s, d = x.shape
    w = cfg.lru_width or d

    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    xs = x @ p["in_x"].astype(x.dtype)

    cw = p["conv_w"].astype(x.dtype)
    pad = cfg.conv_width - 1
    xs_pad = jnp.pad(xs, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(
        xs_pad[:, i : i + s, :] * cw[i][None, None, :] for i in range(cfg.conv_width)
    ) + p["conv_b"].astype(x.dtype)

    u = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(u @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(u @ p["wx"] + p["bx"])
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])[None, None, :]
    a = jnp.exp(log_a)
    gated_x = u * i
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated_x
    _, h = _rglru_scan(a, b)

    y = (h.astype(x.dtype) * gate) @ p["out_proj"].astype(x.dtype)
    conv_state = xs_pad[:, -pad:, :] if pad else jnp.zeros((bsz, 0, w), x.dtype)
    return y, (conv_state, h[:, -1, :])


def rglru_decode(p, x, cfg, state):
    bsz, one, d = x.shape
    w = cfg.lru_width or d
    conv_state, h_prev = state

    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    xs = x @ p["in_x"].astype(x.dtype)
    cw = p["conv_w"].astype(x.dtype)
    hist = jnp.concatenate([conv_state, xs], axis=1)
    conv = jnp.einsum("bwc,wc->bc", hist, cw)[:, None, :] + p["conv_b"].astype(x.dtype)

    u = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(u @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(u @ p["wx"] + p["bx"])
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])[None, None, :]
    a = jnp.exp(log_a)[:, 0]
    b = (jnp.sqrt(jnp.maximum(1.0 - a**2, 1e-6)) * (u[:, 0] * i[:, 0]))
    h = a * h_prev + b
    y = (h[:, None, :].astype(x.dtype) * gate) @ p["out_proj"].astype(x.dtype)
    return y, (hist[:, 1:, :], h)
