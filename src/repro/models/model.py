"""Model assembly: config -> param specs -> train / prefill / decode fns.

Layer stacking uses lax.scan over *pattern groups* (e.g. gemma3's
(5 local + 1 global) block, recurrentgemma's (rglru, rglru, attn) block) so
the compiled HLO is O(group) not O(n_layers) — essential for the 40-cell
multi-pod dry-run compile times.  Remainder layers (n_layers % group) run
unscanned.  Each group kind gets its own stacked parameter tree.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S
from .config import ModelConfig, ParallelConfig

Params = Any


# ---------------------------------------------------------------------------
# Block specs per kind
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, kind: str, layer_idx: int = 10**9) -> dict:
    d = cfg.d_model
    norm = lambda: L.ParamSpec((d,), (None,), init="zeros")
    if kind in ("attn", "local"):
        s = dict(norm1=norm(), attn=L.attention_specs(cfg), norm2=norm())
        if cfg.is_moe and layer_idx >= cfg.first_dense_layers:
            s["moe"] = L.moe_specs(cfg)
        else:
            s["mlp"] = L.mlp_specs(cfg)
        return s
    if kind == "ssm":
        return dict(norm1=norm(), ssm=S.mamba2_specs(cfg))
    if kind == "rglru":
        return dict(norm1=norm(), rglru=S.rglru_specs(cfg), norm2=norm(),
                    mlp=L.mlp_specs(cfg))
    if kind == "xattn":  # decoder block with cross-attention (whisper)
        return dict(
            norm1=norm(), attn=L.attention_specs(cfg),
            norm_x=norm(), xattn=L.cross_attention_specs(cfg),
            norm2=norm(), mlp=L.mlp_specs(cfg),
        )
    if kind == "enc":  # bidirectional encoder block
        return dict(norm1=norm(), attn=L.attention_specs(cfg), norm2=norm(),
                    mlp=L.mlp_specs(cfg))
    raise ValueError(f"unknown block kind {kind}")


# ---------------------------------------------------------------------------
# Segments: scan groups + remainders
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: tuple[str, ...]  # block kinds inside one group
    n_groups: int  # scan length (1 => unscanned)
    scanned: bool
    first_layer: int  # global layer index of the segment start


def build_segments(cfg: ModelConfig, scan_layers: bool = True) -> list[Segment]:
    kinds = list(cfg.layer_kinds)
    g = len(cfg.pattern)
    n_full = len(kinds) // g
    segs: list[Segment] = []
    # MoE models with leading dense layers: peel them off unscanned.
    start = 0
    if cfg.is_moe and cfg.first_dense_layers:
        for i in range(cfg.first_dense_layers):
            segs.append(Segment((kinds[i],), 1, False, i))
        start = cfg.first_dense_layers
        n_full = (len(kinds) - start) // g
    if scan_layers and n_full > 1:
        segs.append(Segment(tuple(cfg.pattern), n_full, True, start))
        rem_start = start + n_full * g
    else:
        rem_start = start
        n_full = 0
    for i in range(rem_start, len(kinds)):
        segs.append(Segment((kinds[i],), 1, False, i))
    return segs


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Facade: specs / init / train forward / prefill / decode."""

    def __init__(
        self,
        cfg: ModelConfig,
        pc: ParallelConfig | None = None,
        mesh=None,
        rules=None,
        compute_dtype=jnp.bfloat16,
        q_chunk: int = 1024,
        kv_chunk: int = 1024,
    ):
        self.cfg = cfg
        self.pc = pc or ParallelConfig()
        self.mesh = mesh
        self.rules = rules
        self.compute_dtype = compute_dtype
        self.segments = build_segments(cfg, self.pc.scan_layers)
        self.q_chunk = q_chunk
        self.kv_chunk = kv_chunk

    # -- constraints --------------------------------------------------------

    def _constrain(self, x, logical):
        if self.mesh is None or self.rules is None:
            return x
        from repro.parallel.sharding import constrain

        return constrain(x, self.mesh, logical, self.rules)

    def _moe_groups(self) -> int:
        """Dispatch groups for MoE = number of data shards (GShard groups)."""
        if self.mesh is None:
            return 1
        g = 1
        for ax in self.pc.all_data_axes:
            g *= self.mesh.shape.get(ax, 1)
        return g

    # -- specs / init -------------------------------------------------------

    def specs(self) -> dict:
        cfg = self.cfg
        specs: dict = dict(embed=L.embed_specs(cfg))
        for si, seg in enumerate(self.segments):
            seg_spec = {
                f"b{i}": block_specs(cfg, k, seg.first_layer + i)
                for i, k in enumerate(seg.kinds)
            }
            if seg.scanned:
                seg_spec = L.stack_specs(seg_spec, seg.n_groups)
            specs[f"seg{si}"] = seg_spec
        specs["final_norm"] = L.ParamSpec((cfg.d_model,), (None,), init="zeros")
        if cfg.is_encoder_decoder:
            enc: dict = {
                f"b{i}": block_specs(cfg, "enc") for i in range(cfg.n_enc_layers)
            }
            enc["norm"] = L.ParamSpec((cfg.d_model,), (None,), init="zeros")
            enc["pos_embed"] = L.ParamSpec(
                (cfg.enc_seq, cfg.d_model), (None, "embed"), scale=0.02
            )
            specs["encoder"] = enc
        if cfg.n_patches:
            specs["patch_proj"] = L.ParamSpec(
                (cfg.d_model, cfg.d_model), ("embed", None)
            )
        return specs

    def init(self, key) -> Params:
        return L.init_tree(self.specs(), key)

    def logical(self):
        return L.logical_tree(self.specs())

    def param_shapes(self):
        return L.shape_tree(self.specs())

    # -- block forward (train/prefill) --------------------------------------

    def _block_train(self, p, x, kind: str, layer_idx, enc_out=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if kind in ("attn", "local"):
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            theta = cfg.rope_theta
            attn_out, _ = L.attention_train(
                p["attn"], h, cfg, kind, theta,
                q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
                constrain_fn=(lambda a, lg: self._constrain(a, lg))
                if self.mesh is not None else None,
            )
            x = x + attn_out
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            if "moe" in p:
                ff, aux = L.moe_ffn(
                    p["moe"], h, cfg,
                    constrain_fn=(lambda a, lg: self._constrain(a, lg)),
                    n_groups=self._moe_groups(),
                )
            else:
                ff = L.mlp(p["mlp"], h, cfg)
            x = x + ff
        elif kind == "ssm":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, _ = S.mamba2_forward(p["ssm"], h, cfg)
            x = x + out
        elif kind == "rglru":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, _ = S.rglru_forward(p["rglru"], h, cfg)
            x = x + out
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, cfg)
        elif kind == "xattn":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            attn_out, _ = L.attention_train(
                p["attn"], h, cfg, "attn", cfg.rope_theta,
                q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
                constrain_fn=(lambda a, lg: self._constrain(a, lg))
                if self.mesh is not None else None,
            )
            x = x + attn_out
            h = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
            kv = L.encode_kv(p["xattn"], enc_out, cfg)
            x = x + L.cross_attention(p["xattn"], h, kv, cfg)
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, cfg)
        else:
            raise ValueError(kind)
        # Sequence-parallel residual: the saved carry between blocks is
        # sharded (batch over data, seq over model).  XLA inserts the
        # Megatron-SP all-gather/reduce-scatter pair around attention/mlp.
        # Falls back to replicated seq when S doesn't divide (decode S=1).
        x = self._constrain(x, ("batch", "act_seq_shard", None))
        return x, aux

    def _encoder(self, params, frames):
        """Whisper-style encoder over precomputed frame embeddings (stub)."""
        cfg = self.cfg
        enc = params["encoder"]
        x = frames.astype(self.compute_dtype) + enc["pos_embed"].astype(
            self.compute_dtype
        )
        for i in range(cfg.n_enc_layers):
            p = enc[f"b{i}"]
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            q, k, v = L._project_qkv(
                p["attn"], h, cfg, jnp.arange(x.shape[1])[None, :], cfg.rope_theta
            )
            n_rep = cfg.n_heads // cfg.n_kv_heads
            k = L._repeat_kv(k, n_rep)
            v = L._repeat_kv(v, n_rep)
            out = L.chunked_attention(
                q, k, v, causal=False, q_chunk=self.q_chunk, kv_chunk=self.kv_chunk
            )
            b, s, _ = x.shape
            out = out.reshape(b, s, -1).astype(x.dtype) @ p["attn"]["wo"].astype(x.dtype)
            x = x + out
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, cfg)
        return L.rms_norm(x, enc["norm"], cfg.norm_eps)

    # -- public forwards ----------------------------------------------------

    def forward(self, params: Params, batch: dict) -> tuple[jax.Array, jax.Array]:
        """Full-sequence forward -> (logits, moe_aux_loss)."""
        x, aux = self.backbone(params, batch)
        logits = L.unembed(params["embed"], x, self.cfg)
        return logits, aux

    def backbone(self, params: Params, batch: dict) -> tuple[jax.Array, jax.Array]:
        """Everything up to (but excluding) the unembedding."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = L.embed(params["embed"], tokens, cfg).astype(self.compute_dtype)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self._encoder(params, batch["frames"])
        if cfg.n_patches:
            patches = batch["patches"].astype(self.compute_dtype)
            patches = patches @ params["patch_proj"].astype(self.compute_dtype)
            x = jnp.concatenate([patches, x], axis=1)
        x = self._constrain(x, ("batch", "act_seq_shard", None))

        aux_total = jnp.zeros((), jnp.float32)
        for si, seg in enumerate(self.segments):
            p_seg = params[f"seg{si}"]
            if seg.scanned:
                remat_policy = self.pc.remat

                def group_body(x, p_group, _seg=seg, _enc=enc_out):
                    aux = jnp.zeros((), jnp.float32)
                    for i, kind in enumerate(_seg.kinds):
                        x, a = self._block_train(p_group[f"b{i}"], x, kind,
                                                 _seg.first_layer + i, _enc)
                        aux = aux + a
                    return x, aux

                if remat_policy != "none":
                    group_body = jax.checkpoint(
                        group_body,
                        policy=jax.checkpoint_policies.nothing_saveable
                        if remat_policy == "full"
                        else jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                    )
                x, auxs = jax.lax.scan(group_body, x, p_seg)
                aux_total = aux_total + auxs.sum()
            else:
                for i, kind in enumerate(seg.kinds):
                    x, a = self._block_train(p_seg[f"b{i}"], x, kind,
                                             seg.first_layer + i, enc_out)
                    aux_total = aux_total + a

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.n_patches:
            x = x[:, cfg.n_patches :, :]
        return x, aux_total

    # -- loss ----------------------------------------------------------------

    def loss_fn(self, params, batch, aux_weight: float = 0.01,
                ce_chunk: int = 512):
        """Chunked cross-entropy: the (B, S, V) fp32 logits are never
        materialized — unembed + CE run per sequence chunk under lax.scan
        (fused-CE memory optimization; essential for the big-vocab archs)."""
        cfg = self.cfg
        x, aux = self.backbone(params, batch)
        labels = batch["labels"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)

        b, s, d = x.shape
        c = L._pick_chunk(s, ce_chunk)
        nc = s // c
        xc = jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
        mc = jnp.moveaxis(mask.reshape(b, nc, c), 1, 0)

        def chunk_nll(carry, inp):
            xq, lq, mq = inp
            logits = L.unembed(params["embed"], xq, cfg).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lq[..., None], axis=-1)[..., 0]
            return carry + jnp.sum((logz - gold) * mq), None

        # checkpoint: without it scan AD *stacks every chunk's logits* for
        # the backward pass, un-doing the whole point of chunking (§Perf
        # gemma3 It9: 8 x 0.5 GB fp32 logit stacks on a 262k vocab).
        total, _ = jax.lax.scan(
            jax.checkpoint(chunk_nll), jnp.zeros((), jnp.float32), (xc, lc, mc)
        )
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = total / denom + aux_weight * aux
        return loss, dict(loss=loss, aux=aux, ntokens=denom)

    # -- KV cache / decode ---------------------------------------------------

    def cache_shape_for(self, kind: str, batch: int, max_seq: int):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if kind in ("attn", "local"):
            s = max_seq
            if kind == "local" and cfg.window:
                s = min(max_seq, cfg.window)
            shp = (batch, s, cfg.n_kv_heads, hd)
            return dict(k=jnp.zeros(shp, self.compute_dtype),
                        v=jnp.zeros(shp, self.compute_dtype))
        if kind == "ssm":
            di = cfg.d_inner or 2 * cfg.d_model
            n = cfg.ssm_state
            nh = di // cfg.ssm_head_dim
            return (
                jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), self.compute_dtype),
                jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32),
            )
        if kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            return (
                jnp.zeros((batch, cfg.conv_width - 1, w), self.compute_dtype),
                jnp.zeros((batch, w), jnp.float32),
            )
        if kind == "xattn":
            shp = (batch, max_seq, cfg.n_kv_heads, hd)
            return dict(
                k=jnp.zeros(shp, self.compute_dtype),
                v=jnp.zeros(shp, self.compute_dtype),
                xk=jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, hd), self.compute_dtype),
                xv=jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, hd), self.compute_dtype),
            )
        raise ValueError(kind)

    def init_cache(self, batch: int, max_seq: int):
        caches = []
        for seg in self.segments:
            seg_cache = {
                f"b{i}": self.cache_shape_for(k, batch, max_seq)
                for i, k in enumerate(seg.kinds)
            }
            if seg.scanned:
                seg_cache = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (seg.n_groups, *a.shape)), seg_cache
                )
            caches.append(seg_cache)
        return caches

    def cache_logical(self, kind: str):
        """Logical axes matching cache_shape_for's structure."""
        cfg = self.cfg
        if kind in ("attn", "local"):
            kv = ("batch", "kv_seq", "kv_heads", None)
            return dict(k=kv, v=kv)
        if kind == "ssm":
            return (
                ("batch", None, "ssm_inner"),
                ("batch", "ssm_heads", None, None),
            )
        if kind == "rglru":
            return (("batch", None, "lru"), ("batch", "lru"))
        if kind == "xattn":
            kv = ("batch", "kv_seq", "kv_heads", None)
            xkv = ("batch", None, "kv_heads", None)
            return dict(k=kv, v=kv, xk=xkv, xv=xkv)
        raise ValueError(kind)

    def cache_logical_tree(self):
        out = []
        for seg in self.segments:
            seg_l = {f"b{i}": self.cache_logical(k) for i, k in enumerate(seg.kinds)}
            if seg.scanned:
                seg_l = jax.tree.map(
                    lambda lg: ("layers", *lg), seg_l,
                    is_leaf=lambda x: isinstance(x, tuple)
                    and all(isinstance(e, (str, type(None))) for e in x),
                )
            out.append(seg_l)
        return out

    def _block_decode(self, p, x, kind, cache, pos):
        cfg = self.cfg
        if kind in ("attn", "local"):
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, cache = L.attention_decode(p["attn"], h, cfg, kind, cfg.rope_theta, cache, pos)
            x = x + out
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            if "moe" in p:
                ff, _ = L.moe_ffn(p["moe"], h, cfg, n_groups=self._moe_groups())
            else:
                ff = L.mlp(p["mlp"], h, cfg)
            x = x + ff
        elif kind == "ssm":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, cache = S.mamba2_decode(p["ssm"], h, cfg, cache)
            x = x + out
        elif kind == "rglru":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, cache = S.rglru_decode(p["rglru"], h, cfg, cache)
            x = x + out
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, cfg)
        elif kind == "xattn":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            self_cache = dict(k=cache["k"], v=cache["v"])
            out, self_cache = L.attention_decode(
                p["attn"], h, cfg, "attn", cfg.rope_theta, self_cache, pos
            )
            x = x + out
            h = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
            x = x + L.cross_attention(p["xattn"], h, (cache["xk"], cache["xv"]), cfg)
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, cfg)
            cache = dict(k=self_cache["k"], v=self_cache["v"],
                         xk=cache["xk"], xv=cache["xv"])
        else:
            raise ValueError(kind)
        x = self._constrain(x, ("batch", "act_seq_shard", None))
        return x, cache

    def decode_step(self, params, caches, token, pos):
        """One decode step.  token: (B,) int32; pos: scalar int32."""
        cfg = self.cfg
        x = L.embed(params["embed"], token[:, None], cfg).astype(self.compute_dtype)
        new_caches = []
        for si, seg in enumerate(self.segments):
            p_seg = params[f"seg{si}"]
            c_seg = caches[si]
            if seg.scanned:

                def body(x, pc, _seg=seg):
                    p_group, c_group = pc
                    new_c = {}
                    for i, kind in enumerate(_seg.kinds):
                        x, nc = self._block_decode(p_group[f"b{i}"], x, kind,
                                                   c_group[f"b{i}"], pos)
                        new_c[f"b{i}"] = nc
                    return x, new_c

                x, c_seg = jax.lax.scan(body, x, (p_seg, c_seg))
            else:
                c_new = {}
                for i, kind in enumerate(seg.kinds):
                    x, nc = self._block_decode(p_seg[f"b{i}"], x, kind,
                                               c_seg[f"b{i}"], pos)
                    c_new[f"b{i}"] = nc
                c_seg = c_new
            new_caches.append(c_seg)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg)
        return logits[:, 0, :], new_caches

    def prefill(self, params, batch):
        """Prompt pass: returns (last-position logits, filled caches).

        Implemented as the full forward plus cache extraction per layer —
        for simplicity caches are rebuilt by re-projecting K/V per block on
        the final hidden states of each layer; to keep one code path we run
        block-by-block and collect.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        x = L.embed(params["embed"], tokens, cfg).astype(self.compute_dtype)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self._encoder(params, batch["frames"])
        if cfg.n_patches:
            patches = batch["patches"].astype(self.compute_dtype)
            patches = patches @ params["patch_proj"].astype(self.compute_dtype)
            x = jnp.concatenate([patches, x], axis=1)
        x = self._constrain(x, ("batch", "act_seq_shard", None))
        caches = []
        for si, seg in enumerate(self.segments):
            p_seg = params[f"seg{si}"]
            if seg.scanned:

                def body(x, p_group, _seg=seg, _enc=enc_out):
                    cc = {}
                    for i, kind in enumerate(_seg.kinds):
                        x, c = self._block_prefill(p_group[f"b{i}"], x, kind, _enc)
                        cc[f"b{i}"] = c
                    return x, cc

                x, c_seg = jax.lax.scan(body, x, p_seg)
            else:
                c_seg = {}
                for i, kind in enumerate(seg.kinds):
                    x, c = self._block_prefill(p_seg[f"b{i}"], x, kind, enc_out)
                    c_seg[f"b{i}"] = c
            caches.append(c_seg)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x[:, -1:, :], cfg)
        return logits[:, 0, :], caches

    def _block_prefill(self, p, x, kind, enc_out):
        cfg = self.cfg
        if kind in ("attn", "local"):
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, (k, v) = L.attention_train(
                p["attn"], h, cfg, kind, cfg.rope_theta,
                q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
                constrain_fn=(lambda a, lg: self._constrain(a, lg))
                if self.mesh is not None else None,
            )
            # keep only un-repeated kv heads
            n_rep = cfg.n_heads // cfg.n_kv_heads
            k = k[:, :, ::n_rep, :] if n_rep > 1 else k
            v = v[:, :, ::n_rep, :] if n_rep > 1 else v
            if kind == "local" and cfg.window and cfg.window < x.shape[1]:
                k = k[:, -cfg.window :, :, :]
                v = v[:, -cfg.window :, :, :]
            cache = dict(k=k.astype(self.compute_dtype), v=v.astype(self.compute_dtype))
            x = x + out
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            if "moe" in p:
                ff, _ = L.moe_ffn(p["moe"], h, cfg, n_groups=self._moe_groups())
            else:
                ff = L.mlp(p["mlp"], h, cfg)
            x = x + ff
        elif kind == "ssm":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, cache = S.mamba2_forward(p["ssm"], h, cfg)
            x = x + out
        elif kind == "rglru":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, cache = S.rglru_forward(p["rglru"], h, cfg)
            x = x + out
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, cfg)
        elif kind == "xattn":
            h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
            out, (k, v) = L.attention_train(
                p["attn"], h, cfg, "attn", cfg.rope_theta,
                q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
                constrain_fn=(lambda a, lg: self._constrain(a, lg))
                if self.mesh is not None else None,
            )
            n_rep = cfg.n_heads // cfg.n_kv_heads
            k = k[:, :, ::n_rep, :] if n_rep > 1 else k
            v = v[:, :, ::n_rep, :] if n_rep > 1 else v
            x = x + out
            h = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
            xk, xv = L.encode_kv(p["xattn"], enc_out, cfg)
            x = x + L.cross_attention(p["xattn"], h, (xk, xv), cfg)
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, cfg)
            cache = dict(
                k=k.astype(self.compute_dtype), v=v.astype(self.compute_dtype),
                xk=xk.astype(self.compute_dtype), xv=xv.astype(self.compute_dtype),
            )
        else:
            raise ValueError(kind)
        x = self._constrain(x, ("batch", "act_seq_shard", None))
        return x, cache


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out
