"""Model / parallelism / shape configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention details
    qkv_bias: bool = False
    window: int = 0  # local-attention window (0 = n/a)
    # per-layer block pattern, cycled over n_layers:
    #   "attn" (global), "local", "ssm" (mamba2), "rglru" (griffin block)
    pattern: tuple[str, ...] = ("attn",)
    logit_softcap: float = 0.0
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    d_inner: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssm_chunk: int = 256

    # RG-LRU (recurrentgemma)
    lru_width: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0  # precomputed frame embeddings (frontend stub)

    # vlm
    n_patches: int = 0  # precomputed patch embeddings prepended (stub)

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "silu"
    # Pad the vocab so the embedding shards over the model axis (Megatron-
    # style).  Padded logit rows are masked to -inf in unembed, so semantics
    # are unchanged.  1 disables padding (smoke tests).
    vocab_pad_multiple: int = 2048

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        m = max(1, self.vocab_pad_multiple)
        return -(-self.vocab_size // m) * m
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind, pattern cycled over n_layers."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate parameter count (reporting / roofline)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for kind in self.layer_kinds:
            if kind in ("attn", "local"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)  # qkv
                total += self.n_heads * hd * d  # o
                total += self._ffn_params(d)
            elif kind == "ssm":
                di = self.d_inner or 2 * d
                nh = di // self.ssm_head_dim
                total += d * (2 * di + 2 * self.ssm_state + nh)  # in_proj-ish
                total += di * d  # out
            elif kind == "rglru":
                w = self.lru_width or d
                total += d * w * 2 + w * d + w * 3  # gates + proj
                total += self._ffn_params(d)
            total += 2 * d  # norms
        return total

    def _ffn_params(self, d: int) -> int:
        if self.is_moe:
            e_ff = self.moe_d_ff
            routed = self.n_experts * 3 * d * e_ff
            shared = self.n_shared_experts * 3 * d * e_ff
            router = d * self.n_experts
            return routed + shared + router
        return 3 * d * self.d_ff  # gate/up/down

    def n_active_params(self) -> int:
        """Active params per token (MoE-aware) — used for MODEL_FLOPS."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        e_ff = self.moe_d_ff
        full = self.n_params()
        routed_all = 0
        routed_active = 0
        for kind in self.layer_kinds:
            if kind in ("attn", "local"):
                routed_all += self.n_experts * 3 * d * e_ff
                routed_active += self.top_k * 3 * d * e_ff
        return full - routed_all + routed_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How to map the model onto the mesh (the TPU 'topology' axis of the
    paper's Algorithm-I search space — core/mesh_explorer.py sweeps these)."""

    # Mesh axis names, outermost first.  ("data", "model") single pod,
    # ("pod", "data", "model") multi-pod.
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp: bool = True  # shard params/opt-state over data axes
    seq_shard_kv: bool = True  # shard decode KV seq over model if heads don't divide
    remat: str = "block"  # none | block | full
    grad_accum: int = 1
    # gradient compression for the DP all-reduce: none | bf16 | int8_ef
    grad_compression: str = "none"
    # scan layers (compile-time/memory win) — turned off for tiny tests
    scan_layers: bool = True

    @property
    def all_data_axes(self) -> tuple[str, ...]:
        return self.data_axes
