"""Transformer building blocks (pure JAX, shard-aware via logical axes).

Parameters are plain nested dicts.  Every leaf is declared with a ParamSpec
(shape + logical axes + init scale); the same spec tree drives init,
eval_shape dry-runs, and sharding (parallel/sharding.py maps logical names
to mesh axes with divisibility fallback).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict of arrays


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    scale: float = 1.0  # stddev multiplier on fan-in init
    init: str = "normal"  # normal | zeros | ones

    def initializer(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, jnp.float32)
        if self.init == "ones":
            return jnp.ones(self.shape, jnp.float32)
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        std = self.scale / math.sqrt(max(1, fan_in))
        return std * jax.random.normal(key, self.shape, jnp.float32)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_tree(specs, key) -> Params:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def logical_tree(specs):
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=is_spec)


def shape_tree(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=is_spec)


def stack_specs(specs, n: int):
    """Prepend a scan ("layers") dim to every leaf spec."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.logical), s.scale, s.init),
        specs,
        is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# Norms / activations / rope
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + gamma)).astype(dt)


def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_specs(cfg) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    s = dict(
        wq=ParamSpec((d, h * hd), ("embed", "qkv")),
        wk=ParamSpec((d, kv * hd), ("embed", "qkv")),
        wv=ParamSpec((d, kv * hd), ("embed", "qkv")),
        wo=ParamSpec((h * hd, d), ("qkv", "embed")),
    )
    if cfg.qkv_bias:
        s.update(
            bq=ParamSpec((h * hd,), ("qkv",), init="zeros"),
            bk=ParamSpec((kv * hd,), ("qkv",), init="zeros"),
            bv=ParamSpec((kv * hd,), ("qkv",), init="zeros"),
        )
    if cfg.qk_norm:
        s.update(
            q_norm=ParamSpec((hd,), (None,), init="zeros"),
            k_norm=ParamSpec((hd,), (None,), init="zeros"),
        )
    return s


def _project_qkv(p, x, cfg, positions, theta):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d)).reshape(
        b, s, kv * n_rep, d
    )


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (falls back to s for primes)."""
    if s <= target:
        return s
    if s % target == 0:
        return target
    best = 1
    d = 1
    while d * d <= s:
        if s % d == 0:
            lo, hi = d, s // d
            if lo <= target:
                best = max(best, lo)
            if hi <= target:
                best = max(best, hi)
        d += 1
    return best if best >= max(8, target // 8) else s


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, H, D)  (already GQA-repeated)
    v: jax.Array,
    q_offset: int | jax.Array = 0,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    cross: bool = False,
) -> jax.Array:
    """Online-softmax attention, O(chunk^2) memory (flash-style, pure JAX).

    Sq and Skv must be divisible by the chunk sizes (pad upstream).  Causal
    masking is by absolute position (q position = q_offset + index).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    q_chunk = _pick_chunk(sq, q_chunk)
    kv_chunk = _pick_chunk(skv, kv_chunk)
    nq, nkv = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / math.sqrt(d)

    qc = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,D)
    kc = k.reshape(b, nkv, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nkv, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.arange(q_chunk)
    kv_pos_base = jnp.arange(kv_chunk)

    # NOTE on block skipping: a statically-unrolled q loop that visits only
    # the un-masked kv blocks (flash-style triangular schedule) was tried
    # and REGRESSED badly under sequence-sharded residuals — the unroll
    # defeats the SPMD partitioner and everything gets replicated
    # (EXPERIMENTS.md §Perf, gemma3 It5: collective 2.48s -> 4.49s).  The
    # fused scan below lets XLA keep the chunk loop sharded; the masked
    # upper-triangle compute it wastes is far cheaper than replication.
    def q_body(qi, q_blk):
        q_blk = q_blk * scale
        q_pos = q_offset + qi * q_chunk + q_pos_base

        def kv_body(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            s_ = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk, preferred_element_type=jnp.float32)
            if causal and not cross:
                kv_pos = ki * kv_chunk + kv_pos_base
                mask = q_pos[:, None] >= kv_pos[None, :]
                if window:
                    mask &= q_pos[:, None] - kv_pos[None, :] < window
                s_ = jnp.where(mask[None, None], s_, -1e30)
            m_new = jnp.maximum(m, s_.max(-1))
            p = jnp.exp(s_ - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        # checkpoint the kv step: probabilities are recomputed in the
        # backward pass instead of being stacked across kv chunks — this is
        # exactly the flash-attention backward memory trade.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_body), (m0, l0, a0), (jnp.arange(nkv), kc, vc)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # cast at the block boundary so any downstream reshard moves bf16
        return out.astype(v.dtype)

    out = jax.lax.map(jax.checkpoint(lambda args: q_body(*args)), (jnp.arange(nq), qc))
    # (nq, B, H, qc, D) -> (B, Sq, H, D)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, d)
    return out


def attention_train(p, x, cfg, kind: str, theta: float, positions=None,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    constrain_fn=None):
    """Full-sequence (train/prefill) attention for one layer.

    ``constrain_fn`` hoists the sequence-parallel gather of q/k/v to a
    single collective *before* the chunk loops: without it the SPMD
    partitioner re-gathers K/V inside every (checkpointed) chunk-loop
    iteration of the backward pass — measured at ~710 GB/step/device on
    gemma3-27b train_4k (§Perf It12).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions, theta)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if constrain_fn is not None:
        hoist = ("batch", None, "act_heads", None)  # seq gathered ONCE here
        q = constrain_fn(q, hoist)
        k = constrain_fn(k, hoist)
        v = constrain_fn(v, hoist)
    window = cfg.window if kind == "local" else 0
    out = chunked_attention(
        q, k, v, causal=True, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk
    )
    out = out.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim).astype(x.dtype)
    return out @ p["wo"].astype(x.dtype), (k, v)


def attention_decode(p, x, cfg, kind: str, theta: float, cache, pos):
    """Single-token decode against a KV cache.

    cache: dict(k=(B, S_cache, KV, D), v=..., )  pos: scalar current index
    (same for the whole batch).  Local layers use a ring cache of size
    ``window`` — positions are mapped modulo the ring.
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, theta)

    s_cache = cache["k"].shape[1]
    is_ring = kind == "local" and cfg.window and cfg.window < 10**9 and s_cache <= cfg.window
    slot = jnp.mod(pos, s_cache) if is_ring else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    new_cache = dict(k=k, v=v)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    kk = _repeat_kv(k, n_rep)
    vv = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(hd)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", (q * scale), kk, preferred_element_type=jnp.float32)
    kv_idx = jnp.arange(s_cache)
    if is_ring:
        # entry at slot i holds absolute position: valid if within window of pos
        age = jnp.mod(pos - kv_idx, s_cache)
        valid = (age < jnp.minimum(pos + 1, cfg.window))
    else:
        valid = kv_idx <= pos
        if kind == "local" and cfg.window:
            valid &= kv_idx > pos - cfg.window
    s_ = jnp.where(valid[None, None, None, :], s_, -1e30)
    prob = jax.nn.softmax(s_, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", prob, vv)
    out = out.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    return out @ p["wo"].astype(x.dtype), new_cache


def cross_attention_specs(cfg) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    return dict(
        wq=ParamSpec((d, h * hd), ("embed", "qkv")),
        wk=ParamSpec((d, kv * hd), ("embed", "qkv")),
        wv=ParamSpec((d, kv * hd), ("embed", "qkv")),
        wo=ParamSpec((h * hd, d), ("qkv", "embed")),
    )


def cross_attention(p, x, enc_kv, cfg, q_chunk: int = 1024, kv_chunk: int = 1024):
    """Decoder cross-attention; enc_kv = (k, v) precomputed from encoder.
    Uses the chunked online-softmax path so scores never materialize."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    k, v = enc_kv
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    out = chunked_attention(q, k, v, causal=False, cross=True,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return out.astype(x.dtype) @ p["wo"].astype(x.dtype)


def encode_kv(p, enc_out, cfg):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return dict(
        w_gate=ParamSpec((d, f), ("embed", "mlp")),
        w_up=ParamSpec((d, f), ("embed", "mlp")),
        w_down=ParamSpec((f, d), ("mlp", "embed")),
    )


def mlp(p, x, cfg):
    a = act_fn(cfg.act)
    h = a(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (fine-grained: shared + routed top-k, sort-based dispatch)
# ---------------------------------------------------------------------------


def moe_specs(cfg) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = dict(
        router=ParamSpec((d, e), ("embed", "experts")),
        we_gate=ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        we_up=ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        we_down=ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    )
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        s.update(
            ws_gate=ParamSpec((d, fs), ("embed", "mlp")),
            ws_up=ParamSpec((d, fs), ("embed", "mlp")),
            ws_down=ParamSpec((fs, d), ("mlp", "embed")),
        )
    return s


def moe_ffn(p, x, cfg, constrain_fn=None, n_groups: int = 1):
    """Fine-grained MoE with grouped sort-based dispatch (GShard groups).

    Tokens are split into ``n_groups`` groups (one per data shard); ALL
    routing bookkeeping — top-k, sort, capacity positions, scatter into the
    (G, E, C, D) buffers, and the combine scatter — is group-local, so the
    SPMD partitioner never has to replicate the token dimension (a naive
    global sort/gather forces exactly that and blows HBM by ~10x).  The
    group dim shards over data; the expert dim shards over the model axis
    (EP); the reshard between them is the expert-parallel all-to-all.
    Overflow beyond capacity is dropped (tiny at capacity_factor 1.25).
    """
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = math.gcd(n_groups, n) if n_groups > 1 else 1
    ng = n // g
    xt = x.reshape(g, ng, d)
    a = act_fn(cfg.act)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Ng, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G, Ng, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style), group-averaged
    me = probs.mean((0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (n * k)
    aux_loss = e * jnp.sum(me * ce)

    cap = int(math.ceil(ng * k / e * cfg.capacity_factor))
    cap = min(max(cap, 8), ng * k)

    flat_expert = expert_idx.reshape(g, ng * k)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(ng), k)[None], (g, ng * k)
    )
    flat_gate = gate_vals.reshape(g, ng * k)

    order = jnp.argsort(flat_expert, axis=-1)
    se = jnp.take_along_axis(flat_expert, order, axis=-1)
    st = jnp.take_along_axis(flat_token, order, axis=-1)
    sg = jnp.take_along_axis(flat_gate, order, axis=-1)
    pos_all = jnp.broadcast_to(jnp.arange(ng * k)[None], (g, ng * k))
    run_start = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e), side="left"))(se)
    pos_in_e = pos_all - jnp.take_along_axis(run_start, se, axis=-1)
    keep = pos_in_e < cap
    dst = se * cap + jnp.where(keep, pos_in_e, 0)

    gathered = jnp.take_along_axis(xt, st[..., None], axis=1)  # (G, Ng*k, D)
    gathered = jnp.where(keep[..., None], gathered, 0)
    buf = jax.vmap(lambda dd, vv: jnp.zeros((e * cap, d), xt.dtype).at[dd].add(vv))(
        dst, gathered
    ).reshape(g, e, cap, d)
    if constrain_fn is not None:
        buf = constrain_fn(buf, ("batch", "act_experts", None, None))

    h = a(jnp.einsum("gecd,edf->gecf", buf, p["we_gate"].astype(buf.dtype))) * jnp.einsum(
        "gecd,edf->gecf", buf, p["we_up"].astype(buf.dtype)
    )
    y = jnp.einsum("gecf,efd->gecd", h, p["we_down"].astype(buf.dtype))
    if constrain_fn is not None:
        y = constrain_fn(y, ("batch", "act_experts", None, None))
    y = y.reshape(g, e * cap, d)

    yd = jnp.take_along_axis(y, dst[..., None], axis=1)  # (G, Ng*k, D)
    contrib = jnp.where(keep[..., None], yd * sg[..., None].astype(y.dtype), 0)
    out = jax.vmap(lambda tt, vv: jnp.zeros((ng, d), xt.dtype).at[tt].add(vv))(
        st, contrib
    )

    if cfg.n_shared_experts:
        hs = a(xt @ p["ws_gate"].astype(xt.dtype)) * (xt @ p["ws_up"].astype(xt.dtype))
        out = out + hs @ p["ws_down"].astype(xt.dtype)
    return out.reshape(b, s, d), aux_loss


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg) -> dict:
    v = cfg.padded_vocab
    s = dict(tok=ParamSpec((v, cfg.d_model), ("vocab", "embed"), scale=1.0))
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((cfg.d_model, v), ("embed", "vocab"))
    return s


def embed(p, tokens, cfg):
    return jnp.take(p["tok"], tokens, axis=0) * math.sqrt(cfg.d_model)


def unembed(p, x, cfg):
    """Logits over the padded vocab; pad rows masked to -inf (Megatron-style
    padded-vocab softmax — semantics identical to the unpadded model)."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"].astype(x.dtype))
    else:
        logits = x @ p["unembed"].astype(x.dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits
