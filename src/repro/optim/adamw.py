"""AdamW + learning-rate schedules (no optax dependency).

Includes the WSD (warmup-stable-decay) schedule used by MiniCPM
(arXiv:2404.06395) — one of the assigned architectures' defining features —
plus cosine and linear for completeness.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def wsd_schedule(
    peak_lr: float,
    warmup_steps: int,
    stable_steps: int,
    decay_steps: int,
    final_frac: float = 0.1,
) -> Callable[[jax.Array], jax.Array]:
    """Warmup-Stable-Decay (MiniCPM §4): linear warmup, long flat stage,
    short (often exponential) decay to final_frac * peak."""

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        stable = jnp.asarray(peak_lr, jnp.float32)
        t = (step - warmup_steps - stable_steps) / max(1, decay_steps)
        t = jnp.clip(t, 0.0, 1.0)
        decay = peak_lr * jnp.exp(jnp.log(final_frac) * t)
        return jnp.where(
            step < warmup_steps, warm, jnp.where(t > 0.0, decay, stable)
        )

    return f


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return f


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # gradient compression for the accumulate/reduce path:
    #   none | bf16 | int8_ef (int8 with error feedback)
    compression: str = "none"


def adamw_init(params: Params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    state = dict(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))
    if cfg.compression == "int8_ef":
        state["ef"] = zeros(params)  # error-feedback residual
    return state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def compress_grads(grads, state, cfg: AdamWConfig):
    """Gradient compression with error feedback.

    On a real multi-pod run this wraps the cross-pod reduce (the quantized
    representation is what crosses the DCI link); here it is applied at the
    same point in the dataflow so convergence behaviour is identical.
    """
    if cfg.compression == "none":
        return grads, state
    if cfg.compression == "bf16":
        g = jax.tree.map(lambda x: x.astype(jnp.bfloat16).astype(jnp.float32), grads)
        return g, state
    if cfg.compression == "int8_ef":
        ef = state["ef"]

        def q(g, e):
            g = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
            qg = jnp.clip(jnp.round(g / scale), -127, 127)
            deq = qg * scale
            return deq, g - deq

        pairs = jax.tree.map(q, grads, ef)
        g = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        state = dict(state, ef=new_ef)
        return g, state
    raise ValueError(cfg.compression)


def adamw_update(
    grads: Params,
    state: dict,
    params: Params,
    lr: jax.Array,
    cfg: AdamWConfig,
) -> tuple[Params, dict]:
    grads, state = compress_grads(grads, state, cfg)

    if cfg.clip_norm:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    new_state = dict(state, step=step, m=new_m, v=new_v)
    return new_params, new_state
