"""Batched serving engine: prefill + decode with cache management.

Handles the cache-layout plumbing between the two phases:
  * global-attention caches are padded from prompt length to max_seq,
  * local-attention ring caches are rotated so entry i holds absolute
    position p with p === i (mod window) — the invariant decode_step's
    ring addressing relies on,
  * recurrent states (SSD / RG-LRU) pass through unchanged.

A lightweight slot-based batcher (continuous-batching lite) serves
variable-length requests on a fixed batch of decode slots.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


def align_prefill_caches(model: Model, caches, prompt_len: int, max_seq: int,
                         batch: int):
    """Pad / rotate prefill caches into decode layout (see module doc).

    The sequence axis of every KV leaf is located through the model's
    cache-logical tree ("kv_seq") — shape heuristics are unsafe: a
    window-full ring cache has the SAME shape as its allocation but still
    needs rotation whenever prompt_len % window != 0 (caught by
    tests/test_models.py::test_ring_cache_alignment_property).
    """
    alloc = jax.eval_shape(lambda: model.init_cache(batch, max_seq))
    logical = model.cache_logical_tree()
    window = model.cfg.window

    is_lg = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )

    def fix(lg, pre, tgt):
        if "kv_seq" not in lg:
            assert pre.shape == tgt.shape, (lg, pre.shape, tgt.shape)
            return pre
        ax = lg.index("kv_seq")
        tgt_len = tgt.shape[ax]
        cur = pre.shape[ax]
        if window and tgt_len == min(window, tgt_len) and tgt_len == window \
                and prompt_len >= window:
            # full ring: rotate so abs position p sits at slot p % window
            out = pre
            if cur < tgt_len:
                pad = [(0, 0)] * pre.ndim
                pad[ax] = (0, tgt_len - cur)
                out = jnp.pad(out, pad)
            shift = prompt_len % window
            return jnp.roll(out, shift, axis=ax) if shift else out
        if cur == tgt_len:
            return pre
        pad = [(0, 0)] * pre.ndim
        pad[ax] = (0, tgt_len - cur)
        return jnp.pad(pre, pad)

    return jax.tree.map(fix, logical, caches, alloc, is_leaf=is_lg)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-batch prefill/decode engine with greedy or temperature sampling."""

    def __init__(self, model: Model, params, batch: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new: int, extra_batch: dict | None = None):
        """prompts: (B, L) int32 (padded to equal length).  Returns (B, max_new)."""
        b, plen = prompts.shape
        assert b == self.batch
        batch = dict(tokens=jnp.asarray(prompts, jnp.int32))
        if extra_batch:
            batch.update(extra_batch)
        logits, caches = self._prefill(self.params, batch)
        plen_abs = plen + (self.model.cfg.n_patches or 0)
        caches = align_prefill_caches(self.model, caches, plen_abs,
                                      self.max_seq + (self.model.cfg.n_patches or 0),
                                      batch=b)

        pos_offset = self.model.cfg.n_patches or 0
        out = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits)
        for t in range(max_new):
            out[:, t] = np.asarray(tok)
            if t == max_new - 1:
                break
            logits, caches = self._decode(
                self.params, caches, tok, jnp.int32(pos_offset + plen + t)
            )
            tok = self._sample(logits)
        return out

    # -- slot-based continuous batching (lite) -------------------------------

    def serve(self, requests: list[Request], prompt_pad: int) -> list[Request]:
        """Serve a request list on ``self.batch`` slots, refilling slots as
        requests finish (waves of prefill + shared decode steps).

        Every prompt must satisfy ``1 <= len(prompt) <= prompt_pad``; a
        violating request raises `ValueError` up front (naming the uid)
        rather than surfacing as a numpy broadcast error mid-wave.
        """
        for r in requests:
            if not 0 < len(r.prompt) <= prompt_pad:
                raise ValueError(
                    f"request uid={r.uid}: prompt length {len(r.prompt)} "
                    f"must be in [1, prompt_pad={prompt_pad}]"
                )
        queue = list(requests)
        done: list[Request] = []
        while queue:
            wave = queue[: self.batch]
            queue = queue[len(wave) :]
            prompts = np.zeros((self.batch, prompt_pad), np.int32)
            for i, r in enumerate(wave):
                prompts[i, prompt_pad - len(r.prompt) :] = r.prompt  # left-pad
            max_new = max(r.max_new for r in wave)
            toks = self.generate(prompts, max_new)
            for i, r in enumerate(wave):
                r.out_tokens = list(toks[i, : r.max_new])
                r.done = True
                done.append(r)
        return done
