"""Exploration-as-a-service: a warm, persistent query engine over
Algorithm I.

The offline tool answers one-shot questions — "given this circuit,
memory budget, and latency bound, which rCiM implementation strategy is
cheapest?" — by characterizing the circuit (~seconds cold) and compiling
a fresh jitted sweep (~seconds per new shape).  `ExplorationService`
turns that into a long-lived query engine that answers the same question
in milliseconds once warm, by arranging the pipeline so every expensive
stage is shared and every request-specific stage is cheap:

    submit() ──> request queue ──> continuous batching (drain up to
    max_batch) ──> bucket: pad circuits onto canonical SuiteTable
    shapes (`batch.pad_suite`: C -> pow2, L -> pow2 x LEVEL_PAD) so
    every batch reuses an already-compiled `evaluate_select_suite`
    trace ──> grid cache: one lazy device-resident (V, T, R) sweep per
    (circuit fingerprint, model spec) ──> per-request re-rank: budget +
    latency constraints applied as a pure masked argmin over the cached
    grid (`batch.select_best_batch_device`) — zero recompiles, zero
    re-characterization when only the constraints change.

Three cache layers, keyed content-addressed:

  * the on-disk `transforms.CharacterizationCache` (shared across
    processes and service restarts) plus an in-memory memo — both keyed
    by AIG structural fingerprint, so a repeated or structurally-shared
    circuit skips the front half entirely;
  * the grid cache: (fingerprint, model-table hash) -> lazy
    `ExplorationGrid`/`VariationGrid` whose metric tensors stay on the
    device; only per-winner scalars cross the host boundary at answer
    time (`GridCell` single-scalar gathers + the (V,) winner-index /
    winner-energy vectors for variation summaries);
  * the XLA trace cache: requests are bucketed so the jitted suite
    kernel traces once per `SuiteTable.bucket_shape` — the stress bench
    and tests pin "exactly one trace per bucket" via
    `batch.trace_counts`.

Robustness is part of the contract: a malformed circuit, an infeasible
memory budget, or an all-non-finite (NaN-salted) model sweep yields a
*structured* `ServiceError` on that request's future while the rest of
the batch keeps being served; the worker thread never dies on request
data.  Three further layers harden the service against its own runtime
(exercised by tests/test_service_faults.py and the chaos CI profile):

  * **per-request deadlines** — ``ExploreRequest.deadline_s`` (or the
    service-wide ``default_deadline_s``) bounds submit-to-answer wall
    time; an expired request resolves to ``deadline-exceeded`` at batch
    pickup or before the answer is assembled, instead of occupying the
    pipeline;
  * **worker supervision** — an exception escaping the batch pipeline
    (a bug, an injected ``service.process`` fault) fails that batch's
    unresolved futures with ``worker-crashed`` and the loop keeps
    serving; if the thread dies anyway, the next `submit` respawns it
    (``worker_restarts`` stat) — queued futures are never stranded;
  * **graceful degradation** — a device-backend characterization
    failure retries on the ``backend="python"`` parity path; the answer
    is bit-identical (both backends are exact) but arrives slower and
    carries ``ExploreResponse.degraded=True`` plus a ``degraded`` stat.

Parity: every answer is bit-identical (same winner cell, same tiering
and tie-breaking) to a one-shot `explorer.explore_request` call with the
same constraints — pinned by tests/test_service.py and asserted on every
request by the ``"service"`` smoke bench in CI.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import queue
import threading
import time
from concurrent.futures import Future
from typing import Mapping, Sequence

import numpy as np

# repro: kernel-module — the service handles device-resident grids; all
# host materializations must be annotated boundary crossings
from repro.core.aig import Aig, AigStats
from repro.core import batch as B
from repro.core.batch import (
    SuiteTable,
    TopologyTable,
    VariationGrid,
    bucket_levels,
    ceil_pow2,
    evaluate_select_suite,
    pad_suite,
    select_best_batch_device,
    winner_summary,
)
from repro.core.explorer import ENERGY_QUANTILES
from repro.core.mapping import BITS_PER_GATE
from repro.core.sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    ModelTable,
    SramTopology,
    inductor_size_nh,
)
from repro.core.transforms import (
    CharacterizationCache,
    characterize_suite,
    resolve_backend,
)
from repro.runtime import faults


# ---------------------------------------------------------------------------
# Request / response schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExploreRequest:
    """One design query: which implementation of ``circuit`` is cheapest
    under the given memory budget / latency bound, optionally across a
    `ModelTable` variation sweep (process corners, Monte-Carlo, ...)?"""

    circuit: Aig
    max_memory_kb: float | None = None
    max_latency_ns: float | None = None
    model_sweep: ModelTable | None = None
    tag: str | None = None  # caller correlation id, echoed in the response
    #: submit-to-answer wall-clock budget in seconds (None = the
    #: service's ``default_deadline_s``); expiry resolves the future
    #: with a ``deadline-exceeded`` `ServiceError`.
    deadline_s: float | None = None


@dataclasses.dataclass(frozen=True)
class ServiceError:
    """Structured per-request failure — the request's future still
    resolves (to a response carrying this), the batch keeps serving.

    Codes: ``malformed-circuit`` (input is not a usable AIG),
    ``characterization-failed`` (the transform front half raised, on
    every backend tried), ``infeasible-memory`` (no candidate topology
    fits the budget), ``no-finite-energy`` (every admissible cell is
    NaN/inf — e.g. a pathological model sweep), ``deadline-exceeded``
    (the request's wall-clock budget expired before an answer),
    ``worker-crashed`` (an exception escaped the batch pipeline; the
    batch's unresolved futures all resolve with this and the worker
    keeps serving), ``shutdown`` (service stopped before the request
    was served), ``internal`` (unexpected bug, message carries the
    exception).
    """

    code: str
    message: str


@dataclasses.dataclass(frozen=True)
class Winner:
    """The chosen implementation, materialized from single-scalar device
    gathers (`GridCell`) — the full sweep tensors never leave the
    device for this."""

    recipe: tuple[str, ...]
    topology: SramTopology
    energy_nj: float
    latency_ns: float
    power_mw: float
    area_mm2: float
    fits: bool
    meets_latency: bool
    inductor_nh: float | None  # None for correlated sweeps (no scalar model)


@dataclasses.dataclass(frozen=True)
class VariationSummary:
    """Per-variant winners + yield figures for a ``model_sweep`` request
    (the service-side analogue of `explorer.VariationResult`, computed
    from the (V,)-sized selection payload without materializing the
    (V, T, R) tensors)."""

    n_variants: int
    winners: tuple[tuple[tuple[str, ...], SramTopology], ...]
    winner_share: dict[str, float]
    best_yield: float
    latency_yield: float
    winner_energy_nj: np.ndarray            # (V,)
    energy_quantiles: dict[float, float]

    def cvar(self, alpha: float = 0.9) -> float:
        """Expected shortfall of the per-variant winner energy (see
        `explorer.VariationResult.cvar`)."""
        if not 0.0 <= alpha < 1.0:
            raise ValueError(f"alpha must be in [0, 1), got {alpha}")
        e = np.sort(self.winner_energy_nj)
        k = max(1, int(np.ceil((1.0 - alpha) * e.size)))
        return float(e[-k:].mean())


@dataclasses.dataclass
class ExploreResponse:
    request: ExploreRequest
    winner: Winner | None = None
    variation: VariationSummary | None = None
    error: ServiceError | None = None
    fingerprint: str | None = None
    bucket: tuple | None = None       # (C, R, L_pad, T, V) trace bucket
    cha_cache_hit: bool = False       # front half skipped (memo/disk)
    grid_cache_hit: bool = False      # back half skipped (re-rank only)
    degraded: bool = False            # served via a fallback backend
    queued_ms: float = 0.0            # submit -> batch pickup
    service_ms: float = 0.0           # batch pickup -> answer

    @property
    def ok(self) -> bool:
        return self.error is None


# ---------------------------------------------------------------------------
# Internal records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Pending:
    request: ExploreRequest
    future: Future
    t_submit: float
    fp: str | None = None
    model_key: str | None = None
    error: ServiceError | None = None
    cha_hit: bool = False
    grid_hit: bool = False
    degraded: bool = False


@dataclasses.dataclass
class _GridEntry:
    """One cached (fingerprint, model spec) sweep: the lazy grid row plus
    flat device views of the re-rank operands."""

    row: "B.ExplorationGrid | VariationGrid"
    energy: object        # (V, N) device array, N = T*R topology-major
    latency: object       # (V, N) device array
    fits: np.ndarray      # (1, N) bool
    min_gates: int        # capacity threshold (Alg. I line 9 input)
    nominal_model: EnergyModel | None
    is_sweep: bool
    bucket: tuple         # (C, R, L_pad, T, V) trace-reuse key


def _model_key(table: ModelTable | None) -> str:
    """Content hash of a model spec — the grid-cache / batch-group key.
    ``None`` (the service's nominal model) hashes to a fixed key."""
    if table is None:
        return "nominal"
    return table.content_key()


_SENTINEL = object()


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


class ExplorationService:
    """A persistent Algorithm-I query engine with continuous batching.

    Usage::

        svc = ExplorationService(cache="runs/cha_cache", max_batch=8)
        fut = svc.submit(ExploreRequest(circuit, max_memory_kb=96,
                                        max_latency_ns=400.0))
        resp = fut.result()          # ExploreResponse
        svc.close()

    ``start=True`` (default) runs a single worker thread that drains the
    queue in batches (all jax work happens on that thread).
    ``start=False`` leaves the service passive — call `pump()` to
    process everything queued on the caller's thread, which is the
    deterministic mode the tests use.

    The topology library, recipe set, accounting mode, and discipline
    are service-level configuration: they define the compiled sweep
    shapes every request shares.  Per-request degrees of freedom are the
    circuit, the constraints, and the model sweep.
    """

    def __init__(
        self,
        sram_list: Sequence[SramTopology] = TOPOLOGY_LIBRARY,
        recipes: Sequence[tuple[str, ...]] | None = None,
        model: EnergyModel | None = None,
        mode: str = "physical",
        discipline: str = "list",
        cache: "CharacterizationCache | str | os.PathLike | None" = None,
        n_jobs: int | None = 1,
        cha_backend: str = "auto",
        max_batch: int = 8,
        grid_cache_size: int = 128,
        default_deadline_s: float | None = None,
        start: bool = True,
    ):
        if not B.jax_available():  # pragma: no cover - container ships jax
            raise RuntimeError("ExplorationService requires jax")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if grid_cache_size < 1:
            raise ValueError("grid_cache_size must be >= 1")
        self._topos = TopologyTable.from_topologies(sram_list)
        self._total_kb = np.array(
            [t.total_kb for t in self._topos.topologies], dtype=np.float64
        )
        self._recipes = (
            None if recipes is None else [tuple(r) for r in recipes]
        )
        self._model = model if model is not None else EnergyModel()
        self._mode = mode
        self._discipline = discipline
        self._cache = cache
        self._n_jobs = n_jobs
        self._cha_backend = cha_backend
        self.max_batch = max_batch
        self._grid_cache_size = grid_cache_size
        self.default_deadline_s = default_deadline_s

        self._queue: "queue.Queue" = queue.Queue()
        # Worker-thread-only state (no locks needed beyond the queue):
        self._cha: "collections.OrderedDict[str, tuple[dict, int]]" = (
            collections.OrderedDict()
        )
        self._grids: "collections.OrderedDict[tuple, _GridEntry]" = (
            collections.OrderedDict()
        )
        self._tables: dict[str, ModelTable | None] = {}
        self._stats = collections.Counter()
        self._buckets: "collections.Counter[tuple]" = collections.Counter()
        self._stats_lock = threading.Lock()
        self._closed = False
        self._thread: threading.Thread | None = None
        if start:
            self._thread = threading.Thread(
                target=self._serve_loop, name="explore-service", daemon=True
            )
            self._thread.start()

    # -- client API ----------------------------------------------------------

    def submit(self, request: ExploreRequest) -> Future:
        """Enqueue a request; the returned future resolves to an
        `ExploreResponse` (errors are *in* the response — the future
        itself only raises on cancellation)."""
        if self._closed:
            raise RuntimeError("ExplorationService is closed")
        self._ensure_worker()
        p = _Pending(request, Future(), time.perf_counter())
        with self._stats_lock:
            self._stats["submitted"] += 1
        self._queue.put(p)
        return p.future

    def submit_batch(self, requests: Sequence[ExploreRequest]) -> list[Future]:
        return [self.submit(r) for r in requests]

    def explore(self, request: "ExploreRequest | Aig", **kw) -> ExploreResponse:
        """Blocking convenience: submit one request and wait.  An `Aig`
        plus keyword constraints builds the `ExploreRequest` inline.  In
        passive (``start=False``) mode the queue is pumped on this
        thread."""
        if isinstance(request, Aig):
            request = ExploreRequest(circuit=request, **kw)
        elif kw:
            raise TypeError("keyword constraints only apply to a bare Aig")
        fut = self.submit(request)
        if self._thread is None:
            self.pump()
        return fut.result()

    def pump(self) -> int:
        """Passive mode: drain and process everything currently queued on
        the *caller's* thread (one `_process` call per ``max_batch``
        slice — the same continuous-batching path the worker runs).
        Returns the number of requests processed."""
        if self._thread is not None:
            raise RuntimeError("pump() is for start=False services")
        done = 0
        while True:
            batch = self._drain(block=False)
            if not batch:
                return done
            self._process(batch)
            done += len(batch)

    def close(self, timeout: float | None = 30.0) -> None:
        """Stop accepting requests, serve everything already queued, then
        shut the worker down.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._thread is not None:
            self._queue.put(_SENTINEL)
            self._thread.join(timeout=timeout)
            self._thread = None
        # Passive mode (or a worker that timed out): fail anything left.
        self._fail_queue()

    def __enter__(self) -> "ExplorationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        """Counter snapshot: submitted / served / errors / cancelled,
        front-half (``cha_hits``/``cha_misses``) and back-half
        (``grid_hits``/``grid_misses``) cache traffic, ``batches`` and
        ``evaluate_calls``, plus the per-bucket batch histogram."""
        with self._stats_lock:
            out = dict(self._stats)
        out["buckets"] = {str(k): v for k, v in self._buckets.items()}
        out["distinct_buckets"] = len(self._buckets)
        return out

    # -- worker --------------------------------------------------------------

    def _ensure_worker(self) -> None:
        """Crash detection at the submit edge: a worker thread that died
        anyway (an error the loop supervision re-raised, a library-level
        crash) is replaced before the new request enqueues, so futures
        are never parked behind a dead consumer."""
        t = self._thread
        if t is None or t.is_alive() or self._closed:
            return
        with self._stats_lock:
            self._stats["worker_restarts"] += 1
        self._thread = threading.Thread(
            target=self._serve_loop, name="explore-service", daemon=True
        )
        self._thread.start()

    def _serve_loop(self) -> None:
        while True:
            batch = self._drain(block=True)
            if batch is None:  # sentinel: drain leftovers, then exit
                self._fail_queue()
                return
            if not batch:
                continue
            try:
                self._process(batch)
            except BaseException as e:  # noqa: BLE001 — supervised loop
                # An exception escaping the batch pipeline used to kill
                # this thread and strand every queued future.  Fail the
                # batch's unresolved futures with a structured error and
                # keep serving; genuinely fatal signals still propagate
                # (the next submit() respawns the worker).
                self._crash_batch(batch, e)
                if isinstance(e, (KeyboardInterrupt, SystemExit)):
                    raise

    def _crash_batch(self, batch: list, exc: BaseException) -> None:
        err = ServiceError(
            "worker-crashed", f"{type(exc).__name__}: {exc}"
        )
        now = time.perf_counter()
        for p in batch:
            if not p.future.done():
                p.error = err
                self._resolve(p, now)
        with self._stats_lock:
            self._stats["worker_crashes"] += 1

    def _drain(self, block: bool) -> "list[_Pending] | None":
        """Continuous batching: take the next request (blocking only in
        worker mode), then greedily drain up to ``max_batch`` without
        waiting.  Returns None when the shutdown sentinel is seen."""
        batch: list[_Pending] = []
        try:
            first = (
                self._queue.get(timeout=0.1) if block
                else self._queue.get_nowait()
            )
        except queue.Empty:
            return batch
        if first is _SENTINEL:
            return None
        batch.append(first)
        while len(batch) < self.max_batch:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is _SENTINEL:
                # Keep the sentinel semantics: everything queued before
                # close() is served; the loop exits on the next drain.
                self._queue.put(_SENTINEL)
                break
            batch.append(item)
        return batch

    def _fail_queue(self) -> None:
        while True:
            try:
                p = self._queue.get_nowait()
            except queue.Empty:
                return
            if p is _SENTINEL:
                continue
            if p.future.set_running_or_notify_cancel():
                p.error = ServiceError("shutdown", "service closed")
                self._resolve(p, time.perf_counter())

    # -- batch pipeline ------------------------------------------------------

    def _deadline_expired(self, p: _Pending) -> bool:
        """Mark ``p`` with a structured deadline error if its wall-clock
        budget (request-level, else service default) has run out."""
        if p.error is not None:
            return False
        d = p.request.deadline_s
        if d is None:
            d = self.default_deadline_s
        if d is None or time.perf_counter() - p.t_submit <= d:
            return False
        p.error = ServiceError(
            "deadline-exceeded",
            f"request exceeded its {d:g}s deadline before an answer",
        )
        with self._stats_lock:
            self._stats["deadline_exceeded"] += 1
        return True

    def _process(self, batch: list[_Pending]) -> None:
        t0 = time.perf_counter()
        faults.inject("service.process", detail=str(len(batch)))
        live: list[_Pending] = []
        for p in batch:
            if p.future.set_running_or_notify_cancel():
                live.append(p)
            else:
                with self._stats_lock:
                    self._stats["cancelled"] += 1
        if not live:
            return
        for p in live:
            self._admit(p)
            # Deadline check at pickup: an already-expired request must
            # not occupy the characterize/evaluate pipeline.
            self._deadline_expired(p)
        self._characterize([p for p in live if p.error is None])
        self._evaluate([p for p in live if p.error is None])
        for p in live:
            if p.error is None and not self._deadline_expired(p):
                try:
                    self._answer(p, t0)
                    continue
                except Exception as e:  # noqa: BLE001 - worker must survive
                    p.error = ServiceError("internal", f"{type(e).__name__}: {e}")
            self._resolve(p, t0)

    def _admit(self, p: _Pending) -> None:
        """Validate + fingerprint; structured error on malformed input."""
        r = p.request
        if not isinstance(r.circuit, Aig):
            p.error = ServiceError(
                "malformed-circuit",
                f"circuit must be an Aig, got {type(r.circuit).__name__}",
            )
            return
        if r.circuit.n_pis < 1 or not r.circuit.pos:
            p.error = ServiceError(
                "malformed-circuit",
                "circuit has no primary inputs or no primary outputs",
            )
            return
        if r.model_sweep is not None and not isinstance(
            r.model_sweep, ModelTable
        ):
            p.error = ServiceError(
                "malformed-circuit",
                f"model_sweep must be a ModelTable, got "
                f"{type(r.model_sweep).__name__}",
            )
            return
        try:
            p.fp = r.circuit.fingerprint()
        except Exception as e:  # noqa: BLE001
            p.error = ServiceError(
                "malformed-circuit", f"fingerprint failed: {e}"
            )
            return
        try:
            p.model_key = _model_key(r.model_sweep)
        except Exception as e:  # noqa: BLE001
            p.error = ServiceError(
                "malformed-circuit", f"bad model_sweep: {e}"
            )
            return
        self._tables.setdefault(p.model_key, r.model_sweep)

    def _characterize(self, live: list[_Pending]) -> None:
        """Front half per unique fingerprint: in-memory memo -> on-disk
        `CharacterizationCache` -> transforms.  Failures are isolated
        per circuit (one bad netlist cannot sink its batch-mates).

        Degradation ladder: when the configured backend (``"auto"``
        resolves to the device engine) fails, the same circuit retries
        on the ``"python"`` parity path — both backends are exact, so
        the answer is bit-identical, just slower; the requests served
        that way carry ``degraded=True``.  Only when every rung fails
        does the request get ``characterization-failed``."""
        todo: dict[str, Aig] = {}
        for p in live:
            if p.fp in self._cha:
                p.cha_hit = True
                self._cha.move_to_end(p.fp)
            elif p.fp not in todo:
                todo[p.fp] = p.request.circuit
        with self._stats_lock:
            self._stats["cha_hits"] += sum(1 for p in live if p.cha_hit)
            self._stats["cha_misses"] += len(todo)
        ladder = [self._cha_backend]
        if resolve_backend(self._cha_backend) != "python":
            ladder.append("python")
        for fp, rtl in todo.items():
            entry = None
            errors = []
            for rung, backend in enumerate(ladder):
                try:
                    cha = characterize_suite(
                        {rtl.name: rtl},
                        self._recipes,
                        cache=self._cache,
                        n_jobs=self._n_jobs,
                        backend=backend,
                    )[rtl.name]
                    # Empty/degenerate characterizations must fail the
                    # request, not the worker thread (min() on an empty
                    # map used to escape the guard and kill the loop).
                    min_gates = min(s.total_gates for s in cha.values())
                    entry = (cha, min_gates)
                    break
                except Exception as e:  # noqa: BLE001 - isolate the request
                    errors.append(f"{backend}: {type(e).__name__}: {e}")
            if entry is None:
                err = ServiceError(
                    "characterization-failed", "; ".join(errors)
                )
                for p in live:
                    if p.fp == fp:
                        p.error = err
                continue
            if rung > 0:
                with self._stats_lock:
                    self._stats["degraded"] += 1
                for p in live:
                    if p.fp == fp:
                        p.degraded = True
            self._cha[fp] = entry
            while len(self._cha) > max(4 * self._grid_cache_size, 64):
                self._cha.popitem(last=False)

    def _evaluate(self, live: list[_Pending]) -> None:
        """Back half: one fused device pass per (model spec, bucket) for
        every (fingerprint, model spec) not already in the grid cache."""
        need: dict[str, list[str]] = {}
        for p in live:
            key = (p.fp, p.model_key)
            if key in self._grids:
                p.grid_hit = True
                self._grids.move_to_end(key)
            elif p.fp in self._cha:
                need.setdefault(p.model_key, [])
                if p.fp not in need[p.model_key]:
                    need[p.model_key].append(p.fp)
        with self._stats_lock:
            self._stats["grid_hits"] += sum(1 for p in live if p.grid_hit)
            self._stats["grid_misses"] += sum(len(v) for v in need.values())
        for model_key, fps in need.items():
            table = self._tables[model_key]
            try:
                self._evaluate_group(model_key, fps, table)
            except ValueError as e:
                # The fused kernel's host-side guard: some (circuit,
                # variant) cell has no finite energy — a poisoned model
                # spec.  Every request sharing the spec gets the
                # structured error; other groups are untouched.
                err = ServiceError("no-finite-energy", str(e))
                for p in live:
                    if p.model_key == model_key and p.fp in fps:
                        p.error = err
            except Exception as e:  # noqa: BLE001 - worker must survive
                err = ServiceError("internal", f"{type(e).__name__}: {e}")
                for p in live:
                    if p.model_key == model_key and p.fp in fps:
                        p.error = err

    def _evaluate_group(
        self, model_key: str, fps: list[str], table: ModelTable | None
    ) -> None:
        suite = SuiteTable.from_cha(
            {fp: self._cha[fp][0] for fp in fps}
        )
        padded = pad_suite(
            suite,
            n_circuits=ceil_pow2(len(fps)),
            pad_levels_to=bucket_levels(suite.ops.shape[2]),
        )
        n_variants = 1 if table is None else len(table)
        bucket = padded.bucket_shape(len(self._topos), n_variants)
        # The batched pass uses the budget-free capacity mask (exactly
        # what `explore_suite` computes); per-request budgets fold in at
        # re-rank time so one cached grid serves every constraint.
        feas = np.stack(
            [
                self._capacity_feasible(self._cha[fp][1])
                for fp in padded.circuits[: len(fps)]
            ]
            + [self._capacity_feasible(self._cha[fps[0]][1])]
            * (len(padded.circuits) - len(fps))
        )
        t0 = time.perf_counter()
        sg, _sel = evaluate_select_suite(
            padded,
            self._topos,
            table if table is not None else self._model,
            mode=self._mode,
            discipline=self._discipline,
            feasible=feas,
            max_latency_ns=None,
            lazy=True,
        )
        with self._stats_lock:
            self._stats["batches"] += 1
            self._stats["evaluate_calls"] += 1
            self._stats["evaluate_ms"] += int(
                (time.perf_counter() - t0) * 1e3
            )
        self._buckets[bucket] += 1
        is_sweep = table is not None
        n = len(self._topos) * len(padded.recipes)
        for fp in fps:
            row = sg.variation(fp) if is_sweep else sg.grid(fp)
            energy = row._raw("energy_nj").reshape(-1, n)[-n_variants:]
            latency = row._raw("latency_ns").reshape(-1, n)[-n_variants:]
            # model-free capacity mask: (1, N) bools, cached per grid
            fits = np.asarray(row._raw("fits")).reshape(1, n)  # repro: host-boundary
            self._grids[(fp, model_key)] = _GridEntry(
                row=row,
                energy=energy,
                latency=latency,
                fits=fits,
                min_gates=self._cha[fp][1],
                nominal_model=(
                    self._model if table is None
                    else (table.model(0) if table.uniform_row(0) else None)
                ),
                is_sweep=is_sweep,
                bucket=bucket,
            )
            while len(self._grids) > self._grid_cache_size:
                self._grids.popitem(last=False)

    # -- per-request re-rank -------------------------------------------------

    def _capacity_feasible(
        self, min_gates: int, within: np.ndarray | None = None
    ) -> np.ndarray:
        """Alg. I line 9 over the (optionally budget-restricted) library:
        capacity-feasible topologies, falling back to the largest
        in-budget candidate when nothing fits — byte-for-byte the
        `explorer._opt_and_feasible` rule applied inside the budget."""
        total_bits = self._topos.total_bits
        feas = total_bits >= BITS_PER_GATE * min_gates
        if within is not None:
            feas = feas & within
            if not feas.any():
                feas = np.zeros(len(self._topos), dtype=bool)
                feas[int(np.argmax(np.where(within, total_bits, -1)))] = True
        elif not feas.any():
            feas = np.zeros(len(self._topos), dtype=bool)
            feas[int(np.argmax(total_bits))] = True
        return feas

    def _answer(self, p: _Pending, t0: float) -> None:
        entry = self._grids[(p.fp, p.model_key)]
        r = p.request
        n_r = len(entry.row.recipes)
        n = len(self._topos) * n_r

        within = None
        if r.max_memory_kb is not None:
            within = self._total_kb <= r.max_memory_kb
            if not within.any():
                p.error = ServiceError(
                    "infeasible-memory",
                    f"no candidate topology fits the {r.max_memory_kb} KB "
                    f"budget (smallest candidate is "
                    f"{self._total_kb.min():g} KB)",
                )
                self._resolve(p, t0)
                return
        feas = self._capacity_feasible(entry.min_gates, within)
        feas_flat = np.broadcast_to(
            feas[:, None], (len(self._topos), n_r)
        ).reshape(1, n)

        energy = entry.energy
        if within is not None and not within.all():
            # Budget exclusion must hold in EVERY tier (a restricted
            # library simply does not contain the big topologies), so
            # out-of-budget cells become +inf — inadmissible everywhere,
            # exactly like `explore_request`'s restricted list.
            mask = np.broadcast_to(
                within[:, None], (len(self._topos), n_r)
            ).reshape(1, n)
            with B.enable_x64():  # keep the f64 metrics undemoted
                energy = B.jnp.where(mask, energy, B.jnp.inf)
        try:
            # Always through the latency tier (an absent bound is +inf,
            # which admits everything), so constraint changes hit ONE
            # compiled filter — zero retraces per request.
            idx = select_best_batch_device(
                energy,
                entry.fits,
                latency=entry.latency,
                max_latency=(
                    r.max_latency_ns
                    if r.max_latency_ns is not None
                    else np.inf
                ),
                feasible=feas_flat,
            )
        except ValueError as e:
            p.error = ServiceError("no-finite-energy", str(e))
            self._resolve(p, t0)
            return

        flat0 = int(idx[0])
        ti, ri = flat0 // n_r, flat0 % n_r
        cell = (
            entry.row.cell(0, ti, ri)
            if entry.is_sweep
            else entry.row.cell(ti, ri)
        )
        resp = self._response(p, t0)
        resp.winner = Winner(
            recipe=cell.recipe,
            topology=cell.topology,
            energy_nj=cell.energy_nj,
            latency_ns=cell.latency_ns,
            power_mw=cell.power_mw,
            area_mm2=cell.area_mm2,
            fits=cell.fits,
            meets_latency=(
                r.max_latency_ns is None
                or cell.latency_ns <= r.max_latency_ns
            ),
            inductor_nh=(
                None
                if entry.nominal_model is None
                else inductor_size_nh(cell.topology, entry.nominal_model)
            ),
        )
        if entry.is_sweep:
            resp.variation = self._variation_summary(entry, idx, r)
        p.future.set_result(resp)
        with self._stats_lock:
            self._stats["served"] += 1

    def _variation_summary(
        self, entry: _GridEntry, idx: np.ndarray, r: ExploreRequest
    ) -> VariationSummary:
        row: VariationGrid = entry.row
        pairs = [row.unravel(int(i)) for i in idx]
        winners = tuple(
            (row.recipes[ri], row.topologies[ti]) for ti, ri in pairs
        )
        share, best_yield = winner_summary(
            [
                f"{topo.name}/{','.join(recipe) or '-'}"
                for recipe, topo in winners
            ]
        )
        # Device gathers: (V,) vectors are the only transfers here.
        with B.enable_x64():  # keep the f64 metrics undemoted
            winner_energy = np.asarray(  # repro: host-boundary
                B.jnp.take_along_axis(
                    entry.energy, B.jnp.asarray(idx)[:, None], axis=-1
                )
            )[:, 0].astype(float)
        nominal_fits = bool(entry.fits[0, int(idx[0])])
        ok = np.full(len(idx), nominal_fits)
        if r.max_latency_ns is not None:
            lat_nom = np.asarray(entry.latency[:, int(idx[0])])  # repro: host-boundary
            ok &= lat_nom <= r.max_latency_ns
        return VariationSummary(
            n_variants=len(idx),
            winners=winners,
            winner_share=share,
            best_yield=best_yield,
            latency_yield=float(np.mean(ok)),  # repro: host-boundary
            winner_energy_nj=winner_energy,
            energy_quantiles={
                q: float(np.quantile(winner_energy, q))  # repro: host-boundary
                for q in ENERGY_QUANTILES
            },
        )

    def _response(self, p: _Pending, t0: float) -> ExploreResponse:
        entry = self._grids.get((p.fp, p.model_key))
        return ExploreResponse(
            request=p.request,
            error=p.error,
            fingerprint=p.fp,
            bucket=getattr(entry, "bucket", None),
            cha_cache_hit=p.cha_hit,
            grid_cache_hit=p.grid_hit,
            degraded=p.degraded,
            queued_ms=(t0 - p.t_submit) * 1e3,
            service_ms=(time.perf_counter() - t0) * 1e3,
        )

    def _resolve(self, p: _Pending, t0: float) -> None:
        p.future.set_result(self._response(p, t0))
        with self._stats_lock:
            self._stats["errors"] += 1
