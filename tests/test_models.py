"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.config import SHAPES, ParallelConfig
from repro.models.model import Model, build_segments

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b, s, key=KEY, train=True):
    batch = dict(tokens=jax.random.randint(key, (b, s), 0, cfg.vocab_size))
    if train:
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        batch["mask"] = jnp.ones((b, s), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes + no NaNs (deliverable f)."""
    cfg = smoke_config(arch)
    m = Model(cfg, ParallelConfig(scan_layers=True), q_chunk=8, kv_chunk=8)
    params = m.init(KEY)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    from repro.optim.adamw import AdamWConfig, adamw_init, constant_schedule
    from repro.train.steps import make_train_step

    opt_cfg = AdamWConfig()
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(m, constant_schedule(1e-3), opt_cfg))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Serving correctness: prefill+decode logits == teacher-forced forward."""
    cfg = smoke_config(arch)
    m = Model(cfg, ParallelConfig(scan_layers=True), compute_dtype=jnp.float32,
              q_chunk=8, kv_chunk=8)
    params = m.init(KEY)
    B, S, P = 2, 24, 16
    off = cfg.n_patches or 0
    batch = make_batch(cfg, B, S, train=False)
    toks = batch["tokens"]
    full_logits, _ = jax.jit(m.forward)(params, batch)

    pre = dict(batch)
    pre["tokens"] = toks[:, :P]
    last_logits, caches = jax.jit(m.prefill)(params, pre)

    from repro.serve.engine import align_prefill_caches

    caches = align_prefill_caches(m, caches, P + off, S + off, batch=B)
    assert np.abs(np.asarray(last_logits) - np.asarray(full_logits[:, P - 1])).max() < 2e-3

    decode = jax.jit(m.decode_step)
    worst, cur = 0.0, caches
    for t in range(P, S):
        lg, cur = decode(params, cur, toks[:, t], jnp.int32(off + t))
        worst = max(worst, np.abs(np.asarray(lg) - np.asarray(full_logits[:, t])).max())
    assert worst < 5e-3, (arch, worst)


def test_segments_cover_all_layers():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        segs = build_segments(cfg)
        total = sum(len(s.kinds) * s.n_groups for s in segs)
        assert total == cfg.n_layers, arch


def test_exact_assigned_configs():
    """The full configs match the assignment card exactly."""
    card = {
        "mamba2-780m": (48, 1536, 50_280),
        "minicpm-2b": (40, 2304, 122_753),
        "qwen1.5-4b": (40, 2560, 151_936),
        "gemma3-27b": (62, 5376, 262_144),
        "deepseek-coder-33b": (62, 7168, 32_256),
        "whisper-tiny": (4, 384, 51_865),
        "recurrentgemma-9b": (38, 4096, 256_000),
        "internvl2-2b": (24, 2048, 92_553),
        "deepseek-moe-16b": (28, 2048, 102_400),
        "moonshot-v1-16b-a3b": (48, 2048, 163_840),
    }
    for arch, (nl, dm, v) in card.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.vocab_size) == (nl, dm, v), arch
    assert get_config("qwen1.5-4b").qkv_bias
    assert get_config("gemma3-27b").pattern.count("local") == 5
    assert get_config("deepseek-moe-16b").n_experts == 64
    assert get_config("deepseek-moe-16b").top_k == 6
    assert get_config("deepseek-moe-16b").n_shared_experts == 2
    assert get_config("mamba2-780m").ssm_state == 128
    assert get_config("recurrentgemma-9b").pattern == ("rglru", "rglru", "local")
    assert get_config("whisper-tiny").is_encoder_decoder


def test_vocab_padding_semantics():
    """Padded logit rows must never win argmax / affect softmax."""
    import dataclasses

    cfg = dataclasses.replace(smoke_config("minicpm-2b"), vocab_pad_multiple=128)
    assert cfg.padded_vocab == 512  # 512 already multiple of 128
    cfg = dataclasses.replace(cfg, vocab_size=500)
    assert cfg.padded_vocab == 512
    m = Model(cfg, ParallelConfig())
    params = m.init(KEY)
    batch = make_batch(cfg, 2, 8, train=False)
    logits, _ = jax.jit(m.forward)(params, batch)
    assert logits.shape[-1] == 512
    assert np.asarray(logits[..., 500:]).max() <= -1e29
    assert (np.asarray(jnp.argmax(logits, -1)) < 500).all()


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524_288


def test_ssd_gradient_finite_regression():
    """Regression: where(tri, exp(seg), 0) overflowed on the masked upper
    triangle and produced inf*0 = NaN gradients (the where-grad trap)."""
    cfg = smoke_config("mamba2-780m")
    m = Model(cfg, ParallelConfig(scan_layers=True))
    params = m.init(KEY)
    batch = make_batch(cfg, 2, 16)
    (_, _), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(params, batch)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.isfinite(np.asarray(g, np.float32)).all(), path


@pytest.mark.parametrize("plen", [8, 16, 20, 24])
def test_ring_cache_alignment_property(plen):
    """Local-attention ring cache: decode must match forward for prompt
    lengths below, at, and above the window (alignment/rotation paths)."""
    cfg = smoke_config("gemma3-27b")  # window=16
    m = Model(cfg, ParallelConfig(), compute_dtype=jnp.float32,
              q_chunk=8, kv_chunk=8)
    params = m.init(KEY)
    B, S = 2, 28
    batch = make_batch(cfg, B, S, train=False)
    toks = batch["tokens"]
    full_logits, _ = jax.jit(m.forward)(params, batch)
    pre = dict(batch)
    pre["tokens"] = toks[:, :plen]
    last_logits, caches = jax.jit(m.prefill)(params, pre)

    from repro.serve.engine import align_prefill_caches

    caches = align_prefill_caches(m, caches, plen, S, batch=B)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full_logits[:, plen - 1]), atol=2e-3
    )
    decode = jax.jit(m.decode_step)
    cur = caches
    for t in range(plen, S):
        lg, cur = decode(params, cur, toks[:, t], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, t]), atol=5e-3
        )


def test_rglru_chunked_scan_equivalence():
    """Hybrid chunked LRU scan == flat associative scan."""
    import jax.numpy as jnp
    from repro.models.ssm import _rglru_scan

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (2, 64, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 64, 8)).astype(np.float32))
    a_flat, h_flat = _rglru_scan(a, b, chunk=1024)  # falls back to flat
    a_chk, h_chk = _rglru_scan(a, b, chunk=16)
    np.testing.assert_allclose(np.asarray(h_flat), np.asarray(h_chk), rtol=2e-5, atol=1e-5)
