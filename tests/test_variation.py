"""Parity + no-recompile tests for the dynamic energy-model axis.

The contract under test (the yield/variation engine of core/batch.py):

  * a 1-variant `ModelTable` sweep is **bit-identical** to the
    static-`EnergyModel` path, across grids, accounting modes, and
    scheduling disciplines (the model constants moved from jit statics
    to traced operands without changing a single float op);
  * an N-variant sweep matches N serial static-model runs on every
    (circuit, recipe, topology) cell, including the per-variant
    `select_best` winners;
  * the whole sweep costs exactly ONE jit trace, and changing only the
    model floats never retriggers tracing (`batch.trace_counts`).

The property suites run under hypothesis when it is installed
(``pip install -e .[test]``); deterministic seeded versions of the same
assertions always run, so the parity contract is enforced either way.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import circuits as C
from repro.core.aig import AigStats
from repro.core.batch import (
    SuiteTable,
    TopologyTable,
    WorkloadTable,
    evaluate_batch,
    evaluate_suite,
    select_best,
    table2_batch,
    trace_counts,
)
from repro.core.explorer import characterize_recipes, explore_suite
from repro.core.mapping import schedule_stats
from repro.core.sram import (
    SWEEPABLE_FIELDS,
    TOPOLOGY_LIBRARY,
    EnergyModel,
    ModelTable,
    evaluate,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False

METRIC_KEYS = (
    "latency_ns", "energy_nj", "power_mw", "throughput_gops", "tops_per_watt"
)


def stats_from_levels(levels):
    ops = [dict(nand=a, nor=b, inv=c) for a, b, c in levels]
    return AigStats(
        n_pis=8, n_pos=4, n_ands=0, n_levels=len(ops), ops_per_level=ops,
        nand_count=sum(l[0] for l in levels),
        nor_count=sum(l[1] for l in levels),
        inv_count=sum(l[2] for l in levels),
    )


def random_workload(rng, n_recipes=5, max_levels=9, max_ops=2000):
    items = []
    for i in range(n_recipes):
        n = int(rng.integers(1, max_levels + 1))
        levels = [
            tuple(int(x) for x in rng.integers(0, max_ops, size=3))
            for _ in range(n)
        ]
        items.append(((str(i),), stats_from_levels(levels)))
    return WorkloadTable.from_stats(items)


def scale_model(base: EnergyModel, k: float) -> EnergyModel:
    """Every sweepable field scaled by ``k`` — a maximally 'different'
    model that still exercises all constants."""
    kw = {}
    for f in SWEEPABLE_FIELDS:
        v = getattr(base, f)
        kw[f] = tuple(x * k for x in v) if isinstance(v, tuple) else v * k
    return dataclasses.replace(base, **kw)


def assert_one_variant_bit_identical(work, topos, model, mode, discipline):
    static = evaluate_batch(work, topos, model, mode=mode,
                            discipline=discipline)
    sweep = evaluate_batch(
        work, topos, ModelTable.from_models([model]), mode=mode,
        discipline=discipline,
    )
    assert sweep.n_variants == 1
    np.testing.assert_array_equal(static.cycles, sweep.cycles)
    np.testing.assert_array_equal(
        static.active_macro_cycles, sweep.active_macro_cycles
    )
    np.testing.assert_array_equal(static.fits, sweep.fits)
    for k in METRIC_KEYS:
        a, b = getattr(static, k), getattr(sweep, k)[0]
        assert np.array_equal(a, b), f"{k} not bit-identical"
    assert np.array_equal(static.area_mm2, sweep.area_mm2[0])
    # identical winner, including tie-breaking
    assert static.best_index() == int(sweep.best_indices()[0])
    # and the variant-0 slice is a full ExplorationGrid equal to static
    g0 = sweep.grid(0)
    assert np.array_equal(static.energy_nj, g0.energy_nj)
    assert g0.model == model


# ---------------------------------------------------------------------------
# 1-variant sweep == static path, bit for bit (deterministic seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["physical", "paper"])
@pytest.mark.parametrize("discipline", ["list", "levels"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_one_variant_bit_identical(mode, discipline, seed):
    rng = np.random.default_rng(seed)
    work = random_workload(rng)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    model = scale_model(EnergyModel(), float(rng.uniform(0.3, 3.0)))
    assert_one_variant_bit_identical(work, topos, model, mode, discipline)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        workloads=st.lists(
            st.lists(
                st.tuples(
                    st.integers(0, 1500),
                    st.integers(0, 1500),
                    st.integers(0, 400),
                ),
                min_size=1,
                max_size=8,
            ),
            min_size=1,
            max_size=4,
        ),
        scale=st.floats(0.25, 4.0),
        mode=st.sampled_from(["physical", "paper"]),
        discipline=st.sampled_from(["list", "levels"]),
    )
    def test_property_one_variant_bit_identical(
        workloads, scale, mode, discipline
    ):
        work = WorkloadTable.from_stats(
            [((str(i),), stats_from_levels(lv))
             for i, lv in enumerate(workloads)]
        )
        topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
        model = scale_model(EnergyModel(), scale)
        assert_one_variant_bit_identical(work, topos, model, mode, discipline)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 9),
        sigma=st.floats(0.01, 0.5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_modeltable_roundtrip(n, sigma, seed):
        table = ModelTable.monte_carlo(n=n, sigma=sigma, seed=seed)
        assert len(table) == n
        # float64 -> EnergyModel -> float64 round-trips exactly
        again = ModelTable.from_models(table.models(), names=table.names)
        for f in dataclasses.fields(EnergyModel):
            np.testing.assert_array_equal(
                getattr(table, f.name), getattr(again, f.name)
            )
        # seeded: same seed reproduces, row 0 is nominal
        assert table.model(0) == EnergyModel()
        table2 = ModelTable.monte_carlo(n=n, sigma=sigma, seed=seed)
        np.testing.assert_array_equal(table.p_ctrl_mw, table2.p_ctrl_mw)

else:  # keep the property suite visible as skips when hypothesis is absent

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
    def test_property_one_variant_bit_identical():
        pass

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
    def test_property_modeltable_roundtrip():
        pass


# ---------------------------------------------------------------------------
# N-variant sweep == N serial static-model runs (65 x 12 slice)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bar_suite():
    suite = C.benchmark_suite(scale="tiny", only=("bar",))
    cha = {"bar": characterize_recipes(suite["bar"])}  # all 64 recipes + ()
    return suite, cha


def test_variant_winners_match_serial_explore_suite(bar_suite):
    suite, cha = bar_suite
    table = ModelTable.monte_carlo(n=4, sigma=0.15, seed=7)
    res = explore_suite(suite, cha=cha, model_sweep=table)["bar"]
    var = res.variation
    assert var is not None and var.n_variants == 4
    assert res.n_evaluations == 65 * 12  # the acceptance slice
    assert sum(var.winner_share.values()) == pytest.approx(1.0)
    for v in range(4):
        serial = explore_suite(suite, cha=cha, model=table.model(v))["bar"]
        # identical winner implementation...
        assert (serial.best.recipe, serial.best.topo) == var.winners[v]
        # ...and identical energies on every (recipe, topology) cell
        np.testing.assert_array_equal(
            var.grid.energy_nj[v], serial.grid.energy_nj
        )
        np.testing.assert_array_equal(
            var.grid.latency_ns[v], serial.grid.latency_ns
        )
    # the headline best/grid are the nominal variant's
    nominal = explore_suite(suite, cha=cha, model=table.model(0))["bar"]
    assert res.best.metrics.energy_nj == nominal.best.metrics.energy_nj
    assert (res.best.recipe, res.best.topo) == (
        nominal.best.recipe, nominal.best.topo
    )


def test_degenerate_sweep_yield_is_one(bar_suite):
    suite, cha = bar_suite
    em = EnergyModel()
    table = ModelTable.from_models([em] * 5)
    res = explore_suite(
        suite, cha=cha, recipes=[("Ba",), ("Rw",)], model_sweep=table
    )["bar"]
    var = res.variation
    assert var.best_yield == 1.0
    assert var.latency_yield == 1.0
    assert len(set(var.winners)) == 1
    assert var.winner_share == {
        f"{res.best.topo.name}/{','.join(res.best.recipe) or '-'}": 1.0
    }


def test_model_sweep_argument_validation(bar_suite):
    suite, cha = bar_suite
    table = ModelTable.corners()
    with pytest.raises(ValueError, match="either model or model_sweep"):
        explore_suite(suite, cha=cha, model=EnergyModel(), model_sweep=table)
    with pytest.raises(ValueError, match="backend"):
        explore_suite(suite, cha=cha, model_sweep=table, backend="python")


# ---------------------------------------------------------------------------
# Compile-count guard: one trace per sweep, zero per float change
# ---------------------------------------------------------------------------


def test_sweep_traces_exactly_once_and_float_changes_do_not_retrace():
    # Unique grid shape (R=11 recipes, C=3 circuits) so the first call is
    # guaranteed to be a fresh trace even when other tests ran first.
    rng = np.random.default_rng(123)
    work = random_workload(rng, n_recipes=11)
    suite = SuiteTable.from_workloads(
        {"a": work, "b": work, "c": work}
    )
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table_a = ModelTable.monte_carlo(n=8, sigma=0.1, seed=0)

    before = trace_counts().get("evaluate_suite", 0)
    svg_a = evaluate_suite(suite, topos, table_a)
    assert trace_counts().get("evaluate_suite", 0) == before + 1

    # Same shapes, different model floats: served from the jit cache.
    table_b = ModelTable.monte_carlo(n=8, sigma=0.4, seed=99)
    svg_b = evaluate_suite(suite, topos, table_b)
    assert trace_counts().get("evaluate_suite", 0) == before + 1
    # ...and the floats really flowed through (not a stale constant).
    assert not np.array_equal(svg_a.energy_nj, svg_b.energy_nj)
    np.testing.assert_array_equal(svg_a.cycles, svg_b.cycles)

    # A new variant count is a new shape: exactly one more trace.
    evaluate_suite(suite, topos, ModelTable.monte_carlo(n=16, seed=1))
    assert trace_counts().get("evaluate_suite", 0) == before + 2


def test_serial_static_models_share_one_compile():
    rng = np.random.default_rng(321)
    work = random_workload(rng, n_recipes=13)  # unique shape again
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.monte_carlo(n=6, sigma=0.2, seed=5)

    before = trace_counts().get("evaluate_grid", 0)
    grids = [
        evaluate_batch(work, topos, table.model(v)) for v in range(6)
    ]
    # the old engine paid one compile per EnergyModel; now the first call
    # traces and the other five hit the cache
    assert trace_counts().get("evaluate_grid", 0) == before + 1
    # parity of the serial runs against the one-call sweep
    sweep = evaluate_batch(work, topos, table)
    assert trace_counts().get("evaluate_grid", 0) == before + 2  # V=6 shape
    for v, g in enumerate(grids):
        np.testing.assert_array_equal(sweep.energy_nj[v], g.energy_nj)
        assert int(sweep.best_indices()[v]) == g.best_index()


# ---------------------------------------------------------------------------
# ModelTable generators
# ---------------------------------------------------------------------------


def test_corners_generator():
    table = ModelTable.corners(spread=0.1)
    assert table.names == ("tt", "ff", "ss")
    base = EnergyModel()
    assert table.model(0) == base
    ff, ss = table.model(1), table.model(2)
    # fast silicon: cheaper ops, faster clock; slow: the reverse
    assert ff.e_op_fj[0] < base.e_op_fj[0] < ss.e_op_fj[0]
    assert ff.f_clk_hz > base.f_clk_hz > ss.f_clk_hz
    # geometry is corner-independent
    assert ff.bitcell_um2 == base.bitcell_um2 == ss.bitcell_um2


def test_sensitivity_generator():
    fields = ("p_ctrl_mw", "e_op_marginal_fj")
    table = ModelTable.sensitivity(fields=fields, rel=0.05)
    assert len(table) == 1 + 2 * len(fields)
    assert table.model(0) == EnergyModel()
    plus = table.model(1)
    assert plus.p_ctrl_mw == pytest.approx(EnergyModel().p_ctrl_mw * 1.05)
    # one-at-a-time: the other field stays nominal
    assert plus.e_op_marginal_fj == EnergyModel().e_op_marginal_fj
    with pytest.raises(ValueError, match="not sweepable"):
        ModelTable.sensitivity(fields=("nonsense",))


def test_monte_carlo_generator_errors_and_fields():
    with pytest.raises(ValueError):
        ModelTable.monte_carlo(n=0)
    with pytest.raises(ValueError):
        ModelTable.from_models([])
    table = ModelTable.monte_carlo(
        n=4, sigma=0.2, seed=11, fields=("f_clk_hz",)
    )
    base = EnergyModel()
    for v in range(1, 4):
        m = table.model(v)
        assert m.f_clk_hz != base.f_clk_hz
        assert m.p_ctrl_mw == base.p_ctrl_mw  # unswept fields untouched


def test_monte_carlo_clamps_utilization():
    # regression: N(1, sigma) at large sigma used to push samples past
    # 1.0 ops per cycle slot, inflating throughput for those variants
    table = ModelTable.monte_carlo(n=64, sigma=2.0, seed=3)
    assert table.pipeline_utilization.max() <= 1.0
    assert table.pipeline_utilization.min() > 0.0
    # the floor still applies to every other field
    assert (table.p_ctrl_mw > 0).all()


def test_empty_model_table_raises():
    # constructing a 0-row table is rejected outright...
    with pytest.raises(ValueError, match="empty ModelTable"):
        ModelTable(
            names=(),
            **{
                f.name: np.zeros((0, 3) if f.name in
                                 ("e_op_fj", "e_op_marginal_fj") else (0,))
                for f in dataclasses.fields(EnergyModel)
            },
        )
    # ...and a degenerate falsy table smuggled past __post_init__ errors
    # loudly instead of being silently swapped for the nominal model by
    # a truthiness check (ModelTable defines __len__)
    rogue = object.__new__(ModelTable)
    object.__setattr__(rogue, "names", ())
    for f in dataclasses.fields(EnergyModel):
        shape = (0, 3) if f.name in ("e_op_fj", "e_op_marginal_fj") else (0,)
        object.__setattr__(rogue, f.name, np.zeros(shape))
    assert not rogue  # falsy: the old `model or EnergyModel()` dropped it
    tt = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:3])
    with pytest.raises(ValueError, match="empty ModelTable"):
        table2_batch(tt, rogue)
    with pytest.raises(ValueError, match="empty ModelTable"):
        evaluate_batch(random_workload(np.random.default_rng(0)), tt, rogue)


# ---------------------------------------------------------------------------
# Correlated (V, T) variation: per-topology model fields
# ---------------------------------------------------------------------------


def as_v1_table(table: ModelTable) -> ModelTable:
    """The same table with every scalar field reshaped (V,) -> (V, 1)."""
    kw = {}
    for f in dataclasses.fields(EnergyModel):
        arr = getattr(table, f.name)
        if f.name not in ("e_op_fj", "e_op_marginal_fj"):
            arr = arr[:, None]
        kw[f.name] = arr
    return ModelTable(names=table.names, **kw)


def test_v1_table_bit_identical_to_uniform_sweep():
    rng = np.random.default_rng(17)
    work = random_workload(rng)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.monte_carlo(n=4, sigma=0.2, seed=9)
    v1 = as_v1_table(table)
    assert v1.n_topologies is None  # (V, 1) broadcasts uniformly
    a = evaluate_batch(work, topos, table)
    b = evaluate_batch(work, topos, v1)
    for k in METRIC_KEYS:
        np.testing.assert_array_equal(getattr(a, k), getattr(b, k))
    np.testing.assert_array_equal(a.area_mm2, b.area_mm2)
    np.testing.assert_array_equal(a.best_indices(), b.best_indices())
    # table2 and the (V, 1) model() round-trip agree too
    tb_a, tb_b = table2_batch(topos, table), table2_batch(topos, v1)
    for k in tb_a:
        np.testing.assert_array_equal(tb_a[k], tb_b[k])
    assert v1.model(2) == table.model(2)


def test_correlated_generator_shapes_and_validation():
    table = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=4, sigma=0.2, seed=0
    )
    assert table.n_topologies == len(TOPOLOGY_LIBRARY)
    assert table.bitcell_um2.shape == (4, 12)
    assert table.f_clk_hz.shape == (4,)  # unswept fields stay (V,)
    assert table.model(0) == EnergyModel()  # row 0 nominal (uniform)
    # smaller macros see a wider spread (Pelgrom-style area averaging):
    # column 0 is (256x128), column 11 is (256x1024)
    spread = table.bitcell_um2[1:].std(axis=0)
    assert spread[0] > spread[9]
    # per-op fields produce a (V, T, 3) axis (tests/test_fused.py covers
    # their kernel parity); unknown fields are rejected
    per_op = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=3, fields=("e_op_fj",)
    )
    assert per_op.e_op_fj.shape == (3, 12, 3)
    assert per_op.n_topologies == 12
    with pytest.raises(ValueError, match="not sweepable"):
        ModelTable.bitcell_sigma_per_macro(
            TOPOLOGY_LIBRARY, fields=("nonsense",)
        )
    with pytest.raises(ValueError, match="empty topology"):
        ModelTable.bitcell_sigma_per_macro(())
    # utilization swept per-topology is clamped like monte_carlo's
    big = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=32, sigma=3.0, seed=1,
        fields=("pipeline_utilization",),
    )
    assert big.pipeline_utilization.max() <= 1.0
    # a mismatched per-topology axis is rejected by the batched paths
    short = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:5])
    table_12 = ModelTable.bitcell_sigma_per_macro(TOPOLOGY_LIBRARY, n=2)
    with pytest.raises(ValueError, match="per-topology axis"):
        evaluate_batch(
            random_workload(np.random.default_rng(0)), short, table_12
        )
    with pytest.raises(ValueError, match="per-topology axis"):
        table2_batch(short, table_12)
    # ...and so is a same-length but reordered/different topology list,
    # where each column's variation would land on the wrong geometry
    assert table_12.topology_names == tuple(
        t.name for t in TOPOLOGY_LIBRARY
    )
    reordered = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[::-1])
    with pytest.raises(ValueError, match="generated for"):
        evaluate_batch(
            random_workload(np.random.default_rng(0)), reordered, table_12
        )
    with pytest.raises(ValueError, match="generated for"):
        table2_batch(reordered, table_12)
    # mixed widths inside one table are rejected at construction
    bad_kw = {
        f.name: getattr(table_12, f.name)
        for f in dataclasses.fields(EnergyModel)
    }
    bad_kw["p_ctrl_mw"] = np.ones((2, 5))
    with pytest.raises(ValueError, match="per-topology width"):
        ModelTable(names=table_12.names, **bad_kw)


def test_correlated_variant_slices_work_without_scalar_model():
    """grid(v)/suite(v) slices of a correlated sweep stay usable for
    every variant; only the scalar-model materialization (which is
    genuinely ill-defined per topology-dependent variant) raises."""
    rng = np.random.default_rng(3)
    work = random_workload(rng, n_recipes=3)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=3, sigma=0.3, seed=4
    )
    assert table.uniform_row(0) and not table.uniform_row(1)
    vg = evaluate_batch(work, topos, table)
    g0, g1 = vg.grid(0), vg.grid(1)
    assert g0.model == EnergyModel()
    assert g1.model is None  # no single EnergyModel represents row 1
    # the slice still filters/selects like any grid
    assert g1.best_index() == int(vg.best_indices()[1])
    assert g1.fit_energies().size > 0
    suite = SuiteTable.from_workloads({"a": work, "b": work})
    svg = evaluate_suite(suite, topos, table)
    assert svg.suite(0).model == EnergyModel()
    assert svg.suite(1).model is None
    # best_worst needs a scalar model to materialize Evaluations: clear
    # error instead of silently evaluating with the wrong constants
    from repro.core.explorer import ExplorationResult, best_worst

    res = ExplorationResult(
        circuit="x", best=None, inductor_nh=0.0, opt_gate_recipe=(),
        opt_level_recipe=(), evaluations=[], n_recipes=1, wall_s=0.0,
        backend="jax", grid=g1, cha={},
    )
    with pytest.raises(ValueError, match="no single scalar model"):
        best_worst(res)


def test_correlated_sweep_matches_scalar_path():
    """Every (variant, topology) cell of a correlated sweep equals the
    scalar path run with that cell's materialized EnergyModel — the
    same parity contract (rtol 1e-12) as the uniform grids."""
    rng = np.random.default_rng(5)
    items = [
        ((str(i),), stats_from_levels(
            [tuple(int(x) for x in rng.integers(0, 800, 3))
             for _ in range(int(rng.integers(1, 6)))]
        ))
        for i in range(4)
    ]
    work = WorkloadTable.from_stats(items)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=3, sigma=0.4, seed=21
    )
    vg = evaluate_batch(work, topos, table)
    for v in range(3):
        for t in range(len(TOPOLOGY_LIBRARY)):
            m = table.model(v, topology=t)
            topo = TOPOLOGY_LIBRARY[t]
            for r, (_, stats) in enumerate(items):
                sched = schedule_stats(stats, topo)
                met = evaluate(sched, topo, m)
                np.testing.assert_allclose(
                    vg.energy_nj[v, t, r], met.energy_nj, rtol=1e-12
                )
                np.testing.assert_allclose(
                    vg.latency_ns[v, t, r], met.latency_ns, rtol=1e-12
                )
                np.testing.assert_allclose(
                    vg.throughput_gops[v, t, r], met.throughput_gops,
                    rtol=1e-12,
                )
                np.testing.assert_allclose(
                    vg.area_mm2[v, t], met.area_mm2, rtol=1e-12
                )


def test_suite_best_indices_match_select_best_loop(bar_suite):
    """Acceptance: the batched (C, V) selection pass returns bit-identical
    winners to the per-variant `select_best` loop across every generator
    on the full 65-recipe x 12-topology suite."""
    suite, cha = bar_suite
    suite_table = SuiteTable.from_cha(cha)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    tables = {
        "corners": ModelTable.corners(spread=0.15),
        "sensitivity": ModelTable.sensitivity(rel=0.1),
        "monte_carlo": ModelTable.monte_carlo(n=7, sigma=0.3, seed=13),
        "correlated": ModelTable.bitcell_sigma_per_macro(
            TOPOLOGY_LIBRARY, n=7, sigma=0.3, seed=13
        ),
    }
    for max_lat in (None, 40.0):
        for kind, table in tables.items():
            svg = evaluate_suite(suite_table, topos, table)
            assert svg.energy_nj.shape[2:] == (12, 65)
            got = svg.best_indices(max_lat)
            assert got.shape == (len(svg.circuits), len(table))
            for c, name in enumerate(svg.circuits):
                vgrid = svg.variation(name)
                feas = np.broadcast_to(
                    vgrid.feasible[:, None], vgrid.fits.shape
                )
                for v in range(len(table)):
                    ref = select_best(
                        vgrid.energy_nj[v], vgrid.fits,
                        latency=vgrid.latency_ns[v], max_latency=max_lat,
                        feasible=feas,
                    )
                    assert int(got[c, v]) == ref, (kind, max_lat, name, v)


def test_variation_cell_matches_materialized_grids(bar_suite):
    """`cell()` on the variation grids — lazy per-design gathers equal
    the materialized tensors field for field, variant axis included."""
    suite, cha = bar_suite
    suite_table = SuiteTable.from_cha(cha)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.monte_carlo(n=5, sigma=0.2, seed=17)
    svg = evaluate_suite(suite_table, topos, table)
    v, t, r = 3, 7, 11
    cell = svg.cell("bar", v, t, r)
    assert cell.circuit == "bar" and cell.variant == v
    assert cell.cycles == int(svg.cycles[0, t, r])
    assert cell.fits == bool(svg.fits[0, t, r])
    assert cell.feasible == bool(svg.feasible[0, t])
    assert cell.energy_nj == float(svg.energy_nj[0, v, t, r])
    assert cell.power_mw == float(svg.power_mw[0, v, t, r])
    assert cell.tops_per_watt == float(svg.tops_per_watt[0, v, t, r])
    assert cell.area_mm2 == float(svg.area_mm2[v, t])
    vg = svg.variation("bar")
    vcell = vg.cell(v, t, r)
    assert vcell.energy_nj == cell.energy_nj
    assert vcell.area_mm2 == cell.area_mm2
    assert vcell.circuit is None and vcell.variant == v


def test_correlated_explore_suite_end_to_end(bar_suite):
    """Acceptance: a (V, T) correlated sweep through
    `explore_suite(model_sweep=...)` -> yield summary, in ONE compile
    (of the fused evaluate+select kernel — the default device-resident
    path since the selection stage moved on device)."""
    suite, cha = bar_suite
    table = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=5, sigma=0.5, seed=2
    )
    before = trace_counts().get("fused_suite", 0)
    res = explore_suite(suite, cha=cha, model_sweep=table)["bar"]
    assert trace_counts().get("fused_suite", 0) == before + 1
    var = res.variation
    assert var is not None and var.n_variants == 5
    assert res.n_evaluations == 65 * 12
    assert sum(var.winner_share.values()) == pytest.approx(1.0)
    assert 0.0 < var.best_yield <= 1.0
    # winners equal the per-variant loop on the circuit's VariationGrid
    feas = np.broadcast_to(var.grid.feasible[:, None], var.grid.fits.shape)
    for v, (recipe, topo) in enumerate(var.winners):
        ti, ri = var.grid.unravel(
            select_best(
                var.grid.energy_nj[v], var.grid.fits,
                latency=var.grid.latency_ns[v], feasible=feas,
            )
        )
        assert (var.grid.recipes[ri], var.grid.topologies[ti]) == (
            recipe, topo
        )
    # headline best stays the nominal variant's
    nominal = explore_suite(suite, cha=cha, model=table.model(0))["bar"]
    assert (res.best.recipe, res.best.topo) == (
        nominal.best.recipe, nominal.best.topo
    )


# ---------------------------------------------------------------------------
# Vectorized area + Table II over the model axis
# ---------------------------------------------------------------------------


def test_topology_table_area_vectorized_matches_scalar():
    tt = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    em = EnergyModel()
    ref = np.array([t.area_mm2(em) for t in TOPOLOGY_LIBRARY])
    np.testing.assert_array_equal(tt.area_mm2(em), ref)

    table = ModelTable.sensitivity(
        fields=("bitcell_um2", "periphery_overhead"), rel=0.1
    )
    va = tt.area_mm2(table)
    assert va.shape == (len(table), len(TOPOLOGY_LIBRARY))
    for v in range(len(table)):
        np.testing.assert_array_equal(
            va[v],
            np.array([t.area_mm2(table.model(v)) for t in TOPOLOGY_LIBRARY]),
        )


def test_table2_batch_over_model_table():
    tt = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:5])
    table = ModelTable.monte_carlo(n=3, sigma=0.1, seed=2)
    out = table2_batch(tt, table)
    for v in range(3):
        ref = table2_batch(tt, table.model(v))
        for k, arr in ref.items():
            assert out[k].shape == (3, 5)
            np.testing.assert_array_equal(out[k][v], arr)
