"""Parity + no-recompile tests for the dynamic energy-model axis.

The contract under test (the yield/variation engine of core/batch.py):

  * a 1-variant `ModelTable` sweep is **bit-identical** to the
    static-`EnergyModel` path, across grids, accounting modes, and
    scheduling disciplines (the model constants moved from jit statics
    to traced operands without changing a single float op);
  * an N-variant sweep matches N serial static-model runs on every
    (circuit, recipe, topology) cell, including the per-variant
    `select_best` winners;
  * the whole sweep costs exactly ONE jit trace, and changing only the
    model floats never retriggers tracing (`batch.trace_counts`).

The property suites run under hypothesis when it is installed
(``pip install -e .[test]``); deterministic seeded versions of the same
assertions always run, so the parity contract is enforced either way.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import circuits as C
from repro.core.aig import AigStats
from repro.core.batch import (
    SuiteTable,
    TopologyTable,
    WorkloadTable,
    evaluate_batch,
    evaluate_suite,
    table2_batch,
    trace_counts,
)
from repro.core.explorer import characterize_recipes, explore_suite
from repro.core.sram import (
    SWEEPABLE_FIELDS,
    TOPOLOGY_LIBRARY,
    EnergyModel,
    ModelTable,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False

METRIC_KEYS = (
    "latency_ns", "energy_nj", "power_mw", "throughput_gops", "tops_per_watt"
)


def stats_from_levels(levels):
    ops = [dict(nand=a, nor=b, inv=c) for a, b, c in levels]
    return AigStats(
        n_pis=8, n_pos=4, n_ands=0, n_levels=len(ops), ops_per_level=ops,
        nand_count=sum(l[0] for l in levels),
        nor_count=sum(l[1] for l in levels),
        inv_count=sum(l[2] for l in levels),
    )


def random_workload(rng, n_recipes=5, max_levels=9, max_ops=2000):
    items = []
    for i in range(n_recipes):
        n = int(rng.integers(1, max_levels + 1))
        levels = [
            tuple(int(x) for x in rng.integers(0, max_ops, size=3))
            for _ in range(n)
        ]
        items.append(((str(i),), stats_from_levels(levels)))
    return WorkloadTable.from_stats(items)


def scale_model(base: EnergyModel, k: float) -> EnergyModel:
    """Every sweepable field scaled by ``k`` — a maximally 'different'
    model that still exercises all constants."""
    kw = {}
    for f in SWEEPABLE_FIELDS:
        v = getattr(base, f)
        kw[f] = tuple(x * k for x in v) if isinstance(v, tuple) else v * k
    return dataclasses.replace(base, **kw)


def assert_one_variant_bit_identical(work, topos, model, mode, discipline):
    static = evaluate_batch(work, topos, model, mode=mode,
                            discipline=discipline)
    sweep = evaluate_batch(
        work, topos, ModelTable.from_models([model]), mode=mode,
        discipline=discipline,
    )
    assert sweep.n_variants == 1
    np.testing.assert_array_equal(static.cycles, sweep.cycles)
    np.testing.assert_array_equal(
        static.active_macro_cycles, sweep.active_macro_cycles
    )
    np.testing.assert_array_equal(static.fits, sweep.fits)
    for k in METRIC_KEYS:
        a, b = getattr(static, k), getattr(sweep, k)[0]
        assert np.array_equal(a, b), f"{k} not bit-identical"
    assert np.array_equal(static.area_mm2, sweep.area_mm2[0])
    # identical winner, including tie-breaking
    assert static.best_index() == int(sweep.best_indices()[0])
    # and the variant-0 slice is a full ExplorationGrid equal to static
    g0 = sweep.grid(0)
    assert np.array_equal(static.energy_nj, g0.energy_nj)
    assert g0.model == model


# ---------------------------------------------------------------------------
# 1-variant sweep == static path, bit for bit (deterministic seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["physical", "paper"])
@pytest.mark.parametrize("discipline", ["list", "levels"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_one_variant_bit_identical(mode, discipline, seed):
    rng = np.random.default_rng(seed)
    work = random_workload(rng)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    model = scale_model(EnergyModel(), float(rng.uniform(0.3, 3.0)))
    assert_one_variant_bit_identical(work, topos, model, mode, discipline)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        workloads=st.lists(
            st.lists(
                st.tuples(
                    st.integers(0, 1500),
                    st.integers(0, 1500),
                    st.integers(0, 400),
                ),
                min_size=1,
                max_size=8,
            ),
            min_size=1,
            max_size=4,
        ),
        scale=st.floats(0.25, 4.0),
        mode=st.sampled_from(["physical", "paper"]),
        discipline=st.sampled_from(["list", "levels"]),
    )
    def test_property_one_variant_bit_identical(
        workloads, scale, mode, discipline
    ):
        work = WorkloadTable.from_stats(
            [((str(i),), stats_from_levels(lv))
             for i, lv in enumerate(workloads)]
        )
        topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
        model = scale_model(EnergyModel(), scale)
        assert_one_variant_bit_identical(work, topos, model, mode, discipline)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 9),
        sigma=st.floats(0.01, 0.5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_modeltable_roundtrip(n, sigma, seed):
        table = ModelTable.monte_carlo(n=n, sigma=sigma, seed=seed)
        assert len(table) == n
        # float64 -> EnergyModel -> float64 round-trips exactly
        again = ModelTable.from_models(table.models(), names=table.names)
        for f in dataclasses.fields(EnergyModel):
            np.testing.assert_array_equal(
                getattr(table, f.name), getattr(again, f.name)
            )
        # seeded: same seed reproduces, row 0 is nominal
        assert table.model(0) == EnergyModel()
        table2 = ModelTable.monte_carlo(n=n, sigma=sigma, seed=seed)
        np.testing.assert_array_equal(table.p_ctrl_mw, table2.p_ctrl_mw)

else:  # keep the property suite visible as skips when hypothesis is absent

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
    def test_property_one_variant_bit_identical():
        pass

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
    def test_property_modeltable_roundtrip():
        pass


# ---------------------------------------------------------------------------
# N-variant sweep == N serial static-model runs (65 x 12 slice)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bar_suite():
    suite = C.benchmark_suite(scale="tiny", only=("bar",))
    cha = {"bar": characterize_recipes(suite["bar"])}  # all 64 recipes + ()
    return suite, cha


def test_variant_winners_match_serial_explore_suite(bar_suite):
    suite, cha = bar_suite
    table = ModelTable.monte_carlo(n=4, sigma=0.15, seed=7)
    res = explore_suite(suite, cha=cha, model_sweep=table)["bar"]
    var = res.variation
    assert var is not None and var.n_variants == 4
    assert res.n_evaluations == 65 * 12  # the acceptance slice
    assert sum(var.winner_share.values()) == pytest.approx(1.0)
    for v in range(4):
        serial = explore_suite(suite, cha=cha, model=table.model(v))["bar"]
        # identical winner implementation...
        assert (serial.best.recipe, serial.best.topo) == var.winners[v]
        # ...and identical energies on every (recipe, topology) cell
        np.testing.assert_array_equal(
            var.grid.energy_nj[v], serial.grid.energy_nj
        )
        np.testing.assert_array_equal(
            var.grid.latency_ns[v], serial.grid.latency_ns
        )
    # the headline best/grid are the nominal variant's
    nominal = explore_suite(suite, cha=cha, model=table.model(0))["bar"]
    assert res.best.metrics.energy_nj == nominal.best.metrics.energy_nj
    assert (res.best.recipe, res.best.topo) == (
        nominal.best.recipe, nominal.best.topo
    )


def test_degenerate_sweep_yield_is_one(bar_suite):
    suite, cha = bar_suite
    em = EnergyModel()
    table = ModelTable.from_models([em] * 5)
    res = explore_suite(
        suite, cha=cha, recipes=[("Ba",), ("Rw",)], model_sweep=table
    )["bar"]
    var = res.variation
    assert var.best_yield == 1.0
    assert var.latency_yield == 1.0
    assert len(set(var.winners)) == 1
    assert var.winner_share == {
        f"{res.best.topo.name}/{','.join(res.best.recipe) or '-'}": 1.0
    }


def test_model_sweep_argument_validation(bar_suite):
    suite, cha = bar_suite
    table = ModelTable.corners()
    with pytest.raises(ValueError, match="either model or model_sweep"):
        explore_suite(suite, cha=cha, model=EnergyModel(), model_sweep=table)
    with pytest.raises(ValueError, match="backend"):
        explore_suite(suite, cha=cha, model_sweep=table, backend="python")


# ---------------------------------------------------------------------------
# Compile-count guard: one trace per sweep, zero per float change
# ---------------------------------------------------------------------------


def test_sweep_traces_exactly_once_and_float_changes_do_not_retrace():
    # Unique grid shape (R=11 recipes, C=3 circuits) so the first call is
    # guaranteed to be a fresh trace even when other tests ran first.
    rng = np.random.default_rng(123)
    work = random_workload(rng, n_recipes=11)
    suite = SuiteTable.from_workloads(
        {"a": work, "b": work, "c": work}
    )
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table_a = ModelTable.monte_carlo(n=8, sigma=0.1, seed=0)

    before = trace_counts().get("evaluate_suite", 0)
    svg_a = evaluate_suite(suite, topos, table_a)
    assert trace_counts().get("evaluate_suite", 0) == before + 1

    # Same shapes, different model floats: served from the jit cache.
    table_b = ModelTable.monte_carlo(n=8, sigma=0.4, seed=99)
    svg_b = evaluate_suite(suite, topos, table_b)
    assert trace_counts().get("evaluate_suite", 0) == before + 1
    # ...and the floats really flowed through (not a stale constant).
    assert not np.array_equal(svg_a.energy_nj, svg_b.energy_nj)
    np.testing.assert_array_equal(svg_a.cycles, svg_b.cycles)

    # A new variant count is a new shape: exactly one more trace.
    evaluate_suite(suite, topos, ModelTable.monte_carlo(n=16, seed=1))
    assert trace_counts().get("evaluate_suite", 0) == before + 2


def test_serial_static_models_share_one_compile():
    rng = np.random.default_rng(321)
    work = random_workload(rng, n_recipes=13)  # unique shape again
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.monte_carlo(n=6, sigma=0.2, seed=5)

    before = trace_counts().get("evaluate_grid", 0)
    grids = [
        evaluate_batch(work, topos, table.model(v)) for v in range(6)
    ]
    # the old engine paid one compile per EnergyModel; now the first call
    # traces and the other five hit the cache
    assert trace_counts().get("evaluate_grid", 0) == before + 1
    # parity of the serial runs against the one-call sweep
    sweep = evaluate_batch(work, topos, table)
    assert trace_counts().get("evaluate_grid", 0) == before + 2  # V=6 shape
    for v, g in enumerate(grids):
        np.testing.assert_array_equal(sweep.energy_nj[v], g.energy_nj)
        assert int(sweep.best_indices()[v]) == g.best_index()


# ---------------------------------------------------------------------------
# ModelTable generators
# ---------------------------------------------------------------------------


def test_corners_generator():
    table = ModelTable.corners(spread=0.1)
    assert table.names == ("tt", "ff", "ss")
    base = EnergyModel()
    assert table.model(0) == base
    ff, ss = table.model(1), table.model(2)
    # fast silicon: cheaper ops, faster clock; slow: the reverse
    assert ff.e_op_fj[0] < base.e_op_fj[0] < ss.e_op_fj[0]
    assert ff.f_clk_hz > base.f_clk_hz > ss.f_clk_hz
    # geometry is corner-independent
    assert ff.bitcell_um2 == base.bitcell_um2 == ss.bitcell_um2


def test_sensitivity_generator():
    fields = ("p_ctrl_mw", "e_op_marginal_fj")
    table = ModelTable.sensitivity(fields=fields, rel=0.05)
    assert len(table) == 1 + 2 * len(fields)
    assert table.model(0) == EnergyModel()
    plus = table.model(1)
    assert plus.p_ctrl_mw == pytest.approx(EnergyModel().p_ctrl_mw * 1.05)
    # one-at-a-time: the other field stays nominal
    assert plus.e_op_marginal_fj == EnergyModel().e_op_marginal_fj
    with pytest.raises(ValueError, match="not sweepable"):
        ModelTable.sensitivity(fields=("nonsense",))


def test_monte_carlo_generator_errors_and_fields():
    with pytest.raises(ValueError):
        ModelTable.monte_carlo(n=0)
    with pytest.raises(ValueError):
        ModelTable.from_models([])
    table = ModelTable.monte_carlo(
        n=4, sigma=0.2, seed=11, fields=("f_clk_hz",)
    )
    base = EnergyModel()
    for v in range(1, 4):
        m = table.model(v)
        assert m.f_clk_hz != base.f_clk_hz
        assert m.p_ctrl_mw == base.p_ctrl_mw  # unswept fields untouched


# ---------------------------------------------------------------------------
# Vectorized area + Table II over the model axis
# ---------------------------------------------------------------------------


def test_topology_table_area_vectorized_matches_scalar():
    tt = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    em = EnergyModel()
    ref = np.array([t.area_mm2(em) for t in TOPOLOGY_LIBRARY])
    np.testing.assert_array_equal(tt.area_mm2(em), ref)

    table = ModelTable.sensitivity(
        fields=("bitcell_um2", "periphery_overhead"), rel=0.1
    )
    va = tt.area_mm2(table)
    assert va.shape == (len(table), len(TOPOLOGY_LIBRARY))
    for v in range(len(table)):
        np.testing.assert_array_equal(
            va[v],
            np.array([t.area_mm2(table.model(v)) for t in TOPOLOGY_LIBRARY]),
        )


def test_table2_batch_over_model_table():
    tt = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:5])
    table = ModelTable.monte_carlo(n=3, sigma=0.1, seed=2)
    out = table2_batch(tt, table)
    for v in range(3):
        ref = table2_batch(tt, table.model(v))
        for k, arr in ref.items():
            assert out[k].shape == (3, 5)
            np.testing.assert_array_equal(out[k][v], arr)
