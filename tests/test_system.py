"""End-to-end behaviour tests for the whole system.

1. The paper's pipeline: circuit -> 64-recipe exploration -> optimal rCiM
   architecture, with functional equivalence verified through the Pallas
   CiM engine end to end.
2. The LM pipeline: train a tiny model for a few steps (loss drops),
   checkpoint, kill, resume (fault tolerance), then serve from it.
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_paper_pipeline_end_to_end():
    """RTL -> Algorithm I -> best topology, and the chosen implementation
    still computes the right function when executed on the CiM engine."""
    from repro.core import circuits as C
    from repro.core.explorer import explore
    from repro.core.transforms import RecipeRunner
    from repro.kernels import ops

    rtl = C.gen_adder(16)
    res = explore(rtl, recipes=[("Ba",), ("Rw",), ("Rs", "Rw")])
    assert res.best.schedule.fits and res.inductor_nh > 0

    # run the best AIG through the Pallas CiM engine and check arithmetic
    best_aig = RecipeRunner(rtl).run(res.best.recipe)
    net = best_aig.to_gate_netlist()
    rng = np.random.default_rng(0)
    xs = rng.integers(0, 1 << 16, size=64)
    ys = rng.integers(0, 1 << 16, size=64)
    bits = np.zeros((32, 64), np.uint8)
    for v in range(64):
        for i in range(16):
            bits[i, v] = (xs[v] >> i) & 1
            bits[16 + i, v] = (ys[v] >> i) & 1
    out = ops.cim_evaluate(net, bits, block_words=128)
    for v in range(64):
        s = sum(int(out[i, v]) << i for i in range(16))
        c = int(out[16, v])
        assert s == (int(xs[v]) + int(ys[v])) % (1 << 16)
        assert c == ((int(xs[v]) + int(ys[v])) >> 16) & 1


def test_train_checkpoint_resume_serve(tmp_path):
    """Tiny end-to-end: train, checkpoint, restore, continue, serve."""
    from repro.ckpt.manager import CheckpointManager
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.models.config import ParallelConfig
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig, adamw_init, constant_schedule
    from repro.serve.engine import ServeEngine
    from repro.train.steps import make_train_step

    cfg = smoke_config("qwen1.5-4b")
    model = Model(cfg, ParallelConfig(), q_chunk=16, kv_chunk=16)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt = adamw_init(params, opt_cfg)
    data = Pipeline(DataConfig(batch_per_host=4, seq_len=32,
                               vocab_size=cfg.vocab_size, seed=0))
    step = jax.jit(make_train_step(model, constant_schedule(3e-3), opt_cfg))

    losses = []
    for s in range(8):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]  # tiny model on zipf data learns marginals

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(8, dict(p=params, o=opt))

    # "crash" -> restore into fresh trees and continue one step
    model2 = Model(cfg, ParallelConfig(), q_chunk=16, kv_chunk=16)
    fresh_p = model2.init(jax.random.PRNGKey(1))
    fresh_o = adamw_init(fresh_p, opt_cfg)
    (restored), meta = mgr.restore(dict(p=fresh_p, o=fresh_o))
    p2, o2 = restored["p"], restored["o"]
    assert int(np.asarray(o2["step"])) == 8
    batch = {k: jnp.asarray(v) for k, v in data.get_batch(8).items()}
    p2, o2, m2 = step(p2, o2, batch)
    assert np.isfinite(float(m2["loss"]))

    # serve from the trained weights
    engine = ServeEngine(model2, p2, batch=2, max_seq=48)
    out = engine.generate(np.ones((2, 16), np.int32), max_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_greedy_serving_deterministic():
    from repro.configs import smoke_config
    from repro.models.config import ParallelConfig
    from repro.models.model import Model
    from repro.serve.engine import ServeEngine

    cfg = smoke_config("gemma3-27b")  # exercises the local ring cache
    model = Model(cfg, ParallelConfig(), compute_dtype=jnp.float32,
                  q_chunk=16, kv_chunk=16)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch=2, max_seq=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 24)).astype(np.int32)
    a = engine.generate(prompts, max_new=6)
    b = engine.generate(prompts, max_new=6)
    np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """One real dry-run cell through the CLI (512 fake devices, compile)."""
    code = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-tiny",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env=dict(PYTHONPATH="src", PATH="/usr/bin:/bin:/usr/local/bin",
                 HOME="/root"),
        cwd="/root/repo",
    )
    assert "dry-run complete: 1 ok" in code.stdout, code.stdout + code.stderr
