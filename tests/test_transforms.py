"""Transform tests: the four ABC-style transforms preserve semantics.

Deterministic equivalence / regression tests always run; the
hypothesis-driven property tests are gated on the optional dependency
(``pip install -e .[test]``) instead of skipping the whole module.
"""

import numpy as np
import pytest

from repro.core import circuits as C
from repro.core.aig import random_aig
from repro.core.transforms import (
    RecipeRunner,
    _cofactors,
    _cover_tt,
    _isop,
    _tt_mask,
    apply_recipe,
    balance,
    enumerate_recipes,
    refactor,
    resub,
    rewrite,
    synth_plan,
    build_plan,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False

TRANSFORMS = [balance, rewrite, refactor, resub]
rng = np.random.default_rng(42)


def equivalent(a, b, n_words=8) -> bool:
    if a.n_pis != b.n_pis or len(a.pos) != len(b.pos):
        return False
    pv = rng.integers(0, 1 << 63, size=(a.n_pis, n_words), dtype=np.int64).astype(np.uint64)
    return np.array_equal(a.simulate(pv), b.simulate(pv))


def exhaustive_equivalent(a, b) -> bool:
    """Exact check for <= 10 PIs via all input patterns."""
    from repro.core.aig import _elementary_tables

    k = a.n_pis
    assert k <= 10
    pv = _elementary_tables(k)
    words = pv.shape[1]
    return np.array_equal(a.simulate(pv), b.simulate(pv))


@pytest.mark.parametrize("fn", TRANSFORMS)
@pytest.mark.parametrize(
    "gen", [lambda: C.gen_adder(16), lambda: C.gen_multiplier(8),
            lambda: C.gen_max(8, 4), lambda: C.gen_sine(8)],
    ids=["adder16", "mult8", "max8", "sine8"],
)
def test_transform_on_circuits(fn, gen):
    a = gen()
    b = fn(a)
    assert equivalent(a, b), fn.__name__
    assert b.n_ands <= a.n_ands * 1.05 + 4  # never blows up


def test_recipe_count():
    rs = enumerate_recipes()
    assert len(rs) == 64  # sum_{i=1..4} P(4,i) = 4+12+24+24
    assert len(set(rs)) == 64


def test_recipe_prefix_cache_consistent():
    a = C.gen_adder(12)
    runner = RecipeRunner(a)
    direct = apply_recipe(a, ("Ba", "Rw", "Rs"))
    cached = runner.run(("Ba", "Rw", "Rs"))
    # same prefix path -> identical results from the runner
    assert equivalent(direct, cached)
    assert equivalent(a, cached)


def test_all_recipes_equivalent_small():
    a = C.gen_max(6, 3)
    runner = RecipeRunner(a)
    for r in enumerate_recipes():
        assert exhaustive_equivalent(a, runner.run(r)) if a.n_pis <= 10 else equivalent(a, runner.run(r)), r


def test_rewrite_reduces_redundant():
    a = random_aig(8, 300, 4, seed=9)
    b = rewrite(a)
    assert b.n_ands <= a.n_ands


# ------------------------- property tests (hypothesis) ---------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        n_pis=st.integers(4, 9),
        n_ands=st.integers(10, 150),
        n_pos=st.integers(1, 6),
        seed=st.integers(0, 10**6),
        which=st.integers(0, 3),
    )
    def test_transform_preserves_function_exact(n_pis, n_ands, n_pos, seed, which):
        a = random_aig(n_pis, n_ands, n_pos, seed=seed)
        b = TRANSFORMS[which](a)
        assert exhaustive_equivalent(a, b), TRANSFORMS[which].__name__

    @settings(max_examples=80, deadline=None)
    @given(k=st.integers(1, 7), tt=st.integers(0, 2**63 - 1), i=st.integers(0, 6))
    def test_cofactors_brute(k, tt, i):
        if i >= k:
            i = i % k
        tt &= _tt_mask(k)
        neg, pos = _cofactors(tt, i, k)
        bneg = bpos = 0
        for p in range(1 << k):
            bpos |= ((tt >> (p | (1 << i))) & 1) << p
            bneg |= ((tt >> (p & ~(1 << i))) & 1) << p
        assert (neg, pos) == (bneg, bpos)

    @settings(max_examples=80, deadline=None)
    @given(k=st.integers(1, 7), tt=st.integers(0, 2**63 - 1))
    def test_isop_covers_exactly(k, tt):
        tt &= _tt_mask(k)
        cubes = _isop(tt, _tt_mask(k), k)
        assert _cover_tt(cubes, k) == tt

    @settings(max_examples=60, deadline=None)
    @given(k=st.integers(1, 4), tt=st.integers(0, 2**16 - 1))
    def test_synth_plan_correct(k, tt):
        from repro.core.aig import Aig, lit

        tt &= _tt_mask(k)
        cost, plan = synth_plan(tt, k)
        aig = Aig(k)
        out = build_plan(aig, plan, [lit(i + 1) for i in range(k)])
        aig.add_po(out)
        got = aig.truth_table(out, list(range(1, k + 1)))
        assert got == tt
        assert cost >= 0

else:  # pragma: no cover - CI installs the test extra

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
    def test_property_transforms():
        pass
