"""Trip-count-corrected HLO parsing, validated on hand-countable programs.

These compile tiny programs for the default (1-device CPU) backend — no
512-device env needed.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hloparse import analyze

M, K = 64, 32


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_matmul_flops_exact():
    hlo = _hlo(lambda a, b: a @ b, jnp.zeros((M, K)), jnp.zeros((K, 2 * M)))
    c = analyze(hlo)
    assert c.flops == pytest.approx(2 * M * K * 2 * M)


def test_scan_flops_multiplied_by_trip_count():
    def step(x, w):
        return x @ w, ()

    hlo = _hlo(lambda x, ws: jax.lax.scan(step, x, ws)[0],
               jnp.zeros((M, K)), jnp.zeros((10, K, K)))
    c = analyze(hlo)
    assert c.flops == pytest.approx(10 * 2 * M * K * K)
    assert 10 in c.trip_counts.values()


def test_nested_scan_multiplicity():
    def inner(x, w):
        return x @ w, ()

    def outer(x, ws):
        return jax.lax.scan(inner, x, ws)[0], ()

    hlo = _hlo(lambda x, ws: jax.lax.scan(outer, x, ws)[0],
               jnp.zeros((M, K)), jnp.zeros((4, 5, K, K)))
    c = analyze(hlo)
    assert c.flops == pytest.approx(4 * 5 * 2 * M * K * K)


def test_hbm_proxy_positive_and_scales_with_trips():
    def step(x, w):
        return x @ w, ()

    h1 = _hlo(lambda x, ws: jax.lax.scan(step, x, ws)[0],
              jnp.zeros((M, K)), jnp.zeros((2, K, K)))
    h2 = _hlo(lambda x, ws: jax.lax.scan(step, x, ws)[0],
              jnp.zeros((M, K)), jnp.zeros((20, K, K)))
    c1, c2 = analyze(h1), analyze(h2)
    assert c2.hbm_bytes > 4 * c1.hbm_bytes  # ~10x more loop traffic
