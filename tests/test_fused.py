"""Fused device-resident pipeline tests: on-device FilterEnergy parity,
sharding bit-identity, lazy grids, and the per-op (V, T, 3) plumbing.

Contracts under test:

  * `evaluate_select_batch` / `evaluate_select_suite` (evaluate + the
    three-tier masked argmin fused into one jitted pass) return winners
    identical to the host-side parity reference (`evaluate_suite` +
    `select_best_batch`) on every (circuit, variant) cell — including
    grids salted with NaN/±inf energies via pathological model variants,
    exact-tie grids (duplicate topology columns; lowest flat index wins),
    all-infeasible cells, and under latency/feasibility constraints;
  * an all-non-finite cell raises, exactly like `select_best_batch`;
  * the 1-device sharded path (`shard=True`) is bit-identical to the
    unsharded path — winners, per-winner metrics, and the full tensors;
  * lazy grids materialize to the same arrays the eager path returns,
    and the fused payload is orders of magnitude below the full-tensor
    transfer;
  * one jit trace per fused sweep; float-only model changes do not
    retrace;
  * correlated generators may emit per-op ``(V, T, 3)`` fields which
    flow through the same kernels and match the scalar path cell by
    cell, with `_check_topo_axis` rejecting mismatched topology lists;
  * `explore_suite(fused=True)` equals the `fused=False` host path end
    to end, `VariationResult` quantiles/CVaR included.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import circuits as C
from repro.core.aig import AigStats
from repro.core.batch import (
    SuiteTable,
    TopologyTable,
    WorkloadTable,
    evaluate_batch,
    evaluate_select_batch,
    evaluate_select_suite,
    evaluate_suite,
    table2_batch,
    trace_counts,
)
from repro.core.explorer import characterize_recipes, explore_suite
from repro.core.mapping import schedule_stats
from repro.core.sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    ModelTable,
    SramTopology,
    evaluate,
)

METRIC_KEYS = (
    "latency_ns", "energy_nj", "power_mw", "throughput_gops", "tops_per_watt"
)


def stats_from_levels(levels):
    ops = [dict(nand=a, nor=b, inv=c) for a, b, c in levels]
    return AigStats(
        n_pis=8, n_pos=4, n_ands=0, n_levels=len(ops), ops_per_level=ops,
        nand_count=sum(l[0] for l in levels),
        nor_count=sum(l[1] for l in levels),
        inv_count=sum(l[2] for l in levels),
    )


def random_workload(rng, n_recipes=6, max_levels=9, max_ops=2000):
    items = []
    for i in range(n_recipes):
        n = int(rng.integers(1, max_levels + 1))
        levels = [
            tuple(int(x) for x in rng.integers(0, max_ops, size=3))
            for _ in range(n)
        ]
        items.append(((str(i),), stats_from_levels(levels)))
    return WorkloadTable.from_stats(items)


def salted_table(topos, n=6, seed=0, nan_frac=0.15):
    """A Monte-Carlo `ModelTable` whose ``p_ctrl_mw`` carries a (V, T)
    axis salted with NaN/+inf entries — physical-mode energies become
    non-finite exactly in those (variant, topology) columns, giving the
    fused filter real NaN-salted grids without tripping the
    all-non-finite error (row 0 stays clean)."""
    table = ModelTable.monte_carlo(n=n, sigma=0.2, seed=seed)
    rng = np.random.default_rng(seed + 1)
    p = np.broadcast_to(
        table.p_ctrl_mw[:, None], (n, len(topos))
    ).copy()
    salt = rng.random((n, len(topos)))
    salt[0] = 1.0  # nominal variant stays finite everywhere
    p[salt < nan_frac / 2] = np.nan
    p[(salt >= nan_frac / 2) & (salt < nan_frac)] = np.inf
    return dataclasses.replace(
        table, p_ctrl_mw=p,
        topology_names=tuple(t.name for t in topos.topologies),
    )


def host_reference(grid, max_latency_ns=None):
    """The host-side parity reference: (C, V) winners via
    `SuiteVariationGrid.best_indices` (select_best_batch underneath)."""
    return grid.best_indices(max_latency_ns)


@pytest.fixture(scope="module")
def workloads():
    rng = np.random.default_rng(42)
    work = random_workload(rng)
    suite = SuiteTable.from_workloads(
        {"a": work, "b": random_workload(rng, n_recipes=6)}
    )
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    return work, suite, topos


# ---------------------------------------------------------------------------
# Fused-vs-host winner parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["physical", "paper"])
@pytest.mark.parametrize("max_lat", [None, 40.0])
def test_fused_suite_matches_host_selection(workloads, mode, max_lat):
    _, suite, topos = workloads
    table = ModelTable.monte_carlo(n=5, sigma=0.3, seed=7)
    svg = evaluate_suite(suite, topos, table, mode=mode)
    grid, sel = evaluate_select_suite(
        suite, topos, table, mode=mode, max_latency_ns=max_lat
    )
    host = host_reference(svg, max_lat)
    np.testing.assert_array_equal(sel.winner_idx.astype(np.int64), host)
    assert sel.winner_idx.dtype == np.int32
    # per-winner metrics equal the host gather on every metric
    c, v = host.shape
    for k in METRIC_KEYS:
        flat = getattr(svg, k).reshape(c, v, -1)
        ref = np.take_along_axis(flat, host[..., None], -1)[..., 0]
        np.testing.assert_array_equal(sel.winner_metrics[k], ref)
    # the lazy grid holds the same tensors the host path materialized
    for k in METRIC_KEYS + ("cycles", "fits"):
        np.testing.assert_array_equal(
            np.asarray(getattr(grid, k)), getattr(svg, k)
        )


def test_fused_matches_host_on_nan_salted_grids(workloads):
    _, suite, topos = workloads
    table = salted_table(topos, n=6, seed=3)
    svg = evaluate_suite(suite, topos, table)
    assert not np.isfinite(svg.energy_nj).all()  # the salt is real
    assert np.isfinite(svg.energy_nj).any(axis=(2, 3)).all()
    grid, sel = evaluate_select_suite(suite, topos, table)
    np.testing.assert_array_equal(
        sel.winner_idx.astype(np.int64), host_reference(svg)
    )
    # NaN cells never win
    c, v = sel.winner_idx.shape
    assert np.isfinite(sel.winner_energy_nj).all()


def test_fused_all_non_finite_raises(workloads):
    work, suite, topos = workloads
    # every variant's clock is NaN -> every energy non-finite
    table = ModelTable.monte_carlo(n=3, sigma=0.1, seed=0)
    table = dataclasses.replace(
        table, f_clk_hz=np.full(3, np.nan)
    )
    with pytest.raises(ValueError, match="finite"):
        evaluate_select_suite(suite, topos, table)
    with pytest.raises(ValueError, match="finite"):
        evaluate_select_batch(work, topos, table)


def test_fused_ties_break_to_lowest_flat_index(workloads):
    """Duplicate topology columns produce exact-tie energies; the fused
    argmin must pick the lower flat index, like the host filter."""
    work, _, _ = workloads
    dup = TopologyTable.from_topologies(
        (TOPOLOGY_LIBRARY[4], TOPOLOGY_LIBRARY[4], TOPOLOGY_LIBRARY[4])
    )
    vg = evaluate_batch(work, dup, ModelTable.monte_carlo(n=3, seed=1))
    grid, sel = evaluate_select_batch(
        work, dup, ModelTable.monte_carlo(n=3, seed=1)
    )
    host = vg.best_indices()
    np.testing.assert_array_equal(sel.winner_idx.astype(np.int64), host)
    # the duplicate columns really did tie, and column 0 won
    n_r = len(grid.recipes)
    assert (host < n_r).all()


def test_fused_all_infeasible_falls_through(workloads):
    """Nothing fits (huge workload) + nothing feasible: the fused filter
    falls through to the finite-energy tier exactly like the host."""
    rng = np.random.default_rng(9)
    big = random_workload(rng, n_recipes=4, max_ops=10_000_000)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:4])
    feas = np.zeros(4, dtype=bool)
    table = ModelTable.monte_carlo(n=4, sigma=0.2, seed=2)
    vg = evaluate_batch(big, topos, table, feasible=feas)
    assert not vg.fits.any()
    grid, sel = evaluate_select_batch(big, topos, table, feasible=feas)
    np.testing.assert_array_equal(
        sel.winner_idx.astype(np.int64), vg.best_indices()
    )


def test_fused_single_model_matches_host(workloads):
    work, suite, topos = workloads
    em = EnergyModel()
    sg = evaluate_suite(suite, topos, em)
    grid, sel = evaluate_select_suite(suite, topos, em)
    assert sel.winner_idx.shape == (len(suite), 1)
    for i, name in enumerate(suite.circuits):
        assert int(sel.winner_idx[i, 0]) == sg.grid(name).best_index()
    g, s = evaluate_select_batch(work, topos, em)
    ref = evaluate_batch(work, topos, em)
    assert int(s.winner_idx[0]) == ref.best_index()
    assert g.model == em


# ---------------------------------------------------------------------------
# Sharding, laziness, payload, trace counts
# ---------------------------------------------------------------------------


def test_one_device_sharded_is_bit_identical(workloads):
    """`shard=True` on a single device builds a 1-device mesh; every
    output — winners, per-winner metrics, the full tensors — must be
    bit-identical to the unsharded path."""
    _, suite, topos = workloads
    table = ModelTable.monte_carlo(n=4, sigma=0.25, seed=11)
    g_plain, s_plain = evaluate_select_suite(
        suite, topos, table, shard=False
    )
    g_shard, s_shard = evaluate_select_suite(suite, topos, table, shard=True)
    assert not s_plain.sharded and s_shard.sharded
    np.testing.assert_array_equal(s_shard.winner_idx, s_plain.winner_idx)
    np.testing.assert_array_equal(
        s_shard.nominal_latency_ns, s_plain.nominal_latency_ns
    )
    for k in METRIC_KEYS:
        np.testing.assert_array_equal(
            s_shard.winner_metrics[k], s_plain.winner_metrics[k]
        )
        np.testing.assert_array_equal(
            np.asarray(getattr(g_shard, k)), np.asarray(getattr(g_plain, k))
        )


def test_lazy_grid_materializes_identically(workloads):
    _, suite, topos = workloads
    table = ModelTable.monte_carlo(n=3, sigma=0.2, seed=5)
    lazy_grid, _ = evaluate_select_suite(suite, topos, table, lazy=True)
    eager_grid, _ = evaluate_select_suite(suite, topos, table, lazy=False)
    # before access the lazy fields are device arrays, not numpy
    assert not isinstance(lazy_grid._raw("energy_nj"), np.ndarray)
    for k in METRIC_KEYS + ("cycles", "active_macro_cycles", "fits"):
        np.testing.assert_array_equal(
            getattr(lazy_grid, k), getattr(eager_grid, k)
        )
    # access materialized + cached the field in place
    assert isinstance(lazy_grid._raw("energy_nj"), np.ndarray)
    # sliced views and shape queries inherit laziness
    lazy2, _ = evaluate_select_suite(suite, topos, table, lazy=True)
    vgrid = lazy2.variation(suite.circuits[0])
    assert lazy2.size == eager_grid.size  # .size must not materialize
    assert not isinstance(lazy2._raw("energy_nj"), np.ndarray)
    np.testing.assert_array_equal(
        vgrid.energy_nj, eager_grid.variation(suite.circuits[0]).energy_nj
    )


def test_fused_payload_is_small(workloads):
    _, suite, topos = workloads
    table = ModelTable.monte_carlo(n=8, sigma=0.2, seed=6)
    svg = evaluate_suite(suite, topos, table)
    _, sel = evaluate_select_suite(suite, topos, table)
    full = sum(getattr(svg, k).nbytes for k in METRIC_KEYS)
    assert sel.payload_bytes < full / 10
    c, v = len(suite), len(table)
    assert sel.winner_idx.nbytes == c * v * 4  # (C, V) int32


def test_fused_traces_once_and_float_changes_do_not_retrace():
    rng = np.random.default_rng(77)
    work = random_workload(rng, n_recipes=7)  # unique shape
    suite = SuiteTable.from_workloads({"x": work, "y": work, "z": work})
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    before = trace_counts().get("fused_suite", 0)
    _, s1 = evaluate_select_suite(
        suite, topos, ModelTable.monte_carlo(n=5, sigma=0.1, seed=0)
    )
    assert trace_counts().get("fused_suite", 0) == before + 1
    # float-only model change: served from the jit cache
    _, s2 = evaluate_select_suite(
        suite, topos, ModelTable.monte_carlo(n=5, sigma=0.4, seed=9)
    )
    assert trace_counts().get("fused_suite", 0) == before + 1
    assert not np.array_equal(s1.winner_energy_nj, s2.winner_energy_nj)
    # changing the latency *bound* does not retrace (traced operand)...
    _, _ = evaluate_select_suite(
        suite, topos, ModelTable.monte_carlo(n=5, seed=1),
        max_latency_ns=100.0,
    )
    after_lat = trace_counts().get("fused_suite", 0)
    _, _ = evaluate_select_suite(
        suite, topos, ModelTable.monte_carlo(n=5, seed=2),
        max_latency_ns=55.0,
    )
    assert trace_counts().get("fused_suite", 0) == after_lat


# ---------------------------------------------------------------------------
# Per-op (V, T, 3) correlated fields
# ---------------------------------------------------------------------------


def test_per_op_topology_axis_shapes_and_validation():
    table = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=4, sigma=0.3, seed=0,
        fields=("e_op_fj", "e_op_marginal_fj", "bitcell_um2"),
    )
    assert table.e_op_fj.shape == (4, 12, 3)
    assert table.e_op_marginal_fj.shape == (4, 12, 3)
    assert table.bitcell_um2.shape == (4, 12)
    assert table.n_topologies == 12
    assert table.model(0) == EnergyModel()  # row 0 nominal
    assert table.uniform_row(0) and not table.uniform_row(1)
    # topology= materializes one column; without it the row raises
    m = table.model(1, topology=3)
    assert m.e_op_marginal_fj == tuple(
        float(x) for x in table.e_op_marginal_fj[1, 3]
    )
    with pytest.raises(ValueError, match="topology-"):
        table.model(1)
    # _check_topo_axis: a mismatched per-op axis is rejected
    short = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:5])
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="per-topology axis|generated for"):
        evaluate_batch(random_workload(rng), short, table)
    with pytest.raises(ValueError, match="per-topology axis|generated for"):
        table2_batch(short, table)
    # mixed per-op/scalar widths inside one table are rejected
    kw = {
        f.name: getattr(table, f.name)
        for f in dataclasses.fields(EnergyModel)
    }
    kw["e_op_fj"] = np.ones((4, 5, 3))
    with pytest.raises(ValueError, match="per-topology width"):
        ModelTable(names=table.names, **kw)
    # malformed trailing axis is rejected
    kw["e_op_fj"] = np.ones((4, 12, 2))
    with pytest.raises(ValueError, match="per-op"):
        ModelTable(names=table.names, **kw)


def test_per_op_correlated_sweep_matches_scalar_path():
    """Every (variant, topology) cell of a per-op (V, T, 3) sweep equals
    the scalar path run with that cell's materialized EnergyModel."""
    rng = np.random.default_rng(15)
    items = [
        ((str(i),), stats_from_levels(
            [tuple(int(x) for x in rng.integers(0, 800, 3))
             for _ in range(int(rng.integers(1, 6)))]
        ))
        for i in range(3)
    ]
    work = WorkloadTable.from_stats(items)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = ModelTable.bitcell_sigma_per_macro(
        TOPOLOGY_LIBRARY, n=3, sigma=0.4, seed=8,
        fields=("e_op_fj", "e_op_marginal_fj"),
    )
    vg = evaluate_batch(work, topos, table)
    for v in range(3):
        for t in range(len(TOPOLOGY_LIBRARY)):
            m = table.model(v, topology=t)
            topo = TOPOLOGY_LIBRARY[t]
            for r, (_, stats) in enumerate(items):
                met = evaluate(schedule_stats(stats, topo), topo, m)
                np.testing.assert_allclose(
                    vg.energy_nj[v, t, r], met.energy_nj, rtol=1e-12
                )
    # table2 over the per-op table matches column materialization
    tb = table2_batch(topos, table)
    for v in range(3):
        for t in range(len(TOPOLOGY_LIBRARY)):
            ref = table2_batch(
                TopologyTable.from_topologies([TOPOLOGY_LIBRARY[t]]),
                table.model(v, topology=t),
            )
            np.testing.assert_allclose(
                tb["power_mw"][v, t], ref["power_mw"][0], rtol=1e-12
            )
    # ...and the fused filter handles the (V, T, 3) shape too
    grid, sel = evaluate_select_batch(work, topos, table)
    np.testing.assert_array_equal(
        sel.winner_idx.astype(np.int64), vg.best_indices()
    )


# ---------------------------------------------------------------------------
# explore_suite end to end: fused == host, quantiles/CVaR
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bar_suite():
    suite = C.benchmark_suite(scale="tiny", only=("bar",))
    cha = {"bar": characterize_recipes(suite["bar"])}
    return suite, cha


def test_explore_suite_fused_equals_host_path(bar_suite):
    suite, cha = bar_suite
    table = ModelTable.monte_carlo(n=6, sigma=0.25, seed=4)
    fused = explore_suite(suite, cha=cha, model_sweep=table, fused=True)
    host = explore_suite(suite, cha=cha, model_sweep=table, fused=False)
    for name in suite:
        f, h = fused[name], host[name]
        assert (f.best.recipe, f.best.topo) == (h.best.recipe, h.best.topo)
        assert f.best.metrics.energy_nj == h.best.metrics.energy_nj
        vf, vh = f.variation, h.variation
        assert vf.winners == vh.winners
        assert vf.winner_share == vh.winner_share
        assert vf.best_yield == vh.best_yield
        assert vf.latency_yield == vh.latency_yield
        np.testing.assert_array_equal(
            vf.winner_energy_nj, vh.winner_energy_nj
        )
        assert vf.energy_quantiles == vh.energy_quantiles
        assert vf.cvar(0.9) == vh.cvar(0.9)


def test_explore_suite_fused_with_latency_bound(bar_suite):
    suite, cha = bar_suite
    table = ModelTable.corners(spread=0.2)
    fused = explore_suite(
        suite, cha=cha, model_sweep=table, max_latency_ns=30.0, fused=True
    )
    host = explore_suite(
        suite, cha=cha, model_sweep=table, max_latency_ns=30.0, fused=False
    )
    for name in suite:
        assert fused[name].variation.winners == host[name].variation.winners
        assert (
            fused[name].variation.latency_yield
            == host[name].variation.latency_yield
        )


def test_fused_matches_host_on_full_ci_grid_with_nan_salt(bar_suite):
    """Acceptance: fused winners == host `select_best_batch` winners on
    every (circuit, variant) cell of the full 65 x 12 CI grid, with
    NaN/+inf-salted model variants in the sweep."""
    suite, cha = bar_suite
    suite_table = SuiteTable.from_cha(cha)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    table = salted_table(topos, n=8, seed=17)
    svg = evaluate_suite(suite_table, topos, table)
    assert svg.energy_nj.shape[2:] == (12, 65)
    assert not np.isfinite(svg.energy_nj).all()
    for max_lat in (None, 40.0):
        grid, sel = evaluate_select_suite(
            suite_table, topos, table, max_latency_ns=max_lat
        )
        np.testing.assert_array_equal(
            sel.winner_idx.astype(np.int64), svg.best_indices(max_lat)
        )


def test_variation_quantiles_and_cvar_reference(bar_suite):
    suite, cha = bar_suite
    table = ModelTable.monte_carlo(n=16, sigma=0.3, seed=12)
    var = explore_suite(suite, cha=cha, model_sweep=table)["bar"].variation
    e = var.winner_energy_nj
    assert e.shape == (16,)
    # quantiles are plain np.quantile over the winner energies
    for q, val in var.energy_quantiles.items():
        assert val == pytest.approx(float(np.quantile(e, q)))
    # cvar: mean of the worst (1 - alpha) tail, monotone in alpha
    srt = np.sort(e)
    assert var.cvar(0.75) == pytest.approx(srt[-4:].mean())
    assert var.cvar(0.0) == pytest.approx(e.mean())
    assert var.cvar(0.9) <= var.cvar(0.95) + 1e-18
    assert var.cvar(0.95) == pytest.approx(srt[-1])
    with pytest.raises(ValueError, match="alpha"):
        var.cvar(1.0)
    # winner energies equal the per-variant winner cells of the grid
    flat = var.grid.energy_nj.reshape(16, -1)
    idx = var.grid.best_indices()
    np.testing.assert_array_equal(e, flat[np.arange(16), idx])
