"""Suite-level engine tests: the prefix-DAG runner, the persistent
characterization cache, and the circuits x recipes x topologies sweep.

Contracts under test:

  * the deduped prefix-DAG runner produces byte-identical AIG stats to
    independent per-recipe transform chains;
  * the on-disk cache hits, misses, and invalidates on a
    `TRANSFORM_VERSION` bump;
  * `SuiteTable` padding/masking is invisible: suite results equal each
    circuit's own `WorkloadTable` results on the full 65 x 12 grid;
  * the programmatic topology grid schedules/evaluates exactly like the
    scalar path.
"""

import numpy as np
import pytest

from repro.core import circuits as C
from repro.core import transforms as T
from repro.core.aig import AigStats
from repro.core.batch import (
    SuiteTable,
    TopologyTable,
    WorkloadTable,
    evaluate_batch,
    evaluate_suite,
    schedule_batch,
    schedule_suite,
)
from repro.core.explorer import explore, explore_suite
from repro.core.mapping import macros_per_type, schedule_stats
from repro.core.sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    SramTopology,
    topology_grid,
)
from repro.core.transforms import (
    CharacterizationCache,
    RecipeRunner,
    characterize_suite,
    enumerate_recipes,
    prefix_nodes,
)

EM = EnergyModel()


@pytest.fixture(scope="module")
def tiny_pair():
    """Two small circuits with different level structures."""
    return {
        "bar-16": C.gen_barrel_shifter(16),
        "sqrt-8": C.gen_sqrt(8),
    }


@pytest.fixture(scope="module")
def tiny_cha(tiny_pair):
    return characterize_suite(tiny_pair, n_jobs=1)


# ---------------------------------------------------------------------------
# Prefix-DAG runner
# ---------------------------------------------------------------------------


SAMPLE_RECIPES = [
    ("Ba",), ("Rf",), ("Rw",), ("Rs",),
    ("Rw", "Ba"), ("Rf", "Rw"), ("Rs", "Rw", "Ba"),
    ("Ba", "Rf", "Rw", "Rs"), ("Rs", "Rw", "Rf", "Ba"),
]


def test_prefix_dag_byte_identical_to_independent_runs(tiny_pair, tiny_cha):
    """Structural dedup must be invisible: each recipe's stats equal an
    independent no-sharing transform chain's."""
    for name, rtl in tiny_pair.items():
        for recipe in SAMPLE_RECIPES:
            a = rtl
            for t in recipe:
                a = T._TRANSFORM_FNS[t](a)
            assert a.characterize() == tiny_cha[name][recipe], (name, recipe)


def test_recipe_runner_dedups_structurally():
    rtl = C.gen_adder(32)
    runner = RecipeRunner(rtl)
    recipes = enumerate_recipes()
    for r in recipes:
        runner.run(r)
    # prefix sharing alone caps at 64; structural dedup must do better
    assert runner.n_applied <= 64
    assert runner.n_applied < len(prefix_nodes(recipes))
    # stats memoized per distinct structure, identical across aliases
    s1 = runner.stats(("Ba", "Rw"))
    s2 = RecipeRunner(rtl).stats(("Ba", "Rw"))
    assert s1 == s2


def test_prefix_nodes_order():
    nodes = prefix_nodes([("Ba", "Rf"), ("Rf",)])
    assert nodes == [("Ba",), ("Rf",), ("Ba", "Rf")]
    assert prefix_nodes([]) == []


def test_characterize_suite_parallel_matches_serial(tiny_pair):
    # include deep chains so the as-completed scheduler's cascade path
    # (resolve -> children -> submit) is exercised, not just the roots
    few = enumerate_recipes()[:6] + [
        ("Ba", "Rf", "Rw", "Rs"), ("Rs", "Rw", "Rf", "Ba"),
        ("Rw", "Ba", "Rs"),
    ]
    serial = characterize_suite(tiny_pair, few, n_jobs=1)
    parallel = characterize_suite(tiny_pair, few, n_jobs=2)
    assert serial == parallel


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def test_cache_miss_then_hit(tmp_path, tiny_pair):
    cache = CharacterizationCache(tmp_path)
    few = enumerate_recipes()[:4]
    first = characterize_suite(tiny_pair, few, cache=cache, n_jobs=1)
    assert cache.misses == len(tiny_pair) and cache.hits == 0
    vdir = tmp_path / f"v{T.TRANSFORM_VERSION}"
    stats_files = [
        p for p in vdir.glob("*.json") if not p.name.endswith(".apps.json")
    ]
    assert len(stats_files) == len(tiny_pair)
    # per-prefix application persistence rides alongside the stats files
    apps_files = list(vdir.glob("*.apps.json"))
    assert len(apps_files) == len(tiny_pair)

    second = characterize_suite(tiny_pair, few, cache=cache, n_jobs=1)
    assert cache.hits == len(tiny_pair)
    assert first == second

    # a path (str) is accepted in place of a CharacterizationCache
    third = characterize_suite(tiny_pair, few, cache=str(tmp_path), n_jobs=1)
    assert first == third


def test_cache_partial_covers_superset(tmp_path, tiny_pair):
    """A cache warmed with a recipe subset must recompute (and then serve)
    a superset request."""
    cache = CharacterizationCache(tmp_path)
    few = enumerate_recipes()[:2]
    more = enumerate_recipes()[:5]
    characterize_suite(tiny_pair, few, cache=cache, n_jobs=1)
    full = characterize_suite(tiny_pair, more, cache=cache, n_jobs=1)
    assert cache.misses == 2 * len(tiny_pair)  # second call missed too
    again = characterize_suite(tiny_pair, more, cache=cache, n_jobs=1)
    assert again == full
    assert cache.hits == len(tiny_pair)


def test_cache_invalidated_on_version_bump(tmp_path, tiny_pair, monkeypatch):
    cache = CharacterizationCache(tmp_path)
    few = enumerate_recipes()[:3]
    characterize_suite(tiny_pair, few, cache=cache, n_jobs=1)
    assert cache.misses == len(tiny_pair)

    monkeypatch.setattr(T, "TRANSFORM_VERSION", T.TRANSFORM_VERSION + 1)
    bumped = CharacterizationCache(tmp_path)
    characterize_suite(tiny_pair, few, cache=bumped, n_jobs=1)
    assert bumped.misses == len(tiny_pair) and bumped.hits == 0
    # stale and fresh version directories coexist
    assert (tmp_path / f"v{T.TRANSFORM_VERSION}").is_dir()


def test_cache_rejects_stale_embedded_version(tmp_path, tiny_pair, monkeypatch):
    """A file whose embedded version disagrees with its directory (e.g. a
    hand-copied cache) is treated as a miss, not served."""
    cache = CharacterizationCache(tmp_path)
    few = enumerate_recipes()[:2]
    characterize_suite(tiny_pair, few, cache=cache, n_jobs=1)
    vdir = tmp_path / f"v{T.TRANSFORM_VERSION}"
    for f in vdir.glob("*.json"):
        text = f.read_text().replace(
            f'"transform_version": {T.TRANSFORM_VERSION}',
            '"transform_version": 0',
        )
        f.write_text(text)
    fresh = CharacterizationCache(tmp_path)
    fp = next(iter(tiny_pair.values())).fingerprint()
    assert fresh.load(fp) == {}


def test_aig_stats_roundtrip(tiny_cha):
    for cha in tiny_cha.values():
        for stats in cha.values():
            assert AigStats.from_dict(stats.to_dict()) == stats


# ---------------------------------------------------------------------------
# SuiteTable / evaluate_suite parity on the 65 x 12 grid
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["physical", "paper"])
@pytest.mark.parametrize("discipline", ["list", "levels"])
def test_suite_matches_per_circuit_grids(tiny_cha, mode, discipline):
    suite = SuiteTable.from_cha(tiny_cha)
    assert suite.ops.shape[:2] == (len(tiny_cha), 65)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    sg = evaluate_suite(suite, topos, EM, mode=mode, discipline=discipline)
    for name, cha in tiny_cha.items():
        work = WorkloadTable.from_stats(cha)
        ref = evaluate_batch(work, topos, EM, mode=mode, discipline=discipline)
        got = sg.grid(name)
        assert np.array_equal(got.cycles, ref.cycles)
        assert np.array_equal(got.active_macro_cycles, ref.active_macro_cycles)
        assert np.array_equal(got.fits, ref.fits)
        for field in ("energy_nj", "latency_ns", "power_mw",
                      "throughput_gops", "tops_per_watt"):
            np.testing.assert_allclose(
                getattr(got, field), getattr(ref, field), rtol=1e-12
            )
        assert got.best_index() == ref.best_index()


def test_suite_padding_is_masked(tiny_cha):
    """Circuits with different level counts share one padded axis; the
    shorter circuit's padded rows must not leak into its schedule."""
    suite = SuiteTable.from_cha(tiny_cha)
    names = list(tiny_cha)
    assert suite.n_levels[0].max() != suite.n_levels[1].max()
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:3])
    ss = schedule_suite(suite, topos)
    for i, name in enumerate(names):
        ref = schedule_batch(WorkloadTable.from_stats(tiny_cha[name]), topos)
        assert np.array_equal(ss["cycles"][i], ref["cycles"])
        assert np.array_equal(ss["fits"][i], ref["fits"])


def test_suite_table_workload_view(tiny_cha):
    suite = SuiteTable.from_cha(tiny_cha)
    for name in tiny_cha:
        w = suite.workload(name)
        assert w.recipes == suite.recipes
        assert w.gates.tolist() == [
            s.total_gates for s in tiny_cha[name].values()
        ]


def test_suite_table_validation(tiny_cha):
    with pytest.raises(ValueError, match="empty"):
        SuiteTable.from_cha({})
    name = next(iter(tiny_cha))
    lopsided = dict(tiny_cha)
    lopsided["short"] = {(): tiny_cha[name][()]}
    with pytest.raises(ValueError, match="different recipe set"):
        SuiteTable.from_cha(lopsided)


def test_explore_suite_matches_explore(tiny_pair, tiny_cha):
    res_jax = explore_suite(tiny_pair, cha=tiny_cha, backend="jax")
    res_py = explore_suite(tiny_pair, cha=tiny_cha, backend="python")
    for name, rtl in tiny_pair.items():
        one = explore(rtl, cha=tiny_cha[name], backend="python")
        for res in (res_jax[name], res_py[name]):
            assert res.best.recipe == one.best.recipe
            assert res.best.topo == one.best.topo
            assert abs(res.best.metrics.energy_nj - one.best.metrics.energy_nj) < 1e-9
        assert res_jax[name].grid is not None
        assert res_jax[name].n_evaluations == 65 * 12


def test_cell_matches_materialized_grids(tiny_pair, tiny_cha):
    """`cell()` — the lazy per-design gather — must equal the
    materialized grid entry field for field, on both the per-circuit and
    suite grids."""
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    suite = SuiteTable.from_cha(tiny_cha)
    sg = evaluate_suite(suite, topos, EM)
    t, r = 3, 5
    for ci, name in enumerate(sg.circuits):
        cell = sg.cell(name, t, r)
        assert cell.circuit == name and cell.variant is None
        assert cell.recipe == sg.recipes[r]
        assert cell.topology == sg.topologies[t]
        assert cell.cycles == int(sg.cycles[ci, t, r])
        assert cell.fits == bool(sg.fits[ci, t, r])
        assert cell.feasible == bool(sg.feasible[ci, t])
        assert cell.energy_nj == float(sg.energy_nj[ci, t, r])
        assert cell.latency_ns == float(sg.latency_ns[ci, t, r])
        assert cell.area_mm2 == float(sg.area_mm2[t])
        # the sliced per-circuit grid agrees with the suite-level gather
        eg = sg.grid(name)
        ecell = eg.cell(t, r)
        assert ecell.energy_nj == cell.energy_nj
        assert ecell.cycles == cell.cycles
        assert sg.cell(ci, t, r) == cell  # index addressing too


# ---------------------------------------------------------------------------
# Programmatic topology grid
# ---------------------------------------------------------------------------


def test_macros_per_type_generalization():
    assert macros_per_type(1) == (1, 1, 1)
    assert macros_per_type(3) == (1, 1, 1)
    assert macros_per_type(6) == (2, 2, 2)
    assert macros_per_type(9) == (3, 3, 3)
    for bad in (0, 2, 4, 5, 7):
        with pytest.raises(ValueError):
            macros_per_type(bad)


def test_from_geometry_and_names():
    t = SramTopology.from_geometry(512, 512, 9)
    assert t.macro_kb == 32 and t.rows == 512 and t.cols == 512
    assert t.name == "(512x512)x9"
    assert t.ops_per_cycle_per_macro == 256
    with pytest.raises(ValueError, match="whole number of KB"):
        SramTopology.from_geometry(100, 100, 1)
    # library entries are untouched by the geometry extension
    t8 = SramTopology(8, 1)
    assert t8.name == "(8KB)x1" and t8.rows == 256 and t8.cols == 256


def test_topology_grid_contents():
    grid = topology_grid()
    assert len(grid) == len(set(grid)) and len(grid) > 12
    for t in grid:
        assert (t.rows * t.cols) % 8192 == 0
        macros_per_type(t.n_macros)  # must not raise
    custom = topology_grid(rows=(256,), cols=(256,), macro_counts=(1, 9))
    assert [t.name for t in custom] == ["(256x256)x1", "(256x256)x9"]
    with pytest.raises(ValueError, match="empty"):
        topology_grid(rows=(100,), cols=(100,))


def test_grid_topology_schedule_matches_scalar(tiny_cha):
    """Custom design points run through the batched path exactly like the
    scalar reference."""
    name = next(iter(tiny_cha))
    cha = tiny_cha[name]
    topos = topology_grid(rows=(128, 512), cols=(256, 512), macro_counts=(1, 3, 9))
    table = TopologyTable.from_topologies(topos)
    work = WorkloadTable.from_stats(cha)
    grid = evaluate_batch(work, table, EM)
    recipes = list(cha)
    for ti, topo in enumerate(topos):
        for ri in (0, len(recipes) // 2, len(recipes) - 1):
            sched = schedule_stats(cha[recipes[ri]], topo)
            assert grid.cycles[ti, ri] == sched.total_cycles
            assert bool(grid.fits[ti, ri]) == sched.fits
