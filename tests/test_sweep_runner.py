"""Journaled resumable sweeps: bit-identical shard/resume parity.

The pinned contract (ISSUE 10 acceptance): a sweep killed mid-run —
whether by an injected crash or a real SIGKILL on a subprocess — resumes
from its journal and assembles a `SelectionResult` equal field-for-field
to an uninterrupted `evaluate_select_suite` over the same suite.
"""

import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.batch import (  # noqa: E402
    SuiteTable,
    TopologyTable,
    evaluate_select_suite,
)
from repro.core.circuits import benchmark_suite  # noqa: E402
from repro.core.explorer import _opt_and_feasible, _restrict_cha  # noqa: E402
from repro.core.sram import TOPOLOGY_LIBRARY  # noqa: E402
from repro.ckpt.manager import CheckpointManager  # noqa: E402
from repro.core.sweep_runner import run_sweep, sweep_config_key  # noqa: E402
from repro.core.transforms import characterize_suite  # noqa: E402
from repro.runtime import faults  # noqa: E402

CIRCUITS = ["adder", "bar", "max", "sqrt"]
RECIPES = [(), ("Rw",), ("Ba", "Rw"), ("Rf",)]
TOPOS = list(TOPOLOGY_LIBRARY[:5])


@pytest.fixture(autouse=True)
def _disarmed():
    faults.disable()
    yield
    faults.disable()


@pytest.fixture(scope="module")
def suite_circuits():
    return benchmark_suite("tiny", only=CIRCUITS)


@pytest.fixture(scope="module")
def cha_cache(tmp_path_factory, suite_circuits):
    """Warm on-disk characterization cache shared by every run in this
    module, so repeated SweepRunner.run calls skip the front half."""
    root = tmp_path_factory.mktemp("cha")
    characterize_suite(suite_circuits, RECIPES, cache=root, n_jobs=1)
    return root


@pytest.fixture(scope="module")
def direct(suite_circuits, cha_cache):
    """The uninterrupted reference: one unsharded fused suite call."""
    cha = characterize_suite(suite_circuits, RECIPES, cache=cha_cache, n_jobs=1)
    cha = {n: _restrict_cha(cha[n], RECIPES) for n in cha}
    feas = np.zeros((len(cha), len(TOPOS)), dtype=bool)
    for i, n in enumerate(cha):
        _, _, f = _opt_and_feasible(cha[n], TOPOS)
        feas[i] = [t in f for t in TOPOS]
    _, sel = evaluate_select_suite(
        SuiteTable.from_cha(cha), TopologyTable.from_topologies(TOPOS),
        None, feasible=feas,
    )
    return sel


def assert_selection_equal(sel, ref, circuits=None, ref_names=None):
    """Field-for-field bit-identity (optionally on a circuit subset)."""
    rows = (
        slice(None)
        if circuits is None
        else [ref_names.index(c) for c in circuits]
    )
    assert sel.winner_idx.dtype == ref.winner_idx.dtype
    assert np.array_equal(sel.winner_idx, ref.winner_idx[rows])
    assert np.array_equal(sel.nominal_latency_ns, ref.nominal_latency_ns[rows])
    assert np.array_equal(sel.nominal_fits, ref.nominal_fits[rows])
    for k, v in ref.winner_metrics.items():
        assert np.array_equal(sel.winner_metrics[k], v[rows]), k
    if circuits is None:
        assert sel.payload_bytes == ref.payload_bytes


@pytest.mark.parametrize("shard_size", [1, 2, 3, None])
def test_sharded_parity_without_journal(
    suite_circuits, cha_cache, direct, shard_size
):
    out = run_sweep(
        suite_circuits, journal_dir=None, shard_size=shard_size,
        sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
    )
    assert out.circuits == tuple(CIRCUITS)
    assert out.shards_resumed == 0 and out.journal_dir is None
    assert_selection_equal(out.selection, direct)


def test_injected_crash_then_resume_bit_identical(
    tmp_path, suite_circuits, cha_cache, direct
):
    journal = tmp_path / "j"
    # Crash (hard FaultError) before the second shard evaluates.
    with faults.injected(
        faults.FaultRule("sweep.shard", "raise", after=1)
    ):
        with pytest.raises(faults.FaultError):
            run_sweep(
                suite_circuits, journal_dir=journal, shard_size=2,
                sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
            )
    # Exactly one shard published before the crash.
    assert len(CheckpointManager(str(journal)).steps()) == 1
    out = run_sweep(
        suite_circuits, journal_dir=journal, shard_size=2,
        sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
    )
    assert out.shards_resumed == 1 and out.shards_run == 1
    assert_selection_equal(out.selection, direct)


def test_resume_with_different_shard_size(
    tmp_path, suite_circuits, cha_cache, direct
):
    """Resume is keyed per circuit, so re-chunking the remainder with a
    different shard size still assembles the identical result."""
    journal = tmp_path / "j"
    with faults.injected(
        faults.FaultRule("sweep.shard", "raise", after=1)
    ):
        with pytest.raises(faults.FaultError):
            run_sweep(
                suite_circuits, journal_dir=journal, shard_size=1,
                sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
            )
    out = run_sweep(
        suite_circuits, journal_dir=journal, shard_size=3,
        sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
    )
    assert out.shards_resumed == 1
    assert_selection_equal(out.selection, direct)


def test_corrupt_journal_entry_is_evicted_and_redone(
    tmp_path, suite_circuits, cha_cache, direct
):
    journal = tmp_path / "j"
    out = run_sweep(
        suite_circuits, journal_dir=journal, shard_size=2,
        sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
    )
    assert out.shards_run == 2
    # The success path does not drain the async writer; do so before
    # poking at the journal files directly.
    CheckpointManager(str(journal)).wait()
    # Tear the tail record of the append-only log behind the manager's
    # back — the frame crc must reject it and only that shard is redone.
    wal = journal / "journal.wal"
    wal.write_bytes(wal.read_bytes()[:-5])
    out2 = run_sweep(
        suite_circuits, journal_dir=journal, shard_size=2,
        sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
    )
    assert out2.shards_resumed == 1 and out2.shards_run == 1
    assert_selection_equal(out2.selection, direct)


def test_torn_write_via_journal_fault_recovers(
    tmp_path, suite_circuits, cha_cache, direct
):
    """A corrupt rule at journal.write models a torn log append that
    survives the flush; the reader must skip the damaged frame (re-sync
    on the next frame magic, keeping later records) and redo only that
    shard."""
    journal = tmp_path / "j"
    with faults.injected(
        faults.FaultRule("journal.write", "corrupt")
    ):
        run_sweep(
            suite_circuits, journal_dir=journal, shard_size=2,
            sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
        )
    out = run_sweep(
        suite_circuits, journal_dir=journal, shard_size=2,
        sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
    )
    assert out.shards_run == 1  # the torn shard was redone
    assert_selection_equal(out.selection, direct)


def test_mismatched_config_entries_are_ignored(
    tmp_path, suite_circuits, cha_cache, direct
):
    journal = tmp_path / "j"
    other = [(), ("Rw",)]
    run_sweep(
        suite_circuits, journal_dir=journal, shard_size=2,
        sram_list=TOPOS, recipes=other, cache=cha_cache, n_jobs=1,
    )
    out = run_sweep(
        suite_circuits, journal_dir=journal, shard_size=2,
        sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
    )
    assert out.shards_resumed == 0 and out.shards_run == 2
    assert_selection_equal(out.selection, direct)
    assert sweep_config_key(
        suite_circuits, RECIPES, TOPOS, None, "physical", "list", None
    ) != sweep_config_key(
        suite_circuits, other, TOPOS, None, "physical", "list", None
    )


def test_quarantined_circuit_is_reported_not_fatal(
    suite_circuits, cha_cache, direct
):
    with faults.injected(
        faults.FaultRule("cha.backend", "raise", match=":bar")
    ):
        out = run_sweep(
            suite_circuits, journal_dir=None, shard_size=2,
            sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
        )
    assert set(out.failures) == {"bar"}
    assert out.circuits == tuple(c for c in CIRCUITS if c != "bar")
    assert_selection_equal(
        out.selection, direct, circuits=out.circuits, ref_names=CIRCUITS
    )


def test_hypothesis_shard_boundary_parity(suite_circuits, cha_cache, direct):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(shard_size=st.integers(min_value=1, max_value=len(CIRCUITS) + 1))
    def prop(shard_size):
        out = run_sweep(
            suite_circuits, journal_dir=None, shard_size=shard_size,
            sram_list=TOPOS, recipes=RECIPES, cache=cha_cache, n_jobs=1,
        )
        assert_selection_equal(out.selection, direct)

    prop()


# ---------------------------------------------------------------------------
# The real thing: SIGKILL a subprocess sweep mid-shard, resume, compare.
# ---------------------------------------------------------------------------


CLI_ARGS = [
    "--circuits", "adder,bar,max", "--scale", "tiny",
    "--recipes", ";Rw", "--topos", "3",
]


def _cli(journal, out, shard_size, cache, **popen_kw):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]
    )
    env.pop("REPRO_FAULTS", None)
    return subprocess.Popen(
        [sys.executable, "-m", "repro.core.sweep_runner",
         "--journal", str(journal), "--out", str(out),
         "--shard-size", str(shard_size), "--cache", str(cache), *CLI_ARGS],
        env=env, **popen_kw,
    )


@pytest.mark.slow
def test_sigkill_mid_sweep_then_resume_bit_identical(tmp_path):
    journal = tmp_path / "j"
    cache = tmp_path / "cha"
    killed_out = tmp_path / "killed.npz"

    # Launch a 3-shard sweep and SIGKILL it the moment shard 0 publishes.
    proc = _cli(
        journal, killed_out, 1, cache,
        stdout=subprocess.PIPE, text=True,
    )
    try:
        deadline = time.time() + 300
        line = ""
        while time.time() < deadline:
            line = proc.stdout.readline()
            if "shard 0 done" in line:
                break
        assert "shard 0 done" in line, "sweep never published a shard"
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert proc.returncode == -signal.SIGKILL
    assert not killed_out.exists()
    published = len(CheckpointManager(str(journal)).steps())
    assert 1 <= published < 3

    # Resume to completion; run an uninterrupted single-shard reference.
    resumed_out = tmp_path / "resumed.npz"
    assert _cli(journal, resumed_out, 1, cache).wait(600) == 0
    ref_out = tmp_path / "ref.npz"
    assert _cli(tmp_path / "j2", ref_out, 3, cache).wait(600) == 0

    a, b = np.load(resumed_out), np.load(ref_out)
    assert int(a["shards_resumed"]) >= 1
    for key in b.files:
        if key in ("shards_run", "shards_resumed"):
            continue
        assert np.array_equal(a[key], b[key]), key
