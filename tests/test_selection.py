"""Parity + NaN-safety suite for the batched selection stage.

The contract under test (`batch.select_best_batch`, the vectorized
masked three-tier argmin that replaced the per-variant python loop):

  * batched winners match an *independent* per-cell reference
    implementation of the three-tier filter on every batch cell —
    including grids salted with NaN/±inf energies, all-infeasible
    tiers, and exact-tie rows (lowest-flat-index winner);
  * non-finite energies are inadmissible in every tier for
    `select_best`, `select_best_batch`, and `select_best_worst` alike —
    a pathological Monte-Carlo variant can no longer "win" with a NaN —
    and an all-non-finite cell raises instead of returning garbage;
  * mask broadcasting: one model-free ``(1, N)`` / ``(C, 1, N)``
    fits/feasible mask serves every variant row;
  * the jitted device reduction (`select_best_batch_device`, the
    standalone fused filter) returns identical winners and errors.

The property suite runs under hypothesis when installed; deterministic
seeded versions of the same assertions always run.
"""

import numpy as np
import pytest

from repro.core.batch import (
    select_best,
    select_best_batch,
    select_best_batch_device,
    select_best_worst,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False


def ref_select_best(energy, fits, latency=None, max_latency=None,
                    feasible=None):
    """Independent scalar reference: the documented three-tier filter
    with non-finite energies inadmissible everywhere.  Deliberately NOT
    implemented via `select_best_batch` so the parity tests compare two
    separate implementations."""
    energy = np.asarray(energy, dtype=float).ravel()
    fits = np.asarray(fits, dtype=bool).ravel()
    finite = np.isfinite(energy)
    tier1 = fits & finite
    if feasible is not None:
        tier1 = tier1 & np.asarray(feasible, dtype=bool).ravel()
    if max_latency is not None and latency is not None:
        tier1 = tier1 & (
            np.asarray(latency, dtype=float).ravel() <= max_latency
        )
    for pool in (tier1, fits & finite, finite):
        if pool.any():
            # python min over (energy, index) pairs: ties break to the
            # lowest flat index, NaNs/infs never enter the pool
            return min(
                (float(energy[i]), i) for i in np.flatnonzero(pool)
            )[1]
    raise ValueError("all energies non-finite")


def salted_grid(rng, v=6, t=12, r=65, nan_frac=0.05):
    """A random (V, T*R) energy/latency/mask set with NaN/±inf salt —
    the 65 x 12 x V acceptance shape."""
    n = t * r
    energy = rng.lognormal(0.0, 2.0, (v, n))
    salt = rng.random((v, n))
    energy[salt < nan_frac / 3] = np.nan
    energy[(salt >= nan_frac / 3) & (salt < 2 * nan_frac / 3)] = np.inf
    energy[(salt >= 2 * nan_frac / 3) & (salt < nan_frac)] = -np.inf
    latency = rng.lognormal(0.0, 1.0, (v, n))
    fits = rng.random(n) < 0.6
    feasible = rng.random(n) < 0.7
    return energy, latency, fits, feasible


@pytest.mark.parametrize("seed", range(8))
def test_batch_matches_reference_on_salted_grids(seed):
    rng = np.random.default_rng(seed)
    energy, latency, fits, feasible = salted_grid(rng)
    max_lat = float(np.nanmedian(latency))
    got = select_best_batch(
        energy, fits[None, :], latency=latency, max_latency=max_lat,
        feasible=feasible[None, :],
    )
    assert got.shape == (energy.shape[0],)
    for v in range(energy.shape[0]):
        ref = ref_select_best(
            energy[v], fits, latency=latency[v], max_latency=max_lat,
            feasible=feasible,
        )
        assert int(got[v]) == ref
        # ...and the single-cell API agrees with both
        assert select_best(
            energy[v], fits, latency=latency[v], max_latency=max_lat,
            feasible=feasible,
        ) == ref


@pytest.mark.parametrize("seed", range(4))
def test_batch_matches_reference_without_constraints(seed):
    rng = np.random.default_rng(100 + seed)
    energy, _, fits, _ = salted_grid(rng, v=4, t=5, r=9, nan_frac=0.2)
    got = select_best_batch(energy, fits[None, :])
    for v in range(4):
        assert int(got[v]) == ref_select_best(energy[v], fits)


def test_three_dim_batch_with_broadcast_masks():
    rng = np.random.default_rng(7)
    c, v, n = 3, 5, 40
    energy = rng.lognormal(0.0, 1.0, (c, v, n))
    energy[0, 1, :7] = np.nan
    latency = rng.lognormal(0.0, 1.0, (c, v, n))
    fits = rng.random((c, n)) < 0.5
    feasible = rng.random((c, n)) < 0.6
    got = select_best_batch(
        energy, fits[:, None, :], latency=latency, max_latency=1.0,
        feasible=feasible[:, None, :],
    )
    assert got.shape == (c, v)
    for ci in range(c):
        for vi in range(v):
            assert int(got[ci, vi]) == ref_select_best(
                energy[ci, vi], fits[ci], latency=latency[ci, vi],
                max_latency=1.0, feasible=feasible[ci],
            )


def test_nan_never_wins():
    # the original bug: a NaN energy survives np.where(pool, e, inf) and
    # argmin returns its index
    energy = np.array([np.nan, 3.0, 2.0, 4.0])
    fits = np.ones(4, dtype=bool)
    assert select_best(energy, fits) == 2
    assert int(select_best_batch(energy[None, :], fits[None, :])[0]) == 2
    # NaN in the only fitting slot: fall through to the finite tier
    fits = np.array([True, False, False, False])
    assert select_best(energy, fits) == 2


def test_all_infeasible_tiers_fall_through():
    energy = np.array([[5.0, 1.0, 3.0]])
    no_fit = np.zeros((1, 3), dtype=bool)
    # nothing fits -> finite-energy tier
    assert int(select_best_batch(energy, no_fit)[0]) == 1
    # fits but nothing feasible/within latency -> capacity tier
    fits = np.array([[False, True, True]])
    got = select_best_batch(
        energy, fits, latency=np.array([[1.0, 9.0, 9.0]]), max_latency=2.0,
        feasible=np.zeros((1, 3), dtype=bool),
    )
    assert int(got[0]) == 1  # cheapest *fitting* entry


def test_exact_ties_break_to_lowest_flat_index():
    energy = np.array([[2.0, 1.0, 1.0, 1.0], [1.0, 1.0, 2.0, 2.0]])
    fits = np.array([[True, False, True, True], [True, True, True, True]])
    got = select_best_batch(energy, fits)
    assert got.tolist() == [2, 0]
    assert select_best(energy[0], fits[0]) == 2


def test_all_non_finite_raises():
    bad = np.array([np.nan, np.inf, -np.inf])
    ok = np.ones(3, dtype=bool)
    with pytest.raises(ValueError, match="finite"):
        select_best(bad, ok)
    with pytest.raises(ValueError, match="finite"):
        select_best_batch(np.stack([bad, np.ones(3)]), ok[None, :])
    with pytest.raises(ValueError, match="finite"):
        select_best_worst(bad, ok)


def test_empty_grid_raises():
    with pytest.raises(ValueError, match="empty"):
        select_best(np.array([]), np.array([], dtype=bool))
    with pytest.raises(ValueError, match="empty"):
        select_best_batch(
            np.empty((3, 0)), np.empty((3, 0), dtype=bool)
        )


def test_select_best_worst_is_nan_safe():
    energy = np.array([np.nan, 2.0, np.inf, 5.0, -np.inf, 3.0])
    fits = np.ones(6, dtype=bool)
    best, worst = select_best_worst(energy, fits)
    assert (best, worst) == (1, 3)  # ±inf/NaN excluded at both ends
    # non-finite-only fitting pool falls back to all finite entries
    fits = np.array([True, False, True, False, True, False])
    best, worst = select_best_worst(energy, fits)
    assert (best, worst) == (1, 3)


@pytest.mark.parametrize("seed", range(4))
def test_device_selection_matches_host_on_salted_grids(seed):
    """The jitted device reduction (`select_best_batch_device`) is the
    same filter as the host `select_best_batch`: identical winners on
    NaN/±inf-salted grids under every constraint combination."""
    rng = np.random.default_rng(200 + seed)
    energy, latency, fits, feasible = salted_grid(rng)
    max_lat = float(np.nanmedian(latency))
    for kw in (
        dict(),
        dict(latency=latency, max_latency=max_lat),
        dict(feasible=feasible[None, :]),
        dict(latency=latency, max_latency=max_lat,
             feasible=feasible[None, :]),
    ):
        host = select_best_batch(energy, fits[None, :], **kw)
        dev = select_best_batch_device(energy, fits[None, :], **kw)
        np.testing.assert_array_equal(dev, host)


def test_device_selection_errors_match_host():
    bad = np.array([[np.nan, np.inf, -np.inf], [1.0, 2.0, 3.0]])
    ok = np.ones((1, 3), dtype=bool)
    with pytest.raises(ValueError, match="finite"):
        select_best_batch_device(bad, ok)
    with pytest.raises(ValueError, match="empty"):
        select_best_batch_device(
            np.empty((3, 0)), np.empty((3, 0), dtype=bool)
        )


def test_device_selection_ties_and_tiers():
    # exact ties break to the lowest flat index, like the host filter
    energy = np.array([[2.0, 1.0, 1.0, 1.0], [1.0, 1.0, 2.0, 2.0]])
    fits = np.array([[True, False, True, True], [True, True, True, True]])
    assert select_best_batch_device(energy, fits).tolist() == [2, 0]
    # all-infeasible tiers fall through identically
    energy = np.array([[5.0, 1.0, 3.0]])
    assert int(
        select_best_batch_device(energy, np.zeros((1, 3), dtype=bool))[0]
    ) == 1


def test_mesh_variation_summary_matches_per_variant_loop():
    """The mesh explorer's constant sweep rides the same batched filter:
    its per-variant winners equal a `select_best` loop over the (V, N)
    energy matrix."""
    from repro.core.mesh_explorer import (
        MeshEvaluation,
        constant_corners,
        variation_summary,
    )

    rng = np.random.default_rng(11)
    evals = []
    for i in range(6):
        roof = dict(
            flops=float(rng.uniform(1e15, 5e15)),
            hbm_bytes=float(rng.uniform(1e12, 9e12)),
            link_bytes=float(rng.uniform(1e11, 9e11)),
        )
        evals.append(
            MeshEvaluation(
                topo=f"t{i % 2}", recipe=f"r{i}",
                latency_s=float(rng.uniform(0.1, 2.0)),
                energy_j=0.0, hbm_gb=10.0, fits=bool(i % 3),
                bottleneck="compute",
                record=dict(roofline=roof, n_chips=256),
            )
        )
    variants = constant_corners(0.4)
    out = variation_summary(evals, variants, max_latency_s=1.0)
    assert out["n_variants"] == len(variants)
    assert sum(out["winner_share"].values()) == pytest.approx(1.0)
    # reference: the per-variant scalar loop over the same energy matrix
    fits = np.array([e.fits for e in evals])
    lat = np.array([e.latency_s for e in evals])
    for v, k in enumerate(variants):
        energy = np.array([
            e.record["n_chips"] * (
                e.record["roofline"]["flops"] * k["pj_per_flop"]
                + e.record["roofline"]["hbm_bytes"] * k["pj_per_hbm_byte"]
                + e.record["roofline"]["link_bytes"] * k["pj_per_link_byte"]
            )
            for e in evals
        ])
        i = select_best(energy, fits, latency=lat, max_latency=1.0)
        assert out["winners"][v] == dict(
            topo=evals[i].topo, recipe=evals[i].recipe
        )


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        v=st.integers(1, 8),
        n=st.integers(1, 60),
        nan_frac=st.floats(0.0, 0.9),
        use_latency=st.booleans(),
        use_feasible=st.booleans(),
    )
    def test_property_batch_matches_reference(
        seed, v, n, nan_frac, use_latency, use_feasible
    ):
        rng = np.random.default_rng(seed)
        # few distinct values -> exact ties are common, not rare
        energy = rng.choice(
            [1.0, 2.0, 3.0, np.nan, np.inf, -np.inf],
            p=[(1 - nan_frac) / 3] * 3 + [nan_frac / 3] * 3,
            size=(v, n),
        )
        if not np.isfinite(energy).any(axis=-1).all():
            with pytest.raises(ValueError, match="finite"):
                select_best_batch(energy, np.ones((1, n), dtype=bool))
            return
        latency = rng.lognormal(0.0, 1.0, (v, n)) if use_latency else None
        feasible = (
            (rng.random(n) < 0.5)[None, :] if use_feasible else None
        )
        fits = rng.random(n) < 0.5
        got = select_best_batch(
            energy, fits[None, :], latency=latency,
            max_latency=1.0 if use_latency else None, feasible=feasible,
        )
        for i in range(v):
            assert int(got[i]) == ref_select_best(
                energy[i], fits,
                latency=None if latency is None else latency[i],
                max_latency=1.0 if use_latency else None,
                feasible=None if feasible is None else feasible[0],
            )

else:  # keep the property suite visible as a skip when hypothesis is absent

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
    def test_property_batch_matches_reference():
        pass
