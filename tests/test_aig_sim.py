"""Bit-packed device AIG simulation vs the python-int reference.

Contracts under test (the front-half device engine of kernels/aig_sim.py):

  * `eval_tts` truth tables are **bit-identical** to `Aig.truth_table`
    across random AIGs, random reconvergence cones, shuffled support
    orders, both root phases, multi-root queries, and every word tier —
    including the host bigint fallback for wide supports;
  * `node_signatures` matches `transforms._node_signatures` word for
    word;
  * repeated same-shape batches never retrace (`aig_sim.trace_counts`);
  * the Pallas engine (interpret mode on CPU) agrees with the jnp engine
    and the python path;
  * the device-backed transforms (`backend="device"`) produce
    fingerprint-identical AIGs to the python transforms, all the way up
    through `characterize_suite`;
  * a `CharacterizationCache` with persisted per-prefix applications
    warm-starts a *different* recipe set without re-running the shared
    prefix transforms.

The property suites run under hypothesis when installed; deterministic
seeded versions of the same assertions always run.
"""

import numpy as np
import pytest

pytest.importorskip("jax", reason="device AIG simulation needs jax")

from repro.core import circuits as C
from repro.core import transforms as T
from repro.core.aig import Aig, lit
from repro.core.transforms import (
    CharacterizationCache,
    characterize_suite,
    transform_fns,
)
from repro.kernels.aig_sim import (
    DEVICE_MAX_VARS,
    compile_aig,
    eval_tt,
    eval_tts,
    node_signatures,
    trace_counts,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False


def random_aig(rng, n_pis=6, n_ands=60) -> Aig:
    """Random strashed AIG: each new node ANDs two random prior literals
    (random phases), so cones reconverge and fold realistically."""
    aig = Aig(n_pis)
    lits = [lit(i) for i in range(1, n_pis + 1)]
    for _ in range(n_ands):
        i, j = rng.integers(0, len(lits), size=2)
        la = int(lits[i]) ^ int(rng.integers(2))
        lb = int(lits[j]) ^ int(rng.integers(2))
        out = aig.g_and(la, lb)
        if out > 1:  # skip folds to const
            lits.append(out)
    aig.add_po(lits[-1])
    return aig


def random_cone_queries(rng, aig, n_queries, max_leaves=8):
    """(root_lits, support) items over random reconvergence cuts, with
    shuffled support order and random root phase."""
    and_nodes = list(range(aig.n_pis + 1, aig.n_nodes))
    items = []
    for _ in range(n_queries):
        root = int(and_nodes[rng.integers(len(and_nodes))])
        leaves = T._reconv_cut(aig, root, max_leaves=max_leaves)
        support = list(leaves)
        rng.shuffle(support)
        items.append(((lit(root, int(rng.integers(2))),), support))
    return items


def assert_items_match_python(aig, items, engine="jnp"):
    got = eval_tts(aig, items, engine=engine)
    for (roots, support), tts in zip(items, got):
        for rl, tt in zip(roots, tts):
            assert tt == aig.truth_table(rl, list(support)), (
                f"device truth table differs for root {rl} over {support}"
            )


# ---------------------------------------------------------------------------
# eval_tts parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_eval_tts_matches_python_reference(seed):
    rng = np.random.default_rng(seed)
    aig = random_aig(rng, n_pis=6, n_ands=60)
    items = random_cone_queries(rng, aig, n_queries=24)
    assert_items_match_python(aig, items)


def test_eval_tts_support_order_sensitivity():
    """Permuting the support must permute the table exactly as the python
    path does (the variable order IS the table's encoding)."""
    rng = np.random.default_rng(3)
    aig = random_aig(rng, n_pis=5, n_ands=40)
    root = aig.n_nodes - 1
    leaves = T._reconv_cut(aig, root, max_leaves=5)
    perms = [list(leaves), list(reversed(leaves))]
    rng.shuffle(leaves)
    perms.append(list(leaves))
    items = [((lit(root),), p) for p in perms]
    assert_items_match_python(aig, items)


def test_eval_tts_multi_root_union_cone():
    """resub-style queries: several root literals over one shared support
    (the union cone) come back as one tuple per item."""
    rng = np.random.default_rng(4)
    aig = random_aig(rng, n_pis=6, n_ands=50)
    support = list(range(1, aig.n_pis + 1))
    and_nodes = list(range(aig.n_pis + 1, aig.n_nodes))
    items = []
    for _ in range(8):
        picks = rng.integers(0, len(and_nodes), size=3)
        roots = tuple(
            lit(int(and_nodes[p]), int(rng.integers(2))) for p in picks
        )
        items.append((roots, support))
    assert_items_match_python(aig, items)


def test_eval_tts_wide_support_host_fallback():
    """Supports wider than DEVICE_MAX_VARS take the host bigint path on
    the jnp engine — same results, mixed freely with device queries."""
    rng = np.random.default_rng(5)
    aig = random_aig(rng, n_pis=DEVICE_MAX_VARS + 2, n_ands=80)
    wide = list(range(1, aig.n_pis + 1))
    items = [((lit(aig.n_nodes - 1),), wide)]
    items += random_cone_queries(rng, aig, n_queries=6, max_leaves=5)
    assert_items_match_python(aig, items)


def test_eval_tt_single_query_wrapper():
    rng = np.random.default_rng(6)
    aig = random_aig(rng, n_pis=4, n_ands=30)
    root_lit = lit(aig.n_nodes - 1, 1)
    support = list(range(1, 5))
    assert eval_tt(aig, root_lit, support, engine="jnp") == aig.truth_table(
        root_lit, support
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_pis=st.integers(3, 8),
        n_ands=st.integers(5, 80),
    )
    def test_eval_tts_property(seed, n_pis, n_ands):
        rng = np.random.default_rng(seed)
        aig = random_aig(rng, n_pis=n_pis, n_ands=n_ands)
        if aig.n_ands == 0:
            return
        items = random_cone_queries(rng, aig, n_queries=8)
        assert_items_match_python(aig, items)


# ---------------------------------------------------------------------------
# node signatures
# ---------------------------------------------------------------------------


def test_node_signatures_parity():
    rng = np.random.default_rng(7)
    aig = random_aig(rng, n_pis=8, n_ands=100)
    patterns = rng.integers(
        0, 1 << 64, size=(aig.n_pis, 2), dtype=np.uint64
    )
    got = node_signatures(aig, patterns, engine="jnp")
    ref = T._node_signatures(aig, patterns)
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# trace-count guards
# ---------------------------------------------------------------------------


def test_eval_trace_count_stable_across_same_shape_batches():
    rng = np.random.default_rng(8)
    aig = random_aig(rng, n_pis=6, n_ands=60)
    items = random_cone_queries(rng, aig, n_queries=16)
    prog = compile_aig(aig)
    eval_tts(aig, items, engine="jnp", program=prog)  # may trace
    after_first = trace_counts().get("aig_eval", 0)
    eval_tts(aig, items, engine="jnp", program=prog)
    assert trace_counts().get("aig_eval", 0) == after_first, (
        "re-running an identical batch retraced the mega-program kernel"
    )


def test_sig_trace_count_stable_across_graphs():
    """Same wave/word shapes from a *different* AIG must not retrace."""
    rng = np.random.default_rng(9)
    a1 = random_aig(rng, n_pis=6, n_ands=60)
    a2 = random_aig(rng, n_pis=6, n_ands=60)
    pats = rng.integers(0, 1 << 64, size=(6, 2), dtype=np.uint64)
    node_signatures(a1, pats, engine="jnp")
    before = trace_counts().get("aig_sig", 0)
    p1, p2 = compile_aig(a1), compile_aig(a2)
    if p1.waves.shape == p2.waves.shape and p1.n_pad == p2.n_pad:
        node_signatures(a2, pats, engine="jnp")
        assert trace_counts().get("aig_sig", 0) == before
    node_signatures(a1, pats, engine="jnp")
    assert trace_counts().get("aig_sig", 0) == before


# ---------------------------------------------------------------------------
# Pallas engine (interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pallas_engine_matches_python():
    rng = np.random.default_rng(10)
    aig = random_aig(rng, n_pis=4, n_ands=16)
    items = random_cone_queries(rng, aig, n_queries=3, max_leaves=4)
    assert_items_match_python(aig, items, engine="pallas")


# ---------------------------------------------------------------------------
# transform / suite parity, device vs python
# ---------------------------------------------------------------------------


TRANSFORM_TEST_CIRCUITS = {
    "adder-8": lambda: C.gen_adder(8),
    "max-8x4": lambda: C.gen_max(8, 4),
}


@pytest.mark.parametrize("name", list(TRANSFORM_TEST_CIRCUITS))
def test_transform_backend_fingerprint_parity(name):
    rtl = TRANSFORM_TEST_CIRCUITS[name]()
    py_fns = transform_fns("python")
    dev_fns = transform_fns("device")
    for t in T.TRANSFORM_NAMES:
        out_py = py_fns[t](rtl)
        out_dev = dev_fns[t](rtl)
        assert out_dev.fingerprint() == out_py.fingerprint(), (
            f"{t} on {name}: device result structure differs from python"
        )


def test_characterize_suite_backend_parity():
    suite = {"bar-16": C.gen_barrel_shifter(16), "sqrt-8": C.gen_sqrt(8)}
    recipes = [("Rw",), ("Rf", "Rs"), ("Rs", "Rw", "Ba")]
    cha_py = characterize_suite(suite, recipes, n_jobs=1, backend="python")
    cha_dev = characterize_suite(suite, recipes, n_jobs=1, backend="device")
    assert cha_py == cha_dev


# ---------------------------------------------------------------------------
# cache partial warm start (per-prefix application persistence)
# ---------------------------------------------------------------------------


def test_cache_partial_warm_start(tmp_path, monkeypatch):
    """A cache populated by one recipe set must warm-start the shared
    prefix of a *different* recipe set: the second run re-runs only the
    genuinely new transform applications."""
    rtl = C.gen_sqrt(8)
    cache = CharacterizationCache(tmp_path / "cha")
    characterize_suite(
        {"sqrt": rtl}, [("Rw", "Ba")], cache=cache, n_jobs=1,
        backend="python",
    )

    calls = {t: 0 for t in T.TRANSFORM_NAMES}
    real_fns = dict(T._TRANSFORM_FNS)
    for t in T.TRANSFORM_NAMES:

        def counted(aig, _t=t):
            calls[_t] += 1
            return real_fns[_t](aig)

        monkeypatch.setitem(T._TRANSFORM_FNS, t, counted)

    # Fresh cache object, same directory: ("Rw", "Rf") shares the ("Rw",)
    # prefix with the persisted run, so only Rf may actually execute.
    cha = characterize_suite(
        {"sqrt": rtl},
        [("Rw", "Rf")],
        cache=CharacterizationCache(tmp_path / "cha"),
        n_jobs=1,
        backend="python",
    )
    assert calls["Rw"] == 0, "persisted Rw application was re-run"
    assert calls["Rf"] == 1
    # And the warm-started result is byte-identical to a cold one.
    cold = characterize_suite(
        {"sqrt": rtl}, [("Rw", "Rf")], n_jobs=1, backend="python"
    )
    assert cha == cold
