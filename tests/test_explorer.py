"""Algorithm I explorer + calibrated energy model vs the paper's claims.

Bands are deliberately generous: the paper's absolute numbers are not
internally consistent (see core/sram.py docstring), so we assert the
*trend directions and rough magnitudes* the paper reports.
"""

import pytest

from repro.core import circuits as C
from repro.core.explorer import best_worst, explore
from repro.core.mapping import schedule_stats
from repro.core.sram import (
    MACRO_COUNTS,
    MACRO_SIZES_KB,
    TOPOLOGY_LIBRARY,
    EnergyModel,
    SramTopology,
    evaluate,
    inductor_size_nh,
    peak_throughput_gops,
    table2_metrics,
)

EM = EnergyModel()


@pytest.fixture(scope="module")
def mult_stats():
    return C.gen_multiplier(32).characterize()


def E(stats, kb, m, discipline="list", mode="physical"):
    t = SramTopology(kb, m)
    return evaluate(schedule_stats(stats, t, discipline=discipline), t, EM, mode=mode)


def test_topology_library():
    assert len(TOPOLOGY_LIBRARY) == 12
    assert {t.macro_kb for t in TOPOLOGY_LIBRARY} == set(MACRO_SIZES_KB)
    assert {t.n_macros for t in TOPOLOGY_LIBRARY} == set(MACRO_COUNTS)
    t8 = SramTopology(8, 1)
    assert t8.rows == 256 and t8.cols == 256  # Table II (256x256) = 8KB
    assert t8.ops_per_cycle_per_macro == 128


def test_macro_doubling_energy_drop(mult_stats):
    """Paper: ~47% energy reduction going 4KB -> 8KB single macro."""
    e4, e8 = E(mult_stats, 4, 1), E(mult_stats, 8, 1)
    drop = 1 - e8.energy_nj / e4.energy_nj
    assert 0.30 <= drop <= 0.60, drop


def test_three_macro_vs_single(mult_stats):
    """Paper: 3-macro ~39% lower energy, ~38% lower latency."""
    e1, e3 = E(mult_stats, 4, 1), E(mult_stats, 4, 3)
    d_e = 1 - e3.energy_nj / e1.energy_nj
    d_t = 1 - e3.latency_ns / e1.latency_ns
    assert 0.25 <= d_e <= 0.65, d_e
    assert 0.25 <= d_t <= 0.70, d_t


def test_six_macro_latency(mult_stats):
    """Paper: 6-macro ~47% lower latency than 3-macro.  (Its +15% energy
    claim conflicts with its own cycle claim — see DESIGN.md; we assert
    only the latency direction.)"""
    e3, e6 = E(mult_stats, 4, 3), E(mult_stats, 4, 6)
    assert e6.latency_ns < e3.latency_ns


def test_large_three_macro_saving(mult_stats):
    """Paper Table I flavor: 3x16KB vs 1x4KB saves >= 50%."""
    e41, e163 = E(mult_stats, 4, 1), E(mult_stats, 16, 3)
    assert 1 - e163.energy_nj / e41.energy_nj >= 0.5


def test_headline_six_topology_saving(mult_stats):
    """Abstract: six-topology implementation reduces energy vs the
    single-macro baseline (80.9% claimed on recipe-swept benchmarks;
    topology-only on one circuit must still clear 50%)."""
    e41 = E(mult_stats, 4, 1)
    best6 = min(E(mult_stats, kb, 6).energy_nj for kb in MACRO_SIZES_KB)
    assert 1 - best6 / e41.energy_nj >= 0.5


def test_table2_metrics_in_paper_range():
    """8KB single macro: 88.2-106.6 GOPS, 8.64-10.45 TOPS/W (Table II)."""
    t8 = SramTopology(8, 1)
    m_nand = table2_metrics(t8, EM, nor_fraction=0.0)
    m_nor = table2_metrics(t8, EM, nor_fraction=1.0)
    assert 80 <= m_nor["throughput_gops"] <= 115
    assert 80 <= m_nand["throughput_gops"] <= 115
    lo = min(m_nand["tops_per_watt"], m_nor["tops_per_watt"])
    hi = max(m_nand["tops_per_watt"], m_nor["tops_per_watt"])
    assert 6.0 <= lo <= 12.0
    assert 8.0 <= hi <= 16.0
    dens = table2_metrics(t8, EM, nor_fraction=0.5)["gops_per_mm2"]
    assert 400 <= dens <= 900  # paper: 551-666


def test_paper_mode_power_formula(mult_stats):
    met = E(mult_stats, 8, 1, discipline="levels", mode="paper")
    assert abs(met.power_mw - EM.alpha_mw_per_level * mult_stats.n_levels) < 1e-6


def test_capacity_constraint():
    st = C.gen_multiplier(16).characterize()  # ~5k gates -> 20k bits needed
    t = SramTopology(4, 1)  # 32k bits
    sched = schedule_stats(st, t)
    assert sched.fits
    big = C.gen_multiplier(32).characterize()  # ~21k gates -> 84k bits
    assert not schedule_stats(big, SramTopology(4, 1)).fits
    assert schedule_stats(big, SramTopology(16, 1)).fits


def test_inductor_sizing():
    l4 = inductor_size_nh(SramTopology(4, 1), EM)
    l32 = inductor_size_nh(SramTopology(32, 1), EM)
    assert l4 > 0 and l32 > 0
    # more bitline capacitance -> smaller inductor at fixed f_res
    assert l32 < l4


def test_explore_algorithm_one():
    res = explore(C.gen_adder(32), recipes=[("Ba",), ("Rw",), ("Rw", "Ba")])
    assert res.best.schedule.fits
    assert res.inductor_nh > 0
    assert res.n_recipes == 4  # 3 + implicit baseline ()
    # full sweep covers all 12 topologies x 4 recipes
    assert len(res.evaluations) == 48
    b, w = best_worst(res)
    assert b.metrics.energy_nj <= w.metrics.energy_nj
    row = res.table_row()
    assert row["benchmark"] == "adder-32"
    assert row["energy_nj"] > 0


def test_explore_respects_latency_constraint():
    rtl = C.gen_adder(32)
    free = explore(rtl, recipes=[("Ba",)])
    tight = explore(rtl, recipes=[("Ba",)],
                    max_latency_ns=free.best.metrics.latency_ns * 0.9)
    assert tight.best.metrics.latency_ns <= free.best.metrics.latency_ns * 1.0001


def test_peak_throughput_scales():
    assert peak_throughput_gops(SramTopology(8, 3)) == pytest.approx(
        3 * peak_throughput_gops(SramTopology(8, 1))
    )
