"""Unit contract of the fault-injection registry (`repro.runtime.faults`).

The load-bearing guarantee is the first one: with no plan armed, every
injection point in the codebase is a strict no-op — production behavior
is bit-identical with the module imported or not.  The chaos CI profile
re-asserts this before running the fault matrix.
"""

import json
import os

import pytest

from repro.runtime import faults


@pytest.fixture(autouse=True)
def _disarmed():
    """Every test starts and ends with no plan armed."""
    faults.disable()
    yield
    faults.disable()


# ---------------------------------------------------------------------------
# disabled means invisible
# ---------------------------------------------------------------------------


def test_disabled_inject_is_noop_for_every_point():
    assert not faults.enabled()
    for point in faults.POINTS:
        faults.inject(point, detail="anything")  # must not raise/hang/exit


def test_disabled_corrupt_returns_payload_unchanged():
    payload = b"x" * 257
    for point in faults.POINTS:
        assert faults.corrupt(point, payload) is payload


def test_disabled_corrupt_file_leaves_file_alone(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(b"y" * 100)
    for point in faults.POINTS:
        faults.corrupt_file(point, p)
    assert p.read_bytes() == b"y" * 100


def test_unknown_point_or_action_fails_loudly():
    with pytest.raises(ValueError, match="unknown fault point"):
        faults.FaultRule("no.such.point", "raise")
    with pytest.raises(ValueError, match="unknown fault action"):
        faults.FaultRule("pool.task", "explode")


# ---------------------------------------------------------------------------
# armed behavior: match / after / count, determinism
# ---------------------------------------------------------------------------


def test_raise_fires_and_counts():
    with faults.injected(faults.FaultRule("pool.task", "raise")) as plan:
        with pytest.raises(faults.FaultError):
            faults.inject("pool.task", "adder:Rw")
        # count=1 exhausted: the second hit passes through
        faults.inject("pool.task", "adder:Rw")
        assert plan.fired["pool.task"] == 1
        assert plan.hits[0] == 2
    # context manager restored the disarmed state
    faults.inject("pool.task", "adder:Rw")


def test_match_filters_on_detail_substring():
    rule = faults.FaultRule("pool.task", "raise", match="sine", count=None)
    with faults.injected(rule):
        faults.inject("pool.task", "adder:Rw")  # no match -> no fire
        with pytest.raises(faults.FaultError):
            faults.inject("pool.task", "sine:Ba")


def test_after_skips_leading_hits():
    rule = faults.FaultRule("sweep.shard", "raise", after=2, count=1)
    with faults.injected(rule):
        faults.inject("sweep.shard", "s0")
        faults.inject("sweep.shard", "s1")
        with pytest.raises(faults.FaultError):
            faults.inject("sweep.shard", "s2")
        faults.inject("sweep.shard", "s3")  # count spent


def test_probabilistic_rule_is_seed_deterministic():
    def firing_pattern(seed, n=32):
        rule = faults.FaultRule(
            "service.process", "raise", count=None, prob=0.5
        )
        fired = []
        with faults.injected(rule, seed=seed):
            for _ in range(n):
                try:
                    faults.inject("service.process", "1")
                    fired.append(False)
                except faults.FaultError:
                    fired.append(True)
        return fired

    a, b = firing_pattern(7), firing_pattern(7)
    assert a == b
    assert firing_pattern(8) != a  # different seed, different pattern
    assert any(a) and not all(a)


def test_corrupt_truncates_deterministically():
    data = bytes(range(256)) * 4
    with faults.injected(
        faults.FaultRule("cache.store", "corrupt", count=None), seed=3
    ):
        out1 = faults.corrupt("cache.store", data)
        out2 = faults.corrupt("cache.store", data)
    assert out1 == out2
    assert 0 < len(out1) < len(data)
    assert data.startswith(out1)


def test_corrupt_file_truncates_in_place(tmp_path):
    p = tmp_path / "arrays.npz"
    p.write_bytes(b"z" * 1000)
    with faults.injected(faults.FaultRule("journal.write", "corrupt")):
        faults.corrupt_file("journal.write", p)
    assert 0 < p.stat().st_size < 1000


# ---------------------------------------------------------------------------
# env parsing (the spawn-worker / subprocess arming path)
# ---------------------------------------------------------------------------


def test_parse_rules_full_syntax():
    rules = faults.parse_rules(
        "pool.task:exit::1:1; sweep.shard:raise:adder; "
        "pool.task:hang:::inf:2.5"
    )
    assert len(rules) == 3
    assert rules[0] == faults.FaultRule("pool.task", "exit", after=1, count=1)
    assert rules[1].match == "adder" and rules[1].count == 1
    assert rules[2].count is None and rules[2].hang_s == 2.5


def test_parse_rules_rejects_garbage():
    with pytest.raises(ValueError):
        faults.parse_rules("pool.task")
    with pytest.raises(ValueError):
        faults.parse_rules("typo.point:raise")


def test_env_arming_in_subprocess(tmp_path):
    """The env path is what spawn pool workers and kill-9 subprocesses
    inherit; exercise it end to end in a real child process."""
    import subprocess
    import sys

    code = (
        "from repro.runtime import faults\n"
        "assert faults.enabled()\n"
        "try:\n"
        "    faults.inject('pool.task', 'adder:Rw')\n"
        "    raise SystemExit('fault did not fire')\n"
        "except faults.FaultError:\n"
        "    pass\n"
        "print('armed-ok')\n"
    )
    env = dict(os.environ, REPRO_FAULTS="pool.task:raise", REPRO_FAULTS_SEED="5")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert out.returncode == 0, out.stderr
    assert "armed-ok" in out.stdout


def test_once_dir_bounds_global_fires(tmp_path, monkeypatch):
    """With REPRO_FAULTS_ONCE_DIR, a count=2 rule fires exactly twice
    even if the per-process hit counters would allow more (fresh
    processes restart their counters; the claim files do not)."""
    monkeypatch.setenv("REPRO_FAULTS_ONCE_DIR", str(tmp_path))
    fired = 0
    for _ in range(3):
        # each iteration simulates a fresh worker process: new plan state
        with faults.injected(
            faults.FaultRule("pool.task", "raise", count=2)
        ):
            try:
                faults.inject("pool.task", "adder:Rw")
            except faults.FaultError:
                fired += 1
    assert fired == 2
    assert len(list(tmp_path.iterdir())) == 2


def test_points_registry_documents_every_point():
    for point, desc in faults.POINTS.items():
        assert isinstance(desc, str) and len(desc) > 10, point
