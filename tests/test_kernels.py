"""Pallas CiM kernel vs the pure-jnp oracle + numpy netlist simulator.

Sweeps shapes (circuit sizes, vector counts incl. non-multiples of 32,
block widths) and validates in interpret mode per the assignment.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import circuits as C
from repro.core.aig import random_aig
from repro.kernels import ops, ref

rng = np.random.default_rng(7)


def netlist_sim_bits(aig, net, bits):
    n_vec = bits.shape[1]
    pv = np.zeros((aig.n_pis, (n_vec + 63) // 64), dtype=np.uint64)
    for v in range(n_vec):
        for i in range(aig.n_pis):
            if bits[i, v]:
                pv[i, v // 64] |= np.uint64(1) << np.uint64(v % 64)
    sim = net.simulate(pv)
    out = np.zeros((len(net.po_signals), n_vec), dtype=np.uint8)
    for v in range(n_vec):
        out[:, v] = (sim[:, v // 64] >> np.uint64(v % 64)) & np.uint64(1)
    return out


@pytest.mark.parametrize("n_vec", [1, 31, 32, 100, 700])
def test_kernel_matches_netlist_adder(n_vec):
    aig = C.gen_adder(8)
    net = aig.to_gate_netlist()
    bits = rng.integers(0, 2, size=(aig.n_pis, n_vec)).astype(np.uint8)
    expect = netlist_sim_bits(aig, net, bits)
    got = ops.cim_evaluate(net, bits, block_words=128)
    assert np.array_equal(got, expect)


@pytest.mark.parametrize("block_words", [128, 256, 512])
def test_kernel_block_width_sweep(block_words):
    aig = C.gen_max(6, 3)
    net = aig.to_gate_netlist()
    bits = rng.integers(0, 2, size=(aig.n_pis, 4096)).astype(np.uint8)
    expect = netlist_sim_bits(aig, net, bits)
    got = ops.cim_evaluate(net, bits, block_words=block_words)
    assert np.array_equal(got, expect)


def test_kernel_vs_jnp_reference():
    aig = C.gen_multiplier(6)
    net = aig.to_gate_netlist()
    bits = rng.integers(0, 2, size=(aig.n_pis, 257)).astype(np.uint8)
    ref_bits = ops.cim_reference_evaluate(net, bits)
    ker_bits = ops.cim_evaluate(net, bits, block_words=128)
    assert np.array_equal(ref_bits, ker_bits)


def test_row_reuse_equivalence():
    aig = C.gen_divisor(6)
    net = aig.to_gate_netlist()
    bits = rng.integers(0, 2, size=(aig.n_pis, 96)).astype(np.uint8)
    cc_reuse = ops.compile_netlist(net, reuse_rows=True)
    cc_flat = ops.compile_netlist(net, reuse_rows=False)
    assert cc_reuse.n_rows < cc_flat.n_rows  # reuse actually helps
    a = ops.cim_evaluate(cc_reuse, bits, block_words=128)
    b = ops.cim_evaluate(cc_flat, bits, block_words=128)
    assert np.array_equal(a, b)


@settings(max_examples=12, deadline=None)
@given(
    n_pis=st.integers(3, 10),
    n_ands=st.integers(5, 120),
    n_pos=st.integers(1, 6),
    seed=st.integers(0, 10**6),
    n_vec=st.integers(1, 300),
)
def test_kernel_random_circuits(n_pis, n_ands, n_pos, seed, n_vec):
    aig = random_aig(n_pis, n_ands, n_pos, seed=seed)
    net = aig.to_gate_netlist()
    if not net.gates:
        pytest.skip("degenerate netlist")
    bits = np.random.default_rng(seed).integers(0, 2, size=(n_pis, n_vec)).astype(np.uint8)
    expect = netlist_sim_bits(aig, net, bits)
    got = ops.cim_evaluate(net, bits, block_words=128)
    assert np.array_equal(got, expect)


def test_pack_unpack_roundtrip():
    for n_vec in [1, 31, 32, 33, 64, 100]:
        bits = rng.integers(0, 2, size=(5, n_vec)).astype(np.uint8)
        assert np.array_equal(ref.unpack_vectors(ref.pack_vectors(bits), n_vec), bits)


def test_compiled_metadata():
    net = C.gen_adder(8).to_gate_netlist()
    cc = ops.compile_netlist(net)
    assert cc.n_gates == len(net.gates)
    assert cc.n_pos == len(net.po_signals)
    assert cc.reuse_factor >= 1.0
    assert cc.n_rows_padded % 8 == 0
