"""Substrate tests: optimizer, schedules, data pipeline, checkpointing,
sharding rules, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Pipeline
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule, wsd_schedule)


# ------------------------------- optimizer ---------------------------------


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01, clip_norm=0.0)
    params = dict(w=jnp.array([1.0, -2.0, 3.0]), b=jnp.array([[0.5, 0.5]]))
    grads = dict(w=jnp.array([0.1, 0.2, -0.3]), b=jnp.array([[1.0, -1.0]]))
    state = adamw_init(params, cfg)
    lr = 0.1
    new_p, new_s = adamw_update(grads, state, params, jnp.float32(lr), cfg)

    def np_adamw(p, g):
        m = 0.1 * g
        v = 0.001 * g * g
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.999)
        return p - lr * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * p)

    for k in params:
        np.testing.assert_allclose(
            np.asarray(new_p[k]), np_adamw(np.asarray(params[k]), np.asarray(grads[k])),
            rtol=1e-5,
        )
    assert int(new_s["step"]) == 1


def test_clip_norm():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    params = dict(w=jnp.zeros(4))
    grads = dict(w=jnp.full(4, 100.0))
    state = adamw_init(params, cfg)
    new_p, _ = adamw_update(grads, state, params, jnp.float32(1.0), cfg)
    # post-clip grad norm is 1 -> adam direction magnitude ~1 per coord
    assert np.all(np.abs(np.asarray(new_p["w"])) < 1.5)


def test_wsd_schedule_shape():
    f = wsd_schedule(1e-3, warmup_steps=10, stable_steps=80, decay_steps=10)
    assert float(f(0)) == 0.0
    assert float(f(5)) == pytest.approx(5e-4)
    assert float(f(50)) == pytest.approx(1e-3)
    assert float(f(89)) == pytest.approx(1e-3)
    assert float(f(100)) == pytest.approx(1e-4, rel=0.01)  # final_frac=0.1
    g = cosine_schedule(1e-3, 10, 100)
    assert float(g(100)) == pytest.approx(1e-4, rel=0.01)


def test_int8_error_feedback_compression():
    cfg = AdamWConfig(compression="int8_ef", clip_norm=0.0, weight_decay=0.0)
    params = dict(w=jnp.zeros(1000))
    state = adamw_init(params, cfg)
    assert "ef" in state
    rng = np.random.default_rng(0)
    g_const = jnp.asarray(rng.normal(size=1000).astype(np.float32)) * 1e-3
    # applying the same gradient repeatedly: error feedback keeps the mean
    # applied update unbiased
    p = params
    for _ in range(20):
        p, state = adamw_update(dict(w=g_const), state, p, jnp.float32(1e-2), cfg)
    # direction should match the uncompressed run closely
    cfg2 = AdamWConfig(compression="none", clip_norm=0.0, weight_decay=0.0)
    p2, s2 = dict(w=jnp.zeros(1000)), adamw_init(params, cfg2)
    for _ in range(20):
        p2, s2 = adamw_update(dict(w=g_const), s2, p2, jnp.float32(1e-2), cfg2)
    cos = np.dot(np.asarray(p["w"]), np.asarray(p2["w"])) / (
        np.linalg.norm(np.asarray(p["w"])) * np.linalg.norm(np.asarray(p2["w"])) + 1e-12
    )
    assert cos > 0.99


# --------------------------------- data ------------------------------------


def test_data_determinism_and_host_disjointness():
    cfg = DataConfig(batch_per_host=4, seq_len=32, vocab_size=1000, seed=3)
    p0 = Pipeline(cfg, host=0, n_hosts=4)
    p0b = Pipeline(cfg, host=0, n_hosts=4)
    p1 = Pipeline(cfg, host=1, n_hosts=4)
    b0 = p0.get_batch(7)
    assert np.array_equal(b0["tokens"], p0b.get_batch(7)["tokens"])  # deterministic
    assert not np.array_equal(b0["tokens"], p1.get_batch(7)["tokens"])  # disjoint
    assert not np.array_equal(b0["tokens"], p0.get_batch(8)["tokens"])  # steps differ
    assert b0["tokens"].shape == (4, 32)
    assert (b0["tokens"] >= 0).all() and (b0["tokens"] < 1000).all()
    assert np.array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_memmap_source(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(33 * 50, dtype=np.int32) % 777
    data.tofile(path)
    cfg = DataConfig(batch_per_host=2, seq_len=32, vocab_size=777, seed=0, path=path)
    p = Pipeline(cfg, host=0, n_hosts=1)
    b = p.get_batch(0)
    assert b["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][0], data[:32])


# ------------------------------ checkpointing -------------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    from repro.ckpt.manager import CheckpointManager

    tree = dict(a=jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                nested=dict(b=jnp.ones(4, jnp.bfloat16)),
                step=jnp.int32(5))
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, tree)
    assert mgr.steps() == [2, 3]  # keep-N GC
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = mgr.restore(like)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    assert meta["step"] == 3


def test_checkpoint_atomicity(tmp_path):
    from repro.ckpt.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, dict(x=jnp.ones(3)))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_restore_with_sharding(tmp_path):
    """Elastic restore: apply a (new) sharding at load time."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.ckpt.manager import CheckpointManager

    mesh = jax.make_mesh((1,), ("data",))
    tree = dict(w=jnp.arange(8, dtype=jnp.float32))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, tree)
    shard = dict(w=NamedSharding(mesh, P("data")))
    restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, tree), shardings=shard)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == shard["w"]


def test_checkpoint_rapid_async_saves_queue_behind(tmp_path):
    """Regression: with async_save, a second save() used to BLOCK on the
    in-flight writer (and a concurrent caller could drop its thread
    handle, so wait() no longer drained it).  Now saves return
    immediately, queue behind each other in call order, and wait()
    drains the whole chain."""
    import threading
    import time

    from repro.ckpt.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep_n=10, async_save=True)
    gate = threading.Event()
    started = threading.Event()
    orig_write = mgr._write

    def gated_write(step, arrays, meta):
        if step == 1:
            started.set()
            gate.wait(timeout=10)
        orig_write(step, arrays, meta)

    mgr._write = gated_write

    mgr.save(1, dict(x=jnp.zeros(3)))
    assert started.wait(timeout=10)  # first writer is alive, mid-write
    t0 = time.monotonic()
    mgr.save(2, dict(x=jnp.ones(3)))
    elapsed = time.monotonic() - t0
    assert elapsed < 2.0, (
        f"save() must not block behind the in-flight writer ({elapsed:.1f}s)"
    )
    assert mgr.steps() == []  # step 2 must not publish ahead of step 1
    gate.set()
    mgr.wait()  # drains BOTH writers
    assert mgr.steps() == [1, 2]
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    restored, meta = mgr.restore(dict(x=jnp.zeros(3)))
    assert meta["step"] == 2
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(3))


def test_checkpoint_concurrent_savers_all_land(tmp_path):
    """Many threads calling save() simultaneously: the lock-protected
    writer handoff means no step is lost and wait() drains everything."""
    import threading

    from repro.ckpt.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep_n=64, async_save=True)
    barrier = threading.Barrier(8)

    def saver(step):
        barrier.wait(timeout=10)
        mgr.save(step, dict(x=jnp.full(4, step, jnp.float32)))

    threads = [threading.Thread(target=saver, args=(s,)) for s in range(1, 9)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    mgr.wait()
    assert mgr.steps() == list(range(1, 9))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    from repro.ckpt.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, dict(w=jnp.ones(4)))
    with pytest.raises(ValueError):
        mgr.restore(dict(w=jnp.ones(5)))


# ------------------------------- sharding ----------------------------------


def test_spec_divisibility_fallback():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import spec_for

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"mlp": ["model"], "embed": [("data",)]}
    # mesh axes of size 1 -> everything replicated
    assert spec_for(mesh, (64, 128), ("embed", "mlp"), rules) == P()


def test_rules_for_model_head_divisibility():
    import os

    from repro.models.config import ParallelConfig
    from repro.parallel.sharding import rules_for_model
    from repro.configs import get_config

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # n_heads % 1 == 0 always; fabricate a non-divisible case via msize=1
    # (structural check only: rules dict has the expected keys)
    pc = ParallelConfig()
    rules = rules_for_model(get_config("minicpm-2b"), pc, mesh)
    for k in ("vocab", "qkv", "kv_seq", "act_heads", "experts"):
        assert k in rules
