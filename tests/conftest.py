"""Shared test configuration: markers, per-test timeout, hypothesis caps.

Per-test timeout: ``PYTEST_PER_TEST_TIMEOUT=<seconds>`` arms a SIGALRM
around each test body (no external pytest-timeout dependency), so a hung
test fails fast with a TimeoutError instead of stalling the CI pipeline.
0 / unset disables it; platforms without SIGALRM (windows) skip arming.

Hypothesis budget: a ``ci`` profile caps ``max_examples`` (override with
``HYPOTHESIS_MAX_EXAMPLES``); ``HYPOTHESIS_PROFILE=ci`` selects it —
scripts/ci.sh exports both so the property suites stay inside the CI
time budget while local runs keep the per-test defaults.
"""

import os
import signal

import pytest

_TIMEOUT_S = int(os.environ.get("PYTEST_PER_TEST_TIMEOUT", "0") or "0")

try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile(
        "ci",
        max_examples=int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", "20")),
        deadline=None,
        derandomize=True,
    )
    if os.environ.get("HYPOTHESIS_PROFILE"):
        _hyp_settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
except ImportError:  # property suites skip via importorskip anyway
    pass


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test (compile-heavy)")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if _TIMEOUT_S > 0 and hasattr(signal, "SIGALRM"):
        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"{item.nodeid} exceeded the per-test timeout "
                f"({_TIMEOUT_S}s, PYTEST_PER_TEST_TIMEOUT)"
            )

        old = signal.signal(signal.SIGALRM, _on_alarm)
        signal.alarm(_TIMEOUT_S)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)
    else:
        yield
