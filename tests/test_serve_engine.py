"""ServeEngine slot-batching unit tests (no real model required).

The engine's generate() is stubbed so the tests exercise exactly the
serve()-side plumbing: prompt validation and left-padding.
"""

import numpy as np
import pytest

from repro.serve.engine import Request, ServeEngine


class _StubModel:
    """Just enough surface for ServeEngine.__init__ (jit wraps lazily)."""

    def prefill(self, params, batch):  # pragma: no cover - never traced here
        raise NotImplementedError

    def decode_step(self, params, caches, tok, pos):  # pragma: no cover
        raise NotImplementedError


def _engine(batch=2):
    return ServeEngine(_StubModel(), params=None, batch=batch, max_seq=32)


def test_serve_rejects_overlong_prompt():
    """Regression: an over-long prompt used to die with a numpy broadcast
    error deep inside the padding loop; it must be a clear ValueError."""
    eng = _engine()
    reqs = [Request(uid=7, prompt=np.arange(9, dtype=np.int32) + 1)]
    with pytest.raises(ValueError, match=r"uid=7.*length 9.*prompt_pad=8"):
        eng.serve(reqs, prompt_pad=8)


def test_serve_rejects_empty_prompt():
    """A zero-length prompt would silently slice the whole row via
    ``[-0:]``; it must be rejected up front too."""
    eng = _engine()
    reqs = [Request(uid=3, prompt=np.zeros(0, np.int32))]
    with pytest.raises(ValueError, match=r"uid=3.*length 0"):
        eng.serve(reqs, prompt_pad=8)


def test_serve_left_pads_including_exact_fit():
    """Prompts shorter than and exactly equal to prompt_pad both land
    left-aligned-to-the-right; validation happens before any prefill."""
    eng = _engine(batch=2)
    captured = []

    def fake_generate(prompts, max_new, extra_batch=None):
        captured.append(np.array(prompts))
        return np.zeros((eng.batch, max_new), np.int32)

    eng.generate = fake_generate
    reqs = [
        Request(uid=0, prompt=np.array([1, 2, 3], np.int32), max_new=4),
        Request(uid=1, prompt=np.arange(1, 9, dtype=np.int32), max_new=4),
    ]
    done = eng.serve(reqs, prompt_pad=8)
    assert [r.uid for r in done] == [0, 1] and all(r.done for r in done)
    (prompts,) = captured
    np.testing.assert_array_equal(
        prompts[0], np.array([0, 0, 0, 0, 0, 1, 2, 3], np.int32)
    )
    np.testing.assert_array_equal(
        prompts[1], np.arange(1, 9, dtype=np.int32)
    )


def test_serve_validates_before_any_wave_runs():
    """A bad request anywhere in the list fails fast — no partial wave of
    prefills runs first."""
    eng = _engine(batch=1)
    calls = []
    eng.generate = lambda *a, **k: calls.append(a) or np.zeros((1, 1), np.int32)
    reqs = [
        Request(uid=0, prompt=np.array([1], np.int32), max_new=1),
        Request(uid=1, prompt=np.arange(99, dtype=np.int32), max_new=1),
    ]
    with pytest.raises(ValueError, match="uid=1"):
        eng.serve(reqs, prompt_pad=8)
    assert calls == []
