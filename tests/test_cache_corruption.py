"""Satellite bugfix pin: a corrupt `CharacterizationCache` is a miss.

A truncated or schema-corrupt cache file (torn write, bad sector,
version skew from a crashed writer) used to raise out of the load paths
and wedge every warm run.  The contract now: byte truncation anywhere is
at worst a whole-circuit miss, a schema-corrupt *entry* inside valid
JSON is an entry-level miss, and re-characterization atomically rewrites
the file — verified to fail on the pre-fix loaders by construction
(`json.load` raises ``JSONDecodeError`` on every truncated fixture
below).
"""

import json
from pathlib import Path

import pytest

from repro.core.circuits import gen_adder
from repro.core.transforms import (
    CharacterizationCache,
    characterize_suite,
)
from repro.runtime import faults

RECIPES = [(), ("Rw",), ("Ba", "Rw")]


@pytest.fixture(autouse=True)
def _disarmed():
    faults.disable()
    yield
    faults.disable()


@pytest.fixture()
def warm_cache(tmp_path):
    adder = gen_adder(6)
    cache = CharacterizationCache(tmp_path)
    clean = characterize_suite(
        {"adder": adder}, RECIPES, cache=cache, n_jobs=1, backend="python"
    )
    return adder, cache, clean


def _cache_files(cache) -> list[Path]:
    files = sorted(Path(cache.root).rglob("*.json"))
    assert files, "warm run persisted nothing"
    return files


def test_byte_truncation_is_a_miss_never_a_crash(warm_cache):
    adder, cache, _ = warm_cache
    fp = adder.fingerprint()
    for path in _cache_files(cache):
        data = path.read_bytes()
        for cut in (0, 1, len(data) // 2, len(data) - 1):
            path.write_bytes(data[:cut])
            # None of the loaders may raise on any truncation point.
            cache.load(fp)
            cache.load_applications(fp)
            cache.load_aig(path.stem)
        path.write_bytes(data)
    # A truncated persisted AIG specifically must read back as a miss.
    aigs = sorted(Path(cache.root).rglob("aigs/*.json"))
    assert aigs
    aig_path = aigs[0]
    data = aig_path.read_bytes()
    aig_path.write_bytes(data[: len(data) // 2])
    assert cache.load_aig(aig_path.stem) is None


def test_truncated_cache_recovers_by_recharacterizing(warm_cache):
    adder, cache, clean = warm_cache
    for path in _cache_files(cache):
        data = path.read_bytes()
        path.write_bytes(data[: max(1, len(data) // 3)])
    assert cache.load(adder.fingerprint()) == {}
    out = characterize_suite(
        {"adder": adder}, RECIPES, cache=cache, n_jobs=1, backend="python"
    )
    assert out == clean
    # The rewrite healed the cache: a fresh instance warm-hits.
    healed = CharacterizationCache(cache.root)
    assert characterize_suite(
        {"adder": adder}, RECIPES, cache=healed, n_jobs=1, backend="python"
    ) == clean
    assert healed.hits == 1 and healed.misses == 0


def test_schema_corrupt_entry_is_entry_level_miss(warm_cache):
    adder, cache, _ = warm_cache
    fp = adder.fingerprint()
    path = cache._path(fp)
    raw = json.loads(path.read_text())
    keys = list(raw["recipes"])
    assert len(keys) >= 3
    raw["recipes"][keys[0]] = {"wrong": "shape"}  # bad stats dict
    raw["recipes"][keys[1]] = 17  # not a dict at all
    path.write_text(json.dumps(raw))
    loaded = cache.load(fp)
    # The good entries survive; only the corrupt two are misses.
    assert {",".join(r) for r in loaded} == set(keys[2:])


def test_wrong_toplevel_json_type_is_a_miss(warm_cache):
    adder, cache, _ = warm_cache
    fp = adder.fingerprint()
    for payload in ("[1, 2, 3]", '"a string"', "17", "null"):
        cache._path(fp).write_text(payload)
        assert cache.load(fp) == {}
        cache._apps_path(fp).write_text(payload)
        assert cache.load_applications(fp) == {}


def test_injected_store_corruption_roundtrip(tmp_path):
    """End to end through the cache.store fault point: every persisted
    file is torn mid-write, warm loads all miss, and the next run
    recovers by re-characterizing and rewriting atomically."""
    adder = gen_adder(6)
    cache = CharacterizationCache(tmp_path)
    with faults.injected(
        faults.FaultRule("cache.store", "corrupt", count=None)
    ):
        clean = characterize_suite(
            {"adder": adder}, RECIPES, cache=cache, n_jobs=1,
            backend="python",
        )
    assert cache.load(adder.fingerprint()) == {}
    out = characterize_suite(
        {"adder": adder}, RECIPES, cache=cache, n_jobs=1, backend="python"
    )
    assert out == clean
