"""Workload lowering + system-comparison tests (core/workloads.py,
launch/system.py): exact primitive semantics, conservation of lowered op
counts for every config in the zoo, suite-kernel pricing, and the traced
roofline-bandwidth sweep (trace discipline + monotonicity)."""

import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import workloads as W
from repro.core.batch import TRACE_COUNTS
from repro.core.sram import TOPOLOGY_LIBRARY
from repro.launch import system as S
from repro.models.config import SHAPES


def _pack(vals, nbits):
    """Pack per-vector integers into bit-parallel uint64 PI rows."""
    out = np.zeros((nbits, 1), np.uint64)
    for j, v in enumerate(vals):
        for i in range(nbits):
            if (int(v) >> i) & 1:
                out[i, 0] |= np.uint64(1) << np.uint64(j)
    return out


def _unpack(po, nbits, n_vecs):
    out = np.zeros(n_vecs, dtype=np.int64)
    for i in range(nbits):
        for j in range(n_vecs):
            if (int(po[i, 0]) >> j) & 1:
                out[j] |= 1 << i
    return out


# ----------------------------- primitives ----------------------------------


def test_mac_tile_exact():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, 64)
    b = rng.integers(0, 256, 64)
    acc = rng.integers(0, 65536, 64)
    mac = W.primitive_aigs()["mac8"]
    po = mac.simulate(np.vstack([_pack(a, 8), _pack(b, 8), _pack(acc, 16)]))
    got = _unpack(po, 16, 64)
    np.testing.assert_array_equal(got, (a * b + acc) % 65536)


def test_add_and_max_tiles_exact():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 65536, 64)
    b = rng.integers(0, 65536, 64)
    add = W.primitive_aigs()["add16"]
    po = add.simulate(np.vstack([_pack(a, 16), _pack(b, 16)]))
    np.testing.assert_array_equal(_unpack(po, 16, 64), (a + b) % 65536)

    a8 = rng.integers(0, 256, 64)
    b8 = rng.integers(0, 256, 64)
    mx = W.primitive_aigs()["max8"]
    po = mx.simulate(np.vstack([_pack(a8, 8), _pack(b8, 8)]))
    np.testing.assert_array_equal(_unpack(po, 8, 64), np.maximum(a8, b8))


def test_primitive_streams_internally_consistent():
    for name, s in W.primitive_stats().items():
        mat = s.ops_matrix()
        assert mat.shape == (s.n_levels, 3)
        assert (mat.sum(axis=0) ==
                [s.nand_count, s.nor_count, s.inv_count]).all(), name
        assert s.total_gates > 0 and s.n_levels > 0


# ------------------------------ lowering -----------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_lowering_conserves_ops_every_config(arch):
    """The CI acceptance invariant: summing the per-level streams equals
    the per-layer op totals for every config in the zoo."""
    cfg = get_config(arch)
    for shape_name in ("decode_32k", "train_4k"):
        lowered = W.lower_config(cfg, SHAPES[shape_name])
        rep = W.conservation_report(lowered)
        assert rep["ok"], (arch, shape_name, rep)
        assert lowered.macs_per_token() > 0
        tiles = lowered.tiles_per_token()
        assert all(v >= 0 for v in tiles.values())
        # matmul work dominates the elementwise terms
        assert tiles["mac8"] > tiles["add16"] + tiles["max8"]


def test_moe_lowering_counts_active_experts_only():
    import dataclasses

    cfg = get_config("deepseek-moe-16b")
    macs = W.lower_config(cfg, SHAPES["decode_32k"]).macs_per_token()
    # routing all experts instead of top_k must cost strictly more MACs,
    # and the per-layer FFN term must equal the active-expert count
    dense = dataclasses.replace(cfg, top_k=cfg.n_experts)
    macs_all = W.lower_config(dense, SHAPES["decode_32k"]).macs_per_token()
    assert macs < macs_all
    d = cfg.d_model
    expect_ffn = ((cfg.top_k + cfg.n_shared_experts) * 3 * d * cfg.moe_d_ff
                  + d * cfg.n_experts)
    layer = {l.kind: l for l in
             W.lower_config(cfg, SHAPES["decode_32k"]).layers}["attn"]
    ctx = SHAPES["decode_32k"].seq_len
    hd = cfg.resolved_head_dim
    attn_macs = (d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
                 + cfg.n_heads * hd * d + 2 * ctx * hd * cfg.n_heads)
    assert layer.tiles["mac8"] == attn_macs + expect_ffn


def test_decode_context_exceeds_prefill_average():
    cfg = get_config("qwen1.5-4b")
    dec = W.lower_config(cfg, SHAPES["decode_32k"]).macs_per_token()
    pre = W.lower_config(cfg, SHAPES["prefill_32k"]).macs_per_token()
    assert dec > pre  # decode attends the full context, prefill averages


# ----------------------- pricing through the kernels -----------------------


def test_evaluate_lowered_prices_through_suite_kernels():
    lowered = W.lower_config(get_config("mamba2-780m"), SHAPES["decode_32k"])
    res = W.evaluate_lowered(lowered)
    lib_names = {t.name for t in TOPOLOGY_LIBRARY}
    for prim in W.primitive_stats():
        assert res.winners[prim] in lib_names
        assert np.isfinite(res.tile_energy_nj[prim])
        assert res.tile_latency_ns[prim] > 0
    assert res.energy_per_token_j > 0
    assert res.latency_per_token_s > 0
    # per-layer parts sum to the totals
    assert res.energy_per_token_j == pytest.approx(
        sum(l["energy_per_token_j"] for l in res.per_layer))
    assert res.latency_per_token_s == pytest.approx(
        sum(l["latency_per_token_s"] for l in res.per_layer))
    # doubling the parallel units halves latency, leaves energy alone
    res2 = W.evaluate_lowered(lowered, n_units=2 * res.n_units)
    assert res2.energy_per_token_j == pytest.approx(res.energy_per_token_j)
    assert res2.latency_per_token_s == pytest.approx(
        res.latency_per_token_s / 2)


# ------------------------- traced roofline sweep ---------------------------


def test_sweep_roofline_trace_discipline_and_monotonicity():
    cost = S.token_cost(get_config("qwen1.5-4b"), SHAPES["decode_32k"])
    # unique sweep length to force exactly one fresh trace in this test
    bw1 = np.linspace(2e11, 2e12, 7)
    bw2 = np.linspace(3e11, 3e12, 7)
    c0 = TRACE_COUNTS["roofline_sweep"]
    out1 = S.sweep_roofline(cost, hbm_bw=bw1)
    c1 = TRACE_COUNTS["roofline_sweep"]
    out2 = S.sweep_roofline(cost, hbm_bw=bw2)
    c2 = TRACE_COUNTS["roofline_sweep"]
    assert c1 - c0 == 1, "an N-point BW sweep must cost exactly one trace"
    assert c2 - c1 == 0, "changing only BW values must not retrace"
    assert np.all(np.diff(out1["memory_s"]) < 0)  # more BW -> less time
    assert np.all(out1["token_s"] >= out1["memory_s"])
    assert np.all(out2["compute_s"] == out1["compute_s"])  # flops unchanged


def test_sweep_roofline_zero_link_bw_is_single_chip():
    cost = dict(flops=1e12, hbm_bytes=1e9, link_bytes=5e9)
    out = S.sweep_roofline(cost, hbm_bw=8e11, link_bw=0.0)
    assert out["collective_s"][0] == 0.0
    out2 = S.sweep_roofline(cost, hbm_bw=8e11, link_bw=5e10)
    assert out2["collective_s"][0] == pytest.approx(0.1)


def test_token_cost_from_dryrun_record():
    rec = dict(n_chips=4, roofline=dict(flops=8e12, hbm_bytes=4e9,
                                        link_bytes=2e9))
    shape = SHAPES["decode_32k"]  # 128 sequences, 1 token each
    cost = S.token_cost_from_dryrun(rec, shape)
    assert cost["flops"] == pytest.approx(8e12 * 4 / 128)
    assert cost["link_bytes"] == pytest.approx(2e9 * 4 / 128)


# --------------------------- end-to-end compare ----------------------------


def test_compare_system_record():
    rec = S.compare_system("mamba2-780m", "decode_32k",
                           hbm_bw_sweep=[4e11, 8e11, 1.6e12])
    assert rec["conserved"]
    assert rec["macs_per_token"] > 0
    for side in ("rcim", "baseline"):
        assert rec[side]["energy_per_token_j"] > 0
        assert rec[side]["latency_per_token_s"] > 0
    assert np.isfinite(rec["energy_ratio_rcim_over_accel"])
    assert np.isfinite(rec["latency_ratio_rcim_over_accel"])
    assert rec["baseline"]["bottleneck"] in S.BOTTLENECKS
    mem = rec["bw_sweep"]["memory_s"]
    assert mem == sorted(mem, reverse=True)
    import json

    json.dumps(rec)  # record must be JSON-serializable for the bench
