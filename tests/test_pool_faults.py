"""Hardened characterization pool: retries, rebuilds, quarantine.

Every parallel scenario arms the fault plan through the ``REPRO_FAULTS``
environment (inherited by the spawn workers — the parent stays disarmed)
and bounds the blast radius with ``REPRO_FAULTS_ONCE_DIR`` so a retried
task landing on a fresh worker cannot re-fire the fault forever.  The
invariant under every scenario is the same: the surviving result is
bit-identical to a clean serial run.
"""

import pytest

from repro.core.circuits import benchmark_suite
from repro.core.transforms import (
    CharacterizationError,
    PoolPolicy,
    characterize_suite,
)
from repro.runtime import faults

CIRCUITS = ["adder", "bar", "max"]
RECIPES = [(), ("Rw",), ("Rf",), ("Ba", "Rw")]
FAST = PoolPolicy(backoff_s=0.01, backoff_cap_s=0.1)


@pytest.fixture(autouse=True)
def _disarmed():
    """The parent process stays disarmed even when REPRO_FAULTS is set
    for the spawn workers (disable() pins the parent's env check)."""
    faults.disable()
    yield
    faults.disable()


@pytest.fixture(scope="module")
def suite_circuits():
    return benchmark_suite("tiny", only=CIRCUITS)


@pytest.fixture(scope="module")
def clean(suite_circuits):
    return characterize_suite(
        suite_circuits, RECIPES, n_jobs=1, backend="python"
    )


def _arm(monkeypatch, tmp_path, spec):
    monkeypatch.setenv("REPRO_FAULTS", spec)
    monkeypatch.setenv("REPRO_FAULTS_SEED", "0")
    once = tmp_path / "once"
    monkeypatch.setenv("REPRO_FAULTS_ONCE_DIR", str(once))
    return once


def test_worker_raise_is_retried_to_parity(
    suite_circuits, clean, monkeypatch, tmp_path
):
    once = _arm(monkeypatch, tmp_path, "pool.task:raise")
    out = characterize_suite(
        suite_circuits, RECIPES, n_jobs=2, backend="python", policy=FAST
    )
    assert out == clean
    assert len(list(once.iterdir())) == 1  # the fault fired exactly once


def test_worker_hard_exit_rebuilds_pool(
    suite_circuits, clean, monkeypatch, tmp_path
):
    """os._exit in a worker breaks the whole ProcessPoolExecutor; the
    scheduler must rebuild it and re-dispatch every in-flight task."""
    _arm(monkeypatch, tmp_path, "pool.task:exit")
    out = characterize_suite(
        suite_circuits, RECIPES, n_jobs=2, backend="python", policy=FAST
    )
    assert out == clean


def test_hung_worker_hits_deadline_and_recovers(
    suite_circuits, clean, monkeypatch, tmp_path
):
    _arm(monkeypatch, tmp_path, "pool.task:hang:::1:30")
    policy = PoolPolicy(
        task_deadline_s=1.0, backoff_s=0.01, backoff_cap_s=0.1
    )
    out = characterize_suite(
        suite_circuits, RECIPES, n_jobs=2, backend="python", policy=policy
    )
    assert out == clean


def test_poisoned_task_quarantines_circuit_only(
    suite_circuits, clean, monkeypatch, tmp_path
):
    # Every 'bar' task raises, forever: retries exhaust, bar is
    # quarantined, and the rest of the suite still matches the clean run.
    _arm(monkeypatch, tmp_path, "pool.task:raise:bar::inf")
    failures = {}
    out = characterize_suite(
        suite_circuits, RECIPES, n_jobs=2, backend="python", policy=FAST,
        failures=failures,
    )
    assert set(failures) == {"bar"}
    err = failures["bar"]
    assert isinstance(err, CharacterizationError) and err.circuit == "bar"
    assert out == {n: clean[n] for n in CIRCUITS if n != "bar"}
    assert list(out) == [n for n in CIRCUITS if n != "bar"]


def test_poisoned_task_raises_without_quarantine_optin(
    suite_circuits, monkeypatch, tmp_path
):
    _arm(monkeypatch, tmp_path, "pool.task:raise:bar::inf")
    with pytest.raises(CharacterizationError, match="bar"):
        characterize_suite(
            suite_circuits, RECIPES, n_jobs=2, backend="python", policy=FAST
        )


def test_front_half_failure_quarantines_serially(suite_circuits, clean):
    """The per-circuit front loop (fingerprint, cache probe, runner
    construction) quarantines too — exercised in process via the
    cha.backend point on the serial path."""
    with faults.injected(
        faults.FaultRule("cha.backend", "raise", match=":bar")
    ):
        failures = {}
        out = characterize_suite(
            suite_circuits, RECIPES, n_jobs=1, backend="python",
            failures=failures,
        )
    assert set(failures) == {"bar"}
    assert out == {n: clean[n] for n in CIRCUITS if n != "bar"}

    with faults.injected(
        faults.FaultRule("cha.backend", "raise", match=":bar")
    ):
        with pytest.raises(CharacterizationError, match="bar"):
            characterize_suite(
                suite_circuits, RECIPES, n_jobs=1, backend="python"
            )
