"""ExplorationService: parity, cache fast paths, failure injection.

The service's contract is that a warm request is *bit-identical* to a
fresh offline `explore_request` call — same winner cell, same tiering
and tie-breaking, same variation summary — while skipping every
expensive stage it can (characterization via the fingerprint memo, the
device sweep via the grid cache, jit compilation via shape bucketing).
These tests pin each of those properties separately, then the failure
injection ones pin the other half of the contract: bad requests get
structured errors, good batch-mates are unaffected, and the worker
survives everything.

Uses `pump()` (passive, single-threaded) mode so cache and trace
assertions are deterministic; the stress test at the bottom exercises
the real worker thread.
"""

import dataclasses
import threading

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import batch as B
from repro.core.aig import Aig
from repro.core.batch import (
    LEVEL_PAD,
    PAD_CIRCUIT_PREFIX,
    SuiteTable,
    bucket_levels,
    bucket_suite,
    ceil_pow2,
    pad_suite,
    trace_counts,
)
from repro.core.circuits import gen_adder, gen_max
from repro.core.explorer import explore_request
from repro.core.sram import TOPOLOGY_LIBRARY, ModelTable
from repro.core.transforms import characterize_suite
from repro.serve import explore_service as ES
from repro.serve.explore_service import (
    ExplorationService,
    ExploreRequest,
)

TOPOS = TOPOLOGY_LIBRARY[:5]
RECIPES = [(), ("Rw",), ("Ba", "Rw"), ("Rf",)]


@pytest.fixture(scope="module")
def adder():
    return gen_adder(6)


@pytest.fixture(scope="module")
def maxc():
    return gen_max(6, 2)


@pytest.fixture(scope="module")
def svc():
    s = ExplorationService(sram_list=TOPOS, recipes=RECIPES, start=False)
    yield s
    s.close()


def nan_table() -> ModelTable:
    """A model sweep whose every variant yields non-finite energies."""
    t = ModelTable.monte_carlo(n=3, seed=0)
    return dataclasses.replace(
        t,
        e_op_fj=np.full_like(t.e_op_fj, np.nan),
        e_op_marginal_fj=np.full_like(t.e_op_marginal_fj, np.nan),
        e_macro_cycle_fj=np.full_like(t.e_macro_cycle_fj, np.nan),
        e_col_cycle_fj=np.full_like(t.e_col_cycle_fj, np.nan),
        writeback_fj_nonresonant=np.full_like(
            t.writeback_fj_nonresonant, np.nan
        ),
    )


# ------------------------- bucket-shape helpers ----------------------------


def test_ceil_pow2():
    assert [ceil_pow2(n) for n in (0, 1, 2, 3, 4, 5, 8, 9)] == [
        1, 1, 2, 4, 4, 8, 8, 16,
    ]


def test_bucket_levels():
    assert bucket_levels(1) == LEVEL_PAD
    assert bucket_levels(LEVEL_PAD) == LEVEL_PAD
    assert bucket_levels(LEVEL_PAD + 1) == 2 * LEVEL_PAD
    assert bucket_levels(3 * LEVEL_PAD) == 4 * LEVEL_PAD


def test_pad_suite_shapes(adder, maxc):
    cha = characterize_suite(
        {"a": adder, "m": maxc, "a5": gen_adder(5)}, RECIPES
    )
    suite = SuiteTable.from_cha(cha)
    padded, bucket = bucket_suite(suite, len(TOPOS), 1)
    c, r, l, _ = padded.ops.shape
    assert c == ceil_pow2(len(suite.circuits)) == 4  # 3 circuits -> 4
    assert l == bucket_levels(suite.ops.shape[2])
    assert bucket == (c, r, l, len(TOPOS), 1)
    # padding rows are copies of circuit 0 (finite workloads), real rows
    # are untouched
    assert padded.circuits[:3] == suite.circuits
    assert all(n.startswith(PAD_CIRCUIT_PREFIX)
               for n in padded.circuits[3:])
    np.testing.assert_array_equal(
        padded.ops[:3, :, : suite.ops.shape[2]], suite.ops
    )
    np.testing.assert_array_equal(
        padded.ops[3], padded.ops[0]
    )
    # no-op padding returns the same object
    assert pad_suite(padded) is padded
    with pytest.raises(ValueError):
        pad_suite(suite, n_circuits=1)


# ------------------------------- parity ------------------------------------


def offline_winner_cell(off):
    """The offline winner's *device-grid* cell: the service's metrics come
    from the same fused kernel, so equality here is bit-exact (the scalar
    `best.metrics` recompute can differ by 1 ulp)."""
    ti = off.grid.topologies.index(off.best.topo)
    ri = off.grid.recipes.index(tuple(off.best.recipe))
    return off.grid.cell(ti, ri)


def test_winner_parity_plain(svc, adder):
    resp = svc.explore(adder)
    assert resp.ok, resp.error
    off = explore_request(adder, TOPOS, RECIPES)
    assert resp.winner.topology.name == off.best.topo.name
    assert resp.winner.recipe == tuple(off.best.recipe)
    cell = offline_winner_cell(off)
    assert resp.winner.energy_nj == cell.energy_nj
    assert resp.winner.latency_ns == cell.latency_ns
    assert resp.winner.power_mw == cell.power_mw
    assert resp.winner.energy_nj == pytest.approx(
        off.best.metrics.energy_nj, rel=1e-9
    )
    assert resp.winner.inductor_nh == off.inductor_nh
    assert resp.fingerprint == adder.fingerprint()
    assert resp.bucket is not None


def test_winner_parity_budget_and_latency(svc, adder):
    kb = sorted(t.total_kb for t in TOPOS)[1]  # excludes some topologies
    resp = svc.explore(adder, max_memory_kb=kb, max_latency_ns=200.0)
    assert resp.ok, resp.error
    off = explore_request(
        adder, TOPOS, RECIPES, max_memory_kb=kb, max_latency_ns=200.0
    )
    assert resp.winner.topology.name == off.best.topo.name
    assert resp.winner.recipe == tuple(off.best.recipe)
    assert resp.winner.energy_nj == offline_winner_cell(off).energy_nj
    assert resp.winner.topology.total_kb <= kb


def test_variation_parity(svc, maxc):
    table = ModelTable.monte_carlo(n=4, seed=2)
    resp = svc.explore(
        ExploreRequest(maxc, model_sweep=table, max_latency_ns=500.0)
    )
    assert resp.ok, resp.error
    off = explore_request(
        maxc, TOPOS, RECIPES, model_sweep=table, max_latency_ns=500.0
    )
    v, vo = resp.variation, off.variation
    assert [t.name for _, t in v.winners] == [t.name for _, t in vo.winners]
    assert [r for r, _ in v.winners] == [tuple(r) for r, _ in vo.winners]
    assert v.winner_share == vo.winner_share
    assert v.best_yield == vo.best_yield
    assert v.latency_yield == vo.latency_yield
    np.testing.assert_array_equal(v.winner_energy_nj, vo.winner_energy_nj)
    assert v.energy_quantiles == vo.energy_quantiles
    assert v.cvar() == vo.cvar()


# --------------------------- cache fast paths ------------------------------


def test_cha_cache_hit_skips_front_half(adder, maxc, monkeypatch):
    calls = []
    real = ES.characterize_suite

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(ES, "characterize_suite", counting)
    s = ExplorationService(sram_list=TOPOS, recipes=RECIPES, start=False)
    r1 = s.explore(adder)
    assert r1.ok and not r1.cha_cache_hit
    n_after_first = len(calls)
    assert n_after_first >= 1
    # same fingerprint, different constraints: front half never re-runs
    r2 = s.explore(adder, max_latency_ns=1e6)
    r3 = s.explore(adder, max_memory_kb=1e9)
    assert r2.ok and r2.cha_cache_hit
    assert r3.ok and r3.cha_cache_hit
    assert len(calls) == n_after_first
    # a new fingerprint does re-characterize
    r4 = s.explore(maxc)
    assert r4.ok and not r4.cha_cache_hit
    assert len(calls) == n_after_first + 1
    s.close()


def test_constraint_change_is_rerank_only(svc, adder):
    base = svc.explore(adder)
    assert base.ok
    before = trace_counts()
    for kw in (
        dict(max_latency_ns=1e6),
        dict(max_latency_ns=25.0),
        dict(max_memory_kb=max(t.total_kb for t in TOPOS)),
        dict(max_memory_kb=sorted(t.total_kb for t in TOPOS)[1],
             max_latency_ns=1e3),
    ):
        r = svc.explore(adder, **kw)
        assert r.ok, r.error
        assert r.cha_cache_hit and r.grid_cache_hit
    # pure masked-argmin re-ranks: zero new jit traces of any kernel
    assert trace_counts() == before


def test_same_bucket_reuses_trace(svc, adder, maxc):
    # both tiny circuits land in the same (C, R, L, T, V) bucket; after
    # each has been evaluated once, re-evaluating ANY same-shape suite
    # costs zero new traces
    assert svc.explore(adder).ok
    assert svc.explore(maxc).ok
    before = trace_counts()
    evaluate_calls = svc.stats()["evaluate_calls"]
    fresh = gen_adder(5)  # new fingerprint, same bucket
    r = svc.explore(fresh)
    assert r.ok and not r.grid_cache_hit
    assert svc.stats()["evaluate_calls"] == evaluate_calls + 1
    assert trace_counts() == before  # compiled sweep reused


# --------------------------- failure injection -----------------------------


def test_malformed_circuit(svc):
    r = svc.explore(ExploreRequest(circuit="not an aig"))
    assert not r.ok and r.error.code == "malformed-circuit"
    no_po = Aig(4, name="no-outputs")
    r2 = svc.explore(ExploreRequest(circuit=no_po))
    assert not r2.ok and r2.error.code == "malformed-circuit"
    r3 = svc.explore(ExploreRequest(circuit=gen_adder(4), model_sweep="x"))
    assert not r3.ok and r3.error.code == "malformed-circuit"


def test_infeasible_memory_budget(svc, adder):
    r = svc.explore(adder, max_memory_kb=0.001)
    assert not r.ok and r.error.code == "infeasible-memory"
    assert "smallest candidate" in r.error.message
    # the offline path rejects the same budget
    with pytest.raises(ValueError):
        explore_request(adder, TOPOS, RECIPES, max_memory_kb=0.001)


def test_nan_sweep_structured_error(svc, adder):
    r = svc.explore(ExploreRequest(adder, model_sweep=nan_table()))
    assert not r.ok and r.error.code == "no-finite-energy"


def test_bad_batch_mates_do_not_sink_healthy(adder, maxc):
    """One pump batch with every failure mode + two healthy requests:
    the healthy ones complete with correct winners."""
    s = ExplorationService(
        sram_list=TOPOS, recipes=RECIPES, start=False, max_batch=8
    )
    futs = s.submit_batch([
        ExploreRequest(adder),
        ExploreRequest(circuit=12345),
        ExploreRequest(adder, max_memory_kb=0.001),
        ExploreRequest(maxc, model_sweep=nan_table()),
        ExploreRequest(maxc, max_latency_ns=1e6),
    ])
    assert s.pump() == 5
    rs = [f.result(timeout=0) for f in futs]
    assert rs[0].ok
    assert rs[1].error.code == "malformed-circuit"
    assert rs[2].error.code == "infeasible-memory"
    assert rs[3].error.code == "no-finite-energy"
    assert rs[4].ok
    off = explore_request(adder, TOPOS, RECIPES)
    assert rs[0].winner.energy_nj == offline_winner_cell(off).energy_nj
    offm = explore_request(maxc, TOPOS, RECIPES, max_latency_ns=1e6)
    assert rs[4].winner.energy_nj == offline_winner_cell(offm).energy_nj
    s.close()


def test_submit_after_close_raises(adder):
    s = ExplorationService(sram_list=TOPOS, recipes=RECIPES, start=False)
    s.close()
    with pytest.raises(RuntimeError):
        s.submit(ExploreRequest(adder))


def test_close_fails_queued_requests(adder):
    s = ExplorationService(sram_list=TOPOS, recipes=RECIPES, start=False)
    fut = s.submit(ExploreRequest(adder))
    s.close()  # passive mode: queued request resolves with shutdown error
    r = fut.result(timeout=0)
    assert not r.ok and r.error.code == "shutdown"


# ------------------------- threaded stress test ----------------------------


def test_threaded_submit_cancel_stress(adder, maxc):
    """Multiple submitter threads race the worker with mixed good/bad
    requests and eager cancellations: every future terminates, every
    non-cancelled response is structured, all winners agree with the
    offline reference."""
    s = ExplorationService(
        sram_list=TOPOS, recipes=RECIPES, start=True, max_batch=4
    )
    off_a = offline_winner_cell(explore_request(adder, TOPOS, RECIPES))
    reqs = [
        ExploreRequest(adder),
        ExploreRequest(maxc),
        ExploreRequest(adder, max_latency_ns=1e6),
        ExploreRequest(adder, max_memory_kb=0.001),
        ExploreRequest(circuit=None),
    ]
    futures, lock = [], threading.Lock()

    def submitter(k: int):
        for i in range(6):
            f = s.submit(reqs[(k + i) % len(reqs)])
            if (k + i) % 5 == 4:
                f.cancel()  # may or may not win the race — both fine
            with lock:
                futures.append(f)

    threads = [threading.Thread(target=submitter, args=(k,)) for k in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(futures) == 18
    done = 0
    for f in futures:
        if f.cancelled():
            continue
        r = f.result(timeout=120)
        done += 1
        if r.ok:
            assert r.winner is not None
            if r.request.circuit is adder and r.request.max_memory_kb is None:
                assert r.winner.energy_nj == off_a.energy_nj
        else:
            assert r.error.code in {
                "malformed-circuit", "infeasible-memory", "shutdown"
            }
    assert done >= 1
    st = s.stats()
    assert st["submitted"] == 18
    assert st["served"] + st["errors"] + st["cancelled"] == 18
    s.close()
    # close is idempotent and the service refuses new work afterwards
    s.close()
    with pytest.raises(RuntimeError):
        s.submit(ExploreRequest(adder))
