"""Service-level fault tolerance: deadlines, worker supervision,
graceful backend degradation.

These pin the three hardening layers of `serve.explore_service` (see its
module docstring): a crash in the batch pipeline fails that batch with a
structured ``worker-crashed`` error and the loop keeps serving; a worker
thread that dies anyway is respawned at the submit edge; an expired
deadline resolves the request instead of occupying the pipeline; and a
device-backend characterization failure degrades to the python parity
path with ``degraded=True`` and a bit-identical answer.
"""

import time

import pytest

jax = pytest.importorskip("jax")

from repro.core.circuits import gen_adder  # noqa: E402
from repro.core.sram import TOPOLOGY_LIBRARY  # noqa: E402
from repro.core.transforms import resolve_backend  # noqa: E402
from repro.runtime import faults  # noqa: E402
from repro.serve.explore_service import (  # noqa: E402
    ExplorationService,
    ExploreRequest,
)

TOPOS = TOPOLOGY_LIBRARY[:5]
RECIPES = [(), ("Rw",), ("Ba", "Rw"), ("Rf",)]


@pytest.fixture(autouse=True)
def _disarmed():
    faults.disable()
    yield
    faults.disable()


@pytest.fixture(scope="module")
def adder():
    return gen_adder(6)


def _service(**kw):
    return ExplorationService(sram_list=TOPOS, recipes=RECIPES, **kw)


def test_injected_crash_fails_batch_and_worker_survives(adder):
    with _service(start=True) as svc:
        with faults.injected(faults.FaultRule("service.process", "raise")):
            resp = svc.submit(ExploreRequest(adder)).result(timeout=300)
        assert not resp.ok and resp.error.code == "worker-crashed"
        # The supervised loop caught the escape: same thread, next
        # request served normally.
        resp2 = svc.submit(ExploreRequest(adder)).result(timeout=300)
        assert resp2.ok
        st = svc.stats()
        assert st["worker_crashes"] == 1
        assert "worker_restarts" not in st


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning"
)
def test_dead_worker_thread_is_respawned_on_submit(adder):
    svc = _service(start=True)
    try:
        orig = svc._process

        def fatal(batch):
            raise SystemExit("simulated fatal worker error")

        svc._process = fatal
        # The batch still resolves (crash handler runs before the fatal
        # signal re-raises and kills the thread).
        resp = svc.submit(ExploreRequest(adder)).result(timeout=60)
        assert resp.error.code == "worker-crashed"
        svc._thread.join(timeout=30)
        assert not svc._thread.is_alive()

        svc._process = orig
        resp2 = svc.submit(ExploreRequest(adder)).result(timeout=300)
        assert resp2.ok
        assert svc.stats()["worker_restarts"] == 1
    finally:
        svc.close()


def test_request_deadline_expires_before_pipeline(adder):
    with _service(start=False) as svc:
        fut = svc.submit(ExploreRequest(adder, deadline_s=0.0))
        time.sleep(0.01)
        svc.pump()
        resp = fut.result(timeout=5)
        assert not resp.ok and resp.error.code == "deadline-exceeded"
        assert resp.winner is None
        assert svc.stats()["deadline_exceeded"] == 1
        # A generous deadline on the same circuit answers normally.
        resp2 = svc.explore(ExploreRequest(adder, deadline_s=600.0))
        assert resp2.ok


def test_service_default_deadline_applies_when_request_has_none(adder):
    with _service(start=False, default_deadline_s=0.0) as svc:
        fut = svc.submit(ExploreRequest(adder))
        time.sleep(0.01)
        svc.pump()
        assert fut.result(timeout=5).error.code == "deadline-exceeded"
        # An explicit per-request deadline overrides the default.
        resp = svc.explore(ExploreRequest(adder, deadline_s=600.0))
        assert resp.ok


def test_device_cha_failure_degrades_to_python_with_parity(adder):
    if resolve_backend("auto") != "device":
        pytest.skip("device backend unavailable; no ladder to descend")
    with _service(start=False) as clean:
        ref = clean.explore(ExploreRequest(adder))
    assert ref.ok and not ref.degraded

    with _service(start=False) as svc:
        with faults.injected(
            faults.FaultRule("cha.backend", "raise", match="device")
        ):
            resp = svc.explore(ExploreRequest(adder))
        assert resp.ok and resp.degraded
        assert svc.stats()["degraded"] == 1
        # Both backends are exact: the degraded answer is bit-identical.
        assert resp.winner.recipe == ref.winner.recipe
        assert resp.winner.topology == ref.winner.topology
        assert resp.winner.energy_nj == ref.winner.energy_nj
        assert resp.winner.latency_ns == ref.winner.latency_ns
        # The memoized repeat is served normally, not flagged degraded.
        resp2 = svc.explore(ExploreRequest(adder))
        assert resp2.ok and not resp2.degraded and resp2.cha_cache_hit
