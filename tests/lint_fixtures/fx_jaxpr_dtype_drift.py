"""Seeds exactly one ``jaxpr-dtype-drift``: an x64 kernel that casts
its float64 operand down to float32 mid-graph."""

import numpy as np

from repro.analysis import registry

MODULE = "lint_fixture.dtype_drift"


def _build():
    import jax
    import jax.numpy as jnp

    def fn(x):
        registry.TRACE_COUNTS["fx_dtype_drift"] += 1
        y = x.astype(jnp.float32)  # VIOLATION: sub-f64 cast in x64 kernel
        return (y * 2.0).astype(jnp.float64)

    return registry.KernelExample(
        fn=jax.jit(fn), args=(np.ones(4, dtype=np.float64),)
    )


registry.register_kernel("fx_dtype_drift", MODULE, _build)
