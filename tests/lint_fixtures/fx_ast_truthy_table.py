"""Seeds exactly one ``ast-truthy-table``: an `or`-default on a
__len__-bearing ModelTable (the PR-4 bug class)."""

DEFAULT = object()


def pick_model(model: "ModelTable"):
    return model or DEFAULT  # VIOLATION: empty table is falsy
