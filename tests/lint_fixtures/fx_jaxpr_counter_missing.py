"""Seeds exactly one ``jaxpr-counter-missing``: the kernel body never
bumps its registered trace counter, so tracing the fresh wrapper leaves
the count unchanged."""

import numpy as np

from repro.analysis import registry

MODULE = "lint_fixture.counter_missing"


def _build():
    import jax

    def fn(x):  # VIOLATION: no TRACE_COUNTS bump in the traced body
        return x + 1.0

    return registry.KernelExample(
        fn=jax.jit(fn), args=(np.ones(4, dtype=np.float64),)
    )


registry.register_kernel("fx_counter_missing", MODULE, _build)
