"""Seeds exactly one ``jaxpr-baked-const``: a 512 KiB table closed over
by the kernel instead of passed as a traced operand (the recompile
hazard the lint's threshold guards)."""

import numpy as np

from repro.analysis import registry

MODULE = "lint_fixture.baked_const"

BIG_TABLE = np.ones((256, 256), dtype=np.float64)  # 512 KiB


def _build():
    import jax
    import jax.numpy as jnp

    def fn(x):
        registry.TRACE_COUNTS["fx_baked_const"] += 1
        # VIOLATION: BIG_TABLE is captured as a jaxpr constant
        return jnp.sum(x * jnp.asarray(BIG_TABLE))

    return registry.KernelExample(
        fn=jax.jit(fn), args=(np.ones((256, 256), dtype=np.float64),)
    )


registry.register_kernel("fx_baked_const", MODULE, _build)
