"""Seeds exactly one ``jaxpr-static-unhashable``: a declared static
argument whose example value is a list (jit statics key the compile
cache and must hash)."""

import numpy as np

from repro.analysis import registry

MODULE = "lint_fixture.static_unhashable"


def _build():
    import jax

    def fn(x, mode):
        registry.TRACE_COUNTS["fx_static_unhashable"] += 1
        return x * 2.0

    return registry.KernelExample(
        fn=jax.jit(fn, static_argnames=("mode",)),
        args=(np.ones(4, dtype=np.float64),),
        statics={"mode": ["not", "hashable"]},  # VIOLATION
    )


registry.register_kernel("fx_static_unhashable", MODULE, _build)
