"""Seeds exactly one ``ast-jit-no-counter``: a jit-wrapped function
whose body never increments the registry trace counter."""

import jax
import jax.numpy as jnp


@jax.jit
def uncounted(x):  # VIOLATION: no TRACE_COUNTS/count_trace in the body
    return jnp.cos(x) * 2.0
