"""Seeds exactly one ``ast-host-sync-unannotated``: a bare np.asarray
in a device-adjacent function of a kernel module."""
# repro: kernel-module

import numpy as np


def gather_energy(grid):
    dev = grid._raw("energy_nj")
    return np.asarray(dev)  # VIOLATION: unannotated device->host sync
