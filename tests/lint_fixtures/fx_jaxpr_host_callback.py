"""Seeds exactly one ``jaxpr-host-callback``: a pure_callback host
round-trip inside the jitted body."""

import numpy as np

from repro.analysis import registry

MODULE = "lint_fixture.host_callback"


def _build():
    import jax
    import jax.numpy as jnp

    def fn(x):
        registry.TRACE_COUNTS["fx_host_callback"] += 1
        y = jax.pure_callback(  # VIOLATION: host callback per dispatch
            lambda v: np.asarray(v) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            x,
        )
        return jnp.sum(y)

    return registry.KernelExample(
        fn=jax.jit(fn), args=(np.ones(4, dtype=np.float64),)
    )


registry.register_kernel("fx_host_callback", MODULE, _build)
