"""Seeds exactly one ``jaxpr-donate-cpu``: donated buffers declared
unconditionally, without the per-backend gate `_jit_fused` uses — on
the CPU backend XLA ignores donation and jax warns per call."""

import numpy as np

from repro.analysis import registry

MODULE = "lint_fixture.donate_cpu"


def _build():
    import jax

    def fn(params):
        registry.TRACE_COUNTS["fx_donate_cpu"] += 1
        return params * 2.0

    return registry.KernelExample(
        fn=jax.jit(fn, donate_argnames=("params",)),
        args=(np.ones(4, dtype=np.float64),),
        donate_argnames=("params",),  # VIOLATION: not gated on backend
    )


registry.register_kernel("fx_donate_cpu", MODULE, _build)
