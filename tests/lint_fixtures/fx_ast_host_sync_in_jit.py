"""Seeds exactly one ``ast-host-sync-in-jit``: float() on a traced
value inside a jit-wrapped function."""

import jax
import jax.numpy as jnp

import collections

TRACE_COUNTS = collections.Counter()


@jax.jit
def kernel(x):
    TRACE_COUNTS["kernel"] += 1
    bad = float(x)  # VIOLATION: host sync inside a traced body
    return jnp.sin(x) + bad
