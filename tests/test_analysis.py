"""The jit-discipline static analyzer (repro.analysis).

Four layers of coverage:

  * every lint rule fires on exactly its seeded-violation fixture
    (tests/lint_fixtures/, one file per rule) and nowhere else in it;
  * the real ``src/`` tree is clean — AST layer over the whole tree,
    jaxpr layer over every registered kernel — against an *empty*
    baseline, so new violations fail immediately;
  * the guards actually guard: stripping one ``# repro: host-boundary``
    annotation or one ``TRACE_COUNTS[...] += 1`` increment from a copy
    of a kernel module flips the lint to failing;
  * the registry unification keeps the historical public API: the
    per-module ``TRACE_COUNTS`` / ``trace_counts()`` names alias one
    shared Counter with module-scoped views.

Plus the regression the analyzer surfaced while being built:
`select_best_batch_device` used to force its operands through
``np.asarray``, materializing the service's device-resident (V, N)
re-rank tensors per request; it must keep jax arrays on device.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.analysis import ast_lint, lint, registry
from repro.analysis.findings import (
    Finding,
    load_baseline,
    split_baselined,
    write_baseline,
)
from repro.analysis.jaxpr_lint import lint_kernels

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "lint_fixtures")
SRC = os.path.join(REPO, "src")


def fixture(name: str) -> str:
    return os.path.join(FIXTURES, name)


# ---------------------------------------------------------------------------
# Every rule fires on its fixture — and only its rule
# ---------------------------------------------------------------------------

AST_FIXTURES = {
    "fx_ast_host_sync.py": "ast-host-sync-unannotated",
    "fx_ast_host_sync_in_jit.py": "ast-host-sync-in-jit",
    "fx_ast_truthy_table.py": "ast-truthy-table",
    "fx_ast_jit_no_counter.py": "ast-jit-no-counter",
}

JAXPR_FIXTURES = {
    "fx_jaxpr_dtype_drift.py": "jaxpr-dtype-drift",
    "fx_jaxpr_host_callback.py": "jaxpr-host-callback",
    "fx_jaxpr_baked_const.py": "jaxpr-baked-const",
    "fx_jaxpr_static_unhashable.py": "jaxpr-static-unhashable",
    "fx_jaxpr_counter_missing.py": "jaxpr-counter-missing",
    "fx_jaxpr_donate_cpu.py": "jaxpr-donate-cpu",
}


@pytest.mark.parametrize("name,rule", sorted(AST_FIXTURES.items()))
def test_ast_rule_fires_exactly_once(name, rule):
    findings = ast_lint.lint_paths([fixture(name)], root=REPO)
    assert [f.rule for f in findings] == [rule]
    f = findings[0]
    assert f.severity == "error"
    assert f.line > 0
    assert "VIOLATION" in f.context


@pytest.mark.parametrize("name,rule", sorted(JAXPR_FIXTURES.items()))
def test_jaxpr_rule_fires_exactly_once(name, rule):
    findings = lint_kernels([fixture(name)])
    assert [f.rule for f in findings] == [rule]
    assert findings[0].severity == "error"


@pytest.mark.parametrize("name", sorted(AST_FIXTURES))
def test_cli_fails_on_ast_fixture(name, capsys):
    rc = lint.main(["--no-jaxpr", "--baseline", "", fixture(name)])
    assert rc == 1
    assert AST_FIXTURES[name] in capsys.readouterr().out


@pytest.mark.parametrize("name", sorted(JAXPR_FIXTURES))
def test_cli_fails_on_jaxpr_fixture(name, capsys):
    rc = lint.main(
        ["--no-ast", "--baseline", "", "--kernels-from", fixture(name)]
    )
    assert rc == 1
    assert JAXPR_FIXTURES[name] in capsys.readouterr().out


# ---------------------------------------------------------------------------
# The real tree is clean
# ---------------------------------------------------------------------------


def test_src_tree_ast_clean():
    findings = ast_lint.lint_paths([SRC], root=REPO)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_registered_kernels_jaxpr_clean():
    findings = lint_kernels()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_all_kernel_modules_register():
    specs = registry.kernel_specs()
    by_module = {}
    for s in specs:
        by_module.setdefault(s.module, []).append(s.name)
    assert sorted(by_module.get("repro.core.batch", [])) == [
        "evaluate_grid", "evaluate_suite", "fused_grid", "fused_suite",
        "schedule_grid", "schedule_suite", "select_batch",
    ]
    assert sorted(by_module.get("repro.kernels.aig_sim", [])) == [
        "aig_eval", "aig_sig",
    ]
    assert by_module.get("repro.launch.system") == ["roofline_sweep"]
    # the Pallas kernels register counters (AST-enforced), not specs
    assert registry.KERNEL_OWNERS["aig_eval_pallas"] == "repro.kernels.aig_sim"
    assert registry.KERNEL_OWNERS["cim_pallas"] == "repro.kernels.cim_logic"


# ---------------------------------------------------------------------------
# The guards guard: stripping an annotation / a counter line flips to red
# ---------------------------------------------------------------------------


def _strip_one(source: str, needle: str) -> str:
    assert needle in source
    return source.replace(needle, "", 1)


def test_flip_removing_host_boundary_annotation(tmp_path):
    src = open(os.path.join(SRC, "repro", "core", "batch.py")).read()
    clean = ast_lint.lint_paths([os.path.join(SRC, "repro", "core", "batch.py")])
    assert clean == []
    stripped = tmp_path / "batch_stripped.py"
    # drop one trailing-comment annotation (whole comment, keep the code)
    needle = "  # repro: host-boundary\n"
    assert needle in src
    stripped.write_text(src.replace(needle, "\n", 1))
    findings = ast_lint.lint_paths([str(stripped)])
    assert any(f.rule == "ast-host-sync-unannotated" for f in findings)


def test_flip_removing_trace_count_increment(tmp_path):
    src = open(os.path.join(SRC, "repro", "core", "batch.py")).read()
    stripped = tmp_path / "batch_stripped.py"
    stripped.write_text(
        _strip_one(src, 'TRACE_COUNTS["schedule_grid"] += 1')
    )
    findings = ast_lint.lint_paths([str(stripped)])
    assert any(f.rule == "ast-jit-no-counter" for f in findings)


def test_no_trace_count_optout(tmp_path):
    p = tmp_path / "m.py"
    p.write_text(
        "import jax\n"
        "import jax.numpy as jnp\n"
        "\n"
        "@jax.jit  # repro: no-trace-count\n"
        "def helper(x):\n"
        "    return jnp.sin(x)\n"
    )
    assert ast_lint.lint_paths([str(p)]) == []


def test_host_boundary_annotation_suppresses(tmp_path):
    p = tmp_path / "m.py"
    p.write_text(
        "# repro: kernel-module\n"
        "import numpy as np\n"
        "\n"
        "def gather(grid):\n"
        "    dev = grid._raw('energy')\n"
        "    return np.asarray(dev)  # repro: host-boundary\n"
    )
    assert ast_lint.lint_paths([str(p)]) == []


def test_truthiness_on_mapping_of_tables_is_fine(tmp_path):
    # Mapping[str, WorkloadTable] is a dict; `if not works` is idiomatic
    p = tmp_path / "m.py"
    p.write_text(
        "from typing import Mapping\n"
        "\n"
        "def f(works: 'Mapping[str, WorkloadTable]'):\n"
        "    if not works:\n"
        "        raise ValueError('empty')\n"
    )
    assert ast_lint.lint_paths([str(p)]) == []


# ---------------------------------------------------------------------------
# Registry unification: one Counter, historical per-module views
# ---------------------------------------------------------------------------


def test_trace_counter_aliases_share_one_counter():
    from repro.core import batch
    from repro.kernels import aig_sim, cim_logic
    from repro.launch import system

    assert batch.TRACE_COUNTS is registry.TRACE_COUNTS
    assert aig_sim.TRACE_COUNTS is registry.TRACE_COUNTS
    assert cim_logic.TRACE_COUNTS is registry.TRACE_COUNTS
    assert system.TRACE_COUNTS is registry.TRACE_COUNTS


def test_trace_counts_views_are_module_scoped():
    from repro.core import batch
    from repro.kernels import aig_sim

    registry.TRACE_COUNTS["aig_eval"] += 1
    try:
        assert "aig_eval" not in batch.trace_counts()
        assert "aig_eval" in aig_sim.trace_counts()
        assert "aig_eval" in registry.trace_counts()  # global view
        # batch's view only ever carries batch-owned keys
        assert all(
            registry.KERNEL_OWNERS[k] == "repro.core.batch"
            for k in batch.trace_counts()
        )
    finally:
        registry.TRACE_COUNTS["aig_eval"] -= 1


def test_counter_ownership_conflict_rejected():
    with pytest.raises(ValueError, match="already registered"):
        registry.register_counter("schedule_grid", "some.other.module")


# ---------------------------------------------------------------------------
# Baseline mechanism
# ---------------------------------------------------------------------------


def test_baseline_roundtrip_and_line_independence(tmp_path):
    f = Finding(
        rule="ast-truthy-table", severity="error", path="src/x.py",
        line=3, message="m", context="return model or DEFAULT",
    )
    path = str(tmp_path / "baseline.json")
    write_baseline(path, [f])
    baseline = load_baseline(path)
    moved = dataclasses.replace(f, line=99)  # edits move code around
    fresh = dataclasses.replace(f, rule="ast-jit-no-counter")
    new, old = split_baselined([moved, fresh], baseline)
    assert old == [moved]
    assert new == [fresh]


def test_cli_write_baseline_then_green(tmp_path, capsys):
    target = fixture("fx_ast_truthy_table.py")
    bl = str(tmp_path / "bl.json")
    assert lint.main(["--no-jaxpr", "--baseline", bl, target]) == 1
    assert (
        lint.main(
            ["--no-jaxpr", "--baseline", bl, "--write-baseline", target]
        )
        == 0
    )
    capsys.readouterr()
    assert lint.main(["--no-jaxpr", "--baseline", bl, target]) == 0
    out = capsys.readouterr().out
    assert "[baselined]" in out


def test_cli_json_format(capsys):
    rc = lint.main(
        ["--no-jaxpr", "--baseline", "", "--format", "json",
         fixture("fx_ast_jit_no_counter.py")]
    )
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["counts"]["new"] == 1
    assert payload["new"][0]["rule"] == "ast-jit-no-counter"


def test_checked_in_baseline_is_empty():
    # the repo tree must be *actually* clean, not grandfathered-clean
    path = os.path.join(SRC, "repro", "analysis", "baseline.json")
    assert json.load(open(path)) == []


# ---------------------------------------------------------------------------
# Regression: device-resident re-rank operands stay on device
# ---------------------------------------------------------------------------


def test_select_best_batch_device_keeps_operands_on_device(monkeypatch):
    from repro.core import batch as B

    if not B.jax_available():  # pragma: no cover - container ships jax
        pytest.skip("jax required")
    B._load_jax()
    rng = np.random.default_rng(7)
    host_energy = rng.random((4, 96))
    host_fits = np.ones((1, 96), dtype=bool)
    with B.enable_x64():
        energy = B.jnp.asarray(host_energy)
        fits = B.jnp.asarray(host_fits)

    materialized = []
    orig_asarray = np.asarray

    def spy(a, *args, **kwargs):
        if isinstance(a, B.jax.Array) and a.size >= 96:
            materialized.append(np.shape(a))
        return orig_asarray(a, *args, **kwargs)

    monkeypatch.setattr(np, "asarray", spy)
    try:
        idx = B.select_best_batch_device(energy, fits)
    finally:
        monkeypatch.undo()

    assert materialized == [], (
        "select_best_batch_device materialized device tensors on host: "
        f"{materialized}"
    )
    expected = B.select_best_batch(host_energy, host_fits)
    np.testing.assert_array_equal(np.asarray(idx), expected)
