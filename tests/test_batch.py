"""Parity + semantics tests for the tensorized exploration engine
(core/batch.py) against the scalar reference path.

The contract under test: ``backend="jax"`` is the same Algorithm I as
``backend="python"`` — same schedules (exact integers), same energies
(float round-off), same argmin picks (including tie-breaking) — just
batched into one jitted grid.
"""

import numpy as np
import pytest

from repro.core import circuits as C
from repro.core.aig import AigStats
from repro.core.batch import (
    TopologyTable,
    WorkloadTable,
    evaluate_batch,
    schedule_batch,
    select_best,
    select_best_worst,
    table2_batch,
)
from repro.core.explorer import best_worst, characterize_recipes, explore
from repro.core.mapping import schedule_stats
from repro.core.sram import (
    TOPOLOGY_LIBRARY,
    EnergyModel,
    SramTopology,
    evaluate,
    table2_metrics,
)

EM = EnergyModel()


def stats_from_levels(levels):
    ops = [dict(nand=a, nor=b, inv=c) for a, b, c in levels]
    return AigStats(
        n_pis=8, n_pos=4, n_ands=0, n_levels=len(ops), ops_per_level=ops,
        nand_count=sum(l[0] for l in levels),
        nor_count=sum(l[1] for l in levels),
        inv_count=sum(l[2] for l in levels),
    )


# Synthetic workloads hitting the structural edge cases: empty levels,
# single-type levels, wide levels, deep-narrow shapes, capacity misfits.
SYNTH = [
    ((), stats_from_levels([(3, 1, 0), (0, 0, 1)])),
    (("a",), stats_from_levels([(0, 0, 0), (5, 0, 0), (0, 7, 2)])),
    (("b",), stats_from_levels([(400, 130, 65)] * 7)),
    (("c",), stats_from_levels([(1, 0, 0)] * 40)),
    (("d",), stats_from_levels([(9000, 9000, 500)])),  # doesn't fit 4KB
]


# ---------------------------------------------------------------------------
# Grid vs scalar parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("discipline", ["list", "levels"])
def test_schedule_batch_matches_scalar(discipline):
    work = WorkloadTable.from_stats(SYNTH)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    grid = schedule_batch(work, topos, discipline=discipline)
    for ti, topo in enumerate(TOPOLOGY_LIBRARY):
        for ri, (_, st) in enumerate(SYNTH):
            ref = schedule_stats(st, topo, discipline=discipline)
            assert grid["cycles"][ti, ri] == ref.total_cycles
            assert (
                grid["active_macro_cycles"][ti, ri] == ref.active_macro_cycles
            )
            assert bool(grid["fits"][ti, ri]) == ref.fits


@pytest.mark.parametrize("mode", ["physical", "paper"])
@pytest.mark.parametrize("discipline", ["list", "levels"])
def test_evaluate_batch_matches_scalar(mode, discipline):
    work = WorkloadTable.from_stats(SYNTH)
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    grid = evaluate_batch(work, topos, EM, mode=mode, discipline=discipline)
    for ti, topo in enumerate(TOPOLOGY_LIBRARY):
        for ri, (_, st) in enumerate(SYNTH):
            ref = evaluate(
                schedule_stats(st, topo, discipline=discipline),
                topo, EM, mode=mode,
            )
            assert grid.cycles[ti, ri] == ref.cycles
            np.testing.assert_allclose(
                grid.energy_nj[ti, ri], ref.energy_nj, rtol=1e-12
            )
            np.testing.assert_allclose(
                grid.latency_ns[ti, ri], ref.latency_ns, rtol=1e-12
            )
            np.testing.assert_allclose(
                grid.power_mw[ti, ri], ref.power_mw, rtol=1e-12
            )
            np.testing.assert_allclose(
                grid.throughput_gops[ti, ri], ref.throughput_gops, rtol=1e-12
            )
            np.testing.assert_allclose(
                grid.tops_per_watt[ti, ri], ref.tops_per_watt, rtol=1e-12
            )


# ---------------------------------------------------------------------------
# Full-recipe backend parity (the ISSUE acceptance grid: 65 recipes x 12
# topologies per circuit, both accounting modes)
# ---------------------------------------------------------------------------

PARITY_CIRCUITS = {
    "bar-16": lambda: C.gen_barrel_shifter(16),
    "sqrt-8": lambda: C.gen_sqrt(8),
    "adder-32": lambda: C.gen_adder(32),
}


@pytest.fixture(scope="module", params=sorted(PARITY_CIRCUITS))
def full_cha(request):
    rtl = PARITY_CIRCUITS[request.param]()
    return rtl, characterize_recipes(rtl)  # all 64 recipes + baseline


@pytest.mark.parametrize("mode", ["physical", "paper"])
def test_backend_parity_full_grid(full_cha, mode):
    rtl, cha = full_cha
    py = explore(rtl, cha=cha, mode=mode, backend="python")
    jx = explore(rtl, cha=cha, mode=mode, backend="jax")

    assert py.n_recipes == jx.n_recipes == 65
    assert py.n_evaluations == jx.n_evaluations == 65 * 12

    # identical argmin pick, identical energy (best is re-materialized
    # through the scalar model, so this is exact, well inside 1e-6 nJ)
    assert jx.best.recipe == py.best.recipe
    assert jx.best.topo == py.best.topo
    assert abs(jx.best.metrics.energy_nj - py.best.metrics.energy_nj) < 1e-6
    assert jx.best.metrics.cycles == py.best.metrics.cycles

    # full-grid value parity
    g = jx.grid
    assert g is not None and g.mode == mode
    for e in py.evaluations:
        ti = g.topologies.index(e.topo)
        ri = g.recipes.index(e.recipe)
        assert g.cycles[ti, ri] == e.schedule.total_cycles
        assert g.active_macro_cycles[ti, ri] == e.schedule.active_macro_cycles
        assert bool(g.fits[ti, ri]) == e.schedule.fits
        np.testing.assert_allclose(
            g.energy_nj[ti, ri], e.metrics.energy_nj, rtol=1e-12
        )

    # best/worst companion agrees too
    b_py, w_py = best_worst(py)
    b_jx, w_jx = best_worst(jx)
    assert (b_jx.recipe, b_jx.topo) == (b_py.recipe, b_py.topo)
    assert (w_jx.recipe, w_jx.topo) == (w_py.recipe, w_py.topo)
    assert abs(w_jx.metrics.energy_nj - w_py.metrics.energy_nj) < 1e-6


def test_explore_honors_recipes_restriction_with_cha(full_cha):
    rtl, cha = full_cha
    for backend in ("python", "jax"):
        res = explore(rtl, cha=cha, recipes=[("Ba",), ("Rw",)],
                      backend=backend)
        assert res.n_recipes == 3  # () + Ba + Rw, not all 65 cached
        assert res.n_evaluations == 3 * 12
    with pytest.raises(ValueError, match="missing requested"):
        explore(rtl, cha={(): cha[()]}, recipes=[("Ba",)])


def test_backend_parity_latency_constraint_and_pseudocode_sweep(full_cha):
    rtl, cha = full_cha
    free = explore(rtl, cha=cha, backend="jax")
    cap = free.best.metrics.latency_ns * 0.9
    for kw in (
        dict(max_latency_ns=cap),
        dict(full_sweep=False),
        dict(discipline="levels"),
    ):
        py = explore(rtl, cha=cha, backend="python", **kw)
        jx = explore(rtl, cha=cha, backend="jax", **kw)
        assert (jx.best.recipe, jx.best.topo) == (py.best.recipe, py.best.topo)
        assert abs(jx.best.metrics.energy_nj - py.best.metrics.energy_nj) < 1e-6


# ---------------------------------------------------------------------------
# select_best / select_best_worst semantics (the shared FilterEnergy)
# ---------------------------------------------------------------------------


def test_select_best_admissibility_tiers():
    energy = np.array([5.0, 1.0, 3.0, 2.0])
    fits = np.array([True, True, True, False])
    # plain: global fitting argmin
    assert select_best(energy, fits) == 1
    # feasible knocks out the minimum
    feasible = np.array([True, False, True, True])
    assert select_best(energy, fits, feasible=feasible) == 2
    # latency constraint knocks out the feasible minimum too
    lat = np.array([1.0, 1.0, 9.0, 1.0])
    assert select_best(energy, fits, latency=lat, max_latency=5.0,
                       feasible=feasible) == 0
    # tier 2: constraint empties the pool -> fall back to fits-only argmin
    assert select_best(energy, fits, latency=lat, max_latency=0.5) == 1
    # tier 3: nothing fits -> global argmin
    assert select_best(energy, np.zeros(4, dtype=bool)) == 1


def test_select_best_tie_breaks_to_first():
    energy = np.array([2.0, 1.0, 1.0, 1.0])
    fits = np.array([True, False, True, True])
    assert select_best(energy, fits) == 2  # first *fitting* minimum
    b, w = select_best_worst(energy, fits)
    assert b == 2 and w == 0


def test_select_best_matches_mesh_explorer_fallback_chain():
    """The chain mesh_explorer used before the port: fits -> (latency or
    fits) -> everything."""
    energy = np.array([4.0, 2.0, 3.0])
    fits = np.array([False, True, True])
    lat = np.array([1.0, 9.0, 9.0])
    # latency filter empties the fitting pool -> fitting argmin survives
    assert select_best(energy, fits, latency=lat, max_latency=2.0) == 1
    with pytest.raises(ValueError):
        select_best(np.array([]), np.array([], dtype=bool))


def test_grid_flat_order_is_topology_major():
    work = WorkloadTable.from_stats(SYNTH[:3])
    topos = TopologyTable.from_topologies(TOPOLOGY_LIBRARY[:4])
    grid = evaluate_batch(work, topos, EM)
    i = grid.best_index()
    ti, ri = grid.unravel(i)
    assert grid.energy_nj.ravel()[i] == grid.energy_nj[ti, ri]
    # same winner as a scalar argmin in the python loop order
    flat = [
        (grid.energy_nj[t, r], bool(grid.fits[t, r]))
        for t in range(len(topos.topologies))
        for r in range(len(work.recipes))
    ]
    pool = [e for e, f in flat if f] or [e for e, _ in flat]
    assert grid.energy_nj.ravel()[i] == min(pool)


# ---------------------------------------------------------------------------
# Tables
# ---------------------------------------------------------------------------


def test_workload_table_padding_and_totals():
    work = WorkloadTable.from_stats(SYNTH, pad_levels_to=64)
    assert work.ops.shape == (5, 64, 3)
    assert work.n_levels.tolist() == [2, 3, 7, 40, 1]
    assert work.gates.tolist() == [
        s.total_gates for _, s in SYNTH
    ]
    # padding rows are zero
    assert work.ops[0, 2:].sum() == 0


def test_topology_table_matches_library():
    tt = TopologyTable.from_topologies(TOPOLOGY_LIBRARY)
    for i, t in enumerate(TOPOLOGY_LIBRARY):
        assert tt.rows[i] == t.rows
        assert tt.cols[i] == t.cols
        assert tt.total_bits[i] == t.total_bits
        assert tt.ops_per_cycle[i] == t.ops_per_cycle_per_macro
        assert tt.is_single[i] == (t.n_macros == 1)
    with pytest.raises(ValueError):
        TopologyTable.from_topologies([])


def test_table2_batch_matches_scalar():
    topos = [SramTopology(8, 1), SramTopology(8, 3), SramTopology(16, 3)]
    tt = TopologyTable.from_topologies(topos)
    for frac in (0.0, 0.5, 1.0):
        batched = table2_batch(tt, EM, nor_fraction=frac)
        for i, topo in enumerate(topos):
            ref = table2_metrics(topo, EM, nor_fraction=frac)
            for k, v in ref.items():
                np.testing.assert_allclose(batched[k][i], v, rtol=1e-12)
